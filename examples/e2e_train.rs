//! End-to-end driver (DESIGN.md §6): proves all three layers compose.
//!
//! ```bash
//! cargo run --release --example e2e_train -- [--workers 4] [--steps 200]
//! ```
//!
//! 1. **Strategy**: build the transformer training graph, run DisCo's
//!    joint op/tensor fusion search, and enact the optimized module
//!    across workers via the coordinator (leader broadcast + hi-fi
//!    execution) — the paper's pipeline on the simulated testbed.
//! 2. **Real training**: train the AOT-compiled LM artifacts for a few
//!    hundred steps across N worker threads with *real* artifact
//!    execution (the in-tree HLO interpreter by default — no setup
//!    needed; `make artifacts` + a PJRT binding swaps in the full
//!    transformer lowered by `python/compile/aot.py`) and a *real* ring
//!    AllReduce — and log the loss curve.
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use disco::coordinator::{enact, EnactConfig};
use disco::prelude::*;
use disco::runtime::trainer::{train_distributed, TrainConfig};
use disco::runtime::Manifest;
use disco::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let workers = args.get_usize("workers", 4);
    let steps = args.get_usize("steps", 200);

    // ---- Phase 1: DisCo strategy search + enactment ------------------------
    println!("== phase 1: strategy search + enactment (simulated testbed) ==");
    let mut spec = ModelSpec::transformer_base();
    spec.depth_scale = 0.5;
    let cluster = Cluster::cluster_a();
    let graph = disco::models::build(&spec, cluster.num_devices());
    let device = DeviceModel::gtx1080ti();
    let profile = disco::profiler::profile(&graph, &device, &cluster, 3, 7);
    let est = CostEstimator::analytical(&profile, &cluster);
    let cfg = SearchConfig { unchanged_limit: 250, ..Default::default() };
    let result = backtracking_search(&graph, &est, &cfg);
    println!(
        "search: {:.2} ms → {:.2} ms per iteration ({} evals)",
        result.initial_cost_ms, result.best_cost_ms, result.evals
    );
    let ecfg = EnactConfig { world: workers, iterations: 5, ..Default::default() };
    let before = enact(&graph, &ecfg)?;
    let after = enact(&result.best, &ecfg)?;
    println!(
        "enactment (hi-fi, {} workers): {:.2} ms → {:.2} ms per iteration",
        workers, before.iteration_ms, after.iteration_ms
    );

    // ---- Phase 2: real distributed training through PJRT --------------------
    println!("\n== phase 2: real training (PJRT + ring AllReduce, {workers} workers) ==");
    let tcfg = TrainConfig {
        artifacts: Manifest::default_dir(),
        world: workers,
        steps,
        eval_every: 25,
        seed: args.get_u64("seed", 0x7EA1),
    };
    let res = train_distributed(&tcfg)?;
    println!(
        "{} parameters, {} steps, {:.1}s wall ({:.2} s/step/worker)",
        res.param_count,
        steps,
        res.wall_seconds,
        res.wall_seconds / steps as f64
    );
    println!("loss curve:");
    for l in &res.log {
        if l.step == 1 || l.step % 20 == 0 || l.step == steps {
            match l.eval_loss {
                Some(e) => println!("  step {:>4}  train {:.4}  eval {:.4}", l.step, l.loss, e),
                None => println!("  step {:>4}  train {:.4}", l.step, l.loss),
            }
        }
    }
    let first = res.log.first().map(|l| l.loss).unwrap_or(0.0);
    let last = res.log.last().map(|l| l.loss).unwrap_or(0.0);
    let vocab = Manifest::load(&tcfg.artifacts)?
        .raw
        .get("lm")
        .get("vocab")
        .as_usize()
        .unwrap_or(256);
    println!(
        "\ntrain loss {first:.4} → {last:.4} ({}); uniform baseline ln({vocab})={:.3}",
        if last < first { "LEARNING ✓" } else { "NOT LEARNING ✗" },
        (vocab as f64).ln()
    );
    Ok(())
}
