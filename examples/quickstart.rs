//! Quickstart: the whole DisCo pipeline on one model in ~30 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds the Transformer training graph for the paper's Cluster A,
//! profiles it on the analytical device substrate, runs the joint
//! op/tensor fusion search, and prints what changed.

use disco::prelude::*;

fn main() {
    // 1. Workload: the paper's Transformer (12 layers, d=512) for 12
    //    data-parallel workers. Use depth_scale to shrink for quick runs.
    let mut spec = ModelSpec::transformer_base();
    spec.depth_scale = 0.5;
    let cluster = Cluster::cluster_a();
    let graph = disco::models::build(&spec, cluster.num_devices());
    println!(
        "graph: {} ops, {} AllReduces, {:.1}M gradient elements",
        graph.live_count(),
        graph.allreduces().len(),
        graph.total_gradient_bytes() / 4.0 / 1e6
    );

    // 2. Profile per-op times + fit the AllReduce linear model.
    let device = DeviceModel::gtx1080ti();
    let profile = disco::profiler::profile(&graph, &device, &cluster, 3, 42);
    println!(
        "comm model: T = {:.3e}·bytes + {:.2} ms (r²={:.3})",
        profile.comm.c, profile.comm.d, profile.comm.r2
    );

    // 3. Joint op + tensor fusion search (Alg. 1).
    let est = CostEstimator::analytical(&profile, &cluster);
    let cfg = SearchConfig { unchanged_limit: 300, ..Default::default() };
    let result = backtracking_search(&graph, &est, &cfg);

    // 4. Report.
    let before = simulate(&graph, &est, SimOptions::default());
    let after = simulate(&result.best, &est, SimOptions::default());
    println!(
        "per-iteration: {:.2} ms → {:.2} ms ({:.1}% faster, {} simulator evals, {:.1}s search)",
        before.makespan_ms,
        after.makespan_ms,
        (before.makespan_ms / after.makespan_ms - 1.0) * 100.0,
        result.evals,
        result.elapsed.as_secs_f64()
    );
    println!(
        "kernels {} → {}; AllReduces {} → {}; overlap {:.2} → {:.2}",
        before.kernels,
        after.kernels,
        before.allreduces,
        after.allreduces,
        before.overlap_ratio(),
        after.overlap_ratio()
    );
}
