//! Single-device compiler comparison (the paper's Fig. 8 scenario) as a
//! library-API example: DisCo's search vs rule-based fusion (XLA, TVM,
//! nGraph) and a TASO-like cost-guided substitution search, on
//! inference-only graphs.
//!
//! ```bash
//! cargo run --release --example compare_compilers -- [--model transformer] [--full]
//! ```

use disco::baselines;
use disco::estimator::CostEstimator;
use disco::models::{self, ModelKind, ModelSpec};
use disco::network::Cluster;
use disco::prelude::*;
use disco::sim::CostSource;
use disco::search::MethodSet;
use disco::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let kinds: Vec<ModelKind> = match args.get("model") {
        Some(m) => vec![ModelKind::from_name(m).expect("unknown model")],
        None => ModelKind::ALL.to_vec(),
    };
    let depth = if args.has_flag("full") { 1.0 } else { 0.25 };

    let device = DeviceModel::gtx1080ti();
    let cluster = Cluster::single_device();
    let sim_opts = SimOptions { ignore_comm: true, ..Default::default() };

    println!(
        "{:<12} {:>12} {:>10} {:>10} {:>10} {:>10}",
        "model", "JAX_default", "nGraph", "TVM", "TASO-like", "DisCo"
    );
    for kind in kinds {
        let mut spec = ModelSpec::new(kind, 8);
        spec.depth_scale = depth;
        let g = models::build(&spec, 1).forward_only();
        let prof = disco::profiler::profile(&g, &device, &cluster, 3, 11);
        let est = CostEstimator::oracle(&prof, &device);
        let cost = |graph: &disco::graph::TrainingGraph| {
            est.prepare(graph);
            simulate(graph, &est, sim_opts).makespan_ms
        };
        let mut cfg = SearchConfig {
            unchanged_limit: if args.has_flag("full") { 1000 } else { 200 },
            sim: sim_opts,
            ..Default::default()
        };
        cfg.methods = MethodSet { nondup_fusion: true, dup_fusion: true, ar_fusion: false };
        let disco_r = backtracking_search(&g, &est, &cfg);
        println!(
            "{:<12} {:>12.3} {:>10.3} {:>10.3} {:>10.3} {:>10.3}",
            kind.name(),
            cost(&baselines::xla_op_fusion(&g)),
            cost(&baselines::ngraph_fusion(&g)),
            cost(&baselines::tvm_rule_fusion(&g)),
            cost(&baselines::taso_like(&g, &est, sim_opts, 150, 3)),
            disco_r.best_cost_ms,
        );
    }
    Ok(())
}
