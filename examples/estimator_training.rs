//! Train the GNN Fused-Op Estimator end-to-end from Rust (paper §4.3/§6.5).
//!
//! ```bash
//! cargo run --release --example estimator_training -- [--per-model 400] [--epochs 15]
//! ```
//!
//! Pipeline: profile the six benchmark models → generate random fused-op
//! samples (§5.2) → train the GNN through the `gnn_train` artifact (the
//! in-tree interpreter backend bootstraps artifacts automatically; a PJRT
//! binding + `make artifacts` swaps in the JAX-lowered variant) →
//! evaluate prediction error on unseen fused ops (the Fig. 9 experiment)
//! → save trained parameters for the search to use (`--estimator gnn`).

use disco::bench::gnn_pipeline;
use disco::bench::{BenchOptions, Scale};
use disco::runtime::Manifest;
use disco::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let per_model = args.get_usize("per-model", 400);
    let epochs = args.get_usize("epochs", 15);
    let opts = BenchOptions {
        scale: if args.has_flag("full") { Scale::Full } else { Scale::Fast },
        ..Default::default()
    };
    let artifacts = Manifest::default_dir();

    println!(
        "generating {} train + {} test fused-op samples per model ...",
        per_model,
        per_model / 4
    );
    let report =
        gnn_pipeline::train_and_eval(&opts, &artifacts, per_model, per_model / 4, epochs)?;
    println!(
        "trained on {} samples for {} epochs: log-MSE {:.4} → {:.4}",
        report.train_samples, report.epochs, report.first_loss, report.last_loss
    );
    println!(
        "held-out ({} unseen fused ops): mean err {:.1}%, p90 {:.1}%, within 14%: {:.1}% (paper: >90%)",
        report.test_samples,
        report.mean_error() * 100.0,
        report.p90_error() * 100.0,
        report.frac_within(0.14) * 100.0
    );
    println!("\nCDF of relative error:");
    let cdf = report.hist.cdf();
    for i in (0..cdf.len()).step_by(5) {
        println!("  err <= {:.2}: {:.1}%", report.hist.edge(i), cdf[i] * 100.0);
    }
    let path = gnn_pipeline::save_params(&artifacts, &report.params)?;
    println!("\nsaved trained estimator to {}", path.display());
    Ok(())
}
