//! Backtracking strategy search — Alg. 1 of the paper.
//!
//! A priority queue of candidate HLO modules (ordered by simulated cost)
//! drives exploration. Each step dequeues the cheapest candidate and, for
//! each enabled optimization method, applies it a random number of times
//! (`n ∈ [0, β]`, the paper's `RandomApply`), evaluates the mutated module
//! with the simulator, tracks the best module found, and re-enqueues
//! candidates whose cost is within `α ×` the best (pruning). The search
//! stops when the queue empties or the best module hasn't improved for
//! `unchanged_limit` candidate evaluations (1000 in the paper).
//!
//! The three optimization methods (paper §4.5) are:
//! 1. non-duplicate op fusion of a random (pred, succ) pair,
//! 2. duplicate op fusion of a random (pred, succ) pair,
//! 3. fusion of a random AllReduce with a random neighbour AllReduce.
//!
//! A fourth and fifth, opt-in method extend the vocabulary past the
//! paper:
//! 4. re-chunking a random AllReduce into a power-of-two chunk stream
//!    (DESIGN.md §13), so the search discovers comm/compute overlap
//!    schedules jointly with the fusion decisions that create the fused
//!    tensors being chunked;
//! 5. toggling a random AllReduce between whole-tensor DDP and a
//!    ZeRO/FSDP-style reduce-scatter + all-gather split (DESIGN.md §16),
//!    so gradient-sharding decisions are searched jointly with the op-
//!    and tensor-fusion decisions that shape the collectives being
//!    sharded.
//!
//! Method subsets are configurable to reproduce the Fig. 10 ablation.
//!
//! ## Hot-path architecture (see `rust/PERF.md`)
//!
//! Evals/sec is the number that decides strategy quality under a fixed
//! budget, so the inner loop is built to spend its time scheduling, not
//! allocating:
//!
//! * queued candidates are **deltas** — (parent arena index, the exact
//!   [`Mutation`] list that produced them) — rematerialized on dequeue,
//!   instead of up to `max_queue` full graph clones;
//! * the fusion-candidate pool is maintained **incrementally** across the
//!   mutations of one `RandomApply` ([`CandidateSet`]);
//! * simulator evaluations reuse per-thread [`SimWorkspace`]s and run the
//!   per-step method batch on `std::thread::scope` workers.
//!
//! Mutation *generation* stays serial on the main RNG and results are
//! merged in method order, so the search is deterministic per seed
//! regardless of `eval_threads` (and identical between delta and eager
//! candidate storage) — both equivalences are property-tested.

pub mod anneal;

use crate::fusion::{self, CandidateSet, FusionKind, Mutation};
use crate::graph::{NodeId, TrainingGraph};
use crate::sim::{
    simulate, simulate_ckpt_in, simulate_delta, simulate_in, simulate_table_in, CheckpointLog,
    CostSource, CostTable, NoRecord, OrderedF64, SimOptions, SimWorkspace,
};
use crate::util::rng::Rng;
use crate::util::trace::{Event, NullSink, TraceSink, TrackId};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet, VecDeque};
use std::time::{Duration, Instant};

/// Search telemetry lane in the shared track scheme (DESIGN.md §15):
/// pid 2 is the search subsystem, one lane of step spans.
pub const SEARCH_TRACK: TrackId = TrackId::new(2, 1);

/// Which optimization methods the search may use (Fig. 10 ablation knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MethodSet {
    pub nondup_fusion: bool,
    pub dup_fusion: bool,
    pub ar_fusion: bool,
    /// Re-chunk AllReduce tensors into pipelined chunk streams
    /// (DESIGN.md §13). Off in [`MethodSet::all`] — the paper's move set
    /// is the three fusion methods, and keeping the default vocabulary
    /// unchanged keeps every recorded search trajectory and the
    /// `BENCH_search.json` projections comparable across PRs. Enable via
    /// `search.chunking` in the config file or `--chunking` on the CLI.
    pub chunking: bool,
    /// Toggle AllReduce collectives between whole-tensor DDP and the
    /// ZeRO/FSDP reduce-scatter + all-gather split (DESIGN.md §16). Off
    /// in [`MethodSet::all`] for the same reason as chunking: the paper's
    /// move set is the three fusion methods, and the default vocabulary
    /// must keep recorded trajectories and `BENCH_search.json`
    /// projections comparable. Enable via `search.sharding` in the config
    /// file or `--sharding` on the CLI.
    pub sharding: bool,
}

impl MethodSet {
    /// The paper's full move set (the three fusion methods). Chunking and
    /// sharding are vocabulary *extensions* and stay opt-in; see
    /// [`MethodSet::chunking`] / [`MethodSet::sharding`].
    pub fn all() -> MethodSet {
        MethodSet {
            nondup_fusion: true,
            dup_fusion: true,
            ar_fusion: true,
            chunking: false,
            sharding: false,
        }
    }

    pub fn none() -> MethodSet {
        MethodSet {
            nondup_fusion: false,
            dup_fusion: false,
            ar_fusion: false,
            chunking: false,
            sharding: false,
        }
    }

    /// All fusion methods plus the chunking extension.
    pub fn all_with_chunking() -> MethodSet {
        MethodSet { chunking: true, ..MethodSet::all() }
    }

    /// All fusion methods plus the gradient-sharding extension.
    pub fn all_with_sharding() -> MethodSet {
        MethodSet { sharding: true, ..MethodSet::all() }
    }

    fn enabled(&self) -> Vec<Method> {
        let mut v = Vec::new();
        if self.nondup_fusion {
            v.push(Method::NonDupFusion);
        }
        if self.dup_fusion {
            v.push(Method::DupFusion);
        }
        if self.ar_fusion {
            v.push(Method::ArFusion);
        }
        if self.chunking {
            v.push(Method::Chunk);
        }
        if self.sharding {
            v.push(Method::Shard);
        }
        v
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Method {
    NonDupFusion,
    DupFusion,
    ArFusion,
    Chunk,
    Shard,
}

/// Search hyper-parameters (paper defaults: α = 1.05, β = 10,
/// unchanged limit 1000) plus the hot-path knobs, which exist so the
/// A/B perf record (`BENCH_search.json`) and the equivalence property
/// tests can pin the pre-refactor behavior. `eval_threads`,
/// `delta_candidates` and `reuse_workspaces` never change the result
/// for a given seed — only where the time and memory go (both
/// equivalences are property-tested). `incremental_candidates` is
/// different: it reproduces the pre-refactor candidate *ordering*
/// (rebuild order interleaves new pairs by consumer id; incremental
/// patching appends them), and since `RandomApply` draws pairs by
/// index, toggling it legitimately steers the random search onto a
/// different — equally valid — trajectory.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub alpha: f64,
    pub beta: usize,
    pub unchanged_limit: usize,
    /// Cap on the priority queue.
    pub max_queue: usize,
    /// Hard wall-clock budget; 0 = unlimited.
    pub max_seconds: f64,
    pub methods: MethodSet,
    /// Cap on the chunk count the chunking method may propose (clamped to
    /// [`fusion::MAX_CHUNKS`]; candidates are powers of two respecting the
    /// [`fusion::MIN_CHUNK_BYTES`] floor). Only read when
    /// [`MethodSet::chunking`] is enabled.
    pub max_chunks: u32,
    pub sim: SimOptions,
    pub seed: u64,
    /// Maximum worker threads for the per-step candidate evaluations
    /// (the ≤ 3 method batch is chunked across at most this many
    /// workers). 1 = serial. Results are identical either way: mutation
    /// generation is serial and merge order is method order.
    pub eval_threads: usize,
    /// Store queued candidates as parent + mutation deltas rematerialized
    /// on dequeue (true) instead of full graph clones (false, the
    /// pre-refactor arena).
    pub delta_candidates: bool,
    /// Reuse per-thread simulator workspaces across evaluations (false =
    /// allocate fresh scratch per eval, the pre-refactor behavior).
    pub reuse_workspaces: bool,
    /// Maintain the fusion-candidate pool incrementally across the
    /// mutations of one `RandomApply` (false = re-enumerate from the
    /// graph before every application, the pre-refactor behavior).
    /// Unlike the two toggles above this affects candidate *ordering*
    /// and therefore which random pairs get drawn — the search stays
    /// deterministic per seed but follows a different trajectory.
    pub incremental_candidates: bool,
    /// Below this many arena nodes the per-step batch is evaluated
    /// serially even when `eval_threads > 1`: for small graphs a
    /// simulation is a few microseconds and per-step thread spawn/join
    /// overhead would exceed the parallel win. Never affects results.
    pub parallel_min_nodes: usize,
    /// Resolve every live node's cost into a flat [`CostTable`] per
    /// candidate and drive the simulator off the table (true) instead of
    /// calling the cost source per scheduled event (false, the pre-table
    /// engine). Never changes results — costs are deterministic per node
    /// (`prop_search_delta_sim_matches_full`).
    pub cost_table: bool,
    /// Evaluate candidates incrementally: simulate the dequeued parent
    /// once recording schedule checkpoints, then replay only each child's
    /// affected suffix from its mutation frontier (true), instead of a
    /// full simulation per child (false). Bit-identical results either
    /// way (`prop_delta_sim_matches_full`); the toggle exists as the A/B
    /// arm of `BENCH_search.json`. Implies table-driven evaluation for
    /// the per-step batch regardless of `cost_table`.
    pub delta_sim: bool,
    /// Checkpoint cadence for the parent simulation, in scheduled events
    /// (0 = auto: n/8, at least 32).
    pub ckpt_every: usize,
    /// Record the mutation path from the input graph to every enqueued
    /// candidate so [`SearchResult::best_path`] holds the exact rewrite
    /// sequence that produced the winner (the strategy service persists
    /// it as the plan — DESIGN.md §11). Pure observation: never changes
    /// the search trajectory, only adds one small `Vec<Mutation>` clone
    /// per enqueued candidate, so it is off by default to keep the hot
    /// path's allocation profile identical to the A/B record's.
    pub track_best_path: bool,
    /// Emit per-step telemetry events (DESIGN.md §15) to the sink passed
    /// to [`backtracking_search_traced`]. Pure observation: with the
    /// toggle off the sink is never touched and the search is
    /// bit-identical to pre-telemetry behavior (property-tested with a
    /// panicking sink, the same pattern as the panic-cost-source).
    pub trace: bool,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            alpha: 1.05,
            beta: 10,
            unchanged_limit: 1000,
            max_queue: 256,
            max_seconds: 0.0,
            methods: MethodSet::all(),
            max_chunks: 8,
            sim: SimOptions::default(),
            seed: 0xD15C0,
            eval_threads: 3,
            delta_candidates: true,
            reuse_workspaces: true,
            incremental_candidates: true,
            parallel_min_nodes: 128,
            cost_table: true,
            delta_sim: true,
            ckpt_every: 0,
            track_best_path: false,
            trace: false,
        }
    }
}

/// Outcome of a search run.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub best: TrainingGraph,
    pub best_cost_ms: f64,
    pub initial_cost_ms: f64,
    /// Queue dequeues performed.
    pub steps: u64,
    /// Candidate evaluations performed (the metric the paper budgets).
    pub evals: u64,
    /// Checkpointed parent re-simulations performed by the delta-sim
    /// engine (0 when `delta_sim` is off). Not counted in `evals`, so
    /// the toggle never changes the comparable fields; the cost shows up
    /// in wall time, which is what the A/B record measures.
    pub resims: u64,
    /// High-water mark of candidate-storage memory (arena entries +
    /// rematerialization memo), approximate bytes.
    pub peak_arena_bytes: usize,
    /// Warm-start seeds (cached plans) that replayed into a valid, novel
    /// candidate (0 for cold searches).
    pub warm_hits: u64,
    /// Total mutations successfully replayed from warm-start seeds —
    /// rewrites the search was handed instead of having to rediscover.
    pub steps_saved: u64,
    /// The exact mutation sequence that turns the input graph into
    /// `best`. Populated only when [`SearchConfig::track_best_path`] is
    /// set (empty means "best == input" in that mode); always empty
    /// otherwise.
    pub best_path: Vec<Mutation>,
    pub elapsed: Duration,
}

impl SearchResult {
    pub fn speedup(&self) -> f64 {
        if self.best_cost_ms == 0.0 {
            1.0
        } else {
            self.initial_cost_ms / self.best_cost_ms
        }
    }
}

/// Apply method `m` up to `n` times with random operands drawn from
/// `cset`, recording each rewrite that succeeded. Invalid applications
/// (paper's validity check) are skipped, with a few retries each.
/// When `frontier` is given it accumulates every node the rewrites
/// touched (operands plus [`fusion::FusionEffects`]) — the delta
/// simulator's mutation frontier. Pass `None` when `delta_sim` is off so
/// the A/B baseline arms don't pay for collection they won't use.
fn random_apply(
    g: &mut TrainingGraph,
    cset: &mut CandidateSet,
    m: Method,
    n: usize,
    max_chunks: u32,
    rng: &mut Rng,
    incremental: bool,
    mut frontier: Option<&mut Vec<NodeId>>,
) -> Vec<Mutation> {
    let mut muts = Vec::new();
    for _ in 0..n {
        if !incremental && !muts.is_empty() {
            *cset = CandidateSet::build(g);
        }
        let applied = match m {
            Method::NonDupFusion | Method::DupFusion => {
                let kind = if m == Method::NonDupFusion {
                    FusionKind::NonDuplicate
                } else {
                    FusionKind::Duplicate
                };
                let mut ok = false;
                for _ in 0..4 {
                    let Some(&(p, s)) = rng.choose(cset.op_pairs()) else { break };
                    if let Ok(fx) = cset.apply_op_fusion(g, p, s, kind) {
                        muts.push(Mutation::FuseOps { pred: p, succ: s, kind });
                        if let Some(f) = frontier.as_deref_mut() {
                            f.push(p);
                            f.push(s);
                            fx.extend_frontier(g, f);
                        }
                        ok = true;
                        break;
                    }
                }
                ok
            }
            Method::ArFusion => {
                let mut ok = false;
                for _ in 0..4 {
                    let Some(&a) = rng.choose(cset.allreduces()) else { break };
                    let neighbors = fusion::ar_neighbors(g, a);
                    let Some(&b) = rng.choose(&neighbors) else { continue };
                    if let Ok(fx) = cset.apply_ar_fusion(g, a, b) {
                        muts.push(Mutation::FuseAllReduce { a, b });
                        if let Some(f) = frontier.as_deref_mut() {
                            f.push(a);
                            f.push(b);
                            fx.extend_frontier(g, f);
                        }
                        ok = true;
                        break;
                    }
                }
                ok
            }
            Method::Chunk => {
                let mut ok = false;
                for _ in 0..4 {
                    let Some(&a) = rng.choose(cset.allreduces()) else { break };
                    let counts = fusion::chunk_candidates(g, a, max_chunks);
                    let Some(&count) = rng.choose(&counts) else { continue };
                    if let Ok(fx) = cset.apply_chunking(g, a, count) {
                        muts.push(Mutation::SetChunks { ar: a, count });
                        if let Some(f) = frontier.as_deref_mut() {
                            f.push(a);
                            fx.extend_frontier(g, f);
                        }
                        ok = true;
                        break;
                    }
                }
                ok
            }
            Method::Shard => {
                let mut ok = false;
                for _ in 0..4 {
                    let Some(&a) = rng.choose(cset.allreduces()) else { break };
                    let kinds = fusion::shard_candidates(g, a);
                    let Some(&kind) = rng.choose(&kinds) else { continue };
                    if let Ok(fx) = cset.apply_sharding(g, a, kind) {
                        muts.push(Mutation::SetSharding { ar: a, kind });
                        if let Some(f) = frontier.as_deref_mut() {
                            f.push(a);
                            fx.extend_frontier(g, f);
                        }
                        ok = true;
                        break;
                    }
                }
                ok
            }
        };
        if !applied {
            break;
        }
    }
    muts
}

/// How a queued candidate is stored in the arena.
#[derive(Debug)]
enum Stored {
    /// Materialized graph (the root; every entry in eager mode).
    Graph(TrainingGraph),
    /// Delta: clone of `parent`'s graph + `muts` replayed in order.
    Delta { parent: usize, muts: Vec<Mutation> },
    /// Eager entry already consumed by its dequeue.
    Taken,
}

/// Number of recently-dequeued parents kept materialized so delta
/// rematerialization rarely walks more than one hop. Children of a good
/// candidate sit near it in the cost-ordered queue, so a small LRU covers
/// most dequeues; misses fall back to replay-from-ancestor, which is
/// always correct.
const REMAT_MEMO: usize = 8;

/// Per-slot fixed overhead of one arena entry (the `Stored` enum plus its
/// `entry_bytes` and `paths` companions), charged to the accounting when a
/// fresh slot is allocated and reclaimed by slot reuse — so unbounded
/// `Taken`-slot growth would show up in `peak_arena_bytes` rather than hide.
const SLOT_BYTES: usize = std::mem::size_of::<Stored>()
    + std::mem::size_of::<usize>()
    + std::mem::size_of::<Vec<Mutation>>();

/// Candidate arena: delta-encoded entries plus a bounded memo of
/// materialized graphs, with byte accounting for the perf record.
/// Eager-mode entries are consumed exactly once by their dequeue, so
/// consumed slots go on a free list and are reused by later pushes —
/// the arena stays bounded by queue depth, not by candidates ever
/// enqueued (delta entries reference parents by index and are never
/// consumed, so reuse only ever sees genuinely dead slots).
struct Arena {
    entries: Vec<Stored>,
    entry_bytes: Vec<usize>,
    /// Mutation path from the root graph to each entry (parallel to
    /// `entries`; all empty unless `track_best_path` is on, and empty
    /// `Vec`s never allocate).
    paths: Vec<Vec<Mutation>>,
    memo: HashMap<usize, TrainingGraph>,
    memo_order: VecDeque<usize>,
    free: Vec<usize>,
    live_bytes: usize,
    peak_bytes: usize,
}

impl Arena {
    fn new(root: TrainingGraph) -> Arena {
        let mut a = Arena {
            entries: Vec::new(),
            entry_bytes: Vec::new(),
            paths: Vec::new(),
            memo: HashMap::new(),
            memo_order: VecDeque::new(),
            free: Vec::new(),
            live_bytes: 0,
            peak_bytes: 0,
        };
        a.push_graph(root, Vec::new());
        a
    }

    fn note(&mut self) {
        self.peak_bytes = self.peak_bytes.max(self.live_bytes);
    }

    /// Store `s` in a reclaimed slot if one is free, else append.
    fn alloc_slot(&mut self, s: Stored, bytes: usize, path: Vec<Mutation>) -> usize {
        let idx = if let Some(idx) = self.free.pop() {
            self.entries[idx] = s;
            self.entry_bytes[idx] = bytes;
            self.paths[idx] = path;
            idx
        } else {
            self.entries.push(s);
            self.entry_bytes.push(bytes);
            self.paths.push(path);
            self.live_bytes += SLOT_BYTES;
            self.entries.len() - 1
        };
        self.live_bytes += bytes;
        self.note();
        idx
    }

    fn push_graph(&mut self, g: TrainingGraph, path: Vec<Mutation>) -> usize {
        let bytes =
            g.approx_bytes() + path.capacity() * std::mem::size_of::<Mutation>();
        self.alloc_slot(Stored::Graph(g), bytes, path)
    }

    fn push_delta(&mut self, parent: usize, muts: Vec<Mutation>, path: Vec<Mutation>) -> usize {
        let bytes =
            (muts.capacity() + path.capacity()) * std::mem::size_of::<Mutation>();
        self.alloc_slot(Stored::Delta { parent, muts }, bytes, path)
    }

    /// Root-to-entry mutation path (empty unless path tracking is on).
    fn path(&self, idx: usize) -> &[Mutation] {
        &self.paths[idx]
    }

    /// Eager-mode dequeue: move the stored clone out and reclaim the slot
    /// (including its path — the accounting subtracts the path bytes, so
    /// the allocation must go too).
    fn take_graph(&mut self, idx: usize) -> TrainingGraph {
        self.live_bytes -= self.entry_bytes[idx];
        self.entry_bytes[idx] = 0;
        let g = match std::mem::replace(&mut self.entries[idx], Stored::Taken) {
            Stored::Graph(g) => g,
            _ => panic!("candidate {idx} is not an eager graph"),
        };
        self.paths[idx] = Vec::new();
        self.free.push(idx);
        g
    }

    /// Delta-mode dequeue: walk up to the nearest materialized ancestor
    /// (memo hit or a `Stored::Graph`), clone it, and replay the deltas
    /// down the path.
    fn materialize(&self, idx: usize) -> TrainingGraph {
        let mut path: Vec<usize> = Vec::new();
        let mut cur = idx;
        let mut g = loop {
            if let Some(hit) = self.memo.get(&cur) {
                break hit.clone();
            }
            match &self.entries[cur] {
                Stored::Graph(gr) => break gr.clone(),
                Stored::Delta { parent, .. } => {
                    path.push(cur);
                    cur = *parent;
                }
                Stored::Taken => unreachable!("delta parent was consumed"),
            }
        };
        for &step in path.iter().rev() {
            if let Stored::Delta { muts, .. } = &self.entries[step] {
                for m in muts {
                    m.replay(&mut g).expect("delta replay diverged from recorded parent");
                }
            }
        }
        g
    }

    /// Keep `g` (the graph of arena entry `idx`, which children reference)
    /// materialized for upcoming dequeues; evicts the oldest memo entry
    /// beyond [`REMAT_MEMO`].
    fn memoize(&mut self, idx: usize, g: TrainingGraph) {
        self.live_bytes += g.approx_bytes();
        self.memo.insert(idx, g);
        self.memo_order.push_back(idx);
        if self.memo_order.len() > REMAT_MEMO {
            if let Some(old) = self.memo_order.pop_front() {
                if let Some(dropped) = self.memo.remove(&old) {
                    self.live_bytes -= dropped.approx_bytes();
                }
            }
        }
        self.note();
    }
}

/// One mutated candidate awaiting evaluation: the rematerializable delta
/// (`muts`) plus the union of nodes the rewrites touched (`frontier`,
/// the delta simulator's divergence set).
struct Prepared {
    graph: TrainingGraph,
    muts: Vec<Mutation>,
    frontier: Vec<NodeId>,
}

/// Full (non-incremental) evaluation of one candidate. With
/// `cfg.cost_table` the per-node costs are resolved once into `table`
/// and the event loop runs lock- and dispatch-free; otherwise the
/// pre-table dyn path is used (the A/B arm).
#[inline]
fn eval_one(
    graph: &TrainingGraph,
    costs: &dyn CostSource,
    cfg: &SearchConfig,
    ws: &mut SimWorkspace,
    table: &mut CostTable,
) -> f64 {
    if cfg.cost_table {
        table.build_in(graph, costs); // includes the batched GNN prefetch
        if cfg.reuse_workspaces {
            simulate_table_in(graph, table, cfg.sim, &mut NoRecord, ws).makespan_ms
        } else {
            simulate_table_in(graph, table, cfg.sim, &mut NoRecord, &mut SimWorkspace::new())
                .makespan_ms
        }
    } else {
        costs.prepare(graph); // batched GNN prefetch (no-op for other sources)
        if cfg.reuse_workspaces {
            simulate_in(graph, costs, cfg.sim, &mut NoRecord, ws).makespan_ms
        } else {
            simulate(graph, costs, cfg.sim).makespan_ms
        }
    }
}

/// Incremental evaluation of one child against its parent's checkpointed
/// schedule: derive the child's cost table from the parent's (O(new
/// nodes) estimator work) and replay only the suffix of the schedule its
/// mutation frontier can influence. Bit-identical to [`eval_one`].
#[inline]
#[allow(clippy::too_many_arguments)]
fn eval_delta(
    parent: &TrainingGraph,
    log: &CheckpointLog,
    parent_table: &CostTable,
    p: &Prepared,
    costs: &dyn CostSource,
    cfg: &SearchConfig,
    ws: &mut SimWorkspace,
    table: &mut CostTable,
) -> f64 {
    table.extend_in(parent_table, &p.graph, costs);
    if cfg.reuse_workspaces {
        simulate_delta(parent, log, &p.graph, &p.frontier, table, cfg.sim, &mut NoRecord, ws)
            .makespan_ms
    } else {
        simulate_delta(
            parent,
            log,
            &p.graph,
            &p.frontier,
            table,
            cfg.sim,
            &mut NoRecord,
            &mut SimWorkspace::new(),
        )
        .makespan_ms
    }
}

/// Evaluate `batch` on up to `threads` scoped workers: the batch is split
/// into contiguous chunks, each worker evaluating its chunk serially into
/// a disjoint result slice (order-preserving, so the caller's merge stays
/// deterministic). Shared by the delta and full evaluation arms.
fn eval_batch_parallel<F>(
    batch: &[Prepared],
    ws_pool: &mut [SimWorkspace],
    tables: &mut [CostTable],
    threads: usize,
    eval: F,
) -> Vec<f64>
where
    F: Fn(&Prepared, &mut SimWorkspace, &mut CostTable) -> f64 + Sync,
{
    let workers = threads.min(batch.len());
    let per = batch.len().div_ceil(workers);
    let mut out = vec![0.0f64; batch.len()];
    let eval = &eval;
    std::thread::scope(|s| {
        let handles: Vec<_> = batch
            .chunks(per)
            .zip(out.chunks_mut(per))
            .zip(ws_pool.iter_mut().zip(tables.iter_mut()))
            .map(|((items, slots), (ws, table))| {
                s.spawn(move || {
                    for (p, slot) in items.iter().zip(slots.iter_mut()) {
                        *slot = eval(p, ws, table);
                    }
                })
            })
            .collect();
        for handle in handles {
            handle.join().expect("candidate evaluation worker panicked");
        }
    });
    out
}

/// Run Alg. 1 on `input` using `costs` as the simulator's cost source.
/// `costs` must be `Sync` so the per-step candidate batch can be
/// evaluated on worker threads; every estimator in this crate is.
pub fn backtracking_search(
    input: &TrainingGraph,
    costs: &(dyn CostSource + Sync),
    cfg: &SearchConfig,
) -> SearchResult {
    backtracking_search_seeded(input, costs, cfg, &[])
}

/// [`backtracking_search`] warm-started from cached plans: each seed is a
/// mutation sequence recorded by an earlier search (the strategy
/// service's plan store — DESIGN.md §11). Seeds are replayed *best
/// effort* onto `input` before the main loop — mutations that no longer
/// apply (the seed came from a perturbed or merely similar graph) are
/// skipped, and whatever replays becomes an ordinary evaluated, enqueued
/// candidate. Seeding therefore never compromises validity, and with an
/// empty seed list the function is exactly the cold search. Seed
/// processing draws nothing from the RNG and does not touch the
/// `unchanged` stop counter, so a given (seed list, config seed) pair is
/// fully deterministic.
pub fn backtracking_search_seeded(
    input: &TrainingGraph,
    costs: &(dyn CostSource + Sync),
    cfg: &SearchConfig,
    seeds: &[Vec<Mutation>],
) -> SearchResult {
    backtracking_search_traced(input, costs, cfg, seeds, &mut NullSink)
}

/// [`backtracking_search_seeded`] with a telemetry sink: when
/// [`SearchConfig::trace`] is set, every dequeue step emits one span on
/// [`SEARCH_TRACK`] (args: step, candidates evaluated, cumulative evals,
/// best makespan, children accepted, backtracks, warm hits, delta-sim
/// parent re-sims, wall ms) framed by `initial` / `final` instants — the
/// convergence curve of the run. The `final` instant's `best_ms` is read
/// from the same variable returned as [`SearchResult::best_cost_ms`], so
/// the two agree exactly. With the toggle off the sink is never touched
/// and results are bit-identical to the untraced search.
pub fn backtracking_search_traced(
    input: &TrainingGraph,
    costs: &(dyn CostSource + Sync),
    cfg: &SearchConfig,
    seeds: &[Vec<Mutation>],
    sink: &mut dyn TraceSink,
) -> SearchResult {
    let start = Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let methods = cfg.methods.enabled();
    let threads = cfg.eval_threads.max(1);
    let mut ws_pool: Vec<SimWorkspace> = (0..threads).map(|_| SimWorkspace::new()).collect();
    // Per-thread scratch cost tables plus the step-shared parent table
    // and checkpoint log of the delta-sim engine.
    let mut tables: Vec<CostTable> = (0..threads).map(|_| CostTable::new()).collect();
    let mut parent_table = CostTable::new();
    let mut ckpt_log = CheckpointLog::new();
    let mut resims = 0u64;

    let initial_cost = eval_one(input, costs, cfg, &mut ws_pool[0], &mut tables[0]);
    let mut best = input.clone();
    let mut best_cost = initial_cost;
    if cfg.trace {
        sink.name_track(SEARCH_TRACK, "search");
        sink.event(
            Event::instant(
                SEARCH_TRACK,
                "initial",
                start.elapsed().as_secs_f64() * 1e3,
                "search-init",
            )
            .with_args(vec![("best_ms", initial_cost), ("evals", 1.0)]),
        );
    }

    // Priority queue of (cost, seq, arena index); the arena holds deltas
    // (or full clones in eager mode).
    let mut arena = Arena::new(input.clone());
    let mut queue: BinaryHeap<Reverse<(OrderedF64, u64, usize)>> = BinaryHeap::new();
    queue.push(Reverse((OrderedF64(initial_cost), 0, 0)));
    let mut seen: HashSet<u64> = HashSet::new();
    seen.insert(input.fingerprint());

    let mut unchanged = 0usize;
    let mut steps = 0u64;
    let mut evals = 1u64;
    let mut backtracks = 0u64;
    let mut seq = 1u64;
    let mut warm_hits = 0u64;
    let mut steps_saved = 0u64;
    let mut best_path: Vec<Mutation> = Vec::new();
    let mut batch: Vec<Prepared> = Vec::with_capacity(methods.len());

    // --- warm-start seeds: replay cached plans, evaluate, enqueue --------
    for seed in seeds {
        let mut candidate = input.clone();
        let mut applied: Vec<Mutation> = Vec::new();
        for m in seed {
            if m.replay(&mut candidate).is_ok() {
                applied.push(*m);
            }
        }
        if applied.is_empty() || !seen.insert(candidate.fingerprint()) {
            continue;
        }
        debug_assert!(candidate.validate().is_ok());
        let cost = eval_one(&candidate, costs, cfg, &mut ws_pool[0], &mut tables[0]);
        evals += 1;
        warm_hits += 1;
        steps_saved += applied.len() as u64;
        if cost < best_cost {
            best_cost = cost;
            best = candidate.clone();
            if cfg.track_best_path {
                best_path = applied.clone();
            }
        }
        if cfg.trace {
            sink.event(
                Event::instant(
                    SEARCH_TRACK,
                    "warm-seed",
                    start.elapsed().as_secs_f64() * 1e3,
                    "search-warm",
                )
                .with_args(vec![
                    ("cost_ms", cost),
                    ("applied", applied.len() as f64),
                    ("best_ms", best_cost),
                ]),
            );
        }
        if cost <= cfg.alpha * best_cost && queue.len() < cfg.max_queue {
            let path = if cfg.track_best_path { applied.clone() } else { Vec::new() };
            // The root (arena slot 0) is a materialized `Stored::Graph`,
            // so delta entries can parent on it directly.
            let slot = if cfg.delta_candidates {
                arena.push_delta(0, applied, path)
            } else {
                arena.push_graph(candidate, path)
            };
            queue.push(Reverse((OrderedF64(cost), seq, slot)));
            seq += 1;
        }
    }

    while let Some(Reverse((_, _, idx))) = queue.pop() {
        if unchanged >= cfg.unchanged_limit {
            break;
        }
        if cfg.max_seconds > 0.0 && start.elapsed().as_secs_f64() > cfg.max_seconds {
            break;
        }
        let step_t0 = if cfg.trace { start.elapsed().as_secs_f64() * 1e3 } else { 0.0 };
        // Capture the parent's root-path before this step's pushes can
        // reuse the slot (eager mode reclaims consumed slots eagerly).
        let parent_path: Vec<Mutation> =
            if cfg.track_best_path { arena.path(idx).to_vec() } else { Vec::new() };
        let h = if cfg.delta_candidates {
            arena.materialize(idx)
        } else {
            arena.take_graph(idx)
        };
        steps += 1;

        // --- serial, deterministic mutation generation -------------------
        let base_cset = CandidateSet::build(&h);
        batch.clear();
        for &m in &methods {
            // n = Random(0, β): 0 applications produce H' == H — skip the
            // no-op evaluation (the fingerprint set would reject it anyway).
            let n = rng.gen_range_inclusive(0, cfg.beta);
            if n == 0 {
                continue;
            }
            let mut candidate = h.clone();
            let mut cset = base_cset.clone();
            let mut frontier = Vec::new();
            let muts = random_apply(
                &mut candidate,
                &mut cset,
                m,
                n,
                cfg.max_chunks,
                &mut rng,
                cfg.incremental_candidates,
                if cfg.delta_sim { Some(&mut frontier) } else { None },
            );
            if muts.is_empty() {
                continue;
            }
            if !seen.insert(candidate.fingerprint()) {
                continue;
            }
            batch.push(Prepared { graph: candidate, muts, frontier });
        }

        // --- evaluation: the expensive part, parallel when it pays -------
        // At most `eval_threads` workers: the batch is split into
        // contiguous chunks, each worker evaluating its chunk serially
        // into a disjoint result slice (order-preserving, so the merge
        // below stays deterministic). With `delta_sim`, the parent is
        // first simulated once with schedule checkpoints; the ≤3 children
        // share that log (read-only) and replay only their suffixes.
        let parallel =
            threads > 1 && batch.len() > 1 && h.nodes.len() >= cfg.parallel_min_nodes;
        let batch_costs: Vec<f64> = if batch.is_empty() {
            Vec::new()
        } else if cfg.delta_sim {
            parent_table.build_in(&h, costs);
            simulate_ckpt_in(
                &h,
                &parent_table,
                cfg.sim,
                &mut NoRecord,
                &mut ws_pool[0],
                &mut ckpt_log,
                cfg.ckpt_every,
            );
            resims += 1;
            if parallel {
                let (h_ref, log_ref, ptab_ref) = (&h, &ckpt_log, &parent_table);
                eval_batch_parallel(&batch, &mut ws_pool, &mut tables, threads, |p, ws, table| {
                    eval_delta(h_ref, log_ref, ptab_ref, p, costs, cfg, ws, table)
                })
            } else {
                let ws = &mut ws_pool[0];
                let table = &mut tables[0];
                batch
                    .iter()
                    .map(|p| eval_delta(&h, &ckpt_log, &parent_table, p, costs, cfg, ws, table))
                    .collect()
            }
        } else if parallel {
            eval_batch_parallel(&batch, &mut ws_pool, &mut tables, threads, |p, ws, table| {
                eval_one(&p.graph, costs, cfg, ws, table)
            })
        } else {
            let ws = &mut ws_pool[0];
            let table = &mut tables[0];
            batch.iter().map(|p| eval_one(&p.graph, costs, cfg, ws, table)).collect()
        };

        // --- deterministic merge, in method order ------------------------
        let mut h_is_parent = false;
        let step_candidates = batch_costs.len();
        let mut step_accepted = 0u64;
        for (prepared, &cost) in batch.drain(..).zip(&batch_costs) {
            evals += 1;
            if cost < best_cost {
                best_cost = cost;
                best = prepared.graph.clone();
                if cfg.track_best_path {
                    best_path.clear();
                    best_path.extend_from_slice(&parent_path);
                    best_path.extend_from_slice(&prepared.muts);
                }
                unchanged = 0;
            } else {
                unchanged += 1;
                backtracks += 1;
            }
            if cost <= cfg.alpha * best_cost && queue.len() < cfg.max_queue {
                let child_path = if cfg.track_best_path {
                    let mut p = parent_path.clone();
                    p.extend_from_slice(&prepared.muts);
                    p
                } else {
                    Vec::new()
                };
                let slot = if cfg.delta_candidates {
                    h_is_parent = true;
                    arena.push_delta(idx, prepared.muts, child_path)
                } else {
                    arena.push_graph(prepared.graph, child_path)
                };
                queue.push(Reverse((OrderedF64(cost), seq, slot)));
                seq += 1;
                step_accepted += 1;
            }
        }
        // `h` is an enqueued child's parent: keep it materialized (no
        // extra clone — `h` is owned and no longer needed).
        if cfg.delta_candidates && h_is_parent {
            arena.memoize(idx, h);
        }
        if cfg.trace {
            let wall_ms = start.elapsed().as_secs_f64() * 1e3;
            sink.event(
                Event::span(SEARCH_TRACK, format!("step {steps}"), step_t0, wall_ms, "search-step")
                    .with_args(vec![
                        ("step", steps as f64),
                        ("candidates", step_candidates as f64),
                        ("accepted", step_accepted as f64),
                        ("evals", evals as f64),
                        ("best_ms", best_cost),
                        ("backtracks", backtracks as f64),
                        ("warm_hits", warm_hits as f64),
                        ("resims", resims as f64),
                        ("wall_ms", wall_ms),
                    ]),
            );
        }
    }

    if cfg.trace {
        let wall_ms = start.elapsed().as_secs_f64() * 1e3;
        sink.event(
            // `best_ms` here is the same `best_cost` returned below as
            // `SearchResult::best_cost_ms` — the convergence curve's last
            // point equals the result exactly.
            Event::instant(SEARCH_TRACK, "final", wall_ms, "search-final").with_args(vec![
                ("best_ms", best_cost),
                ("initial_ms", initial_cost),
                ("steps", steps as f64),
                ("evals", evals as f64),
                ("backtracks", backtracks as f64),
                ("warm_hits", warm_hits as f64),
                ("resims", resims as f64),
                ("wall_ms", wall_ms),
            ]),
        );
    }
    SearchResult {
        best,
        best_cost_ms: best_cost,
        initial_cost_ms: initial_cost,
        steps,
        evals,
        resims,
        peak_arena_bytes: arena.peak_bytes,
        warm_hits,
        steps_saved,
        best_path,
        elapsed: start.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceModel;
    use crate::estimator::CostEstimator;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::{OpKind, Role};
    use crate::network::Cluster;
    use crate::profiler;

    /// A graph with obvious fusion wins: long elementwise chains producing
    /// many small gradients.
    fn workload() -> TrainingGraph {
        let mut b = GraphBuilder::new("wl", 12);
        let x = b.constant("x", &[1 << 16]);
        let mut prev = x;
        for i in 0..6 {
            let m = b.compute(OpKind::Mul, &format!("m{i}"), &[prev], &[1 << 16], Role::Forward);
            let t = b.compute(OpKind::Tanh, &format!("t{i}"), &[m], &[1 << 16], Role::Forward);
            prev = t;
        }
        // Backward chain with small per-layer gradients.
        let mut grad = prev;
        for i in 0..6 {
            let gop =
                b.compute(OpKind::Mul, &format!("bg{i}"), &[grad], &[1 << 12], Role::Backward);
            let p = b.param(&format!("w{i}"), &[1 << 12]);
            let ar = b.allreduce(&format!("ar{i}"), gop, &[1 << 12]);
            b.optimizer_update(&format!("u{i}"), &[ar, p]);
            grad = gop;
        }
        b.finish()
    }

    fn quick_cfg() -> SearchConfig {
        SearchConfig { unchanged_limit: 60, max_queue: 64, seed: 7, ..Default::default() }
    }

    #[test]
    fn search_improves_cost() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let r = backtracking_search(&g, &est, &quick_cfg());
        assert!(r.best_cost_ms < r.initial_cost_ms, "no improvement: {} -> {}", r.initial_cost_ms, r.best_cost_ms);
        assert!(r.best.validate().is_ok());
        assert!(r.evals > 10);
        assert!(r.peak_arena_bytes > 0);
    }

    #[test]
    fn best_graph_preserves_gradient_bytes() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let r = backtracking_search(&g, &est, &quick_cfg());
        assert!((r.best.total_gradient_bytes() - g.total_gradient_bytes()).abs() < 1e-6);
    }

    #[test]
    fn deterministic_for_seed() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let a = backtracking_search(&g, &est, &quick_cfg());
        let b = backtracking_search(&g, &est, &quick_cfg());
        assert_eq!(a.best_cost_ms, b.best_cost_ms);
        assert_eq!(a.evals, b.evals);
    }

    #[test]
    fn delta_arena_matches_eager_clones() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let delta = backtracking_search(&g, &est, &quick_cfg());
        let eager_cfg = SearchConfig { delta_candidates: false, ..quick_cfg() };
        let eager = backtracking_search(&g, &est, &eager_cfg);
        assert_eq!(delta.best_cost_ms, eager.best_cost_ms);
        assert_eq!(delta.evals, eager.evals);
        assert_eq!(delta.steps, eager.steps);
        assert_eq!(delta.best.fingerprint(), eager.best.fingerprint());
        // Memory accounting is live in both modes (the big-workload
        // delta-vs-eager comparison lives in the perf record, where queue
        // depth makes the gap unambiguous).
        assert!(delta.peak_arena_bytes > 0 && eager.peak_arena_bytes > 0);
        // Regression guard for eager-slot reclamation: with consumed slots
        // reused, peak accounting is bounded by queue capacity times a
        // (generous) per-candidate size — not by total candidates ever
        // enqueued across the run.
        let per_candidate = 8 * g.approx_bytes();
        assert!(
            eager.peak_arena_bytes <= (eager_cfg.max_queue + 2) * per_candidate,
            "eager arena accounting unbounded: {} bytes",
            eager.peak_arena_bytes
        );
    }

    #[test]
    fn parallel_eval_matches_serial() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let serial_cfg = SearchConfig { eval_threads: 1, ..quick_cfg() };
        // parallel_min_nodes: 0 forces the chunked worker path even on
        // this small test workload.
        let par_cfg = SearchConfig { eval_threads: 3, parallel_min_nodes: 0, ..quick_cfg() };
        let a = backtracking_search(&g, &est, &serial_cfg);
        let b = backtracking_search(&g, &est, &par_cfg);
        assert_eq!(a.best_cost_ms, b.best_cost_ms);
        assert_eq!(a.evals, b.evals);
        assert_eq!(a.best.fingerprint(), b.best.fingerprint());
    }

    #[test]
    fn legacy_engine_config_still_works() {
        // The "before" A/B configuration used by the perf record.
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let cfg = SearchConfig {
            eval_threads: 1,
            delta_candidates: false,
            reuse_workspaces: false,
            incremental_candidates: false,
            cost_table: false,
            delta_sim: false,
            ..quick_cfg()
        };
        let r = backtracking_search(&g, &est, &cfg);
        assert!(r.best_cost_ms <= r.initial_cost_ms);
        assert!(r.best.validate().is_ok());
        assert_eq!(r.resims, 0);
    }

    #[test]
    fn delta_sim_and_cost_table_toggles_do_not_change_result() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let delta = backtracking_search(&g, &est, &quick_cfg()); // delta_sim + cost_table on
        let table_only =
            backtracking_search(&g, &est, &SearchConfig { delta_sim: false, ..quick_cfg() });
        let dyn_full = backtracking_search(
            &g,
            &est,
            &SearchConfig { delta_sim: false, cost_table: false, ..quick_cfg() },
        );
        for (name, r) in [("table_only", &table_only), ("dyn_full", &dyn_full)] {
            assert_eq!(delta.best_cost_ms, r.best_cost_ms, "{name}");
            assert_eq!(delta.evals, r.evals, "{name}");
            assert_eq!(delta.steps, r.steps, "{name}");
            assert_eq!(delta.best.fingerprint(), r.best.fingerprint(), "{name}");
        }
        assert!(delta.resims > 0, "delta engine records parent re-sims");
        assert_eq!(table_only.resims, 0);
        assert_eq!(dyn_full.resims, 0);
    }

    #[test]
    fn delta_sim_checkpoint_cadence_never_changes_result() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let auto = backtracking_search(&g, &est, &quick_cfg());
        for every in [1usize, 7, 10_000] {
            let r = backtracking_search(
                &g,
                &est,
                &SearchConfig { ckpt_every: every, ..quick_cfg() },
            );
            assert_eq!(auto.best_cost_ms, r.best_cost_ms, "every={every}");
            assert_eq!(auto.evals, r.evals, "every={every}");
            assert_eq!(auto.best.fingerprint(), r.best.fingerprint(), "every={every}");
        }
    }

    #[test]
    fn eager_arena_reclaims_consumed_slots() {
        let g = workload();
        let mut arena = Arena::new(g.clone());
        let baseline_live = arena.live_bytes;
        let mut idx = arena.push_graph(g.clone(), Vec::new());
        let peak_two_resident = arena.peak_bytes;
        // A long eager run consumes and re-enqueues candidates constantly;
        // consumed slots must be reused, not left as dead `Taken` entries.
        for _ in 0..200 {
            let taken = arena.take_graph(idx);
            idx = arena.push_graph(taken, Vec::new());
        }
        assert_eq!(arena.entries.len(), 2, "consumed slots were not reused");
        assert_eq!(arena.free.len(), 0);
        // Accounting regression: peak never exceeds two resident graphs'
        // worth, and taking returns live_bytes to the root baseline (plus
        // the one extra slot the arena legitimately still owns).
        assert_eq!(arena.peak_bytes, peak_two_resident);
        let _ = arena.take_graph(idx);
        assert_eq!(arena.live_bytes, baseline_live + SLOT_BYTES);
    }

    #[test]
    fn track_best_path_toggle_never_changes_results_and_replays_to_best() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let off = backtracking_search(&g, &est, &quick_cfg());
        let tracked_cfg = SearchConfig { track_best_path: true, ..quick_cfg() };
        let on = backtracking_search(&g, &est, &tracked_cfg);
        assert_eq!(off.best_cost_ms, on.best_cost_ms);
        assert_eq!(off.evals, on.evals);
        assert_eq!(off.steps, on.steps);
        assert_eq!(off.best.fingerprint(), on.best.fingerprint());
        assert!(off.best_path.is_empty(), "path tracked while toggle off");
        // The recorded path, replayed on the input, reproduces `best`.
        let mut replayed = g.clone();
        for m in &on.best_path {
            m.replay(&mut replayed).expect("best_path replay failed");
        }
        assert_eq!(replayed.fingerprint(), on.best.fingerprint());
        assert!(!on.best_path.is_empty(), "search improved but path empty");
    }

    #[test]
    fn seeded_search_cost_at_most_seed_cost_and_counts_saved_steps() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let cfg = SearchConfig { track_best_path: true, ..quick_cfg() };
        let cold = backtracking_search(&g, &est, &cfg);
        assert!(cold.best_cost_ms < cold.initial_cost_ms);
        // Warm-start from the cold run's own winning plan: the seed
        // candidate replays exactly, so the warm best can never be worse
        // than the cached plan's cost.
        let seeds = vec![cold.best_path.clone()];
        let warm = backtracking_search_seeded(&g, &est, &cfg, &seeds);
        assert!(
            warm.best_cost_ms <= cold.best_cost_ms + 1e-9,
            "warm {} > cached {}",
            warm.best_cost_ms,
            cold.best_cost_ms
        );
        assert_eq!(warm.warm_hits, 1);
        assert_eq!(warm.steps_saved, cold.best_path.len() as u64);
        assert!(warm.best.validate().is_ok());
        // Determinism of the seeded run.
        let warm2 = backtracking_search_seeded(&g, &est, &cfg, &seeds);
        assert_eq!(warm.best_cost_ms, warm2.best_cost_ms);
        assert_eq!(warm.evals, warm2.evals);
    }

    #[test]
    fn empty_seed_list_is_exactly_cold_search() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let a = backtracking_search(&g, &est, &quick_cfg());
        let b = backtracking_search_seeded(&g, &est, &quick_cfg(), &[]);
        assert_eq!(a.best_cost_ms, b.best_cost_ms);
        assert_eq!(a.evals, b.evals);
        assert_eq!(a.warm_hits, 0);
        assert_eq!(a.steps_saved, 0);
        // A seed that replays nothing (empty mutation list) is skipped.
        let c2 = backtracking_search_seeded(&g, &est, &quick_cfg(), &[Vec::new()]);
        assert_eq!(c2.best_cost_ms, a.best_cost_ms);
        assert_eq!(c2.warm_hits, 0);
    }

    #[test]
    fn empty_method_set_is_identity() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let cfg = SearchConfig { methods: MethodSet::none(), ..quick_cfg() };
        let r = backtracking_search(&g, &est, &cfg);
        assert_eq!(r.best_cost_ms, r.initial_cost_ms);
        assert_eq!(r.best.fingerprint(), g.fingerprint());
    }

    #[test]
    fn more_methods_never_hurt() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let only_nondup = SearchConfig {
            methods: MethodSet { nondup_fusion: true, ..MethodSet::none() },
            ..quick_cfg()
        };
        let all = quick_cfg();
        let r1 = backtracking_search(&g, &est, &only_nondup);
        let r2 = backtracking_search(&g, &est, &all);
        // With the same budget the richer space should do at least roughly
        // as well (allow small stochastic slack).
        assert!(r2.best_cost_ms <= r1.best_cost_ms * 1.10, "all={} nondup={}", r2.best_cost_ms, r1.best_cost_ms);
    }

    /// Communication-dominated cost model: every compute op is cheap and
    /// uniform, the channel is the bottleneck. Under it the only way to
    /// shave the tail is to start dependent compute before the collective
    /// fully lands — exactly what the chunking method buys.
    struct CommBound;
    impl CostSource for CommBound {
        fn compute_time_ms(&self, _n: &crate::graph::Node) -> f64 {
            0.5
        }
        fn comm_time_ms(&self, bytes: f64) -> f64 {
            1.0 + bytes * 1e-3
        }
    }

    #[test]
    fn chunking_method_discovers_overlap() {
        let g = workload();
        let cfg = SearchConfig {
            methods: MethodSet { chunking: true, ..MethodSet::none() },
            ..quick_cfg()
        };
        let r = backtracking_search(&g, &CommBound, &cfg);
        // With chunking as the *only* move, any improvement is overlap the
        // chunk schedule created: the optimizer updates start on their
        // first landed chunk instead of waiting out the whole collective.
        assert!(
            r.best_cost_ms < r.initial_cost_ms,
            "chunking found no overlap win: {} -> {}",
            r.initial_cost_ms,
            r.best_cost_ms
        );
        assert!(r.best.has_chunking(), "winning plan carries no chunk schedule");
        assert!(r.best.validate().is_ok());
        assert!((r.best.total_gradient_bytes() - g.total_gradient_bytes()).abs() < 1e-6);
        // Deterministic per seed, like every other method.
        let r2 = backtracking_search(&g, &CommBound, &cfg);
        assert_eq!(r.best_cost_ms, r2.best_cost_ms);
        assert_eq!(r.evals, r2.evals);
        assert_eq!(r.best.fingerprint(), r2.best.fingerprint());
    }

    #[test]
    fn chunking_joins_fusion_without_hurting() {
        let g = workload();
        let base = backtracking_search(&g, &CommBound, &quick_cfg());
        let joint_cfg =
            SearchConfig { methods: MethodSet::all_with_chunking(), ..quick_cfg() };
        let joint = backtracking_search(&g, &CommBound, &joint_cfg);
        // Same budget, richer vocabulary: at least roughly as good (same
        // stochastic slack as `more_methods_never_hurt`) — and on this
        // comm-bound workload the overlap schedule should genuinely win.
        assert!(
            joint.best_cost_ms <= base.best_cost_ms * 1.10,
            "joint={} fusion-only={}",
            joint.best_cost_ms,
            base.best_cost_ms
        );
        assert!(joint.best.validate().is_ok());
    }

    #[test]
    fn sharding_method_discovers_zero_style_win() {
        let g = workload();
        let cfg = SearchConfig {
            methods: MethodSet { sharding: true, ..MethodSet::none() },
            ..quick_cfg()
        };
        let r = backtracking_search(&g, &CommBound, &cfg);
        // With sharding as the *only* move, any improvement comes from the
        // reduce-scatter/all-gather split: optimizer updates shrink to the
        // local shard and the all-gathers hide behind the next iteration's
        // forward window.
        assert!(
            r.best_cost_ms < r.initial_cost_ms,
            "sharding found no win: {} -> {}",
            r.initial_cost_ms,
            r.best_cost_ms
        );
        assert!(r.best.has_sharding(), "winning plan carries no shard spec");
        assert!(r.best.validate().is_ok());
        assert!((r.best.total_gradient_bytes() - g.total_gradient_bytes()).abs() < 1e-6);
        // Deterministic per seed, like every other method.
        let r2 = backtracking_search(&g, &CommBound, &cfg);
        assert_eq!(r.best_cost_ms, r2.best_cost_ms);
        assert_eq!(r.evals, r2.evals);
        assert_eq!(r.best.fingerprint(), r2.best.fingerprint());
    }

    #[test]
    fn sharding_joins_fusion_without_hurting() {
        let g = workload();
        let base = backtracking_search(&g, &CommBound, &quick_cfg());
        let joint_cfg =
            SearchConfig { methods: MethodSet::all_with_sharding(), ..quick_cfg() };
        let joint = backtracking_search(&g, &CommBound, &joint_cfg);
        // Same budget, richer vocabulary: at least roughly as good (same
        // stochastic slack as `more_methods_never_hurt`).
        assert!(
            joint.best_cost_ms <= base.best_cost_ms * 1.10,
            "joint={} fusion-only={}",
            joint.best_cost_ms,
            base.best_cost_ms
        );
        assert!(joint.best.validate().is_ok());
    }

    #[test]
    fn sharded_best_path_replays_to_best() {
        let g = workload();
        let cfg = SearchConfig {
            methods: MethodSet::all_with_sharding(),
            track_best_path: true,
            ..quick_cfg()
        };
        let r = backtracking_search(&g, &CommBound, &cfg);
        let mut replayed = g.clone();
        for m in &r.best_path {
            m.replay(&mut replayed).expect("best_path replay failed");
        }
        assert_eq!(replayed.fingerprint(), r.best.fingerprint());
    }

    #[test]
    fn chunked_best_path_replays_to_best() {
        let g = workload();
        let cfg = SearchConfig {
            methods: MethodSet::all_with_chunking(),
            track_best_path: true,
            ..quick_cfg()
        };
        let r = backtracking_search(&g, &CommBound, &cfg);
        let mut replayed = g.clone();
        for m in &r.best_path {
            m.replay(&mut replayed).expect("best_path replay failed");
        }
        assert_eq!(replayed.fingerprint(), r.best.fingerprint());
    }

    #[test]
    fn trace_toggle_is_pure_observation() {
        use crate::util::trace::{MemSink, PanicSink};
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let off = backtracking_search(&g, &est, &quick_cfg());
        let mut sink = MemSink::default();
        let on_cfg = SearchConfig { trace: true, ..quick_cfg() };
        let on = backtracking_search_traced(&g, &est, &on_cfg, &[], &mut sink);
        assert_eq!(off.best_cost_ms, on.best_cost_ms);
        assert_eq!(off.evals, on.evals);
        assert_eq!(off.steps, on.steps);
        assert_eq!(off.best.fingerprint(), on.best.fingerprint());
        // The final instant reports exactly the returned best cost, and
        // there is one step span per dequeue.
        let last = sink.events.last().unwrap();
        assert_eq!(last.name, "final");
        let best_ms = last.args.iter().find(|(k, _)| *k == "best_ms").unwrap().1;
        assert_eq!(best_ms, on.best_cost_ms);
        let step_spans = sink.events.iter().filter(|e| e.cat == "search-step").count();
        assert_eq!(step_spans as u64, on.steps);
        // With the toggle off the sink is never touched.
        let _ = backtracking_search_traced(&g, &est, &quick_cfg(), &[], &mut PanicSink);
    }

    #[test]
    fn respects_time_budget() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let cfg = SearchConfig { max_seconds: 0.05, unchanged_limit: 1_000_000, ..quick_cfg() };
        let start = std::time::Instant::now();
        let _ = backtracking_search(&g, &est, &cfg);
        assert!(start.elapsed().as_secs_f64() < 5.0);
    }
}
