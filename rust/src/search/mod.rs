//! Backtracking strategy search — Alg. 1 of the paper.
//!
//! A priority queue of candidate HLO modules (ordered by simulated cost)
//! drives exploration. Each step dequeues the cheapest candidate and, for
//! each enabled optimization method, applies it a random number of times
//! (`n ∈ [0, β]`, the paper's `RandomApply`), evaluates the mutated module
//! with the simulator, tracks the best module found, and re-enqueues
//! candidates whose cost is within `α ×` the best (pruning). The search
//! stops when the queue empties or the best module hasn't improved for
//! `unchanged_limit` candidate evaluations (1000 in the paper).
//!
//! The three optimization methods (paper §4.5) are:
//! 1. non-duplicate op fusion of a random (pred, succ) pair,
//! 2. duplicate op fusion of a random (pred, succ) pair,
//! 3. fusion of a random AllReduce with a random neighbour AllReduce.
//!
//! Method subsets are configurable to reproduce the Fig. 10 ablation.

pub mod anneal;

use crate::fusion::{self, FusionKind};
use crate::graph::TrainingGraph;
use crate::sim::{simulate, CostSource, OrderedF64, SimOptions};
use crate::util::rng::Rng;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashSet};
use std::time::{Duration, Instant};

/// Which optimization methods the search may use (Fig. 10 ablation knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MethodSet {
    pub nondup_fusion: bool,
    pub dup_fusion: bool,
    pub ar_fusion: bool,
}

impl MethodSet {
    pub fn all() -> MethodSet {
        MethodSet { nondup_fusion: true, dup_fusion: true, ar_fusion: true }
    }

    pub fn none() -> MethodSet {
        MethodSet { nondup_fusion: false, dup_fusion: false, ar_fusion: false }
    }

    fn enabled(&self) -> Vec<Method> {
        let mut v = Vec::new();
        if self.nondup_fusion {
            v.push(Method::NonDupFusion);
        }
        if self.dup_fusion {
            v.push(Method::DupFusion);
        }
        if self.ar_fusion {
            v.push(Method::ArFusion);
        }
        v
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Method {
    NonDupFusion,
    DupFusion,
    ArFusion,
}

/// Search hyper-parameters (paper defaults: α = 1.05, β = 10,
/// unchanged limit 1000).
#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub alpha: f64,
    pub beta: usize,
    pub unchanged_limit: usize,
    /// Cap on the priority queue (memory guard; the paper's queue is
    /// unbounded but our candidates are full graph clones).
    pub max_queue: usize,
    /// Hard wall-clock budget; 0 = unlimited.
    pub max_seconds: f64,
    pub methods: MethodSet,
    pub sim: SimOptions,
    pub seed: u64,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            alpha: 1.05,
            beta: 10,
            unchanged_limit: 1000,
            max_queue: 256,
            max_seconds: 0.0,
            methods: MethodSet::all(),
            sim: SimOptions::default(),
            seed: 0xD15C0,
        }
    }
}

/// Outcome of a search run.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub best: TrainingGraph,
    pub best_cost_ms: f64,
    pub initial_cost_ms: f64,
    /// Queue dequeues performed.
    pub steps: u64,
    /// Simulator evaluations performed.
    pub evals: u64,
    pub elapsed: Duration,
}

impl SearchResult {
    pub fn speedup(&self) -> f64 {
        if self.best_cost_ms == 0.0 {
            1.0
        } else {
            self.initial_cost_ms / self.best_cost_ms
        }
    }
}

/// Apply method `m` up to `n` times with random operands. Returns true if
/// the graph changed. Invalid applications (paper's validity check) are
/// skipped, with a few retries each.
fn random_apply(g: &mut TrainingGraph, m: Method, n: usize, rng: &mut Rng) -> bool {
    let mut changed = false;
    for _ in 0..n {
        let applied = match m {
            Method::NonDupFusion | Method::DupFusion => {
                let kind = if m == Method::NonDupFusion {
                    FusionKind::NonDuplicate
                } else {
                    FusionKind::Duplicate
                };
                let cands = fusion::op_fusion_candidates(g);
                let mut ok = false;
                for _ in 0..4 {
                    let Some(&(p, s)) = rng.choose(&cands) else { break };
                    if fusion::fuse_ops(g, p, s, kind).is_ok() {
                        ok = true;
                        break;
                    }
                }
                ok
            }
            Method::ArFusion => {
                let ars = g.allreduces();
                let mut ok = false;
                for _ in 0..4 {
                    let Some(&a) = rng.choose(&ars) else { break };
                    let neighbors = fusion::ar_neighbors(g, a);
                    let Some(&b) = rng.choose(&neighbors) else { continue };
                    if fusion::fuse_allreduce(g, a, b).is_ok() {
                        ok = true;
                        break;
                    }
                }
                ok
            }
        };
        changed |= applied;
        if !applied {
            break;
        }
    }
    changed
}

/// Run Alg. 1 on `input` using `costs` as the simulator's cost source.
pub fn backtracking_search(
    input: &TrainingGraph,
    costs: &dyn CostSource,
    cfg: &SearchConfig,
) -> SearchResult {
    let start = Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let methods = cfg.methods.enabled();

    let cost_of = |g: &TrainingGraph| {
        costs.prepare(g); // batched GNN prefetch (no-op for other sources)
        simulate(g, costs, cfg.sim).makespan_ms
    };

    let initial_cost = cost_of(input);
    let mut best = input.clone();
    let mut best_cost = initial_cost;

    // Priority queue of (cost, seq, arena index); arena holds the graphs.
    let mut arena: Vec<Option<TrainingGraph>> = vec![Some(input.clone())];
    let mut queue: BinaryHeap<Reverse<(OrderedF64, u64, usize)>> = BinaryHeap::new();
    queue.push(Reverse((OrderedF64(initial_cost), 0, 0)));
    let mut seen: HashSet<u64> = HashSet::new();
    seen.insert(input.fingerprint());

    let mut unchanged = 0usize;
    let mut steps = 0u64;
    let mut evals = 1u64;
    let mut seq = 1u64;

    while let Some(Reverse((_, _, idx))) = queue.pop() {
        if unchanged >= cfg.unchanged_limit {
            break;
        }
        if cfg.max_seconds > 0.0 && start.elapsed().as_secs_f64() > cfg.max_seconds {
            break;
        }
        let h = arena[idx].take().expect("candidate already consumed");
        steps += 1;

        for &m in &methods {
            // n = Random(0, β): 0 applications produce H' == H — skip the
            // no-op evaluation (the fingerprint set would reject it anyway).
            let n = rng.gen_range_inclusive(0, cfg.beta);
            if n == 0 {
                continue;
            }
            let mut candidate = h.clone();
            if !random_apply(&mut candidate, m, n, &mut rng) {
                continue;
            }
            let fp = candidate.fingerprint();
            if !seen.insert(fp) {
                continue;
            }
            let cost = cost_of(&candidate);
            evals += 1;
            if cost < best_cost {
                best_cost = cost;
                best = candidate.clone();
                unchanged = 0;
            } else {
                unchanged += 1;
            }
            if cost <= cfg.alpha * best_cost && queue.len() < cfg.max_queue {
                arena.push(Some(candidate));
                queue.push(Reverse((OrderedF64(cost), seq, arena.len() - 1)));
                seq += 1;
            }
        }
    }

    SearchResult {
        best,
        best_cost_ms: best_cost,
        initial_cost_ms: initial_cost,
        steps,
        evals,
        elapsed: start.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceModel;
    use crate::estimator::CostEstimator;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::{OpKind, Role};
    use crate::network::Cluster;
    use crate::profiler;

    /// A graph with obvious fusion wins: long elementwise chains producing
    /// many small gradients.
    fn workload() -> TrainingGraph {
        let mut b = GraphBuilder::new("wl", 12);
        let x = b.constant("x", &[1 << 16]);
        let mut prev = x;
        for i in 0..6 {
            let m = b.compute(OpKind::Mul, &format!("m{i}"), &[prev], &[1 << 16], Role::Forward);
            let t = b.compute(OpKind::Tanh, &format!("t{i}"), &[m], &[1 << 16], Role::Forward);
            prev = t;
        }
        // Backward chain with small per-layer gradients.
        let mut grad = prev;
        for i in 0..6 {
            let gop =
                b.compute(OpKind::Mul, &format!("bg{i}"), &[grad], &[1 << 12], Role::Backward);
            let p = b.param(&format!("w{i}"), &[1 << 12]);
            let ar = b.allreduce(&format!("ar{i}"), gop, &[1 << 12]);
            b.optimizer_update(&format!("u{i}"), &[ar, p]);
            grad = gop;
        }
        b.finish()
    }

    fn quick_cfg() -> SearchConfig {
        SearchConfig { unchanged_limit: 60, max_queue: 64, seed: 7, ..Default::default() }
    }

    #[test]
    fn search_improves_cost() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let r = backtracking_search(&g, &est, &quick_cfg());
        assert!(r.best_cost_ms < r.initial_cost_ms, "no improvement: {} -> {}", r.initial_cost_ms, r.best_cost_ms);
        assert!(r.best.validate().is_ok());
        assert!(r.evals > 10);
    }

    #[test]
    fn best_graph_preserves_gradient_bytes() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let r = backtracking_search(&g, &est, &quick_cfg());
        assert!((r.best.total_gradient_bytes() - g.total_gradient_bytes()).abs() < 1e-6);
    }

    #[test]
    fn deterministic_for_seed() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let a = backtracking_search(&g, &est, &quick_cfg());
        let b = backtracking_search(&g, &est, &quick_cfg());
        assert_eq!(a.best_cost_ms, b.best_cost_ms);
        assert_eq!(a.evals, b.evals);
    }

    #[test]
    fn empty_method_set_is_identity() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let cfg = SearchConfig { methods: MethodSet::none(), ..quick_cfg() };
        let r = backtracking_search(&g, &est, &cfg);
        assert_eq!(r.best_cost_ms, r.initial_cost_ms);
        assert_eq!(r.best.fingerprint(), g.fingerprint());
    }

    #[test]
    fn more_methods_never_hurt() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let only_nondup = SearchConfig {
            methods: MethodSet { nondup_fusion: true, dup_fusion: false, ar_fusion: false },
            ..quick_cfg()
        };
        let all = quick_cfg();
        let r1 = backtracking_search(&g, &est, &only_nondup);
        let r2 = backtracking_search(&g, &est, &all);
        // With the same budget the richer space should do at least roughly
        // as well (allow small stochastic slack).
        assert!(r2.best_cost_ms <= r1.best_cost_ms * 1.10, "all={} nondup={}", r2.best_cost_ms, r1.best_cost_ms);
    }

    #[test]
    fn respects_time_budget() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let cfg = SearchConfig { max_seconds: 0.05, unchanged_limit: 1_000_000, ..quick_cfg() };
        let start = std::time::Instant::now();
        let _ = backtracking_search(&g, &est, &cfg);
        assert!(start.elapsed().as_secs_f64() < 5.0);
    }
}
