//! Simulated-annealing search over the same joint op/tensor-fusion move
//! set — the design-choice ablation for the paper's backtracking
//! algorithm (DESIGN.md §4). Same moves, same cost model, different
//! exploration: a single walker accepts worsening moves with probability
//! `exp(−Δ/T)` under a geometric cooling schedule, instead of maintaining
//! a pruned priority queue of candidates.

use super::{MethodSet, SearchResult};
use crate::fusion::{self, FusionKind};
use crate::graph::TrainingGraph;
use crate::sim::{simulate_in, CostSource, NoRecord, SimOptions, SimWorkspace};
use crate::util::rng::Rng;
use std::time::Instant;

/// Annealing hyper-parameters.
#[derive(Debug, Clone)]
pub struct AnnealConfig {
    /// Total proposal steps.
    pub steps: usize,
    /// Initial temperature as a fraction of the initial cost.
    pub t0_frac: f64,
    /// Geometric cooling factor per step.
    pub cooling: f64,
    pub methods: MethodSet,
    pub sim: SimOptions,
    pub seed: u64,
}

impl Default for AnnealConfig {
    fn default() -> Self {
        AnnealConfig {
            steps: 2000,
            t0_frac: 0.05,
            cooling: 0.998,
            methods: MethodSet::all(),
            sim: SimOptions::default(),
            seed: 0xA11EA1,
        }
    }
}

/// Propose one random rewrite (mutates `g`); returns false if no move was
/// applicable.
fn propose(g: &mut TrainingGraph, methods: &MethodSet, rng: &mut Rng) -> bool {
    let mut options = Vec::new();
    if methods.nondup_fusion {
        options.push(0u8);
    }
    if methods.dup_fusion {
        options.push(1);
    }
    if methods.ar_fusion {
        options.push(2);
    }
    if methods.chunking {
        options.push(3);
    }
    let Some(&m) = rng.choose(&options) else { return false };
    match m {
        0 | 1 => {
            let kind = if m == 0 { FusionKind::NonDuplicate } else { FusionKind::Duplicate };
            let cands = fusion::op_fusion_candidates(g);
            for _ in 0..4 {
                if let Some(&(p, s)) = rng.choose(&cands) {
                    if fusion::fuse_ops(g, p, s, kind).is_ok() {
                        return true;
                    }
                }
            }
            false
        }
        2 => {
            let ars = g.allreduces();
            for _ in 0..4 {
                if let Some(&a) = rng.choose(&ars) {
                    let nbrs = fusion::ar_neighbors(g, a);
                    if let Some(&b) = rng.choose(&nbrs) {
                        if fusion::fuse_allreduce(g, a, b).is_ok() {
                            return true;
                        }
                    }
                }
            }
            false
        }
        _ => {
            let ars = g.allreduces();
            for _ in 0..4 {
                if let Some(&a) = rng.choose(&ars) {
                    let counts = fusion::chunk_candidates(g, a, fusion::MAX_CHUNKS);
                    if let Some(&count) = rng.choose(&counts) {
                        if fusion::set_chunks(g, a, count).is_ok() {
                            return true;
                        }
                    }
                }
            }
            false
        }
    }
}

/// Run simulated annealing from `input`. Moves are fusion-only (no
/// un-fusion), so rejected proposals restart from the current state's
/// clone — the walk monotonically coarsens but temperature decides which
/// coarsenings stick.
pub fn anneal_search(
    input: &TrainingGraph,
    costs: &dyn CostSource,
    cfg: &AnnealConfig,
) -> SearchResult {
    let start = Instant::now();
    let mut rng = Rng::new(cfg.seed);
    // Single walker → a single reused simulator workspace suffices for an
    // allocation-free eval loop (same contract as the backtracking search).
    let mut ws = SimWorkspace::new();
    let cost_of = |g: &TrainingGraph, ws: &mut SimWorkspace| {
        costs.prepare(g);
        simulate_in(g, costs, cfg.sim, &mut NoRecord, ws).makespan_ms
    };
    let initial_cost = cost_of(input, &mut ws);
    let mut current = input.clone();
    let mut current_cost = initial_cost;
    let mut best = current.clone();
    let mut best_cost = current_cost;
    let mut temp = initial_cost * cfg.t0_frac;
    let mut evals = 1u64;

    for _ in 0..cfg.steps {
        let mut cand = current.clone();
        if !propose(&mut cand, &cfg.methods, &mut rng) {
            break; // no applicable moves left
        }
        let c = cost_of(&cand, &mut ws);
        evals += 1;
        let accept = c <= current_cost
            || (temp > 0.0 && rng.gen_f64() < ((current_cost - c) / temp).exp());
        if accept {
            current = cand;
            current_cost = c;
            if c < best_cost {
                best_cost = c;
                best = current.clone();
            }
        }
        temp *= cfg.cooling;
    }

    // Annealing keeps current + best + one proposal resident, no arena.
    let peak_arena_bytes = 3 * input.approx_bytes();
    SearchResult {
        best,
        best_cost_ms: best_cost,
        initial_cost_ms: initial_cost,
        steps: cfg.steps as u64,
        evals,
        resims: 0,
        peak_arena_bytes,
        warm_hits: 0,
        steps_saved: 0,
        best_path: Vec::new(),
        elapsed: start.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceModel;
    use crate::estimator::CostEstimator;
    use crate::models::{build, ModelKind, ModelSpec};
    use crate::network::Cluster;
    use crate::profiler::profile;

    #[test]
    fn anneal_improves_and_stays_valid() {
        let g = build(&ModelSpec { kind: ModelKind::Rnnlm, batch: 16, depth_scale: 0.25 }, 12);
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profile(&g, &d, &c, 2, 3);
        let est = CostEstimator::oracle(&prof, &d);
        let cfg = AnnealConfig { steps: 400, seed: 9, ..Default::default() };
        let r = anneal_search(&g, &est, &cfg);
        assert!(r.best_cost_ms <= r.initial_cost_ms);
        assert!(r.best.validate().is_ok());
        assert!((r.best.total_gradient_bytes() - g.total_gradient_bytes()).abs() < 1e-6);
    }

    #[test]
    fn anneal_deterministic() {
        let g = build(&ModelSpec { kind: ModelKind::Rnnlm, batch: 16, depth_scale: 0.25 }, 12);
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profile(&g, &d, &c, 2, 3);
        let est = CostEstimator::oracle(&prof, &d);
        let cfg = AnnealConfig { steps: 200, seed: 4, ..Default::default() };
        let a = anneal_search(&g, &est, &cfg);
        let b = anneal_search(&g, &est, &cfg);
        assert_eq!(a.best_cost_ms, b.best_cost_ms);
    }
}
