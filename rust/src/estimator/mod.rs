//! Cost estimation — what the search is allowed to know.
//!
//! [`CostEstimator`] implements [`crate::sim::CostSource`] for candidate
//! graphs. Per the paper's information structure (§4.2–4.3):
//!
//! * **original ops** → profiled times, looked up by node id;
//! * **AllReduce instructions** → the fitted linear model `T = C·x + D`;
//! * **fused ops** → a pluggable [`FusedOpEstimator`]:
//!   - [`AnalyticalFused`] — a white-box heuristic using only
//!     profiler-visible quantities (member times, launch/bandwidth
//!     estimates): the "no GNN" ablation;
//!   - [`OracleFused`] — queries the device model directly (an upper bound
//!     on estimator quality, used in tests and ablations; a real system
//!     cannot have this);
//!   - the GNN predictor in [`crate::runtime::gnn`] — the paper's
//!     Fused Op Estimator, executed as an AOT-compiled XLA artifact.
//!
//! Predictions are memoized by the fused group's structural signature —
//! the search revisits the same fused ops constantly, and this cache is
//! the difference between O(1) and O(GNN) per `Cost(H)` call.

use crate::device::DeviceModel;
use crate::graph::{FusedGroup, Node, OpKind};
use crate::network::{Cluster, CommModel};
use crate::profiler::ProfileData;
use crate::sim::CostSource;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Default capacity of the fused-op prediction memo: ~64k entries ≈ a
/// couple of MB including map overhead. Large enough that a full search
/// on the paper workloads never evicts; small enough that a long-lived
/// service process (many searches over many models) stays bounded.
pub const DEFAULT_MEMO_CAPACITY: usize = 1 << 16;

/// Bounded signature → prediction memo with FIFO eviction. Evicting a
/// live signature only costs a recompute (predictions are deterministic),
/// so the cheap policy is correct; FIFO keeps the critical section to a
/// hash insert + a deque push.
#[derive(Debug, Default)]
struct Memo {
    map: HashMap<u64, f64>,
    order: VecDeque<u64>,
    cap: usize,
    evictions: u64,
}

impl Memo {
    fn with_capacity(cap: usize) -> Memo {
        Memo { cap: cap.max(1), ..Memo::default() }
    }

    fn get(&self, sig: u64) -> Option<f64> {
        self.map.get(&sig).copied()
    }

    fn insert(&mut self, sig: u64, t: f64) {
        if self.map.insert(sig, t).is_none() {
            self.order.push_back(sig);
            while self.map.len() > self.cap {
                if let Some(old) = self.order.pop_front() {
                    self.map.remove(&old);
                    self.evictions += 1;
                } else {
                    break;
                }
            }
        }
    }
}

/// Snapshot of the prediction-memo counters (`disco bench perf` table).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub len: usize,
    pub capacity: usize,
}

/// Strategy for predicting fused-op execution time.
pub trait FusedOpEstimator {
    /// Predict execution time (ms) of the fused kernel described by
    /// `group` with the given boundary traffic. `group` members carry
    /// profiled `time_ms`.
    fn estimate_ms(&self, group: &FusedGroup, bytes_in: f64, bytes_out: f64) -> f64;

    /// Batched prediction — backends with per-call overhead (the GNN via
    /// PJRT) override this to amortize it; the default maps the scalar
    /// path.
    fn estimate_batch(&self, items: &[(FusedGroup, f64, f64)]) -> Vec<f64> {
        items.iter().map(|(g, bi, bo)| self.estimate_ms(g, *bi, *bo)).collect()
    }

    /// Human-readable backend name (for logs / EXPERIMENTS.md).
    fn name(&self) -> &'static str;
}

/// White-box estimate from profiler-visible quantities only:
/// sum of member times, minus saved launches, minus saved intermediate
/// round-trips — but blind to spills and interaction penalties.
pub struct AnalyticalFused {
    pub launch_ms: f64,
    pub bw_bytes_per_ms: f64,
}

impl AnalyticalFused {
    pub fn from_profile(p: &ProfileData) -> AnalyticalFused {
        AnalyticalFused { launch_ms: p.launch_est_ms, bw_bytes_per_ms: p.bw_est_bytes_per_ms }
    }
}

impl FusedOpEstimator for AnalyticalFused {
    fn estimate_ms(&self, group: &FusedGroup, _bytes_in: f64, _bytes_out: f64) -> f64 {
        let sum_members: f64 = group.ops.iter().map(|o| o.time_ms).sum();
        let saved_launches = self.launch_ms * (group.len().saturating_sub(1)) as f64;
        // Each internal producer's output no longer round-trips (write+read).
        let mut internal: Vec<usize> = group.edges.iter().map(|&(p, _)| p).collect();
        internal.sort_unstable();
        internal.dedup();
        let saved_traffic: f64 =
            internal.iter().map(|&p| 2.0 * group.ops[p].bytes_out).sum::<f64>()
                / self.bw_bytes_per_ms;
        (sum_members - saved_launches - saved_traffic).max(self.launch_ms)
    }

    fn name(&self) -> &'static str {
        "analytical"
    }
}

/// Oracle backend: asks the device model (ground truth). Only for tests and
/// estimator-quality ablations.
pub struct OracleFused {
    pub device: DeviceModel,
}

impl FusedOpEstimator for OracleFused {
    fn estimate_ms(&self, group: &FusedGroup, bytes_in: f64, bytes_out: f64) -> f64 {
        self.device.fused_time_ms(group, bytes_in, bytes_out)
    }

    fn name(&self) -> &'static str {
        "oracle"
    }
}

/// The full cost model handed to the simulator. `Sync`: the search's
/// parallel candidate evaluation shares one estimator across worker
/// threads, so the prediction memo is a `Mutex` and the stats are atomics
/// (cached *values* are deterministic — only the hit/miss/eviction split
/// varies with thread interleaving). The memo is **bounded**
/// ([`DEFAULT_MEMO_CAPACITY`], FIFO eviction) so a long-lived process
/// cannot grow it without limit; with the search's table-driven
/// evaluation (`sim::CostTable`) it is consulted only at table-build
/// time, never inside the simulator event loop.
pub struct CostEstimator<'a> {
    pub profile: &'a ProfileData,
    pub comm: CommModel,
    pub fused: Box<dyn FusedOpEstimator + Sync + 'a>,
    cache: Mutex<Memo>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<'a> CostEstimator<'a> {
    pub fn new(profile: &'a ProfileData, fused: Box<dyn FusedOpEstimator + Sync + 'a>) -> Self {
        CostEstimator {
            profile,
            comm: profile.comm,
            fused,
            cache: Mutex::new(Memo::with_capacity(DEFAULT_MEMO_CAPACITY)),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Override the prediction-memo capacity (entries, min 1). Eviction
    /// never changes results — only the recompute rate.
    pub fn with_cache_capacity(self, cap: usize) -> Self {
        self.cache.lock().unwrap().cap = cap.max(1);
        self
    }

    /// Analytical-backend estimator (searcher without a GNN).
    pub fn analytical(profile: &'a ProfileData, _cluster: &Cluster) -> Self {
        Self::new(profile, Box::new(AnalyticalFused::from_profile(profile)))
    }

    /// Oracle-backend estimator (tests / upper bound).
    pub fn oracle(profile: &'a ProfileData, device: &DeviceModel) -> Self {
        Self::new(profile, Box::new(OracleFused { device: device.clone() }))
    }

    /// (cache hits, misses) — perf metric for EXPERIMENTS.md §Perf.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Full memo counters including evictions and occupancy
    /// (`disco bench perf` markdown table).
    pub fn cache_detail(&self) -> MemoStats {
        let memo = self.cache.lock().unwrap();
        MemoStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: memo.evictions,
            len: memo.map.len(),
            capacity: memo.cap,
        }
    }

    /// Batch-predict every not-yet-cached fused op of `graph` in one
    /// backend call (the search invokes this before each `Cost(H')`
    /// evaluation so GNN queries arrive in batches, not one-by-one).
    /// The lock is dropped around the backend call; a concurrent thread
    /// may redundantly predict the same signature, which is wasted work
    /// but not a correctness issue (predictions are deterministic).
    pub fn warm_cache(&self, graph: &crate::graph::TrainingGraph) {
        let mut pending: Vec<(u64, (FusedGroup, f64, f64))> = Vec::new();
        {
            let cache = self.cache.lock().unwrap();
            for n in graph.live() {
                if let Some(group) = &n.fused {
                    let sig = group.signature();
                    if cache.get(sig).is_none() && !pending.iter().any(|(s, _)| *s == sig) {
                        let mut g = group.clone();
                        self.profile.annotate_group(&mut g);
                        pending.push((sig, (g, n.bytes_in, n.bytes_out)));
                    }
                }
            }
        }
        if pending.is_empty() {
            return;
        }
        let items: Vec<(FusedGroup, f64, f64)> =
            pending.iter().map(|(_, it)| it.clone()).collect();
        let preds = self.fused.estimate_batch(&items);
        let mut cache = self.cache.lock().unwrap();
        for ((sig, _), t) in pending.into_iter().zip(preds) {
            cache.insert(sig, t);
        }
        self.misses.fetch_add(items.len() as u64, Ordering::Relaxed);
    }

    fn fused_time(&self, node: &Node) -> f64 {
        let group = node.fused.as_ref().expect("fused node without group");
        let sig = group.signature();
        if let Some(t) = self.cache.lock().unwrap().get(sig) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return t;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut g = group.clone();
        self.profile.annotate_group(&mut g);
        let t = self.fused.estimate_ms(&g, node.bytes_in, node.bytes_out);
        self.cache.lock().unwrap().insert(sig, t);
        t
    }
}

impl CostSource for CostEstimator<'_> {
    fn compute_time_ms(&self, node: &Node) -> f64 {
        match node.kind {
            OpKind::Parameter | OpKind::Constant => 0.0,
            OpKind::Fused => self.fused_time(node),
            _ => {
                let t = self.profile.time_of(node.id);
                if t > 0.0 {
                    t
                } else {
                    // Unprofiled original op (shouldn't happen in the normal
                    // pipeline): fall back to a bandwidth estimate.
                    (node.bytes_in + node.bytes_out) / self.profile.bw_est_bytes_per_ms
                        + self.profile.launch_est_ms
                }
            }
        }
    }

    fn comm_time_ms(&self, bytes: f64) -> f64 {
        self.comm.predict_ms(bytes)
    }

    fn comm_overhead_ms(&self) -> f64 {
        // The fitted model's intercept `D`: the per-collective negotiation
        // cost a chunked stream pays once, not per chunk (DESIGN.md §13).
        self.comm.d
    }

    fn prepare(&self, graph: &crate::graph::TrainingGraph) {
        self.warm_cache(graph);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::{fuse_ops, FusionKind};
    use crate::graph::builder::GraphBuilder;
    use crate::graph::{Role, TrainingGraph};
    use crate::profiler;

    fn setup() -> (TrainingGraph, DeviceModel, Cluster, ProfileData) {
        let mut b = GraphBuilder::new("e", 12);
        let x = b.constant("x", &[1 << 16]);
        let mut prev = x;
        for i in 0..6 {
            prev = b.compute(OpKind::Mul, &format!("m{i}"), &[prev], &[1 << 16], Role::Forward);
        }
        let p = b.param("w", &[1 << 16]);
        b.grad_sync("w", &[prev], p, 1e6);
        let g = b.finish();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 3, 11);
        (g, d, c, prof)
    }

    #[test]
    fn original_ops_use_profiled_times() {
        let (g, _d, c, prof) = setup();
        let est = CostEstimator::analytical(&prof, &c);
        for n in g.live() {
            if n.kind == OpKind::Mul {
                assert_eq!(est.compute_time_ms(n), prof.time_of(n.id));
            }
        }
    }

    #[test]
    fn comm_uses_fitted_model() {
        let (_g, _d, c, prof) = setup();
        let est = CostEstimator::analytical(&prof, &c);
        let bytes = 8.0 * 1024.0 * 1024.0;
        assert_eq!(est.comm_time_ms(bytes), prof.comm.predict_ms(bytes));
    }

    #[test]
    fn oracle_matches_device_exactly() {
        let (mut g, d, _c, prof) = setup();
        let f = fuse_ops(&mut g, 1, 2, FusionKind::NonDuplicate).unwrap();
        let est = CostEstimator::oracle(&prof, &d);
        let node = &g.nodes[f];
        let truth = d.node_time_ms(node);
        assert!((est.compute_time_ms(node) - truth).abs() < 1e-12);
    }

    #[test]
    fn analytical_prediction_in_ballpark() {
        let (mut g, d, c, prof) = setup();
        let mut f = fuse_ops(&mut g, 1, 2, FusionKind::NonDuplicate).unwrap();
        f = fuse_ops(&mut g, f, 3, FusionKind::NonDuplicate).unwrap();
        let est = CostEstimator::analytical(&prof, &c);
        let pred = est.compute_time_ms(&g.nodes[f]);
        let truth = d.node_time_ms(&g.nodes[f]);
        // White-box heuristic: right order of magnitude, not exact.
        assert!(pred > 0.0);
        assert!((pred - truth).abs() / truth < 0.8, "pred={pred} truth={truth}");
    }

    #[test]
    fn bounded_memo_evicts_without_changing_predictions() {
        let (mut g, d, _c, prof) = setup();
        let f1 = fuse_ops(&mut g, 1, 2, FusionKind::NonDuplicate).unwrap();
        let f2 = fuse_ops(&mut g, 3, 4, FusionKind::NonDuplicate).unwrap();
        // Capacity 1: the second distinct signature evicts the first.
        let est = CostEstimator::oracle(&prof, &d).with_cache_capacity(1);
        let a1 = est.compute_time_ms(&g.nodes[f1]);
        let a2 = est.compute_time_ms(&g.nodes[f2]);
        let s = est.cache_detail();
        assert_eq!(s.capacity, 1);
        assert_eq!(s.len, 1);
        assert_eq!(s.evictions, 1);
        // Re-querying the evicted signature recomputes the same value.
        let b1 = est.compute_time_ms(&g.nodes[f1]);
        assert_eq!(a1, b1);
        assert_eq!(a2, est.compute_time_ms(&g.nodes[f2]));
        let s2 = est.cache_detail();
        assert!(s2.evictions >= 2, "evictions={}", s2.evictions);
        assert_eq!(s2.len, 1);
    }

    #[test]
    fn cache_hits_on_repeat_queries() {
        let (mut g, d, _c, prof) = setup();
        let f = fuse_ops(&mut g, 1, 2, FusionKind::NonDuplicate).unwrap();
        let est = CostEstimator::oracle(&prof, &d);
        let a = est.compute_time_ms(&g.nodes[f]);
        let b = est.compute_time_ms(&g.nodes[f]);
        assert_eq!(a, b);
        let (hits, misses) = est.cache_stats();
        assert_eq!((hits, misses), (1, 1));
    }
}
