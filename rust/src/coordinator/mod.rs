//! Leader/worker coordinator — the paper's *Enactment Phase* (§4.1).
//!
//! The **leader** (Strategy Maker host) runs the search, then broadcasts
//! the optimized module to every **worker** (Activator); workers validate
//! it (fingerprint acknowledgement — the MPIBroadcast + NCCL-id exchange
//! of §5.1, over TCP here), execute the module for the requested number of
//! iterations, and report per-iteration timings back.
//!
//! Workers run the hi-fi execution substrate ([`crate::sim::hifi`]) with
//! per-rank seeds; the leader aggregates their reports (max across ranks =
//! the synchronous-iteration time). The same protocol drives in-process
//! worker threads (tests, single-host runs) and separate processes
//! (`disco worker` / `disco enact` over real sockets).
//!
//! Unlike the paper's idealized happy path, the protocol here is
//! fault-tolerant (DESIGN.md §12): per-phase deadlines, heartbeat-based
//! straggler detection, quorum-based graceful degradation, worker
//! reconnect with capped backoff, and a seeded fault-injection shim
//! ([`fault`]) for deterministic chaos testing.

pub mod fault;
pub mod messages;
pub mod leader;
pub mod worker;

pub use fault::{ChaosStream, Fault, FaultPlan, FaultStream, RankFaults};
pub use leader::{
    enact, rank_track, EnactConfig, EnactError, EnactReport, Phase, RankState, RankStatus,
    ENACT_PID, LEADER_TRACK,
};
pub use messages::{Msg, MsgError, MAX_FRAME_BYTES, PROTOCOL_VERSION};
pub use worker::{run_worker, run_worker_opts, Backoff, WorkerOptions};
