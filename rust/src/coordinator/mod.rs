//! Leader/worker coordinator — the paper's *Enactment Phase* (§4.1).
//!
//! The **leader** (Strategy Maker host) runs the search, then broadcasts
//! the optimized module to every **worker** (Activator); workers validate
//! it (fingerprint acknowledgement — the MPIBroadcast + NCCL-id exchange
//! of §5.1, over TCP here), execute the module for the requested number of
//! iterations, and report per-iteration timings back.
//!
//! Workers run the hi-fi execution substrate ([`crate::sim::hifi`]) with
//! per-rank seeds; the leader aggregates their reports (max across ranks =
//! the synchronous-iteration time). The same protocol drives in-process
//! worker threads (tests, single-host runs) and separate processes
//! (`disco worker` / `disco enact` over real sockets).

pub mod messages;
pub mod leader;
pub mod worker;

pub use leader::{enact, EnactConfig, EnactReport};
pub use messages::Msg;
pub use worker::run_worker;
