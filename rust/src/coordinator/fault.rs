//! Deterministic fault injection for the enactment protocol
//! (DESIGN.md §12).
//!
//! Chaos testing only earns trust if failures are *reproducible*: a
//! [`FaultPlan`] is a seeded, declarative description of which ranks
//! misbehave and how, and [`FaultStream`] enacts the byte-level faults by
//! wrapping the worker's `TcpStream`. The same plan + seed always yields
//! the same byte-for-byte failure, so every chaos test shrinks to a
//! one-line spec.
//!
//! Spec grammar (comma- or `|`-separated clauses):
//!
//! ```text
//! kill@R:K      rank R exits abruptly at iteration K (socket drop, no Error frame)
//! drop@R:N      rank R's connection drops after N bytes transferred (either direction)
//! delay@R:MS    rank R's socket ops are each delayed by MS milliseconds (straggler)
//! corrupt@R[:N] rank R's N-th outbound frame (default 1st) gets one byte flipped
//! ```
//!
//! e.g. `--chaos "kill@3:1,delay@2:80"` kills rank 3 after its first
//! iteration and makes rank 2 a straggler.

use crate::util::frame::TimedStream;
use crate::util::rng::Rng;
use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::time::Duration;

/// One injected fault, bound to a rank.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// Worker exits abruptly at iteration `iter` (0-based) of the run
    /// phase — no Error frame, no shutdown handshake, just a dead socket.
    KillAtIter { rank: usize, iter: usize },
    /// Connection is severed after `bytes` total bytes in either
    /// direction.
    DropAfterBytes { rank: usize, bytes: u64 },
    /// Every socket operation on this rank sleeps `ms` first — models a
    /// straggler / congested fabric, visible to the leader as silence.
    DelayMs { rank: usize, ms: u64 },
    /// The `nth` outbound frame (1-based) has one byte flipped — models
    /// fabric corruption the codec must catch, not crash on.
    CorruptFrame { rank: usize, nth: usize },
}

impl Fault {
    pub fn rank(&self) -> usize {
        match *self {
            Fault::KillAtIter { rank, .. }
            | Fault::DropAfterBytes { rank, .. }
            | Fault::DelayMs { rank, .. }
            | Fault::CorruptFrame { rank, .. } => rank,
        }
    }
}

/// A seeded set of faults: the complete, reproducible description of one
/// chaos scenario.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub seed: u64,
    pub faults: Vec<Fault>,
}

/// Faults projected onto a single rank, in the shape the worker and its
/// I/O shim consume.
#[derive(Debug, Clone, Default)]
pub struct RankFaults {
    pub seed: u64,
    pub kill_at_iter: Option<usize>,
    pub drop_after_bytes: Option<u64>,
    pub delay: Option<Duration>,
    pub corrupt_frame: Option<usize>,
}

impl RankFaults {
    /// True if this rank has any byte-level fault the stream shim must
    /// enact (kill-at-iter lives in the worker loop instead).
    pub fn wants_stream(&self) -> bool {
        self.drop_after_bytes.is_some() || self.delay.is_some() || self.corrupt_frame.is_some()
    }
}

impl FaultPlan {
    /// Parse the spec grammar above. Clauses separated by `,` or `|`;
    /// whitespace around clauses is ignored; empty spec = empty plan.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan, String> {
        let mut faults = Vec::new();
        for clause in spec.split(|c| c == ',' || c == '|') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (kind, args) = clause
                .split_once('@')
                .ok_or_else(|| format!("fault clause '{clause}' missing '@'"))?;
            let mut parts = args.split(':');
            let rank: usize = parts
                .next()
                .unwrap_or("")
                .parse()
                .map_err(|_| format!("fault clause '{clause}': bad rank"))?;
            let arg = parts.next();
            let num = |what: &str| -> Result<u64, String> {
                arg.ok_or_else(|| format!("fault clause '{clause}' missing :{what}"))?
                    .parse()
                    .map_err(|_| format!("fault clause '{clause}': bad {what}"))
            };
            faults.push(match kind {
                "kill" => Fault::KillAtIter { rank, iter: num("iteration")? as usize },
                "drop" => Fault::DropAfterBytes { rank, bytes: num("bytes")? },
                "delay" => Fault::DelayMs { rank, ms: num("ms")? },
                "corrupt" => Fault::CorruptFrame {
                    rank,
                    nth: arg.map(|a| a.parse().map_err(|_| format!("fault clause '{clause}': bad nth")))
                        .transpose()?
                        .unwrap_or(1),
                },
                other => return Err(format!("unknown fault kind '{other}'")),
            });
        }
        Ok(FaultPlan { seed, faults })
    }

    /// Render back to the spec grammar (inverse of [`FaultPlan::parse`]).
    pub fn to_spec(&self) -> String {
        self.faults
            .iter()
            .map(|f| match *f {
                Fault::KillAtIter { rank, iter } => format!("kill@{rank}:{iter}"),
                Fault::DropAfterBytes { rank, bytes } => format!("drop@{rank}:{bytes}"),
                Fault::DelayMs { rank, ms } => format!("delay@{rank}:{ms}"),
                Fault::CorruptFrame { rank, nth } => format!("corrupt@{rank}:{nth}"),
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Project the plan onto one rank. Later clauses win on conflict.
    pub fn for_rank(&self, rank: usize) -> RankFaults {
        let mut rf = RankFaults {
            // Per-rank stream randomness must diverge across ranks even
            // under one plan seed.
            seed: self.seed ^ (rank as u64).wrapping_mul(0x9E3779B97F4A7C15),
            ..RankFaults::default()
        };
        for f in &self.faults {
            if f.rank() != rank {
                continue;
            }
            match *f {
                Fault::KillAtIter { iter, .. } => rf.kill_at_iter = Some(iter),
                Fault::DropAfterBytes { bytes, .. } => rf.drop_after_bytes = Some(bytes),
                Fault::DelayMs { ms, .. } => rf.delay = Some(Duration::from_millis(ms)),
                Fault::CorruptFrame { nth, .. } => rf.corrupt_frame = Some(nth),
            }
        }
        rf
    }
}

/// A `TcpStream` wrapper that enacts the byte-level faults of a
/// [`RankFaults`]: connection drops after a byte budget, per-op delays,
/// and single-byte corruption of a chosen outbound frame.
#[derive(Debug)]
pub struct FaultStream {
    inner: TcpStream,
    rng: Rng,
    faults: RankFaults,
    /// Total bytes moved in either direction (drop-after-bytes budget).
    transferred: u64,
    /// Completed outbound frames, counted at flush (corrupt-frame index).
    frames_out: usize,
    /// Set once the drop fault has fired; all later ops fail fast.
    dead: bool,
}

impl FaultStream {
    pub fn new(inner: TcpStream, faults: RankFaults) -> FaultStream {
        let rng = Rng::new(faults.seed);
        FaultStream { inner, rng, faults, transferred: 0, frames_out: 0, dead: false }
    }

    fn delay(&self) {
        if let Some(d) = self.faults.delay {
            std::thread::sleep(d);
        }
    }

    /// Fire the drop fault: sever the underlying socket so the peer sees
    /// a reset, then report the reset locally too.
    fn sever(&mut self) -> io::Error {
        self.dead = true;
        let _ = self.inner.shutdown(Shutdown::Both);
        io::Error::new(io::ErrorKind::ConnectionReset, "fault: connection dropped")
    }

    fn budget_exhausted(&self) -> bool {
        matches!(self.faults.drop_after_bytes, Some(b) if self.transferred >= b)
    }
}

impl Read for FaultStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if self.dead {
            return Err(io::Error::new(io::ErrorKind::ConnectionReset, "fault: dead"));
        }
        if self.budget_exhausted() {
            return Err(self.sever());
        }
        self.delay();
        let n = self.inner.read(buf)?;
        self.transferred += n as u64;
        Ok(n)
    }
}

impl Write for FaultStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if self.dead {
            return Err(io::Error::new(io::ErrorKind::ConnectionReset, "fault: dead"));
        }
        if self.budget_exhausted() {
            return Err(self.sever());
        }
        self.delay();
        // Corrupt one byte of the frame *body* (writes longer than the
        // 4-byte length prefix) when this is the chosen outbound frame.
        if self.faults.corrupt_frame == Some(self.frames_out + 1) && buf.len() > 4 {
            let mut poisoned = buf.to_vec();
            let at = self.rng.gen_range(poisoned.len());
            poisoned[at] ^= 0x55;
            let n = self.inner.write(&poisoned)?;
            self.transferred += n as u64;
            return Ok(n);
        }
        let n = self.inner.write(buf)?;
        self.transferred += n as u64;
        Ok(n)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.frames_out += 1;
        self.inner.flush()
    }
}

impl TimedStream for FaultStream {
    fn set_rd_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        self.inner.set_read_timeout(t)
    }
    fn set_wr_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        self.inner.set_write_timeout(t)
    }
}

/// Either a plain socket or a fault-wrapped one — the worker's single
/// stream type, chosen at connect time (avoids trait objects in the
/// deadline helpers).
#[derive(Debug)]
pub enum ChaosStream {
    Plain(TcpStream),
    Fault(FaultStream),
}

impl ChaosStream {
    pub fn connect(addr: &str, faults: &RankFaults) -> io::Result<ChaosStream> {
        let s = TcpStream::connect(addr)?;
        Ok(if faults.wants_stream() {
            ChaosStream::Fault(FaultStream::new(s, faults.clone()))
        } else {
            ChaosStream::Plain(s)
        })
    }
}

impl Read for ChaosStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            ChaosStream::Plain(s) => s.read(buf),
            ChaosStream::Fault(s) => s.read(buf),
        }
    }
}

impl Write for ChaosStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            ChaosStream::Plain(s) => s.write(buf),
            ChaosStream::Fault(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> io::Result<()> {
        match self {
            ChaosStream::Plain(s) => s.flush(),
            ChaosStream::Fault(s) => s.flush(),
        }
    }
}

impl TimedStream for ChaosStream {
    fn set_rd_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        match self {
            ChaosStream::Plain(s) => s.set_read_timeout(t),
            ChaosStream::Fault(s) => s.set_rd_timeout(t),
        }
    }
    fn set_wr_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        match self {
            ChaosStream::Plain(s) => s.set_write_timeout(t),
            ChaosStream::Fault(s) => s.set_wr_timeout(t),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parse_roundtrip() {
        let plan = FaultPlan::parse("kill@3:1, drop@0:4096 | delay@2:80, corrupt@1", 42).unwrap();
        assert_eq!(plan.seed, 42);
        assert_eq!(
            plan.faults,
            vec![
                Fault::KillAtIter { rank: 3, iter: 1 },
                Fault::DropAfterBytes { rank: 0, bytes: 4096 },
                Fault::DelayMs { rank: 2, ms: 80 },
                Fault::CorruptFrame { rank: 1, nth: 1 },
            ]
        );
        // to_spec normalizes (explicit nth, comma-joined) and reparses to
        // the same plan.
        let again = FaultPlan::parse(&plan.to_spec(), 42).unwrap();
        assert_eq!(plan, again);
    }

    #[test]
    fn spec_rejects_garbage() {
        for bad in ["boom@0:1", "kill3:1", "kill@x:1", "kill@0:y", "drop@1", "delay@1"] {
            assert!(FaultPlan::parse(bad, 0).is_err(), "{bad} should not parse");
        }
        // Empty spec is a valid empty plan.
        assert!(FaultPlan::parse("", 0).unwrap().faults.is_empty());
    }

    #[test]
    fn for_rank_projects_and_seeds_diverge() {
        let plan = FaultPlan::parse("kill@1:2,delay@1:50,drop@0:100", 7).unwrap();
        let r0 = plan.for_rank(0);
        let r1 = plan.for_rank(1);
        assert_eq!(r0.drop_after_bytes, Some(100));
        assert!(r0.kill_at_iter.is_none() && r0.delay.is_none());
        assert_eq!(r1.kill_at_iter, Some(2));
        assert_eq!(r1.delay, Some(Duration::from_millis(50)));
        assert_ne!(r0.seed, r1.seed, "per-rank streams must not correlate");
        assert!(r0.wants_stream());
        assert!(!plan.for_rank(2).wants_stream());
    }

    #[test]
    fn drop_after_bytes_severs_both_sides() {
        use std::io::Read as _;
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut sink = Vec::new();
            let _ = s.read_to_end(&mut sink); // until reset/EOF
            sink.len()
        });
        let faults = RankFaults { drop_after_bytes: Some(8), ..RankFaults::default() };
        let mut fs = FaultStream::new(TcpStream::connect(addr).unwrap(), faults);
        assert_eq!(fs.write(&[0u8; 8]).unwrap(), 8);
        let err = fs.write(&[0u8; 8]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::ConnectionReset);
        let peer_got = t.join().unwrap();
        assert!(peer_got <= 8, "peer saw bytes past the drop budget");
    }

    #[test]
    fn corrupt_frame_flips_exactly_one_body_byte() {
        use std::io::Read as _;
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let body = b"0123456789abcdef";
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut got = Vec::new();
            s.read_to_end(&mut got).unwrap();
            got
        });
        let faults =
            RankFaults { corrupt_frame: Some(1), seed: 99, ..RankFaults::default() };
        let mut fs = FaultStream::new(TcpStream::connect(addr).unwrap(), faults);
        fs.write_all(&(body.len() as u32).to_be_bytes()).unwrap();
        fs.write_all(body).unwrap();
        fs.flush().unwrap();
        drop(fs);
        let got = t.join().unwrap();
        assert_eq!(&got[..4], &(body.len() as u32).to_be_bytes(), "prefix untouched");
        let diff: Vec<usize> =
            (0..body.len()).filter(|&i| got[4 + i] != body[i]).collect();
        assert_eq!(diff.len(), 1, "exactly one body byte flipped: {diff:?}");
    }
}
