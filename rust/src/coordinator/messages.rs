//! Wire protocol: length-prefixed JSON frames over TCP.
//!
//! Deliberately simple — 4-byte big-endian length, then a JSON object with
//! a `"type"` tag and a `"v"` protocol version. All fields are
//! strings/numbers so the in-tree JSON module suffices. Framing rides on
//! [`crate::util::frame`], which supplies the hard cap on the length
//! prefix (validated before allocation), deadline-bounded socket ops, and
//! typed errors (DESIGN.md §12).

use crate::util::frame::{
    read_frame_deadline, write_frame_deadline, FrameError, FrameReader, TimedStream,
};
use crate::util::json::Json;
use std::time::{Duration, Instant};

/// Protocol version carried on every frame. Bumped with the
/// fault-tolerance rework (Heartbeat/Error frames, versioning itself);
/// v1 peers are rejected with a typed error instead of silently
/// misbehaving.
pub const PROTOCOL_VERSION: u64 = 2;

/// Hard upper bound on a coordinator frame. Strategy graphs serialize to
/// well under a megabyte even for the largest workloads in-tree; 16 MiB
/// leaves headroom without letting a hostile prefix drive allocation.
pub const MAX_FRAME_BYTES: usize = 16 << 20;

/// Default per-operation deadline when callers don't supply one.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(30);

/// What went wrong decoding or transporting a message, precisely.
#[derive(Debug, thiserror::Error)]
pub enum MsgError {
    #[error(transparent)]
    Frame(#[from] FrameError),
    #[error("frame is not valid JSON: {0}")]
    Json(String),
    #[error("protocol version mismatch: peer speaks v{got}, we speak v{want}")]
    Version { got: u64, want: u64 },
    #[error("unknown message type '{0}'")]
    UnknownType(String),
    #[error("message field missing or malformed: {0}")]
    Field(&'static str),
}

/// Coordinator protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Worker → leader: join with a rank request.
    Hello { rank: usize },
    /// Leader → worker: the optimized training graph (serialized).
    Strategy { graph_json: String },
    /// Worker → leader: strategy received; fingerprint echo for
    /// consistency checking (stable FNV `service::arena_fingerprint`).
    Ack { rank: usize, fingerprint: u64 },
    /// Leader → worker: execute `iterations` training iterations.
    Run { iterations: usize, seed: u64 },
    /// Worker → leader: liveness signal between iterations, so the
    /// leader can tell a straggler from a corpse.
    Heartbeat { rank: usize, iter: usize },
    /// Worker → leader: execution report.
    Report { rank: usize, makespan_ms: f64, comp_ms: f64, comm_ms: f64 },
    /// Either direction: typed failure notice before the sender gives up
    /// on the session — lets the peer retire the rank with a reason
    /// instead of diagnosing a bare hangup.
    Error { rank: usize, reason: String },
    /// Leader → worker: shut down cleanly.
    Shutdown,
}

impl Msg {
    pub fn to_json(&self) -> Json {
        let v = ("v", Json::Num(PROTOCOL_VERSION as f64));
        match self {
            Msg::Hello { rank } => Json::obj(vec![
                v,
                ("type", Json::Str("hello".into())),
                ("rank", Json::Num(*rank as f64)),
            ]),
            Msg::Strategy { graph_json } => Json::obj(vec![
                v,
                ("type", Json::Str("strategy".into())),
                ("graph", Json::Str(graph_json.clone())),
            ]),
            Msg::Ack { rank, fingerprint } => Json::obj(vec![
                v,
                ("type", Json::Str("ack".into())),
                ("rank", Json::Num(*rank as f64)),
                // u64 doesn't fit f64 exactly; ship as hex string.
                ("fingerprint", Json::Str(format!("{fingerprint:016x}"))),
            ]),
            Msg::Run { iterations, seed } => Json::obj(vec![
                v,
                ("type", Json::Str("run".into())),
                ("iterations", Json::Num(*iterations as f64)),
                ("seed", Json::Str(format!("{seed:016x}"))),
            ]),
            Msg::Heartbeat { rank, iter } => Json::obj(vec![
                v,
                ("type", Json::Str("heartbeat".into())),
                ("rank", Json::Num(*rank as f64)),
                ("iter", Json::Num(*iter as f64)),
            ]),
            Msg::Report { rank, makespan_ms, comp_ms, comm_ms } => Json::obj(vec![
                v,
                ("type", Json::Str("report".into())),
                ("rank", Json::Num(*rank as f64)),
                ("makespan_ms", Json::Num(*makespan_ms)),
                ("comp_ms", Json::Num(*comp_ms)),
                ("comm_ms", Json::Num(*comm_ms)),
            ]),
            Msg::Error { rank, reason } => Json::obj(vec![
                v,
                ("type", Json::Str("error".into())),
                ("rank", Json::Num(*rank as f64)),
                ("reason", Json::Str(reason.clone())),
            ]),
            Msg::Shutdown => Json::obj(vec![v, ("type", Json::Str("shutdown".into()))]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Msg, MsgError> {
        // v1 frames carried no version field; treat absence as v1 so the
        // mismatch error names the actual peer version.
        let got = j.get("v").as_usize().unwrap_or(1) as u64;
        if got != PROTOCOL_VERSION {
            return Err(MsgError::Version { got, want: PROTOCOL_VERSION });
        }
        let t = j.get("type").as_str().ok_or(MsgError::Field("type"))?;
        let hex = |s: &Json, f: &'static str| -> Result<u64, MsgError> {
            u64::from_str_radix(s.as_str().ok_or(MsgError::Field(f))?, 16)
                .map_err(|_| MsgError::Field(f))
        };
        Ok(match t {
            "hello" => Msg::Hello { rank: j.get("rank").as_usize().ok_or(MsgError::Field("rank"))? },
            "strategy" => Msg::Strategy {
                graph_json: j.get("graph").as_str().ok_or(MsgError::Field("graph"))?.to_string(),
            },
            "ack" => Msg::Ack {
                rank: j.get("rank").as_usize().ok_or(MsgError::Field("rank"))?,
                fingerprint: hex(j.get("fingerprint"), "fingerprint")?,
            },
            "run" => Msg::Run {
                iterations: j.get("iterations").as_usize().ok_or(MsgError::Field("iterations"))?,
                seed: hex(j.get("seed"), "seed")?,
            },
            "heartbeat" => Msg::Heartbeat {
                rank: j.get("rank").as_usize().ok_or(MsgError::Field("rank"))?,
                iter: j.get("iter").as_usize().ok_or(MsgError::Field("iter"))?,
            },
            "report" => Msg::Report {
                rank: j.get("rank").as_usize().ok_or(MsgError::Field("rank"))?,
                makespan_ms: j.get("makespan_ms").as_f64().ok_or(MsgError::Field("makespan_ms"))?,
                comp_ms: j.get("comp_ms").as_f64().ok_or(MsgError::Field("comp_ms"))?,
                comm_ms: j.get("comm_ms").as_f64().ok_or(MsgError::Field("comm_ms"))?,
            },
            "error" => Msg::Error {
                rank: j.get("rank").as_usize().ok_or(MsgError::Field("rank"))?,
                reason: j.get("reason").as_str().ok_or(MsgError::Field("reason"))?.to_string(),
            },
            "shutdown" => Msg::Shutdown,
            other => return Err(MsgError::UnknownType(other.to_string())),
        })
    }

    /// Decode a frame body that has already been read off the wire.
    pub fn decode(body: &str) -> Result<Msg, MsgError> {
        let j = Json::parse(body).map_err(|e| MsgError::Json(e.to_string()))?;
        Msg::from_json(&j)
    }

    /// Write one length-prefixed frame, bounded by the default deadline.
    pub fn send<S: TimedStream + ?Sized>(&self, stream: &mut S) -> Result<(), MsgError> {
        self.send_deadline(stream, Instant::now() + DEFAULT_IO_TIMEOUT)
    }

    /// Write one length-prefixed frame, bounded by `deadline`.
    pub fn send_deadline<S: TimedStream + ?Sized>(
        &self,
        stream: &mut S,
        deadline: Instant,
    ) -> Result<(), MsgError> {
        let payload = self.to_json().to_string();
        write_frame_deadline(stream, payload.as_bytes(), deadline)?;
        Ok(())
    }

    /// Read one length-prefixed frame, bounded by the default deadline.
    pub fn recv<S: TimedStream + ?Sized>(stream: &mut S) -> Result<Msg, MsgError> {
        let mut reader = FrameReader::with_cap(MAX_FRAME_BYTES);
        Msg::recv_deadline(stream, &mut reader, Instant::now() + DEFAULT_IO_TIMEOUT)
    }

    /// Read one length-prefixed frame, bounded by `deadline`, resuming
    /// any partial frame held in `reader`.
    pub fn recv_deadline<S: TimedStream + ?Sized>(
        stream: &mut S,
        reader: &mut FrameReader,
        deadline: Instant,
    ) -> Result<Msg, MsgError> {
        let body = read_frame_deadline(stream, reader, deadline)?;
        Msg::decode(&body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn json_roundtrip_all_variants() {
        let msgs = vec![
            Msg::Hello { rank: 3 },
            Msg::Strategy { graph_json: "{\"x\":1}".into() },
            Msg::Ack { rank: 1, fingerprint: 0xDEADBEEF12345678 },
            Msg::Run { iterations: 10, seed: u64::MAX },
            Msg::Heartbeat { rank: 2, iter: 7 },
            Msg::Report { rank: 2, makespan_ms: 1.5, comp_ms: 1.0, comm_ms: 0.75 },
            Msg::Error { rank: 4, reason: "fingerprint mismatch".into() },
            Msg::Shutdown,
        ];
        for m in msgs {
            let j = m.to_json();
            let back = Msg::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
            assert_eq!(m, back);
        }
    }

    #[test]
    fn tcp_frame_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let m = Msg::recv(&mut s).unwrap();
            Msg::send(&m, &mut s).unwrap(); // echo
        });
        let mut c = TcpStream::connect(addr).unwrap();
        let m = Msg::Ack { rank: 7, fingerprint: 42 };
        m.send(&mut c).unwrap();
        let back = Msg::recv(&mut c).unwrap();
        assert_eq!(m, back);
        t.join().unwrap();
    }

    #[test]
    fn version_mismatch_is_typed() {
        // A v1 frame (no "v" field) and a future v3 frame both fail with
        // the precise version error, never a confusing field error.
        let v1 = Json::obj(vec![("type", Json::Str("shutdown".into()))]);
        match Msg::from_json(&v1) {
            Err(MsgError::Version { got: 1, want }) => assert_eq!(want, PROTOCOL_VERSION),
            other => panic!("expected Version error, got {other:?}"),
        }
        let v3 = Json::obj(vec![
            ("v", Json::Num(3.0)),
            ("type", Json::Str("shutdown".into())),
        ]);
        assert!(matches!(Msg::from_json(&v3), Err(MsgError::Version { got: 3, .. })));
    }

    #[test]
    fn oversized_prefix_yields_too_large_without_allocation() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            s.write_all(&(u32::MAX).to_be_bytes()).unwrap(); // 4 GiB claim
            s.write_all(b"junk").unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        match Msg::recv(&mut c) {
            Err(MsgError::Frame(FrameError::TooLarge { got, cap })) => {
                assert_eq!(got, u32::MAX as usize);
                assert_eq!(cap, MAX_FRAME_BYTES);
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
        t.join().unwrap();
    }

    #[test]
    fn garbage_json_and_bad_utf8_are_typed() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            // Frame 1: valid UTF-8, invalid JSON.
            s.write_all(&(7u32).to_be_bytes()).unwrap();
            s.write_all(b"{nope!!").unwrap();
            // Frame 2: invalid UTF-8.
            s.write_all(&(3u32).to_be_bytes()).unwrap();
            s.write_all(&[0xFF, 0xFE, 0xFD]).unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        assert!(matches!(Msg::recv(&mut c), Err(MsgError::Json(_))));
        assert!(matches!(Msg::recv(&mut c), Err(MsgError::Frame(FrameError::Utf8(_)))));
        t.join().unwrap();
    }

    #[test]
    fn mid_frame_eof_is_typed() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            s.write_all(&(100u32).to_be_bytes()).unwrap();
            s.write_all(b"truncated").unwrap();
            // drop: peer closes mid-frame
        });
        let mut c = TcpStream::connect(addr).unwrap();
        assert!(matches!(Msg::recv(&mut c), Err(MsgError::Frame(FrameError::Eof))));
        t.join().unwrap();
    }
}
