//! Wire protocol: length-prefixed JSON frames over TCP.
//!
//! Deliberately simple — 4-byte big-endian length, then a JSON object with
//! a `"type"` tag. All fields are strings/numbers so the in-tree JSON
//! module suffices.

use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::io::{Read, Write};
use std::net::TcpStream;

/// Coordinator protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Worker → leader: join with a rank request.
    Hello { rank: usize },
    /// Leader → worker: the optimized training graph (serialized).
    Strategy { graph_json: String },
    /// Worker → leader: strategy received; fingerprint echo for
    /// consistency checking.
    Ack { rank: usize, fingerprint: u64 },
    /// Leader → worker: execute `iterations` training iterations.
    Run { iterations: usize, seed: u64 },
    /// Worker → leader: execution report.
    Report { rank: usize, makespan_ms: f64, comp_ms: f64, comm_ms: f64 },
    /// Leader → worker: shut down cleanly.
    Shutdown,
}

impl Msg {
    pub fn to_json(&self) -> Json {
        match self {
            Msg::Hello { rank } => Json::obj(vec![
                ("type", Json::Str("hello".into())),
                ("rank", Json::Num(*rank as f64)),
            ]),
            Msg::Strategy { graph_json } => Json::obj(vec![
                ("type", Json::Str("strategy".into())),
                ("graph", Json::Str(graph_json.clone())),
            ]),
            Msg::Ack { rank, fingerprint } => Json::obj(vec![
                ("type", Json::Str("ack".into())),
                ("rank", Json::Num(*rank as f64)),
                // u64 doesn't fit f64 exactly; ship as hex string.
                ("fingerprint", Json::Str(format!("{fingerprint:016x}"))),
            ]),
            Msg::Run { iterations, seed } => Json::obj(vec![
                ("type", Json::Str("run".into())),
                ("iterations", Json::Num(*iterations as f64)),
                ("seed", Json::Str(format!("{seed:016x}"))),
            ]),
            Msg::Report { rank, makespan_ms, comp_ms, comm_ms } => Json::obj(vec![
                ("type", Json::Str("report".into())),
                ("rank", Json::Num(*rank as f64)),
                ("makespan_ms", Json::Num(*makespan_ms)),
                ("comp_ms", Json::Num(*comp_ms)),
                ("comm_ms", Json::Num(*comm_ms)),
            ]),
            Msg::Shutdown => Json::obj(vec![("type", Json::Str("shutdown".into()))]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Msg> {
        let t = j.get("type").as_str().ok_or_else(|| anyhow!("missing type"))?;
        let hex = |s: &Json| -> Result<u64> {
            u64::from_str_radix(s.as_str().ok_or_else(|| anyhow!("missing hex"))?, 16)
                .map_err(|e| anyhow!("bad hex: {e}"))
        };
        Ok(match t {
            "hello" => Msg::Hello {
                rank: j.get("rank").as_usize().ok_or_else(|| anyhow!("rank"))?,
            },
            "strategy" => Msg::Strategy {
                graph_json: j.get("graph").as_str().ok_or_else(|| anyhow!("graph"))?.to_string(),
            },
            "ack" => Msg::Ack {
                rank: j.get("rank").as_usize().ok_or_else(|| anyhow!("rank"))?,
                fingerprint: hex(j.get("fingerprint"))?,
            },
            "run" => Msg::Run {
                iterations: j.get("iterations").as_usize().ok_or_else(|| anyhow!("iters"))?,
                seed: hex(j.get("seed"))?,
            },
            "report" => Msg::Report {
                rank: j.get("rank").as_usize().ok_or_else(|| anyhow!("rank"))?,
                makespan_ms: j.get("makespan_ms").as_f64().ok_or_else(|| anyhow!("ms"))?,
                comp_ms: j.get("comp_ms").as_f64().ok_or_else(|| anyhow!("comp"))?,
                comm_ms: j.get("comm_ms").as_f64().ok_or_else(|| anyhow!("comm"))?,
            },
            "shutdown" => Msg::Shutdown,
            other => return Err(anyhow!("unknown message type '{other}'")),
        })
    }

    /// Write one length-prefixed frame.
    pub fn send(&self, stream: &mut TcpStream) -> Result<()> {
        let payload = self.to_json().to_string();
        let bytes = payload.as_bytes();
        let len = (bytes.len() as u32).to_be_bytes();
        stream.write_all(&len)?;
        stream.write_all(bytes)?;
        stream.flush()?;
        Ok(())
    }

    /// Read one length-prefixed frame.
    pub fn recv(stream: &mut TcpStream) -> Result<Msg> {
        let mut len = [0u8; 4];
        stream.read_exact(&mut len)?;
        let n = u32::from_be_bytes(len) as usize;
        if n > 256 * 1024 * 1024 {
            return Err(anyhow!("frame too large: {n}"));
        }
        let mut buf = vec![0u8; n];
        stream.read_exact(&mut buf)?;
        let s = String::from_utf8(buf)?;
        let j = Json::parse(&s).map_err(|e| anyhow!("frame parse: {e}"))?;
        Msg::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_all_variants() {
        let msgs = vec![
            Msg::Hello { rank: 3 },
            Msg::Strategy { graph_json: "{\"x\":1}".into() },
            Msg::Ack { rank: 1, fingerprint: 0xDEADBEEF12345678 },
            Msg::Run { iterations: 10, seed: u64::MAX },
            Msg::Report { rank: 2, makespan_ms: 1.5, comp_ms: 1.0, comm_ms: 0.75 },
            Msg::Shutdown,
        ];
        for m in msgs {
            let j = m.to_json();
            let back = Msg::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
            assert_eq!(m, back);
        }
    }

    #[test]
    fn tcp_frame_roundtrip() {
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let m = Msg::recv(&mut s).unwrap();
            Msg::send(&m, &mut s).unwrap(); // echo
        });
        let mut c = TcpStream::connect(addr).unwrap();
        let m = Msg::Ack { rank: 7, fingerprint: 42 };
        m.send(&mut c).unwrap();
        let back = Msg::recv(&mut c).unwrap();
        assert_eq!(m, back);
        t.join().unwrap();
    }
}
