//! Leader: strategy broadcast + report aggregation.

use super::messages::Msg;
use crate::device::DeviceModel;
use crate::graph::TrainingGraph;
use crate::network::Cluster;
use anyhow::{anyhow, Result};
use std::net::{TcpListener, TcpStream};

/// Enactment configuration.
#[derive(Debug, Clone)]
pub struct EnactConfig {
    /// Address to bind ("127.0.0.1:0" picks a free port).
    pub bind: String,
    /// Number of workers expected to join.
    pub world: usize,
    /// Iterations each worker must execute.
    pub iterations: usize,
    pub seed: u64,
    /// If true, spawn in-process worker threads instead of waiting for
    /// external `disco worker` processes.
    pub spawn_inproc: bool,
    pub device: DeviceModel,
    pub cluster: Cluster,
}

impl Default for EnactConfig {
    fn default() -> Self {
        EnactConfig {
            bind: "127.0.0.1:0".to_string(),
            world: 4,
            iterations: 5,
            seed: 0xC0DE,
            spawn_inproc: true,
            device: DeviceModel::gtx1080ti(),
            cluster: Cluster::cluster_a(),
        }
    }
}

/// Aggregated result of an enactment round.
#[derive(Debug, Clone)]
pub struct EnactReport {
    /// Per-rank (makespan, comp, comm) in ms.
    pub per_rank: Vec<(f64, f64, f64)>,
    /// Synchronous per-iteration time: max makespan across ranks.
    pub iteration_ms: f64,
    pub acks: usize,
}

/// Run the enactment phase: broadcast `graph` to `world` workers, have
/// them execute it, aggregate their reports.
pub fn enact(graph: &TrainingGraph, cfg: &EnactConfig) -> Result<EnactReport> {
    let listener = TcpListener::bind(&cfg.bind)?;
    let addr = listener.local_addr()?;

    // Optionally host the workers ourselves (single-machine mode).
    let mut worker_handles = Vec::new();
    if cfg.spawn_inproc {
        for rank in 0..cfg.world {
            let device = cfg.device.clone();
            let cluster = cfg.cluster.clone();
            let addr = addr.to_string();
            worker_handles.push(std::thread::spawn(move || {
                super::worker::run_worker(&addr, rank, &device, &cluster)
            }));
        }
    }

    // Accept exactly `world` workers.
    let mut conns: Vec<(usize, TcpStream)> = Vec::new();
    for _ in 0..cfg.world {
        let (mut stream, _) = listener.accept()?;
        match Msg::recv(&mut stream)? {
            Msg::Hello { rank } => conns.push((rank, stream)),
            other => return Err(anyhow!("expected Hello, got {other:?}")),
        }
    }
    conns.sort_by_key(|(r, _)| *r);
    let ranks: Vec<usize> = conns.iter().map(|(r, _)| *r).collect();
    let expect: Vec<usize> = (0..cfg.world).collect();
    if ranks != expect {
        return Err(anyhow!("worker ranks {ranks:?} != {expect:?}"));
    }

    // Broadcast the strategy; collect fingerprint acks.
    let graph_json = graph.to_json();
    let fp = graph.fingerprint();
    let mut acks = 0;
    for (_, stream) in conns.iter_mut() {
        Msg::Strategy { graph_json: graph_json.clone() }.send(stream)?;
    }
    for (rank, stream) in conns.iter_mut() {
        match Msg::recv(stream)? {
            Msg::Ack { rank: r, fingerprint } => {
                if r != *rank {
                    return Err(anyhow!("ack rank mismatch: {r} != {rank}"));
                }
                if fingerprint != fp {
                    return Err(anyhow!(
                        "worker {rank} fingerprint {fingerprint:#x} != leader {fp:#x}"
                    ));
                }
                acks += 1;
            }
            other => return Err(anyhow!("expected Ack, got {other:?}")),
        }
    }

    // Run + collect reports.
    for (rank, stream) in conns.iter_mut() {
        Msg::Run { iterations: cfg.iterations, seed: cfg.seed ^ (*rank as u64) }.send(stream)?;
    }
    let mut per_rank = vec![(0.0, 0.0, 0.0); cfg.world];
    for (_, stream) in conns.iter_mut() {
        match Msg::recv(stream)? {
            Msg::Report { rank, makespan_ms, comp_ms, comm_ms } => {
                per_rank[rank] = (makespan_ms, comp_ms, comm_ms);
            }
            other => return Err(anyhow!("expected Report, got {other:?}")),
        }
    }
    for (_, stream) in conns.iter_mut() {
        Msg::Shutdown.send(stream)?;
    }
    for h in worker_handles {
        h.join().map_err(|_| anyhow!("worker thread panicked"))??;
    }

    let iteration_ms = per_rank.iter().map(|r| r.0).fold(0.0f64, f64::max);
    Ok(EnactReport { per_rank, iteration_ms, acks })
}
