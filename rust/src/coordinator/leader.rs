//! Leader: strategy broadcast + report aggregation, fault-tolerant.
//!
//! The happy path is the paper's Enactment Phase (§4.1): every worker
//! joins, acks the module fingerprint, executes, reports. This
//! implementation additionally survives the unhappy paths (DESIGN.md
//! §12): each phase (join / ack / run) has its own wall-clock deadline, a
//! rank that dies is retired — or re-admitted on reconnect, up to
//! `max_rank_retries` — heartbeats separate stragglers from corpses, and
//! the round degrades gracefully to any `quorum` of survivors instead of
//! hanging or aborting. `enact()` never blocks past its deadlines: it
//! returns a report (possibly `degraded`) or a typed [`EnactError`], and
//! always joins its in-process worker threads before returning.

use super::fault::FaultPlan;
use super::messages::{Msg, MAX_FRAME_BYTES};
use super::worker::WorkerOptions;
use crate::device::DeviceModel;
use crate::graph::TrainingGraph;
use crate::network::Cluster;
use crate::service::arena_fingerprint;
use crate::util::frame::FrameReader;
use crate::util::trace::{Event, SharedSink, TrackId};
use std::io;
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// Enactment pid in the shared track scheme (DESIGN.md §15).
pub const ENACT_PID: u32 = 3;

/// Leader phase lane: Join/Ack/Run spans.
pub const LEADER_TRACK: TrackId = TrackId::new(ENACT_PID, 0);

/// One lane per rank: leader-observed instants (join/ack/heartbeat/
/// report/retire) interleaved with worker-side iteration spans.
pub fn rank_track(rank: usize) -> TrackId {
    TrackId::new(ENACT_PID, rank as u32 + 1)
}

/// Enactment configuration.
#[derive(Debug, Clone)]
pub struct EnactConfig {
    /// Address to bind ("127.0.0.1:0" picks a free port).
    pub bind: String,
    /// Number of workers expected to join.
    pub world: usize,
    /// Iterations each worker must execute.
    pub iterations: usize,
    pub seed: u64,
    /// If true, spawn in-process worker threads instead of waiting for
    /// external `disco worker` processes.
    pub spawn_inproc: bool,
    pub device: DeviceModel,
    pub cluster: Cluster,
    /// Minimum ranks that must complete for the round to succeed.
    /// `0` means "all of them" (no degradation tolerated).
    pub quorum: usize,
    /// Wall-clock budget per phase (join / ack / run), milliseconds.
    pub phase_timeout_ms: u64,
    /// Times a dead rank may be re-admitted on reconnect before being
    /// retired for good. `0` disables re-admission.
    pub max_rank_retries: usize,
    /// Run-phase silence (no frame from a rank) after which it is
    /// retired as a straggler. `0` disables straggler retirement (the
    /// phase deadline still bounds the wait).
    pub straggler_timeout_ms: u64,
    /// Injected faults for in-process workers (chaos testing only).
    pub fault: Option<FaultPlan>,
    /// Record a per-rank timeline of the round (DESIGN.md §15): leader
    /// phase spans, rank lifecycle instants, worker iteration spans —
    /// returned in [`EnactReport::trace_events`]. Pure observation.
    pub trace: bool,
}

impl Default for EnactConfig {
    fn default() -> Self {
        EnactConfig {
            bind: "127.0.0.1:0".to_string(),
            world: 4,
            iterations: 5,
            seed: 0xC0DE,
            spawn_inproc: true,
            device: DeviceModel::gtx1080ti(),
            cluster: Cluster::cluster_a(),
            quorum: 0,
            phase_timeout_ms: 10_000,
            max_rank_retries: 1,
            straggler_timeout_ms: 0,
            fault: None,
            trace: false,
        }
    }
}

/// Typed enactment failures — every way `enact()` can give up, bounded
/// by its deadlines.
#[derive(Debug, thiserror::Error)]
pub enum EnactError {
    #[error("enact config invalid: {0}")]
    Config(String),
    #[error("i/o: {0}")]
    Io(#[from] io::Error),
    #[error(
        "quorum lost in {phase} phase: {live} usable ranks < quorum {quorum} (failed: {failed:?})"
    )]
    QuorumLost { phase: Phase, live: usize, quorum: usize, failed: Vec<usize> },
}

/// Protocol phase, each with its own deadline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Join,
    Ack,
    Run,
}

impl std::fmt::Display for Phase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Phase::Join => "join",
            Phase::Ack => "ack",
            Phase::Run => "run",
        })
    }
}

/// Final disposition of one rank.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RankState {
    /// Completed: acked and reported.
    Ok,
    /// Never joined (or vanished and never came back) — no specific
    /// failure reason observed.
    Missing,
    /// Actively retired, with the reason (error frame, fingerprint
    /// mismatch, straggler, phase timeout, retries exhausted...).
    Retired(String),
}

/// Per-rank outcome detail in the [`EnactReport`].
#[derive(Debug, Clone)]
pub struct RankStatus {
    pub rank: usize,
    pub state: RankState,
    pub makespan_ms: f64,
    pub comp_ms: f64,
    pub comm_ms: f64,
    /// Times this rank was re-admitted after losing its connection.
    pub reconnects: usize,
    /// Heartbeats received during the run phase.
    pub heartbeats: usize,
}

/// Aggregated result of an enactment round.
#[derive(Debug, Clone)]
pub struct EnactReport {
    /// Per-rank (makespan, comp, comm) in ms, indexed by rank; failed
    /// ranks hold zeros (see `status` / `failed_ranks`).
    pub per_rank: Vec<(f64, f64, f64)>,
    /// Synchronous per-iteration time: max makespan across reporting
    /// ranks.
    pub iteration_ms: f64,
    pub acks: usize,
    /// Per-rank disposition detail.
    pub status: Vec<RankStatus>,
    /// True if any rank failed to complete (survivors still ≥ quorum).
    pub degraded: bool,
    /// Ranks that did not deliver a report.
    pub failed_ranks: Vec<usize>,
    /// In-process worker threads joined before returning — always equal
    /// to the number spawned (leak check).
    pub workers_joined: usize,
    /// Timeline of the round (empty unless [`EnactConfig::trace`]):
    /// render with `util::trace::to_chrome_json(&events, &tracks)`.
    pub trace_events: Vec<Event>,
    /// Track labels for `trace_events` (leader + one per rank).
    pub trace_tracks: Vec<(TrackId, String)>,
}

/// One live worker connection.
struct Conn {
    stream: TcpStream,
    reader: FrameReader,
    last_heard: Instant,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn { stream, reader: FrameReader::with_cap(MAX_FRAME_BYTES), last_heard: Instant::now() }
    }
}

/// What one poll of a connection produced.
enum Polled {
    Idle,
    Frame(Msg),
    /// Connection unusable (EOF, reset, oversize, undecodable frame) —
    /// the transport-level reason travels with it.
    Dead(String),
}

fn poll_conn(conn: &mut Conn) -> Polled {
    let _ = conn.stream.set_read_timeout(Some(Duration::from_millis(1)));
    match conn.reader.poll(&mut conn.stream) {
        Ok(Some(body)) => match Msg::decode(&body) {
            Ok(m) => {
                conn.last_heard = Instant::now();
                Polled::Frame(m)
            }
            Err(e) => Polled::Dead(format!("undecodable frame: {e}")),
        },
        Ok(None) => Polled::Idle,
        Err(e) => Polled::Dead(e.to_string()),
    }
}

/// Leader-side bookkeeping for one rank.
struct Slot {
    conn: Option<Conn>,
    /// Times this rank has been admitted (1 = first join).
    admissions: usize,
    acked: bool,
    reported: bool,
    report: (f64, f64, f64),
    heartbeats: usize,
    retired: Option<String>,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            conn: None,
            admissions: 0,
            acked: false,
            reported: false,
            report: (0.0, 0.0, 0.0),
            heartbeats: 0,
            retired: None,
        }
    }
    fn live(&self) -> bool {
        self.retired.is_none()
    }
    fn joined(&self) -> bool {
        self.admissions > 0
    }
}

struct Engine {
    listener: TcpListener,
    slots: Vec<Slot>,
    /// Accepted sockets whose Hello hasn't arrived yet.
    pending: Vec<Conn>,
    graph_json: String,
    fp: u64,
    iterations: usize,
    seed: u64,
    quorum: usize,
    phase_timeout: Duration,
    max_rank_retries: usize,
    straggler_timeout: Option<Duration>,
    /// Shared timeline sink (None = tracing off; never touched then).
    tr: Option<SharedSink>,
}

impl Engine {
    /// Instant on a rank's lane; no-op with tracing off.
    fn mark(&self, rank: usize, name: String, args: Vec<(&'static str, f64)>) {
        if let Some(tr) = &self.tr {
            tr.emit(Event::instant(rank_track(rank), name, tr.now_ms(), "enact").with_args(args));
        }
    }

    fn io_deadline(&self) -> Instant {
        // Frame writes to a local worker are small; bound them by a
        // short slice of the phase budget so one wedged peer can't eat
        // the whole phase.
        Instant::now() + self.phase_timeout.min(Duration::from_secs(2))
    }

    fn retire(&mut self, rank: usize, reason: impl Into<String>) {
        let reason = reason.into();
        if self.slots[rank].retired.is_none() {
            // The retire instant is the last leader-side event on this
            // rank's lane — the well-formedness tests pin that.
            self.mark(rank, format!("retire: {reason}"), Vec::new());
            self.slots[rank].retired = Some(reason);
        }
        // Close the socket so the worker learns promptly.
        self.slots[rank].conn = None;
    }

    /// Accept fresh sockets (nonblocking) into the pending set.
    fn accept_new(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let _ = stream.set_nodelay(true);
                    self.pending.push(Conn::new(stream));
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(_) => return,
            }
        }
    }

    /// Pump pending sockets for their Hello; admit or reject.
    fn drain_pending(&mut self) {
        let mut i = 0;
        while i < self.pending.len() {
            match poll_conn(&mut self.pending[i]) {
                Polled::Idle => {
                    // A socket that never says Hello is dropped at the
                    // phase-budget age: slow-join defense.
                    if self.pending[i].last_heard.elapsed() > self.phase_timeout {
                        self.pending.swap_remove(i);
                    } else {
                        i += 1;
                    }
                }
                Polled::Frame(Msg::Hello { rank }) => {
                    let conn = self.pending.swap_remove(i);
                    self.admit(rank, conn);
                }
                Polled::Frame(_) | Polled::Dead(_) => {
                    self.pending.swap_remove(i);
                }
            }
        }
    }

    /// A Hello arrived: wire the connection to its rank slot and send
    /// the strategy immediately (the phases pipeline per rank).
    fn admit(&mut self, rank: usize, mut conn: Conn) {
        let deadline = self.io_deadline();
        if rank >= self.slots.len() {
            let _ = Msg::Error { rank, reason: format!("rank {rank} out of range") }
                .send_deadline(&mut conn.stream, deadline);
            return;
        }
        if self.slots[rank].retired.is_some() {
            // Tell the comeback attempt it's over so the worker dies
            // fast instead of burning its reconnect budget.
            let _ = Msg::Error { rank, reason: "rank is retired".into() }
                .send_deadline(&mut conn.stream, deadline);
            return;
        }
        if self.slots[rank].conn.is_some() {
            // The rank redialed before we noticed its old session die —
            // workers only reconnect after abandoning a session, so the
            // newcomer is authoritative. Charge the loss against the
            // retry budget; if that exhausts it, turn the comeback away.
            self.conn_lost(rank, "superseded by reconnect");
            if self.slots[rank].retired.is_some() {
                let _ = Msg::Error { rank, reason: "rank is retired".into() }
                    .send_deadline(&mut conn.stream, deadline);
                return;
            }
        }
        self.slots[rank].admissions += 1;
        // Re-admission invalidates the previous session's ack: the
        // worker must prove it still holds the right module.
        self.slots[rank].acked = false;
        let strategy = Msg::Strategy { graph_json: self.graph_json.clone() };
        if strategy.send_deadline(&mut conn.stream, deadline).is_err() {
            self.conn_lost(rank, "strategy send failed");
            return;
        }
        self.slots[rank].conn = Some(conn);
        let n = self.slots[rank].admissions;
        self.mark(
            rank,
            if n > 1 { "readmit".to_string() } else { "join".to_string() },
            vec![("admissions", n as f64)],
        );
    }

    /// A rank's connection became unusable: re-admittable while its
    /// retry budget lasts, retired otherwise.
    fn conn_lost(&mut self, rank: usize, reason: &str) {
        self.slots[rank].conn = None;
        if self.slots[rank].retired.is_none() {
            self.mark(rank, format!("conn-lost: {reason}"), Vec::new());
        }
        let readmits_used = self.slots[rank].admissions.saturating_sub(1);
        if readmits_used >= self.max_rank_retries {
            self.retire(rank, format!("{reason} (retries exhausted)"));
        }
        // else: stays Missing; a reconnect within the phase deadline
        // re-admits it.
    }

    /// Pump every live connection once; advance per-rank protocol state.
    fn pump_slots(&mut self, phase: Phase) {
        for rank in 0..self.slots.len() {
            if self.slots[rank].retired.is_some() {
                continue;
            }
            // Straggler retirement: run-phase silence beyond the budget.
            if phase == Phase::Run && !self.slots[rank].reported {
                if let (Some(limit), Some(conn)) =
                    (self.straggler_timeout, self.slots[rank].conn.as_ref())
                {
                    if conn.last_heard.elapsed() > limit {
                        self.retire(rank, format!("straggler: silent for {limit:?}"));
                        continue;
                    }
                }
            }
            let Some(conn) = self.slots[rank].conn.as_mut() else { continue };
            match poll_conn(conn) {
                Polled::Idle => {}
                Polled::Dead(reason) => self.conn_lost(rank, &reason),
                Polled::Frame(msg) => self.on_frame(rank, msg),
            }
        }
    }

    fn on_frame(&mut self, rank: usize, msg: Msg) {
        match msg {
            Msg::Ack { rank: r, fingerprint } => {
                if r != rank {
                    self.retire(rank, format!("ack rank mismatch: said {r}"));
                    return;
                }
                if fingerprint != self.fp {
                    // Deterministic disagreement — a retry would fail the
                    // same way, so no re-admission.
                    let reason = format!(
                        "fingerprint mismatch: worker {fingerprint:#x} != leader {:#x}",
                        self.fp
                    );
                    if let Some(conn) = self.slots[rank].conn.as_mut() {
                        let _ = Msg::Error { rank, reason: reason.clone() }
                            .send_deadline(&mut conn.stream, Instant::now() + Duration::from_millis(200));
                    }
                    self.retire(rank, reason);
                    return;
                }
                self.slots[rank].acked = true;
                self.mark(rank, "ack".to_string(), Vec::new());
                // Pipelined: a verified rank starts running immediately;
                // ranks that already reported (re-ack after a post-report
                // reconnect) are not re-run.
                if !self.slots[rank].reported {
                    let deadline = self.io_deadline();
                    let run = Msg::Run { iterations: self.iterations, seed: self.seed ^ rank as u64 };
                    let conn = self.slots[rank].conn.as_mut().expect("acked conn");
                    if run.send_deadline(&mut conn.stream, deadline).is_err() {
                        self.conn_lost(rank, "run send failed");
                    }
                }
            }
            Msg::Heartbeat { rank: r, iter } => {
                if r == rank {
                    self.slots[rank].heartbeats += 1;
                    self.mark(rank, "heartbeat".to_string(), vec![("iter", iter as f64)]);
                } else {
                    self.retire(rank, format!("heartbeat rank mismatch: said {r}"));
                }
            }
            Msg::Report { rank: r, makespan_ms, comp_ms, comm_ms } => {
                if r != rank {
                    self.retire(rank, format!("report rank mismatch: said {r}"));
                    return;
                }
                self.slots[rank].report = (makespan_ms, comp_ms, comm_ms);
                self.slots[rank].reported = true;
                self.mark(rank, "report".to_string(), vec![("makespan_ms", makespan_ms)]);
            }
            Msg::Error { reason, .. } => {
                self.retire(rank, format!("worker error: {reason}"));
            }
            other => {
                self.retire(rank, format!("unexpected frame {other:?}"));
            }
        }
    }

    /// Has `rank` met the milestone that ends `phase`?
    fn milestone(&self, phase: Phase, rank: usize) -> bool {
        let s = &self.slots[rank];
        match phase {
            Phase::Join => s.joined(),
            Phase::Ack => s.acked || s.reported,
            Phase::Run => s.reported,
        }
    }

    /// Drive one phase to completion or its deadline. Returns the
    /// quorum-loss error if too few ranks remain usable.
    fn run_phase(&mut self, phase: Phase) -> Result<(), EnactError> {
        let deadline = Instant::now() + self.phase_timeout;
        loop {
            self.accept_new();
            self.drain_pending();
            self.pump_slots(phase);

            let live: Vec<usize> = (0..self.slots.len()).filter(|&r| self.slots[r].live()).collect();
            if live.iter().all(|&r| self.milestone(phase, r)) && !live.is_empty() {
                if live.len() < self.quorum {
                    return Err(self.quorum_lost(phase));
                }
                return Ok(());
            }
            if live.len() < self.quorum {
                // Nothing can restore a retired rank: fail fast, well
                // before the deadline.
                return Err(self.quorum_lost(phase));
            }
            if Instant::now() >= deadline {
                // Laggards are retired at the deadline; the round
                // continues iff a quorum met the milestone.
                for r in 0..self.slots.len() {
                    if self.slots[r].live() && !self.milestone(phase, r) {
                        self.retire(r, format!("{phase} phase deadline"));
                    }
                }
                let met = (0..self.slots.len())
                    .filter(|&r| self.slots[r].live() && self.milestone(phase, r))
                    .count();
                if met < self.quorum || met == 0 {
                    return Err(self.quorum_lost(phase));
                }
                return Ok(());
            }
            // poll_conn's 1ms read timeouts pace the loop per live
            // connection; add a floor so an empty slot table can't spin.
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    fn quorum_lost(&self, phase: Phase) -> EnactError {
        let live = (0..self.slots.len()).filter(|&r| self.slots[r].live()).count();
        let failed: Vec<usize> =
            (0..self.slots.len()).filter(|&r| !self.slots[r].live()).collect();
        EnactError::QuorumLost { phase, live, quorum: self.quorum, failed }
    }

    /// Best-effort clean shutdown of every remaining socket.
    fn shutdown_all(&mut self) {
        let deadline = Instant::now() + Duration::from_millis(500);
        for slot in &mut self.slots {
            if let Some(conn) = slot.conn.as_mut() {
                let _ = Msg::Shutdown.send_deadline(&mut conn.stream, deadline);
            }
            slot.conn = None;
        }
        for conn in &mut self.pending {
            let _ = Msg::Shutdown.send_deadline(&mut conn.stream, deadline);
        }
        self.pending.clear();
    }
}

/// Run the enactment phase: broadcast `graph` to `world` workers, have
/// them execute it, aggregate their reports. Degrades to `cfg.quorum`
/// survivors; never blocks past `phase_timeout_ms` per phase (plus a
/// bounded shutdown); always joins in-process worker threads before
/// returning — on both success and failure.
pub fn enact(graph: &TrainingGraph, cfg: &EnactConfig) -> Result<EnactReport, EnactError> {
    if cfg.world == 0 {
        return Err(EnactError::Config("world must be ≥ 1".into()));
    }
    let quorum = if cfg.quorum == 0 { cfg.world } else { cfg.quorum };
    if quorum > cfg.world {
        return Err(EnactError::Config(format!(
            "quorum {quorum} exceeds world {}",
            cfg.world
        )));
    }
    let listener = TcpListener::bind(&cfg.bind)?;
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;

    // One shared clock + buffer for the leader and its in-process
    // workers, so all lanes sit on a single timeline.
    let tr = cfg.trace.then(SharedSink::new);
    if let Some(t) = &tr {
        t.name_track(LEADER_TRACK, "leader");
        for r in 0..cfg.world {
            t.name_track(rank_track(r), &format!("rank {r}"));
        }
    }

    // Optionally host the workers ourselves (single-machine mode). Their
    // deadlines derive from the phase budget so a hung leader can't
    // strand them, and their retry budget mirrors the leader's
    // re-admission budget.
    let mut worker_handles = Vec::new();
    if cfg.spawn_inproc {
        for rank in 0..cfg.world {
            let device = cfg.device.clone();
            let cluster = cfg.cluster.clone();
            let addr = addr.to_string();
            let opts = WorkerOptions {
                io_timeout_ms: cfg.phase_timeout_ms.max(1),
                idle_timeout_ms: cfg.phase_timeout_ms.saturating_mul(2).max(1),
                retry: cfg.max_rank_retries > 0,
                max_reconnects: cfg.max_rank_retries,
                backoff_base_ms: 10,
                backoff_cap_ms: 100,
                seed: cfg.seed ^ (rank as u64).wrapping_mul(0x9E3779B97F4A7C15),
                faults: cfg.fault.as_ref().map(|p| p.for_rank(rank)),
                trace: tr.clone(),
            };
            worker_handles.push(std::thread::spawn(move || {
                super::worker::run_worker_opts(&addr, rank, &device, &cluster, &opts)
            }));
        }
    }

    let mut eng = Engine {
        listener,
        slots: (0..cfg.world).map(|_| Slot::new()).collect(),
        pending: Vec::new(),
        graph_json: graph.to_json(),
        fp: arena_fingerprint(graph),
        iterations: cfg.iterations,
        seed: cfg.seed,
        quorum,
        phase_timeout: Duration::from_millis(cfg.phase_timeout_ms.max(1)),
        max_rank_retries: cfg.max_rank_retries,
        straggler_timeout: (cfg.straggler_timeout_ms > 0)
            .then(|| Duration::from_millis(cfg.straggler_timeout_ms)),
        tr: tr.clone(),
    };

    let outcome = [Phase::Join, Phase::Ack, Phase::Run].into_iter().try_for_each(|p| {
        let t0 = eng.tr.as_ref().map_or(0.0, |t| t.now_ms());
        let res = eng.run_phase(p);
        if let Some(t) = &eng.tr {
            let mut ev = Event::span(LEADER_TRACK, p.to_string(), t0, t.now_ms(), "phase");
            if res.is_err() {
                ev = ev.with_args(vec![("quorum_lost", 1.0)]);
            }
            t.emit(ev);
        }
        res
    });

    // Teardown is unconditional: close sockets, stop listening, then
    // join every spawned thread — no leaks on either path. Workers
    // racing a reconnect hit a dead port (fast refusal) and exhaust a
    // bounded retry budget, so these joins are bounded too.
    eng.shutdown_all();
    drop(eng.listener);
    let workers_joined = worker_handles.len();
    let mut worker_results = Vec::new();
    for h in worker_handles {
        // A worker thread may legitimately return Err under injected
        // faults (e.g. killed with no retry budget); panics are still
        // surfaced as retirement-grade errors, never ignored silently.
        worker_results.push(match h.join() {
            Ok(r) => r,
            Err(_) => Err(anyhow::anyhow!("worker thread panicked")),
        });
    }
    outcome?;

    // Attribute worker-thread failures to otherwise-unexplained slots so
    // the report names a cause, not just "missing".
    if worker_results.len() == eng.slots.len() {
        for (rank, res) in worker_results.iter().enumerate() {
            if let Err(e) = res {
                let slot = &mut eng.slots[rank];
                if !slot.reported && slot.retired.is_none() {
                    slot.retired = Some(format!("worker thread: {e}"));
                }
            }
        }
    }

    let mut status = Vec::with_capacity(cfg.world);
    let mut per_rank = vec![(0.0, 0.0, 0.0); cfg.world];
    let mut failed_ranks = Vec::new();
    let mut acks = 0;
    for (rank, slot) in eng.slots.iter().enumerate() {
        if slot.acked || slot.reported {
            acks += 1;
        }
        if slot.reported {
            per_rank[rank] = slot.report;
        } else {
            failed_ranks.push(rank);
        }
        let state = if slot.reported {
            RankState::Ok
        } else if let Some(reason) = &slot.retired {
            RankState::Retired(reason.clone())
        } else {
            RankState::Missing
        };
        status.push(RankStatus {
            rank,
            state,
            makespan_ms: slot.report.0,
            comp_ms: slot.report.1,
            comm_ms: slot.report.2,
            reconnects: slot.admissions.saturating_sub(1),
            heartbeats: slot.heartbeats,
        });
    }
    let iteration_ms = per_rank.iter().map(|r| r.0).fold(0.0f64, f64::max);
    // Workers are joined, so every producer clone of the sink is done.
    let (trace_events, trace_tracks) = match &tr {
        Some(t) => {
            let m = t.take();
            (m.events, m.tracks)
        }
        None => (Vec::new(), Vec::new()),
    };
    Ok(EnactReport {
        per_rank,
        iteration_ms,
        acks,
        status,
        degraded: !failed_ranks.is_empty(),
        failed_ranks,
        workers_joined,
        trace_events,
        trace_tracks,
    })
}
