//! Worker (Activator): receive the optimized module, execute, report.

use super::messages::Msg;
use crate::device::DeviceModel;
use crate::graph::TrainingGraph;
use crate::network::Cluster;
use crate::sim::hifi::{execute_real, HifiOptions};
use anyhow::{anyhow, Result};
use std::net::TcpStream;

/// Connect to the leader at `addr` as `rank` and serve the enactment
/// protocol until Shutdown. Execution uses the hi-fi substrate with a
/// per-rank seed (DESIGN.md §2 — this is "running on the testbed").
pub fn run_worker(
    addr: &str,
    rank: usize,
    device: &DeviceModel,
    cluster: &Cluster,
) -> Result<()> {
    let mut stream = TcpStream::connect(addr)?;
    Msg::Hello { rank }.send(&mut stream)?;

    let mut graph: Option<TrainingGraph> = None;
    loop {
        match Msg::recv(&mut stream)? {
            Msg::Strategy { graph_json } => {
                let g = TrainingGraph::from_json(&graph_json)?;
                // Validate before acking: a worker must never execute a
                // malformed module.
                g.validate().map_err(|e| anyhow!("invalid strategy: {e}"))?;
                Msg::Ack { rank, fingerprint: g.fingerprint() }.send(&mut stream)?;
                graph = Some(g);
            }
            Msg::Run { iterations, seed } => {
                let g = graph.as_ref().ok_or_else(|| anyhow!("Run before Strategy"))?;
                let opts = HifiOptions { iterations, seed, ..Default::default() };
                let r = execute_real(g, device, cluster, &opts);
                Msg::Report {
                    rank,
                    makespan_ms: r.makespan_ms,
                    comp_ms: r.comp_busy_ms,
                    comm_ms: r.comm_busy_ms,
                }
                .send(&mut stream)?;
            }
            Msg::Shutdown => return Ok(()),
            other => return Err(anyhow!("worker {rank}: unexpected {other:?}")),
        }
    }
}
