//! Worker (Activator): receive the optimized module, execute, report.
//!
//! Fault-tolerant shape (DESIGN.md §12): every socket op is
//! deadline-bounded, a lost leader connection is survivable — with
//! `retry` the worker reconnects under capped exponential backoff with
//! seeded jitter — and validated `Strategy` state is cached so a
//! reconnect re-acks instantly instead of re-parsing (and a byte-identical
//! re-broadcast is recognized as the same module). Execution is split per
//! iteration so the worker can emit [`Msg::Heartbeat`] between
//! iterations, giving the leader a liveness signal that distinguishes a
//! straggler from a corpse.

use super::fault::{ChaosStream, RankFaults};
use super::messages::Msg;
use crate::device::DeviceModel;
use crate::graph::TrainingGraph;
use crate::network::Cluster;
use crate::service::arena_fingerprint;
use crate::sim::hifi::{execute_real, HifiOptions};
use crate::util::frame::{FrameError, FrameReader};
use crate::util::rng::Rng;
use crate::util::trace::{Event, SharedSink};
use anyhow::{anyhow, Result};
use std::time::{Duration, Instant};

use super::messages::MAX_FRAME_BYTES;

/// Worker-side fault-tolerance knobs.
#[derive(Debug, Clone)]
pub struct WorkerOptions {
    /// Deadline for each individual send/recv (ms).
    pub io_timeout_ms: u64,
    /// Max silence while waiting for the leader's next command (ms).
    pub idle_timeout_ms: u64,
    /// Reconnect after a transient connection loss instead of dying.
    pub retry: bool,
    /// Cap on reconnect attempts (per worker lifetime).
    pub max_reconnects: usize,
    /// Backoff base delay (ms): attempt n sleeps ~base·2ⁿ, jittered.
    pub backoff_base_ms: u64,
    /// Backoff ceiling (ms).
    pub backoff_cap_ms: u64,
    /// Seed for backoff jitter — deterministic in tests.
    pub seed: u64,
    /// Injected faults for this rank (chaos testing only).
    pub faults: Option<RankFaults>,
    /// Shared timeline sink from the leader (in-process workers only):
    /// iteration spans land on this rank's lane with the leader's clock.
    pub trace: Option<SharedSink>,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        WorkerOptions {
            io_timeout_ms: 10_000,
            idle_timeout_ms: 30_000,
            retry: false,
            max_reconnects: 3,
            backoff_base_ms: 10,
            backoff_cap_ms: 250,
            seed: 0x5EED,
            faults: None,
            trace: None,
        }
    }
}

/// Capped exponential backoff with seeded jitter. Attempt `n` sleeps a
/// uniform draw from `[d/2, d]` where `d = min(base·2ⁿ, cap)` — the
/// classic decorrelation that keeps reconnecting workers from
/// thundering-herding the leader, yet fully reproducible per seed.
#[derive(Debug)]
pub struct Backoff {
    base_ms: u64,
    cap_ms: u64,
    attempt: u32,
    rng: Rng,
}

impl Backoff {
    pub fn new(base_ms: u64, cap_ms: u64, seed: u64) -> Backoff {
        Backoff { base_ms: base_ms.max(1), cap_ms: cap_ms.max(1), attempt: 0, rng: Rng::new(seed) }
    }

    /// Delay for the next attempt (advances the attempt counter).
    pub fn next_ms(&mut self) -> u64 {
        let exp = self
            .base_ms
            .saturating_mul(1u64.checked_shl(self.attempt).unwrap_or(u64::MAX))
            .min(self.cap_ms);
        self.attempt = self.attempt.saturating_add(1);
        let half = (exp / 2).max(1);
        half + self.rng.gen_range((exp - half + 1) as usize) as u64
    }
}

/// Strategy state that survives reconnects: the raw module string, the
/// validated graph, and its stable fingerprint. A re-broadcast of the
/// identical string re-acks without re-parsing.
#[derive(Default)]
struct WorkerState {
    raw: Option<String>,
    graph: Option<TrainingGraph>,
    fp: u64,
    kill_at_iter: Option<usize>,
}

/// Why one leader session ended without a fatal error.
enum Served {
    /// Leader sent Shutdown — clean exit.
    Shutdown,
    /// Connection lost / deadline expired — transient, retryable.
    Lost(String),
}

/// Connect to the leader at `addr` as `rank` and serve the enactment
/// protocol until Shutdown. Execution uses the hi-fi substrate with a
/// per-rank seed (DESIGN.md §2 — this is "running on the testbed").
///
/// Compatibility wrapper over [`run_worker_opts`] with default options
/// (no retry).
pub fn run_worker(
    addr: &str,
    rank: usize,
    device: &DeviceModel,
    cluster: &Cluster,
) -> Result<()> {
    run_worker_opts(addr, rank, device, cluster, &WorkerOptions::default())
}

/// Full-control worker entry point.
pub fn run_worker_opts(
    addr: &str,
    rank: usize,
    device: &DeviceModel,
    cluster: &Cluster,
    opts: &WorkerOptions,
) -> Result<()> {
    let faults = opts.faults.clone().unwrap_or_default();
    let mut state = WorkerState { kill_at_iter: faults.kill_at_iter, ..WorkerState::default() };
    let mut backoff = Backoff::new(opts.backoff_base_ms, opts.backoff_cap_ms, opts.seed);
    let mut reconnects = 0usize;
    loop {
        // Scope the stream to the session so a lost connection is torn
        // down (FIN sent) *before* the backoff sleep — the leader then
        // observes the death ahead of the reconnect's Hello instead of
        // racing it.
        let served = match ChaosStream::connect(addr, &faults) {
            Ok(mut stream) => serve_once(&mut stream, rank, device, cluster, opts, &mut state),
            Err(e) => {
                if opts.retry && reconnects < opts.max_reconnects {
                    reconnects += 1;
                    std::thread::sleep(Duration::from_millis(backoff.next_ms()));
                    continue;
                }
                return Err(anyhow!("worker {rank}: connect {addr}: {e}"));
            }
        };
        match served {
            Ok(Served::Shutdown) => return Ok(()),
            Ok(Served::Lost(reason)) => {
                if opts.retry && reconnects < opts.max_reconnects {
                    reconnects += 1;
                    std::thread::sleep(Duration::from_millis(backoff.next_ms()));
                    continue;
                }
                return Err(anyhow!("worker {rank}: connection lost: {reason}"));
            }
            Err(e) => return Err(e),
        }
    }
}

/// Serve one leader session on `stream`. `Ok(Lost)` is transient (the
/// caller may reconnect); `Err` is fatal (protocol violation or invalid
/// strategy — announced to the leader with an [`Msg::Error`] frame first
/// where the socket still permits).
fn serve_once(
    stream: &mut ChaosStream,
    rank: usize,
    device: &DeviceModel,
    cluster: &Cluster,
    opts: &WorkerOptions,
    state: &mut WorkerState,
) -> Result<Served> {
    let io = Duration::from_millis(opts.io_timeout_ms.max(1));
    let idle = Duration::from_millis(opts.idle_timeout_ms.max(1));
    let mut reader = FrameReader::with_cap(MAX_FRAME_BYTES);

    if let Err(e) = Msg::Hello { rank }.send_deadline(stream, Instant::now() + io) {
        return Ok(Served::Lost(format!("hello: {e}")));
    }

    loop {
        let msg = match Msg::recv_deadline(stream, &mut reader, Instant::now() + idle) {
            Ok(m) => m,
            // Transport-level trouble is transient — the session can be
            // re-established. Decode-level trouble (bad JSON, wrong
            // version) means the leader is broken: die loudly.
            Err(super::messages::MsgError::Frame(fe)) => {
                return match fe {
                    FrameError::Utf8(_) => {
                        let reason = format!("leader sent non-UTF8 frame: {fe}");
                        let _ = Msg::Error { rank, reason: reason.clone() }
                            .send_deadline(stream, Instant::now() + io);
                        Err(anyhow!("worker {rank}: {reason}"))
                    }
                    _ => Ok(Served::Lost(fe.to_string())),
                };
            }
            Err(e) => {
                let reason = format!("undecodable frame from leader: {e}");
                let _ = Msg::Error { rank, reason: reason.clone() }
                    .send_deadline(stream, Instant::now() + io);
                return Err(anyhow!("worker {rank}: {reason}"));
            }
        };
        match msg {
            Msg::Strategy { graph_json } => {
                // Resumable state: a byte-identical module re-acks from
                // cache (the common case after a reconnect).
                if state.raw.as_deref() != Some(graph_json.as_str()) {
                    let g = match TrainingGraph::from_json(&graph_json)
                        .and_then(|g| g.validate().map(|_| g).map_err(|e| anyhow!("{e}")))
                    {
                        Ok(g) => g,
                        Err(e) => {
                            // A worker must never execute a malformed
                            // module — tell the leader why, then die.
                            let reason = format!("invalid strategy: {e}");
                            let _ = Msg::Error { rank, reason: reason.clone() }
                                .send_deadline(stream, Instant::now() + io);
                            return Err(anyhow!("worker {rank}: {reason}"));
                        }
                    };
                    state.fp = arena_fingerprint(&g);
                    state.graph = Some(g);
                    state.raw = Some(graph_json);
                }
                if let Err(e) = Msg::Ack { rank, fingerprint: state.fp }
                    .send_deadline(stream, Instant::now() + io)
                {
                    return Ok(Served::Lost(format!("ack: {e}")));
                }
            }
            Msg::Run { iterations, seed } => {
                let g = match state.graph.as_ref() {
                    Some(g) => g,
                    None => {
                        let reason = "Run before Strategy".to_string();
                        let _ = Msg::Error { rank, reason: reason.clone() }
                            .send_deadline(stream, Instant::now() + io);
                        return Err(anyhow!("worker {rank}: {reason}"));
                    }
                };
                let iters = iterations.max(1);
                let (mut mk, mut cp, mut cm) = (0.0f64, 0.0f64, 0.0f64);
                for it in 0..iters {
                    if state.kill_at_iter == Some(it) {
                        // Abrupt death: no Error frame, no handshake —
                        // the leader must cope with a bare dead socket.
                        // Fires once so a readmitted worker can finish.
                        state.kill_at_iter = None;
                        return Ok(Served::Lost(format!("fault: killed at iteration {it}")));
                    }
                    let opts1 = HifiOptions {
                        iterations: 1,
                        seed: seed.wrapping_add(it as u64),
                        ..Default::default()
                    };
                    let t0 = opts.trace.as_ref().map_or(0.0, |t| t.now_ms());
                    let r = execute_real(g, device, cluster, &opts1);
                    if let Some(tr) = &opts.trace {
                        tr.emit(
                            Event::span(
                                super::leader::rank_track(rank),
                                format!("iter {it}"),
                                t0,
                                tr.now_ms(),
                                "iter",
                            )
                            .with_args(vec![("makespan_ms", r.makespan_ms)]),
                        );
                    }
                    mk += r.makespan_ms;
                    cp += r.comp_busy_ms;
                    cm += r.comm_busy_ms;
                    if it + 1 < iters {
                        // Liveness between iterations: lets the leader
                        // tell a straggler from a corpse.
                        if let Err(e) = Msg::Heartbeat { rank, iter: it }
                            .send_deadline(stream, Instant::now() + io)
                        {
                            return Ok(Served::Lost(format!("heartbeat: {e}")));
                        }
                    }
                }
                let k = iters as f64;
                if let Err(e) = (Msg::Report {
                    rank,
                    makespan_ms: mk / k,
                    comp_ms: cp / k,
                    comm_ms: cm / k,
                })
                .send_deadline(stream, Instant::now() + io)
                {
                    return Ok(Served::Lost(format!("report: {e}")));
                }
            }
            Msg::Shutdown => return Ok(Served::Shutdown),
            Msg::Error { reason, .. } => {
                return Err(anyhow!("worker {rank}: leader error: {reason}"))
            }
            other => {
                let reason = format!("unexpected {other:?}");
                let _ = Msg::Error { rank, reason: reason.clone() }
                    .send_deadline(stream, Instant::now() + io);
                return Err(anyhow!("worker {rank}: {reason}"));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_capped_jittered_and_deterministic() {
        let seq = |seed: u64| -> Vec<u64> {
            let mut b = Backoff::new(10, 250, seed);
            (0..8).map(|_| b.next_ms()).collect()
        };
        let a = seq(42);
        let b = seq(42);
        assert_eq!(a, b, "same seed must give the same schedule");
        let c = seq(43);
        assert_ne!(a, c, "different seeds must jitter differently");
        // Every delay respects the cap and the half-to-full jitter band
        // of the capped exponential.
        for (i, &d) in a.iter().enumerate() {
            let exp = 10u64.saturating_mul(1 << i.min(60)).min(250);
            assert!(d <= exp, "attempt {i}: {d} > {exp}");
            assert!(d >= (exp / 2).max(1), "attempt {i}: {d} below jitter floor");
        }
        // The tail must sit at the cap's band, not keep growing.
        assert!(a[7] <= 250);
    }

    #[test]
    fn backoff_shift_overflow_saturates() {
        let mut b = Backoff::new(u64::MAX / 2, u64::MAX, 1);
        for _ in 0..70 {
            let _ = b.next_ms(); // must not panic on shift overflow
        }
    }
}
