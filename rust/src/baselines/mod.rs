//! Baseline fusion schemes the paper compares against (§6.1):
//!
//! * [`no_fusion`] — JAX_no_fusion: the graph as-is.
//! * [`xla_op_fusion`] — JAX_op_fusion: XLA's rule-based post-order op
//!   fusion (extensive fusion of injective producers / elementwise
//!   epilogues, no communication awareness).
//! * [`ar_threshold_fusion`] — JAX_AllReduce_fusion: XLA's AllReduce
//!   combiner — greedily merge neighbouring AllReduces (in gradient
//!   production order) up to a fixed size threshold (30 MB default).
//! * [`jax_default`] — both of the above, op fusion first (the separate,
//!   sequential passes the paper criticizes).
//! * [`pytorch_ddp`] — PyTorch DDP: no op fusion, 25 MB gradient buckets
//!   overlapping with backward.
//! * [`tvm_rule_fusion`] — TVM's pattern rules (injective/reduction/
//!   complex-out-fusible), for the Fig. 8 single-device comparison.
//! * [`ngraph_fusion`] — nGraph-style extensive elementwise-chain fusion.
//! * [`taso_like`] — greedy best-improvement local search over the op
//!   substitution (fusion) space with a cost model, standing in for TASO's
//!   backtracking graph-substitution search (Fig. 8; see DESIGN.md §10).
//!
//! All baselines are pure graph→graph functions; `Cluster`/cost knowledge
//! enters only where the original system had it.

use crate::fusion::{self, FusionKind};
use crate::graph::{NodeId, OpKind, PatternClass, TrainingGraph};
use crate::sim::{simulate, CostSource, SimOptions};
use crate::util::rng::Rng;

/// JAX_no_fusion: identity.
pub fn no_fusion(g: &TrainingGraph) -> TrainingGraph {
    g.clone()
}

/// Would XLA's heuristic fuse producer `p` into consumer `s`?
/// Injective producers fuse into anything; heavy producers accept
/// injective epilogues. Two heavy ops never fuse.
fn xla_fusible_pair(g: &TrainingGraph, p: NodeId, s: NodeId) -> bool {
    let pk = effective_class(g, p);
    let sk = effective_class(g, s);
    match (pk, sk) {
        (PatternClass::Injective, _) => true,
        (_, PatternClass::Injective) => true,
        _ => false,
    }
}

/// Pattern class of a (possibly fused) node: a fused group takes the
/// "heaviest" class of its members.
fn effective_class(g: &TrainingGraph, id: NodeId) -> PatternClass {
    let n = &g.nodes[id];
    match &n.fused {
        None => n.kind.pattern_class(),
        Some(grp) => {
            let mut cls = PatternClass::Injective;
            for o in &grp.ops {
                cls = heavier(cls, o.kind.pattern_class());
            }
            cls
        }
    }
}

fn heavier(a: PatternClass, b: PatternClass) -> PatternClass {
    use PatternClass::*;
    let rank = |c: PatternClass| match c {
        Injective => 0,
        Reduction => 1,
        ComplexOutFusible => 2,
        Opaque => 3,
    };
    if rank(a) >= rank(b) {
        a
    } else {
        b
    }
}

/// Greedy rule-driven fusion to fixpoint: walk consumers in post order
/// (reverse topological), fusing each with an eligible predecessor.
fn rule_fusion_fixpoint<F>(g: &TrainingGraph, eligible: F, max_passes: usize) -> TrainingGraph
where
    F: Fn(&TrainingGraph, NodeId, NodeId) -> bool,
{
    let mut g = g.clone();
    for _pass in 0..max_passes {
        let mut changed = false;
        let mut order = g.topo_order().expect("valid graph");
        order.reverse(); // post order: consumers before producers
        for id in order {
            if g.nodes[id].deleted {
                continue;
            }
            let k = g.nodes[id].kind;
            if !(k.is_fusible_compute() || k == OpKind::Fused) {
                continue;
            }
            let preds: Vec<NodeId> = g.nodes[id].inputs.clone();
            for p in preds {
                if g.nodes[p].deleted {
                    continue;
                }
                let pk = g.nodes[p].kind;
                if !(pk.is_fusible_compute() || pk == OpKind::Fused) {
                    continue;
                }
                if !eligible(&g, p, id) {
                    continue;
                }
                if fusion::fuse_ops(&mut g, p, id, FusionKind::NonDuplicate).is_ok() {
                    changed = true;
                    break; // this consumer is gone; move on
                }
            }
        }
        if !changed {
            break;
        }
    }
    g
}

/// JAX_op_fusion: XLA default heuristic op fusion (post order, extensive).
pub fn xla_op_fusion(g: &TrainingGraph) -> TrainingGraph {
    rule_fusion_fixpoint(g, xla_fusible_pair, 16)
}

/// XLA AllReduce combiner / Horovod-style tensor fusion: merge neighbouring
/// AllReduces in gradient production order until the fused tensor reaches
/// `threshold_bytes`.
pub fn ar_threshold_fusion(g: &TrainingGraph, threshold_bytes: f64) -> TrainingGraph {
    let mut g = g.clone();
    // Production order ≈ topological position of the AllReduce node (its
    // producers all precede it).
    let order = g.topo_order().expect("valid graph");
    let ars: Vec<NodeId> = order
        .into_iter()
        .filter(|&id| !g.nodes[id].deleted && g.nodes[id].kind == OpKind::AllReduce)
        .collect();
    let mut cur: Option<NodeId> = None;
    for ar in ars {
        if g.nodes[ar].deleted {
            continue;
        }
        match cur {
            None => cur = Some(ar),
            Some(c) => {
                if g.nodes[c].bytes_out < threshold_bytes
                    && fusion::are_ar_neighbors(&g, c, ar)
                {
                    match fusion::fuse_allreduce(&mut g, c, ar) {
                        Ok(f) => cur = Some(f),
                        Err(_) => cur = Some(ar),
                    }
                } else {
                    cur = Some(ar);
                }
            }
        }
    }
    g
}

/// XLA's default AllReduce-combiner threshold (30 MB).
pub const XLA_AR_THRESHOLD: f64 = 30.0 * 1024.0 * 1024.0;
/// PyTorch DDP's default bucket size (25 MB).
pub const DDP_BUCKET_BYTES: f64 = 25.0 * 1024.0 * 1024.0;

/// JAX_default: XLA op fusion, then the AllReduce combiner — two separate
/// passes, communication-oblivious op fusion first.
pub fn jax_default(g: &TrainingGraph) -> TrainingGraph {
    ar_threshold_fusion(&xla_op_fusion(g), XLA_AR_THRESHOLD)
}

/// PyTorch DDP: gradient bucketing only (25 MB buckets), no op fusion.
pub fn pytorch_ddp(g: &TrainingGraph) -> TrainingGraph {
    ar_threshold_fusion(g, DDP_BUCKET_BYTES)
}

/// TVM fusion rules (§7.1): injective chains fuse; reductions absorb input
/// injectives; complex-out-fusible ops absorb elementwise epilogues.
fn tvm_eligible(g: &TrainingGraph, p: NodeId, s: NodeId) -> bool {
    use PatternClass::*;
    match (effective_class(g, p), effective_class(g, s)) {
        (Injective, Injective) => true,
        (Injective, Reduction) => true,
        (ComplexOutFusible, Injective) => true,
        _ => false,
    }
}

/// TVM-style rule fusion.
pub fn tvm_rule_fusion(g: &TrainingGraph) -> TrainingGraph {
    rule_fusion_fixpoint(g, tvm_eligible, 16)
}

/// nGraph-style fusion: elementwise chains (and norm folding) only.
fn ngraph_eligible(g: &TrainingGraph, p: NodeId, s: NodeId) -> bool {
    use PatternClass::*;
    matches!(
        (effective_class(g, p), effective_class(g, s)),
        (Injective, Injective) | (Injective, Reduction)
    )
}

/// nGraph-style extensive elementwise fusion.
pub fn ngraph_fusion(g: &TrainingGraph) -> TrainingGraph {
    rule_fusion_fixpoint(g, ngraph_eligible, 16)
}

/// TASO-like cost-model-guided greedy substitution search: at each step,
/// sample fusion candidates, apply the single best cost improvement, stop
/// when no sampled candidate improves (or the step budget runs out).
pub fn taso_like(
    g: &TrainingGraph,
    costs: &dyn CostSource,
    sim: SimOptions,
    max_steps: usize,
    seed: u64,
) -> TrainingGraph {
    let mut rng = Rng::new(seed);
    let mut cur = g.clone();
    let mut cur_cost = simulate(&cur, costs, sim).makespan_ms;
    for _ in 0..max_steps {
        let cands = fusion::op_fusion_candidates(&cur);
        if cands.is_empty() {
            break;
        }
        // Sample up to 48 candidates per step to bound cost-model calls.
        let sample: Vec<(NodeId, NodeId)> = if cands.len() <= 48 {
            cands
        } else {
            (0..48).map(|_| cands[rng.gen_range(cands.len())]).collect()
        };
        let mut best: Option<(f64, TrainingGraph)> = None;
        for &(p, s) in &sample {
            for kind in [FusionKind::NonDuplicate, FusionKind::Duplicate] {
                let mut trial = cur.clone();
                if fusion::fuse_ops(&mut trial, p, s, kind).is_err() {
                    continue;
                }
                let c = simulate(&trial, costs, sim).makespan_ms;
                if c < cur_cost && best.as_ref().map(|(bc, _)| c < *bc).unwrap_or(true) {
                    best = Some((c, trial));
                }
            }
        }
        match best {
            Some((c, gnext)) => {
                cur_cost = c;
                cur = gnext;
            }
            None => break,
        }
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceModel;
    use crate::estimator::CostEstimator;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::Role;
    use crate::network::Cluster;
    use crate::profiler;

    fn cnn_ish() -> TrainingGraph {
        let mut b = GraphBuilder::new("cnn", 12);
        let x = b.constant("x", &[8, 3, 64, 64]);
        let c1 = b.conv2d("c1", &[x], 8, 3, 64, 64, 16, 3, 1, Role::Forward);
        let r1 = b.compute(OpKind::Relu, "r1", &[c1], &[8, 16, 64, 64], Role::Forward);
        let bn = b.compute(OpKind::BatchNorm, "bn", &[r1], &[8, 16, 64, 64], Role::Forward);
        let c2 = b.conv2d("c2", &[bn], 8, 16, 64, 64, 16, 3, 1, Role::Forward);
        let r2 = b.compute(OpKind::Relu, "r2", &[c2], &[8, 16, 64, 64], Role::Forward);
        for i in 0..4 {
            let p = b.param(&format!("w{i}"), &[16 * 16 * 9]);
            let gop = b.compute(
                OpKind::Mul,
                &format!("g{i}"),
                &[r2],
                &[16 * 16 * 9],
                Role::Backward,
            );
            let ar = b.allreduce(&format!("ar{i}"), gop, &[16 * 16 * 9]);
            b.optimizer_update(&format!("u{i}"), &[ar, p]);
        }
        b.finish()
    }

    #[test]
    fn xla_fusion_reduces_kernels() {
        let g = cnn_ish();
        let fused = xla_op_fusion(&g);
        assert!(fused.validate().is_ok());
        assert!(fused.compute_ops().len() < g.compute_ops().len());
        // No gradient bytes lost.
        assert_eq!(fused.total_gradient_bytes(), g.total_gradient_bytes());
    }

    #[test]
    fn xla_never_fuses_two_heavy_ops_directly() {
        let mut b = GraphBuilder::new("h", 2);
        let x = b.constant("x", &[64, 64]);
        let m1 = b.matmul("m1", &[x], 1, 64, 64, 64, Role::Forward);
        let m2 = b.matmul("m2", &[m1], 1, 64, 64, 64, Role::Forward);
        let g = b.finish();
        let fused = xla_op_fusion(&g);
        // Both matmuls survive unfused.
        assert!(!fused.nodes[m1].deleted);
        assert!(!fused.nodes[m2].deleted);
    }

    #[test]
    fn ar_combiner_respects_threshold() {
        let mut b = GraphBuilder::new("ar", 8);
        let x = b.constant("x", &[1024]);
        let mut prev = x;
        for i in 0..6 {
            let gop =
                b.compute(OpKind::Mul, &format!("g{i}"), &[prev], &[1024], Role::Backward);
            b.allreduce(&format!("ar{i}"), gop, &[1024]);
            prev = gop;
        }
        let g = b.finish();
        // Tiny tensors, 16KB threshold: 4KB each, so ~4 per fused AR.
        let fused = ar_threshold_fusion(&g, 16.0 * 1024.0);
        let ars = fused.allreduces();
        assert!(ars.len() < 6, "combiner did nothing");
        assert_eq!(fused.total_gradient_bytes(), g.total_gradient_bytes());
        // With an enormous threshold everything neighbouring merges.
        let all = ar_threshold_fusion(&g, 1e12);
        assert_eq!(all.allreduces().len(), 1);
        // With a zero threshold nothing merges.
        let none = ar_threshold_fusion(&g, 0.0);
        assert_eq!(none.allreduces().len(), 6);
    }

    #[test]
    fn jax_default_composes_both_passes() {
        let g = cnn_ish();
        let fused = jax_default(&g);
        assert!(fused.validate().is_ok());
        assert!(fused.compute_ops().len() < g.compute_ops().len());
    }

    #[test]
    fn ddp_only_buckets() {
        let g = cnn_ish();
        let d = pytorch_ddp(&g);
        // Same number of compute ops (no op fusion).
        assert_eq!(d.compute_ops().len(), g.compute_ops().len());
    }

    #[test]
    fn tvm_fuses_conv_epilogue() {
        let g = cnn_ish();
        let fused = tvm_rule_fusion(&g);
        // conv+relu should merge: find a fused node containing Conv2D+Relu.
        let has_conv_relu = fused.live().any(|n| {
            n.fused
                .as_ref()
                .map(|grp| {
                    grp.ops.iter().any(|o| o.kind == OpKind::Conv2D)
                        && grp.ops.iter().any(|o| o.kind == OpKind::Relu)
                })
                .unwrap_or(false)
        });
        assert!(has_conv_relu);
    }

    #[test]
    fn ngraph_fuses_only_injective() {
        let g = cnn_ish();
        let fused = ngraph_fusion(&g);
        // No fused group may contain a conv.
        for n in fused.live() {
            if let Some(grp) = &n.fused {
                assert!(grp.ops.iter().all(|o| o.kind != OpKind::Conv2D));
            }
        }
    }

    #[test]
    fn taso_like_improves_or_equal() {
        let g = cnn_ish();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 3);
        let est = CostEstimator::oracle(&prof, &d);
        let opts = SimOptions { ignore_comm: true, ..Default::default() };
        let out = taso_like(&g, &est, opts, 10, 17);
        let before = simulate(&g, &est, opts).makespan_ms;
        let after = simulate(&out, &est, opts).makespan_ms;
        assert!(after <= before);
        assert!(out.validate().is_ok());
    }
}
