//! Small in-tree substitutes for crates unavailable in the airgapped build
//! (rand, serde_json, clap, criterion, proptest) plus shared numerics.

pub mod checksum;
pub mod config;
pub mod frame;
pub mod rng;
pub mod stats;
pub mod json;
pub mod metrics;
pub mod trace;
pub mod cli;
pub mod prop;
pub mod table;
pub mod timer;
