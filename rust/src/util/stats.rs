//! Statistics helpers: summary stats, percentiles, histograms (for the
//! Fig. 9 PDF/CDF plots), and ordinary least-squares linear regression
//! (the paper's AllReduce time model `T = C·x + D`, §4.2).

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on the sorted copy; `p` in [0,100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Summary statistics of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: stddev(xs),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            p50: percentile(xs, 50.0),
            p90: percentile(xs, 90.0),
            p99: percentile(xs, 99.0),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

/// Fixed-width histogram over `[lo, hi)` with `bins` buckets.
/// Used to report the Fig. 9 PDF/CDF of GNN prediction errors.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Histogram {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], total: 0, underflow: 0, overflow: 0 }
    }

    pub fn add(&mut self, x: f64) {
        self.total += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let w = (self.hi - self.lo) / self.counts.len() as f64;
            let idx = ((x - self.lo) / w) as usize;
            let idx = idx.min(self.counts.len() - 1);
            self.counts[idx] += 1;
        }
    }

    /// Probability density per bin (sums to fraction of in-range samples).
    pub fn pdf(&self) -> Vec<f64> {
        if self.total == 0 {
            return vec![0.0; self.counts.len()];
        }
        self.counts.iter().map(|&c| c as f64 / self.total as f64).collect()
    }

    /// Cumulative distribution at each bin's right edge (includes underflow).
    pub fn cdf(&self) -> Vec<f64> {
        let mut acc = self.underflow as f64;
        let mut out = Vec::with_capacity(self.counts.len());
        for &c in &self.counts {
            acc += c as f64;
            out.push(if self.total == 0 { 0.0 } else { acc / self.total as f64 });
        }
        out
    }

    /// Right edge of bin `i`.
    pub fn edge(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.lo + w * (i + 1) as f64
    }
}

/// Result of an OLS fit `y = slope * x + intercept`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearFit {
    pub slope: f64,
    pub intercept: f64,
    /// Coefficient of determination.
    pub r2: f64,
}

/// Ordinary least squares on paired samples. Panics if fewer than 2 points
/// or zero variance in `x`.
pub fn linear_regression(xs: &[f64], ys: &[f64]) -> LinearFit {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need >= 2 points");
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    assert!(sxx > 0.0, "zero variance in x");
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (slope * x + intercept);
            e * e
        })
        .sum();
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    let _ = n;
    LinearFit { slope, intercept, r2 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.118033988749895).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&xs, 50.0), 25.0);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn histogram_pdf_cdf() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        for i in 0..100 {
            h.add(i as f64 / 100.0);
        }
        let pdf = h.pdf();
        assert!((pdf.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        let cdf = h.cdf();
        assert!((cdf.last().unwrap() - 1.0).abs() < 1e-12);
        // CDF is monotone.
        for w in cdf.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn histogram_overflow_underflow() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.add(-1.0);
        h.add(2.0);
        h.add(0.5);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total, 3);
    }

    #[test]
    fn linreg_exact() {
        // y = 3x + 2, exact.
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 2.0).collect();
        let fit = linear_regression(&xs, &ys);
        assert!((fit.slope - 3.0).abs() < 1e-12);
        assert!((fit.intercept - 2.0).abs() < 1e-12);
        assert!((fit.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn linreg_noisy_recovers() {
        let mut r = crate::util::rng::Rng::new(77);
        let xs: Vec<f64> = (0..500).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 0.8 * x + 15.0 + r.gen_normal() * 2.0).collect();
        let fit = linear_regression(&xs, &ys);
        assert!((fit.slope - 0.8).abs() < 0.01, "slope={}", fit.slope);
        assert!((fit.intercept - 15.0).abs() < 1.0);
        assert!(fit.r2 > 0.99);
    }
}
