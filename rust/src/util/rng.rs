//! Deterministic pseudo-random number generation.
//!
//! The search algorithm (paper Alg. 1) is randomized; reproducibility of
//! every table in EXPERIMENTS.md requires a seeded, portable generator.
//! We implement SplitMix64 (for seeding) and Xoshiro256** (the workhorse),
//! both public-domain algorithms.

/// Xoshiro256** seeded via SplitMix64. Deterministic across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-worker / per-trial RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64 bits (xoshiro256**).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift reduction.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0, "gen_range(0)");
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in the *inclusive* range `[lo, hi]`.
    #[inline]
    pub fn gen_range_inclusive(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.gen_range(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.gen_f64() * (hi - lo)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn gen_normal(&mut self) -> f64 {
        // Avoid log(0).
        let u1 = (self.gen_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal multiplicative noise factor with spread `sigma`
    /// (mean-corrected so E[factor] == 1).
    pub fn gen_lognormal_factor(&mut self, sigma: f64) -> f64 {
        (self.gen_normal() * sigma - 0.5 * sigma * sigma).exp()
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.gen_range(xs.len())])
        }
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.gen_range(17);
            assert!(x < 17);
        }
        // All residues reachable.
        let mut seen = [false; 17];
        for _ in 0..10_000 {
            seen[r.gen_range(17)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..100_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gen_normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_factor_mean_one() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let mut s = 0.0;
        for _ in 0..n {
            s += r.gen_lognormal_factor(0.08);
        }
        assert!((s / n as f64 - 1.0).abs() < 0.01);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
