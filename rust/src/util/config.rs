//! JSON config files: define custom clusters, devices and search
//! hyper-parameters without recompiling (`disco ... --config my.json`).
//!
//! ```json
//! {
//!   "cluster": {"machines": 4, "gpus_per_machine": 4, "nic_gbps": 100,
//!                "overhead_ms": 0.35},
//!   "device":  {"preset": "tesla_t4", "peak_tflops": 8.1,
//!                "mem_gbps": 300, "onchip_mb": 4},
//!   "search":  {"alpha": 1.05, "beta": 10, "unchanged_limit": 1000,
//!                "seed": 7, "chunking": true, "max_chunks": 8,
//!                "sharding": false},
//!   "service": {"addr": "127.0.0.1:7077", "store_path": "plans.jsonl",
//!                "capacity": 512, "warm_start": true, "nearest": true,
//!                "max_conns": 256, "cold_budget_ms": 0, "max_cold": 8}
//! }
//! ```
//!
//! Every field is optional; omitted ones keep the preset/default. The
//! `service` section configures `disco serve`'s plan store (DESIGN.md
//! §11): `store_path` (JSONL file; the string `"none"` = memory-only),
//! `capacity` (LRU bound on cached plans), the `warm_start`/`nearest`
//! toggles, and the admission-control knobs (DESIGN.md §14):
//! `cold_budget_ms` (per-request cold-search deadline, 0 = unlimited)
//! and `max_cold` (concurrent cold-search cap, separate from
//! `max_conns`).

use crate::device::DeviceModel;
use crate::network::Cluster;
use crate::search::SearchConfig;
use crate::service::ServiceConfig;
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Parsed configuration bundle.
#[derive(Debug, Clone)]
pub struct Config {
    pub cluster: Cluster,
    pub device: DeviceModel,
    pub search: SearchConfig,
    pub service: ServiceConfig,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cluster: Cluster::cluster_a(),
            device: DeviceModel::gtx1080ti(),
            search: SearchConfig::default(),
            service: ServiceConfig::default(),
        }
    }
}

impl Config {
    pub fn from_file(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json_str(&text)
    }

    pub fn from_json_str(text: &str) -> Result<Config> {
        let j = Json::parse(text).map_err(|e| anyhow!("config parse: {e}"))?;
        let mut cfg = Config::default();

        let c = j.get("cluster");
        if *c != Json::Null {
            if let Some(preset) = c.get("preset").as_str() {
                cfg.cluster = match preset {
                    "a" => Cluster::cluster_a(),
                    "b" => Cluster::cluster_b(),
                    "single" => Cluster::single_device(),
                    other => return Err(anyhow!("unknown cluster preset '{other}'")),
                };
            }
            if let Some(m) = c.get("machines").as_usize() {
                cfg.cluster.machines = m;
            }
            if let Some(g) = c.get("gpus_per_machine").as_usize() {
                cfg.cluster.gpus_per_machine = g;
            }
            if let Some(bw) = c.get("nic_gbps").as_f64() {
                cfg.cluster.nic_bw = bw * 1e9 / 8.0;
            }
            if let Some(o) = c.get("overhead_ms").as_f64() {
                cfg.cluster.overhead_ms = o;
            }
        }

        let d = j.get("device");
        if *d != Json::Null {
            if let Some(preset) = d.get("preset").as_str() {
                cfg.device = match preset {
                    "gtx1080ti" => DeviceModel::gtx1080ti(),
                    "tesla_t4" => DeviceModel::tesla_t4(),
                    other => return Err(anyhow!("unknown device preset '{other}'")),
                };
            }
            if let Some(p) = d.get("peak_tflops").as_f64() {
                cfg.device.spec.peak_flops = p * 1e12;
            }
            if let Some(bw) = d.get("mem_gbps").as_f64() {
                cfg.device.spec.mem_bw = bw * 1e9;
            }
            if let Some(mb) = d.get("onchip_mb").as_f64() {
                cfg.device.spec.onchip_bytes = mb * 1024.0 * 1024.0;
            }
            if let Some(l) = d.get("launch_us").as_f64() {
                cfg.device.spec.launch_overhead_ms = l / 1e3;
            }
        }

        let s = j.get("search");
        if *s != Json::Null {
            if let Some(a) = s.get("alpha").as_f64() {
                cfg.search.alpha = a;
            }
            if let Some(bta) = s.get("beta").as_usize() {
                cfg.search.beta = bta;
            }
            if let Some(u) = s.get("unchanged_limit").as_usize() {
                cfg.search.unchanged_limit = u;
            }
            if let Some(q) = s.get("max_queue").as_usize() {
                cfg.search.max_queue = q;
            }
            if let Some(sec) = s.get("max_seconds").as_f64() {
                cfg.search.max_seconds = sec;
            }
            if let Some(seed) = s.get("seed").as_usize() {
                cfg.search.seed = seed as u64;
            }
            if let Some(t) = s.get("eval_threads").as_usize() {
                cfg.search.eval_threads = t;
            }
            if let Some(d) = s.get("delta_candidates").as_bool() {
                cfg.search.delta_candidates = d;
            }
            if let Some(w) = s.get("reuse_workspaces").as_bool() {
                cfg.search.reuse_workspaces = w;
            }
            if let Some(i) = s.get("incremental_candidates").as_bool() {
                cfg.search.incremental_candidates = i;
            }
            if let Some(p) = s.get("parallel_min_nodes").as_usize() {
                cfg.search.parallel_min_nodes = p;
            }
            if let Some(ct) = s.get("cost_table").as_bool() {
                cfg.search.cost_table = ct;
            }
            if let Some(ds) = s.get("delta_sim").as_bool() {
                cfg.search.delta_sim = ds;
            }
            if let Some(ce) = s.get("ckpt_every").as_usize() {
                cfg.search.ckpt_every = ce;
            }
            if let Some(t) = s.get("track_best_path").as_bool() {
                cfg.search.track_best_path = t;
            }
            if let Some(t) = s.get("trace").as_bool() {
                cfg.search.trace = t;
            }
            if let Some(ck) = s.get("chunking").as_bool() {
                cfg.search.methods.chunking = ck;
            }
            if let Some(mc) = s.get("max_chunks").as_usize() {
                cfg.search.max_chunks = mc as u32;
            }
            if let Some(sh) = s.get("sharding").as_bool() {
                cfg.search.methods.sharding = sh;
            }
        }

        let v = j.get("service");
        if *v != Json::Null {
            if let Some(a) = v.get("addr").as_str() {
                cfg.service.addr = a.to_string();
            }
            match v.get("store_path") {
                Json::Null => {}
                Json::Str(p) if p == "none" => cfg.service.store_path = None,
                Json::Str(p) => cfg.service.store_path = Some(p.clone()),
                other => return Err(anyhow!("service.store_path must be a string, got {other:?}")),
            }
            if let Some(c) = v.get("capacity").as_usize() {
                cfg.service.capacity = c;
            }
            if let Some(w) = v.get("warm_start").as_bool() {
                cfg.service.warm_start = w;
            }
            if let Some(n) = v.get("nearest").as_bool() {
                cfg.service.nearest = n;
            }
            if let Some(m) = v.get("max_conns").as_usize() {
                cfg.service.max_conns = m;
            }
            if let Some(b) = v.get("cold_budget_ms").as_f64() {
                cfg.service.cold_budget_ms = b.max(0.0);
            }
            if let Some(mc) = v.get("max_cold").as_usize() {
                cfg.service.max_cold = mc;
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_config_is_default() {
        let c = Config::from_json_str("{}").unwrap();
        assert_eq!(c.cluster.name, "A");
        assert_eq!(c.search.alpha, 1.05);
    }

    #[test]
    fn overrides_apply() {
        let c = Config::from_json_str(
            r#"{
              "cluster": {"preset": "b", "machines": 2, "nic_gbps": 200},
              "device": {"preset": "tesla_t4", "peak_tflops": 10.0},
              "search": {"alpha": 1.1, "beta": 5, "unchanged_limit": 42}
            }"#,
        )
        .unwrap();
        assert_eq!(c.cluster.machines, 2);
        assert_eq!(c.cluster.gpus_per_machine, 8); // from preset b
        assert!((c.cluster.nic_bw - 25e9).abs() < 1.0);
        assert_eq!(c.device.spec.peak_flops, 10.0e12);
        assert_eq!(c.search.alpha, 1.1);
        assert_eq!(c.search.beta, 5);
        assert_eq!(c.search.unchanged_limit, 42);
    }

    #[test]
    fn engine_knobs_apply() {
        let c = Config::from_json_str(
            r#"{"search": {"eval_threads": 1, "delta_candidates": false,
                 "reuse_workspaces": false, "incremental_candidates": false}}"#,
        )
        .unwrap();
        assert_eq!(c.search.eval_threads, 1);
        assert!(!c.search.delta_candidates);
        assert!(!c.search.reuse_workspaces);
        assert!(!c.search.incremental_candidates);
        // Defaults are the fast engine.
        let d = Config::from_json_str("{}").unwrap();
        assert!(d.search.delta_candidates && d.search.reuse_workspaces);
    }

    #[test]
    fn service_section_applies() {
        let c = Config::from_json_str(
            r#"{"service": {"addr": "0.0.0.0:9000", "store_path": "cache/plans.jsonl",
                 "capacity": 64, "warm_start": false, "nearest": false,
                 "max_conns": 8},
                "search": {"track_best_path": true}}"#,
        )
        .unwrap();
        assert_eq!(c.service.addr, "0.0.0.0:9000");
        assert_eq!(c.service.store_path.as_deref(), Some("cache/plans.jsonl"));
        assert_eq!(c.service.capacity, 64);
        assert!(!c.service.warm_start && !c.service.nearest);
        assert_eq!(c.service.max_conns, 8);
        assert!(c.search.track_best_path);
        // Memory-only spelling.
        let m = Config::from_json_str(r#"{"service": {"store_path": "none"}}"#).unwrap();
        assert_eq!(m.service.store_path, None);
        // Defaults.
        let d = Config::from_json_str("{}").unwrap();
        assert!(d.service.warm_start && d.service.nearest);
        assert_eq!(d.service.capacity, 512);
        assert!(!d.search.track_best_path);
    }

    #[test]
    fn admission_control_knobs_apply() {
        let c = Config::from_json_str(
            r#"{"service": {"cold_budget_ms": 1500, "max_cold": 2}}"#,
        )
        .unwrap();
        assert_eq!(c.service.cold_budget_ms, 1500.0);
        assert_eq!(c.service.max_cold, 2);
        // Defaults: budget off, cap at 8 (DESIGN.md §14).
        let d = Config::from_json_str("{}").unwrap();
        assert_eq!(d.service.cold_budget_ms, 0.0);
        assert_eq!(d.service.max_cold, 8);
        // Negative budget clamps to "off" instead of going backwards.
        let n = Config::from_json_str(r#"{"service": {"cold_budget_ms": -5}}"#).unwrap();
        assert_eq!(n.service.cold_budget_ms, 0.0);
    }

    #[test]
    fn trace_knob_applies() {
        let c = Config::from_json_str(r#"{"search": {"trace": true}}"#).unwrap();
        assert!(c.search.trace);
        // Off by default: telemetry is strictly opt-in.
        let d = Config::from_json_str("{}").unwrap();
        assert!(!d.search.trace);
    }

    #[test]
    fn chunking_knobs_apply() {
        let c = Config::from_json_str(
            r#"{"search": {"chunking": true, "max_chunks": 16}}"#,
        )
        .unwrap();
        assert!(c.search.methods.chunking);
        assert_eq!(c.search.max_chunks, 16);
        // Off by default: the paper's vocabulary unless explicitly enabled.
        let d = Config::from_json_str("{}").unwrap();
        assert!(!d.search.methods.chunking);
        assert_eq!(d.search.max_chunks, 8);
    }

    #[test]
    fn sharding_knob_applies() {
        let c = Config::from_json_str(r#"{"search": {"sharding": true}}"#).unwrap();
        assert!(c.search.methods.sharding);
        // Off by default: the paper's vocabulary unless explicitly enabled.
        let d = Config::from_json_str("{}").unwrap();
        assert!(!d.search.methods.sharding);
    }

    #[test]
    fn bad_preset_rejected() {
        assert!(Config::from_json_str(r#"{"cluster": {"preset": "zzz"}}"#).is_err());
        assert!(Config::from_json_str("not json").is_err());
    }
}
