//! Markdown / aligned-text table rendering for the bench harness output
//! (EXPERIMENTS.md rows that mirror the paper's tables).

/// A simple table builder producing GitHub-flavoured markdown.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Render as markdown with per-column alignment padding.
    pub fn to_markdown(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push(' ');
                s.push_str(&format!("{:w$}", cells[i], w = widths[i]));
                s.push_str(" |");
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}--|", "", w = w));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }
}

/// Format milliseconds with adaptive precision.
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 100.0 {
        format!("{ms:.0}")
    } else if ms >= 10.0 {
        format!("{ms:.1}")
    } else {
        format!("{ms:.2}")
    }
}

/// Format a fraction as a percentage string.
pub fn fmt_pct(frac: f64) -> String {
    format!("{:.1}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = Table::new("T", &["model", "ms"]);
        t.row(vec!["vgg19".into(), "1.85".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| model | ms   |"));
        assert!(md.contains("| vgg19 | 1.85 |"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ms(123.4), "123");
        assert_eq!(fmt_ms(12.34), "12.3");
        assert_eq!(fmt_ms(1.234), "1.23");
        assert_eq!(fmt_pct(0.267), "26.7%");
    }
}
