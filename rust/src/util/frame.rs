//! Hardened length-prefixed framing shared by the coordinator and the
//! strategy service (DESIGN.md §12).
//!
//! Both TCP front-ends speak the same wire shape — a 4-byte big-endian
//! length followed by one UTF-8 JSON document — and both face the same
//! hostile-input surface: corrupt length prefixes (an attacker-controlled
//! allocation if trusted blindly), truncated frames, mid-frame EOF, and
//! peers that stall forever. This module is the single implementation of
//! the defenses:
//!
//! * **Bounded allocation** — the length prefix is validated against a
//!   cap *before* any buffer is allocated ([`FrameReader::poll`]).
//! * **Incremental, resumable reads** — [`FrameReader`] keeps partial
//!   state across `WouldBlock`/timeout ticks, so short read timeouts
//!   never desync the protocol mid-frame.
//! * **Deadlines on every op** — [`read_frame_deadline`] and
//!   [`write_frame_deadline`] bound each socket operation by wall clock,
//!   so a dead or byte-dribbling peer costs at most the deadline.
//! * **Typed errors** — [`FrameError`] distinguishes clean close,
//!   mid-frame EOF, oversized frames, UTF-8 violations and deadline
//!   expiry, so callers can retire a peer with a precise reason.

use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Poll granularity for deadline-bounded reads: short enough that
/// deadlines are honored promptly, long enough not to spin.
const READ_TICK: Duration = Duration::from_millis(25);

/// What went wrong with a frame, precisely.
#[derive(Debug, thiserror::Error)]
pub enum FrameError {
    /// The length prefix claims more than the cap — rejected before any
    /// allocation happens.
    #[error("frame of {got} bytes exceeds the {cap}-byte cap")]
    TooLarge { got: usize, cap: usize },
    /// The peer closed the connection before a frame started (normal
    /// disconnect).
    #[error("connection closed")]
    Closed,
    /// The peer closed the connection in the middle of a frame.
    #[error("peer closed the connection mid-frame")]
    Eof,
    /// The wall-clock deadline expired before the operation completed.
    #[error("deadline exceeded (mid-frame: {mid_frame})")]
    Deadline { mid_frame: bool },
    /// The frame body is not valid UTF-8.
    #[error("frame is not UTF-8: {0}")]
    Utf8(#[from] std::string::FromUtf8Error),
    #[error("i/o: {0}")]
    Io(#[from] io::Error),
}

/// A stream whose read/write timeouts can be (re)armed — the hook the
/// deadline helpers need. Implemented by [`TcpStream`] and by the chaos
/// fault shim ([`crate::coordinator::fault::FaultStream`]).
pub trait TimedStream: Read + Write {
    fn set_rd_timeout(&self, t: Option<Duration>) -> io::Result<()>;
    fn set_wr_timeout(&self, t: Option<Duration>) -> io::Result<()>;
}

impl TimedStream for TcpStream {
    fn set_rd_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        TcpStream::set_read_timeout(self, t)
    }
    fn set_wr_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        TcpStream::set_write_timeout(self, t)
    }
}

/// Incremental length-prefixed frame decoder. Feed it a stream whenever
/// bytes might be available; partial frames survive across calls, so it
/// composes with read timeouts and nonblocking polling without ever
/// desyncing (TCP gives no atomicity between the prefix and the body).
#[derive(Debug)]
pub struct FrameReader {
    cap: usize,
    len: [u8; 4],
    len_filled: usize,
    body: Vec<u8>,
    body_filled: usize,
}

impl FrameReader {
    /// A reader that rejects frames larger than `cap` bytes.
    pub fn with_cap(cap: usize) -> FrameReader {
        FrameReader { cap, len: [0; 4], len_filled: 0, body: Vec::new(), body_filled: 0 }
    }

    /// True if a frame has started but not finished — a disconnect now
    /// is a protocol violation, not a clean close.
    pub fn mid_frame(&self) -> bool {
        self.len_filled > 0
    }

    fn reset(&mut self) {
        self.len_filled = 0;
        self.body = Vec::new();
        self.body_filled = 0;
    }

    /// Pump bytes from `r`. Returns `Ok(Some(frame))` when a complete
    /// frame is decoded (the reader resets for the next one),
    /// `Ok(None)` when the stream would block (partial state is kept),
    /// and a typed error on EOF / oversize / UTF-8 / I/O failure.
    ///
    /// The body buffer is only allocated *after* the length prefix has
    /// been validated against the cap — a hostile prefix can never drive
    /// an unbounded allocation.
    pub fn poll<R: Read + ?Sized>(&mut self, r: &mut R) -> Result<Option<String>, FrameError> {
        loop {
            if self.len_filled < 4 {
                match r.read(&mut self.len[self.len_filled..]) {
                    Ok(0) => {
                        let e = if self.mid_frame() { FrameError::Eof } else { FrameError::Closed };
                        self.reset();
                        return Err(e);
                    }
                    Ok(n) => {
                        self.len_filled += n;
                        if self.len_filled == 4 {
                            let want = u32::from_be_bytes(self.len) as usize;
                            if want > self.cap {
                                let cap = self.cap;
                                self.reset();
                                return Err(FrameError::TooLarge { got: want, cap });
                            }
                            self.body = vec![0u8; want];
                            self.body_filled = 0;
                        }
                        continue;
                    }
                    Err(e) if would_block(&e) => return Ok(None),
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => {
                        self.reset();
                        return Err(FrameError::Io(e));
                    }
                }
            }
            if self.body_filled < self.body.len() {
                match r.read(&mut self.body[self.body_filled..]) {
                    Ok(0) => {
                        self.reset();
                        return Err(FrameError::Eof);
                    }
                    Ok(n) => {
                        self.body_filled += n;
                        continue;
                    }
                    Err(e) if would_block(&e) => return Ok(None),
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => {
                        self.reset();
                        return Err(FrameError::Io(e));
                    }
                }
            }
            let bytes = std::mem::take(&mut self.body);
            self.reset();
            return Ok(Some(String::from_utf8(bytes)?));
        }
    }
}

fn would_block(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Read one complete frame, blocking at most until `deadline`. Partial
/// progress is kept in `reader`, so a frame that straddles several
/// timeout ticks still completes — but never past the deadline.
pub fn read_frame_deadline<S: TimedStream + ?Sized>(
    stream: &mut S,
    reader: &mut FrameReader,
    deadline: Instant,
) -> Result<String, FrameError> {
    loop {
        let now = Instant::now();
        if now >= deadline {
            return Err(FrameError::Deadline { mid_frame: reader.mid_frame() });
        }
        let tick = (deadline - now).min(READ_TICK).max(Duration::from_millis(1));
        let _ = stream.set_rd_timeout(Some(tick));
        if let Some(frame) = reader.poll(stream)? {
            return Ok(frame);
        }
    }
}

/// Write one complete frame, bounded by `deadline`. A peer applying
/// backpressure past the deadline (or the deadline already being in the
/// past) yields `FrameError::Deadline`, never an indefinite block.
pub fn write_frame_deadline<S: TimedStream + ?Sized>(
    stream: &mut S,
    body: &[u8],
    deadline: Instant,
) -> Result<(), FrameError> {
    let now = Instant::now();
    if now >= deadline {
        return Err(FrameError::Deadline { mid_frame: false });
    }
    let _ = stream.set_wr_timeout(Some(deadline - now));
    let wr = (|| {
        stream.write_all(&(body.len() as u32).to_be_bytes())?;
        stream.write_all(body)?;
        stream.flush()
    })();
    match wr {
        Ok(()) => Ok(()),
        Err(e) if would_block(&e) => Err(FrameError::Deadline { mid_frame: true }),
        Err(e) => Err(FrameError::Io(e)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn oversized_prefix_rejected_before_allocation() {
        // A prefix claiming 1 GiB against a 1 KiB cap must produce a
        // typed error without the reader ever growing its buffer.
        let mut fr = FrameReader::with_cap(1024);
        let mut data: &[u8] = &(1u32 << 30).to_be_bytes();
        match fr.poll(&mut data) {
            Err(FrameError::TooLarge { got, cap }) => {
                assert_eq!(got, 1 << 30);
                assert_eq!(cap, 1024);
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
        assert_eq!(fr.body.capacity(), 0, "no allocation for rejected frame");
    }

    #[test]
    fn frame_split_across_reads_reassembles() {
        let payload = b"hello frame";
        let mut framed = (payload.len() as u32).to_be_bytes().to_vec();
        framed.extend_from_slice(payload);
        let mut fr = FrameReader::with_cap(64);
        // Feed one byte at a time through a cursor that yields 1 byte per
        // read call.
        struct OneByte<'a>(&'a [u8], usize);
        impl Read for OneByte<'_> {
            fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
                if self.1 >= self.0.len() {
                    return Err(io::ErrorKind::WouldBlock.into());
                }
                buf[0] = self.0[self.1];
                self.1 += 1;
                Ok(1)
            }
        }
        let mut src = OneByte(&framed, 0);
        let mut out = None;
        for _ in 0..framed.len() + 1 {
            match fr.poll(&mut src) {
                Ok(Some(s)) => {
                    out = Some(s);
                    break;
                }
                Ok(None) => continue,
                Err(e) => panic!("{e}"),
            }
        }
        assert_eq!(out.as_deref(), Some("hello frame"));
    }

    #[test]
    fn mid_frame_eof_is_typed() {
        let mut framed = (100u32).to_be_bytes().to_vec();
        framed.extend_from_slice(b"short");
        let mut fr = FrameReader::with_cap(1024);
        let mut src: &[u8] = &framed;
        match fr.poll(&mut src) {
            Err(FrameError::Eof) => {}
            other => panic!("expected Eof, got {other:?}"),
        }
    }

    #[test]
    fn clean_close_distinguished_from_mid_frame() {
        let mut fr = FrameReader::with_cap(1024);
        let mut empty: &[u8] = &[];
        assert!(matches!(fr.poll(&mut empty), Err(FrameError::Closed)));
    }

    #[test]
    fn non_utf8_body_is_typed() {
        let mut framed = (2u32).to_be_bytes().to_vec();
        framed.extend_from_slice(&[0xFF, 0xFE]);
        let mut fr = FrameReader::with_cap(1024);
        let mut src: &[u8] = &framed;
        assert!(matches!(fr.poll(&mut src), Err(FrameError::Utf8(_))));
    }

    #[test]
    fn deadline_bounds_a_silent_peer() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _held = TcpStream::connect(addr).unwrap(); // connects, never writes
        let (mut srv, _) = listener.accept().unwrap();
        let mut fr = FrameReader::with_cap(1024);
        let start = Instant::now();
        let res = read_frame_deadline(&mut srv, &mut fr, start + Duration::from_millis(120));
        assert!(matches!(res, Err(FrameError::Deadline { mid_frame: false })), "{res:?}");
        let waited = start.elapsed();
        assert!(waited >= Duration::from_millis(100), "returned too early: {waited:?}");
        assert!(waited < Duration::from_secs(2), "deadline ignored: {waited:?}");
    }

    #[test]
    fn roundtrip_over_tcp_with_deadlines() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut fr = FrameReader::with_cap(1 << 20);
            let deadline = Instant::now() + Duration::from_secs(5);
            let body = read_frame_deadline(&mut s, &mut fr, deadline).unwrap();
            write_frame_deadline(&mut s, body.as_bytes(), deadline).unwrap(); // echo
        });
        let mut c = TcpStream::connect(addr).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        write_frame_deadline(&mut c, "ping".as_bytes(), deadline).unwrap();
        let mut fr = FrameReader::with_cap(1 << 20);
        assert_eq!(read_frame_deadline(&mut c, &mut fr, deadline).unwrap(), "ping");
        t.join().unwrap();
    }
}
