//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! Runs a property over many seeded random cases; on failure it reports the
//! seed and case index so the exact counterexample is reproducible with
//! `Rng::new(seed)`. Used for the invariants listed in DESIGN.md §7
//! (fusion legality, simulator bounds, coordinator routing/batching).

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 128, seed: 0xD15C0 }
    }
}

/// Outcome of a single case.
pub enum CaseResult {
    Pass,
    /// Property does not apply to this input; does not count as a pass.
    Discard,
    Fail(String),
}

/// Run `property` over `cfg.cases` random cases. Each case receives a
/// deterministic per-case RNG. Panics (failing the test) on the first
/// failure, printing seed + case index.
pub fn check<F: FnMut(&mut Rng) -> CaseResult>(name: &str, cfg: PropConfig, mut property: F) {
    let mut passed = 0usize;
    let mut discarded = 0usize;
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        match property(&mut rng) {
            CaseResult::Pass => passed += 1,
            CaseResult::Discard => discarded += 1,
            CaseResult::Fail(msg) => panic!(
                "property '{name}' FAILED at case {case} (seed {case_seed:#x}): {msg}"
            ),
        }
    }
    assert!(
        passed > cfg.cases / 2,
        "property '{name}': too many discards ({discarded}/{})",
        cfg.cases
    );
}

/// Assert-style helper producing a CaseResult.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return $crate::util::prop::CaseResult::Fail(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", PropConfig::default(), |rng| {
            let a = rng.gen_range(1000) as i64;
            let b = rng.gen_range(1000) as i64;
            prop_assert!(a + b == b + a, "a={a} b={b}");
            CaseResult::Pass
        });
    }

    #[test]
    #[should_panic(expected = "FAILED")]
    fn failing_property_panics_with_seed() {
        check("always-false", PropConfig { cases: 8, seed: 1 }, |_rng| {
            CaseResult::Fail("nope".into())
        });
    }

    #[test]
    #[should_panic(expected = "too many discards")]
    fn discard_heavy_property_rejected() {
        check("all-discard", PropConfig { cases: 8, seed: 1 }, |_rng| CaseResult::Discard);
    }
}
