//! std-only metrics core (DESIGN.md §15): atomic counters and gauges
//! plus fixed-bucket log₂-scale histograms, behind a registry with
//! stable names and Prometheus-style text exposition.
//!
//! Observation paths are lock-free: counters/gauges are single atomic
//! ops, a histogram observe is one atomic bucket increment plus a CAS
//! loop folding the value into an f64 sum. Only registration (startup)
//! and exposition (scrape) take the registry lock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

// ---------------------------------------------------------------------------
// Counter / Gauge
// ---------------------------------------------------------------------------

/// Monotonically increasing count.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Instantaneous level (active connections, in-flight cold searches).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub fn set(&self, n: u64) {
        self.v.store(n, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    /// Saturating decrement (a stray double-release must not wrap to
    /// u64::MAX and wedge admission forever).
    pub fn dec(&self) {
        let _ = self.v.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |x| {
            Some(x.saturating_sub(1))
        });
    }

    /// Admission-style CAS increment: succeed only while the level is
    /// below `cap`. Pairs with [`Gauge::dec`] on release.
    pub fn inc_if_below(&self, cap: u64) -> bool {
        self.v
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |x| {
                if x < cap {
                    Some(x + 1)
                } else {
                    None
                }
            })
            .is_ok()
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Histogram
// ---------------------------------------------------------------------------

/// Number of log₂ buckets: upper bounds `LO · 2^i` for `i` in
/// `0..BUCKETS`, i.e. 1 µs up to ~8.8e9 ms (≈102 days) — everything a
/// resolve latency or store write can plausibly take. The last bucket
/// also absorbs overflow.
pub const BUCKETS: usize = 44;

/// Lowest bucket upper bound, in the histogram's own unit (we use ms
/// everywhere): values ≤ 1 µs land in bucket 0.
pub const LO: f64 = 0.001;

/// Lock-free fixed-bucket log₂-scale histogram.
///
/// Replaces `server.rs`'s `Mutex<Vec<f64>>` latency ring: observe is
/// wait-free per bucket, memory is constant, and percentiles come from
/// a cumulative scan. A percentile estimate is the upper bound of the
/// bucket holding the target rank, so for any sample `s` the estimate
/// `e` satisfies `s ≤ e < 2s` — error bounded by the bucket width
/// (property-tested in `tests/properties.rs`).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    /// f64 bit pattern of the running sum, updated by CAS.
    sum_bits: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }
}

/// Bucket index for a value: smallest `i` with `v ≤ LO · 2^i`, clamped
/// into range. Non-finite and non-positive values fold into bucket 0.
fn bucket_of(v: f64) -> usize {
    if !v.is_finite() || v <= LO {
        return 0;
    }
    let i = (v / LO).log2().ceil() as i64;
    i.clamp(0, BUCKETS as i64 - 1) as usize
}

/// Upper bound of bucket `i` (`LO · 2^i`).
pub fn bucket_bound(i: usize) -> f64 {
    LO * (2f64).powi(i as i32)
}

impl Histogram {
    pub fn observe(&self, v: f64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let add = if v.is_finite() { v.max(0.0) } else { 0.0 };
        let _ = self.sum_bits.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
            Some((f64::from_bits(bits) + add).to_bits())
        });
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() / n as f64
        }
    }

    /// Percentile estimate for `q` in `[0, 100]` (same convention as
    /// `util::stats::percentile`): upper bound of the bucket containing
    /// the nearest-rank sample; 0 when empty.
    pub fn percentile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = ((q / 100.0) * n as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for i in 0..BUCKETS {
            cum += self.buckets[i].load(Ordering::Relaxed);
            if cum >= rank {
                return bucket_bound(i);
            }
        }
        bucket_bound(BUCKETS - 1)
    }

    /// Cumulative bucket counts (Prometheus `le` semantics), ending at
    /// the total for `+Inf`.
    fn cumulative(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(BUCKETS);
        let mut cum = 0u64;
        for b in &self.buckets {
            cum += b.load(Ordering::Relaxed);
            out.push(cum);
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Registry + exposition
// ---------------------------------------------------------------------------

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

/// Names are stable API: `[a-z_][a-z0-9_]*`, registered once, never
/// renamed. Re-registering a name returns the existing handle (so call
/// sites can be wired independently); registering it as a *different*
/// kind panics — that is a programming error, caught at startup.
fn check_name(name: &str) {
    let ok = !name.is_empty()
        && (name.as_bytes()[0].is_ascii_lowercase() || name.as_bytes()[0] == b'_')
        && name.bytes().all(|b| b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'_');
    assert!(ok, "invalid metric name {name:?}: want [a-z_][a-z0-9_]*");
}

/// Home for every metric the process exports. Lock is held only for
/// registration and exposition; handles are `Arc`s observed lock-free.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Vec<(String, Metric)>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn register<T>(
        &self,
        name: &str,
        make: impl FnOnce() -> Metric,
        pick: impl Fn(&Metric) -> Option<Arc<T>>,
    ) -> Arc<T> {
        check_name(name);
        let mut inner = self.inner.lock().unwrap();
        if let Some((_, m)) = inner.iter().find(|(n, _)| n == name) {
            return pick(m).unwrap_or_else(|| {
                panic!("metric {name:?} already registered as a {}", m.kind())
            });
        }
        let m = make();
        let h = pick(&m).unwrap();
        inner.push((name.to_string(), m));
        h
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.register(
            name,
            || Metric::Counter(Arc::new(Counter::default())),
            |m| match m {
                Metric::Counter(c) => Some(c.clone()),
                _ => None,
            },
        )
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.register(
            name,
            || Metric::Gauge(Arc::new(Gauge::default())),
            |m| match m {
                Metric::Gauge(g) => Some(g.clone()),
                _ => None,
            },
        )
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.register(
            name,
            || Metric::Histogram(Arc::new(Histogram::default())),
            |m| match m {
                Metric::Histogram(h) => Some(h.clone()),
                _ => None,
            },
        )
    }

    /// Prometheus-style text exposition, metrics sorted by name. For
    /// histograms, only buckets up to the last non-empty one are listed
    /// (plus `+Inf`) to keep the payload proportional to observed range.
    pub fn expose(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut entries: Vec<&(String, Metric)> = inner.iter().collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        let mut out = String::new();
        for (name, m) in entries {
            out.push_str(&format!("# TYPE {name} {}\n", m.kind()));
            match m {
                Metric::Counter(c) => out.push_str(&format!("{name} {}\n", c.get())),
                Metric::Gauge(g) => out.push_str(&format!("{name} {}\n", g.get())),
                Metric::Histogram(h) => {
                    let cum = h.cumulative();
                    let total = h.count();
                    let last = cum.iter().rposition(|&c| c < total).map_or(0, |i| i + 1);
                    for (i, &c) in cum.iter().enumerate().take(last + 1) {
                        out.push_str(&format!(
                            "{name}_bucket{{le=\"{}\"}} {c}\n",
                            bucket_bound(i)
                        ));
                    }
                    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {total}\n"));
                    out.push_str(&format!("{name}_sum {}\n", h.sum()));
                    out.push_str(&format!("{name}_count {total}\n"));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let r = Registry::new();
        let c = r.counter("disco_requests_total");
        c.inc();
        c.add(2);
        assert_eq!(c.get(), 3);
        // Same name → same handle.
        assert_eq!(r.counter("disco_requests_total").get(), 3);
        let g = r.gauge("disco_active");
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.dec();
        g.dec(); // saturates, no wrap
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn gauge_admission_cas() {
        let g = Gauge::default();
        assert!(g.inc_if_below(2));
        assert!(g.inc_if_below(2));
        assert!(!g.inc_if_below(2));
        g.dec();
        assert!(g.inc_if_below(2));
    }

    #[test]
    fn bucket_mapping_monotone_and_bounding() {
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(-3.0), 0);
        assert_eq!(bucket_of(f64::NAN), 0);
        assert_eq!(bucket_of(LO), 0);
        for i in 0..BUCKETS {
            let ub = bucket_bound(i);
            assert!(bucket_of(ub) <= i, "upper bound maps into its bucket");
            if i + 1 < BUCKETS {
                assert_eq!(bucket_of(ub * 1.5), i + 1);
            }
        }
        // Overflow clamps to the last bucket.
        assert_eq!(bucket_of(1e300), BUCKETS - 1);
    }

    #[test]
    fn histogram_percentiles_bound_samples() {
        let h = Histogram::default();
        for v in [0.2, 0.4, 1.0, 3.0, 9.0, 20.0, 120.0, 450.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 8);
        let p50 = h.percentile(50.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p99);
        // Nearest-rank p50 sample is 3.0; estimate within [3, 6).
        assert!((3.0..6.0).contains(&p50), "p50 {p50}");
        // p99 sample is 450; estimate within [450, 900).
        assert!((450.0..900.0).contains(&p99), "p99 {p99}");
        assert!((h.sum() - 603.6).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn exposition_format() {
        let r = Registry::new();
        r.counter("disco_b_total").add(2);
        r.gauge("disco_a").set(5);
        let h = r.histogram("disco_lat_ms");
        h.observe(0.5);
        h.observe(2.0);
        let text = r.expose();
        // Sorted by name, typed, histogram has cumulative buckets.
        let a = text.find("# TYPE disco_a gauge").unwrap();
        let b = text.find("# TYPE disco_b_total counter").unwrap();
        let l = text.find("# TYPE disco_lat_ms histogram").unwrap();
        assert!(a < b && b < l);
        assert!(text.contains("disco_a 5\n"));
        assert!(text.contains("disco_b_total 2\n"));
        assert!(text.contains("disco_lat_ms_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("disco_lat_ms_count 2\n"));
        assert!(text.contains("disco_lat_ms_sum 2.5\n"));
        // Buckets are cumulative: the bucket holding 2.0 (le = 0.001·2^11
        // = 2.048) already counts both observations.
        assert!(text.contains("disco_lat_ms_bucket{le=\"2.048\"} 2\n"));
    }

    #[test]
    #[should_panic(expected = "invalid metric name")]
    fn bad_names_rejected() {
        Registry::new().counter("Disco-Requests");
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn kind_collision_rejected() {
        let r = Registry::new();
        r.counter("disco_x");
        r.gauge("disco_x");
    }
}
