//! Shared tracing core (DESIGN.md §15): one event shape for every
//! timeline the system produces — simulated schedules (`sim/trace.rs`),
//! search telemetry (`search --trace`), and enactment runs
//! (`enact --trace`) — so all exports load side by side in one
//! Perfetto / `chrome://tracing` session.
//!
//! Design rules:
//! * **Explicit tracks.** Every event names its `(pid, tid)` lane; the
//!   pid partitions subsystems (1 = simulated schedule, 2 = search,
//!   3 = enactment) so merged views never collide.
//! * **Milliseconds everywhere.** `ts_ms`/`dur_ms` match the simulator's
//!   native unit; the Chrome emitter converts to µs at the edge.
//! * **Sinks are dumb.** A [`TraceSink`] only records; producers decide
//!   *whether* to emit (a disabled path must never touch its sink —
//!   [`PanicSink`] exists to property-test exactly that, the same
//!   pattern as PR 4's panic-cost-source).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Events and tracks
// ---------------------------------------------------------------------------

/// Track identity. Perfetto renders one horizontal lane per `(pid, tid)`
/// pair; [`MemSink::name_track`] attaches the human-readable label.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TrackId {
    pub pid: u32,
    pub tid: u32,
}

impl TrackId {
    pub const fn new(pid: u32, tid: u32) -> TrackId {
        TrackId { pid, tid }
    }
}

/// Event phase: a complete span (`ph:"X"`) or an instant marker
/// (`ph:"i"`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ph {
    Span,
    Instant,
}

/// One trace event. Numeric `args` ride along into both emitters; the
/// JSONL emitter flattens them to top-level keys so a convergence curve
/// is directly plottable line by line.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub name: String,
    pub cat: &'static str,
    pub track: TrackId,
    pub ph: Ph,
    pub ts_ms: f64,
    pub dur_ms: f64,
    pub args: Vec<(&'static str, f64)>,
}

impl Event {
    pub fn span(
        track: TrackId,
        name: impl Into<String>,
        start_ms: f64,
        end_ms: f64,
        cat: &'static str,
    ) -> Event {
        Event {
            name: name.into(),
            cat,
            track,
            ph: Ph::Span,
            ts_ms: start_ms,
            dur_ms: (end_ms - start_ms).max(0.0),
            args: Vec::new(),
        }
    }

    pub fn instant(track: TrackId, name: impl Into<String>, ts_ms: f64, cat: &'static str) -> Event {
        Event { name: name.into(), cat, track, ph: Ph::Instant, ts_ms, dur_ms: 0.0, args: Vec::new() }
    }

    pub fn with_args(mut self, args: Vec<(&'static str, f64)>) -> Event {
        self.args = args;
        self
    }

    pub fn end_ms(&self) -> f64 {
        self.ts_ms + self.dur_ms
    }
}

// ---------------------------------------------------------------------------
// Sinks
// ---------------------------------------------------------------------------

/// Where events go. Producers hold `&mut dyn TraceSink` (single-thread
/// paths) or a [`SharedSink`] clone (multi-thread paths).
pub trait TraceSink {
    fn event(&mut self, ev: Event);
    /// Attach a display name to a track (renders as the lane label).
    fn name_track(&mut self, track: TrackId, name: &str);
}

/// Discards everything. The default sink for untraced runs.
#[derive(Debug, Default, Clone, Copy)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn event(&mut self, _ev: Event) {}
    fn name_track(&mut self, _track: TrackId, _name: &str) {}
}

/// Panics on any call — a test-only guard proving a disabled trace path
/// never touches its sink (zero events, zero track names, zero arg
/// construction reaching the sink boundary).
#[derive(Debug, Default, Clone, Copy)]
pub struct PanicSink;

impl TraceSink for PanicSink {
    fn event(&mut self, ev: Event) {
        panic!("PanicSink received event {:?} with tracing disabled", ev.name);
    }
    fn name_track(&mut self, track: TrackId, name: &str) {
        panic!("PanicSink received track name {:?} for {:?} with tracing disabled", name, track);
    }
}

/// Collecting sink: events in arrival order plus named tracks.
#[derive(Debug, Default, Clone)]
pub struct MemSink {
    pub events: Vec<Event>,
    pub tracks: Vec<(TrackId, String)>,
}

impl TraceSink for MemSink {
    fn event(&mut self, ev: Event) {
        self.events.push(ev);
    }
    fn name_track(&mut self, track: TrackId, name: &str) {
        if let Some(slot) = self.tracks.iter_mut().find(|(t, _)| *t == track) {
            slot.1 = name.to_string();
        } else {
            self.tracks.push((track, name.to_string()));
        }
    }
}

/// Thread-safe sink plus a shared wall clock, for producers spread
/// across threads (the enactment leader and its in-process workers).
/// Clones share both the buffer and the epoch, so `now_ms()` timestamps
/// from any thread land on one common timeline.
#[derive(Debug, Clone)]
pub struct SharedSink {
    t0: Instant,
    inner: Arc<Mutex<MemSink>>,
}

impl Default for SharedSink {
    fn default() -> SharedSink {
        SharedSink::new()
    }
}

impl SharedSink {
    pub fn new() -> SharedSink {
        SharedSink { t0: Instant::now(), inner: Arc::new(Mutex::new(MemSink::default())) }
    }

    /// Milliseconds since this sink's epoch.
    pub fn now_ms(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e3
    }

    pub fn emit(&self, ev: Event) {
        self.inner.lock().unwrap().event(ev);
    }

    pub fn name_track(&self, track: TrackId, name: &str) {
        self.inner.lock().unwrap().name_track(track, name);
    }

    /// Drain the collected buffer (events + tracks), leaving it empty.
    pub fn take(&self) -> MemSink {
        std::mem::take(&mut *self.inner.lock().unwrap())
    }
}

// ---------------------------------------------------------------------------
// Emitters
// ---------------------------------------------------------------------------

/// Chronological copy: stable sort by start time, then track — exports
/// are emitted in this order so file-order timestamps are monotone.
pub fn sorted(events: &[Event]) -> Vec<Event> {
    let mut v = events.to_vec();
    v.sort_by(|a, b| {
        a.ts_ms
            .partial_cmp(&b.ts_ms)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.track.cmp(&b.track))
    });
    v
}

fn args_json(args: &[(&'static str, f64)]) -> Json {
    Json::Obj(args.iter().map(|(k, v)| (k.to_string(), Json::Num(*v))).collect())
}

/// Chrome-trace / Perfetto JSON: `thread_name` metadata rows label the
/// tracks, span events carry `ph:"X"` with µs `ts`/`dur`, instants carry
/// `ph:"i"` with thread scope. Wraps in `{"traceEvents": ..}` (object
/// form) so `displayTimeUnit` applies.
pub fn to_chrome_json(events: &[Event], tracks: &[(TrackId, String)]) -> String {
    let mut rows = Vec::with_capacity(events.len() + tracks.len());
    for (track, name) in tracks {
        rows.push(Json::obj(vec![
            ("name", Json::Str("thread_name".into())),
            ("ph", Json::Str("M".into())),
            ("pid", Json::Num(track.pid as f64)),
            ("tid", Json::Num(track.tid as f64)),
            ("args", Json::obj(vec![("name", Json::Str(name.clone()))])),
        ]));
    }
    for ev in sorted(events) {
        let mut pairs = vec![
            ("name", Json::Str(ev.name.clone())),
            ("cat", Json::Str(ev.cat.into())),
            ("pid", Json::Num(ev.track.pid as f64)),
            ("tid", Json::Num(ev.track.tid as f64)),
            ("ts", Json::Num(ev.ts_ms * 1e3)),
        ];
        match ev.ph {
            Ph::Span => {
                pairs.push(("ph", Json::Str("X".into())));
                pairs.push(("dur", Json::Num(ev.dur_ms * 1e3)));
            }
            Ph::Instant => {
                pairs.push(("ph", Json::Str("i".into())));
                pairs.push(("s", Json::Str("t".into())));
            }
        }
        if !ev.args.is_empty() {
            pairs.push(("args", args_json(&ev.args)));
        }
        rows.push(Json::obj(pairs));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(rows)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
    .to_string()
}

/// JSONL: one ts-sorted JSON object per line with `args` flattened to
/// top-level keys — `tail -1` of a search trace IS the final makespan
/// record, and each line plots directly as a convergence-curve point.
pub fn to_jsonl(events: &[Event]) -> String {
    let mut out = String::new();
    for ev in sorted(events) {
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        // Args first: the fixed keys below win any (unlikely) collision.
        for (k, v) in &ev.args {
            m.insert(k.to_string(), Json::Num(*v));
        }
        m.insert("name".into(), Json::Str(ev.name.clone()));
        m.insert("cat".into(), Json::Str(ev.cat.into()));
        m.insert("pid".into(), Json::Num(ev.track.pid as f64));
        m.insert("tid".into(), Json::Num(ev.track.tid as f64));
        m.insert("ph".into(), Json::Str(if ev.ph == Ph::Span { "X" } else { "i" }.into()));
        m.insert("ts_ms".into(), Json::Num(ev.ts_ms));
        if ev.ph == Ph::Span {
            m.insert("dur_ms".into(), Json::Num(ev.dur_ms));
        }
        out.push_str(&Json::Obj(m).to_string());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> MemSink {
        let mut s = MemSink::default();
        let t = TrackId::new(7, 1);
        s.name_track(t, "lane");
        s.event(Event::span(t, "b", 2.0, 5.0, "work").with_args(vec![("n", 3.0)]));
        s.event(Event::span(t, "a", 0.0, 2.0, "work"));
        s.event(Event::instant(t, "mark", 4.0, "note"));
        s
    }

    #[test]
    fn chrome_export_sorted_and_labeled() {
        let s = sample();
        let parsed = Json::parse(&to_chrome_json(&s.events, &s.tracks)).unwrap();
        let rows = parsed.get("traceEvents").as_arr().unwrap();
        assert_eq!(rows.len(), 4); // 1 metadata + 3 events
        assert_eq!(rows[0].get("ph").as_str(), Some("M"));
        assert_eq!(rows[0].get("args").get("name").as_str(), Some("lane"));
        // Events sorted by ts regardless of arrival order.
        assert_eq!(rows[1].get("name").as_str(), Some("a"));
        assert_eq!(rows[2].get("name").as_str(), Some("b"));
        assert_eq!(rows[2].get("ts").as_f64(), Some(2000.0));
        assert_eq!(rows[2].get("dur").as_f64(), Some(3000.0));
        assert_eq!(rows[2].get("args").get("n").as_f64(), Some(3.0));
        assert_eq!(rows[3].get("ph").as_str(), Some("i"));
    }

    #[test]
    fn jsonl_flattens_args_and_sorts() {
        let s = sample();
        let lines: Vec<&str> = to_jsonl(&s.events).lines().collect();
        assert_eq!(lines.len(), 3);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("name").as_str(), Some("a"));
        let second = Json::parse(lines[1]).unwrap();
        assert_eq!(second.get("n").as_f64(), Some(3.0));
        assert_eq!(second.get("dur_ms").as_f64(), Some(3.0));
    }

    #[test]
    fn shared_sink_merges_across_clones() {
        let s = SharedSink::new();
        let s2 = s.clone();
        s.emit(Event::instant(TrackId::new(1, 1), "x", s.now_ms(), "t"));
        s2.emit(Event::instant(TrackId::new(1, 2), "y", s2.now_ms(), "t"));
        s2.name_track(TrackId::new(1, 1), "first");
        let m = s.take();
        assert_eq!(m.events.len(), 2);
        assert_eq!(m.tracks.len(), 1);
        assert!(s.take().events.is_empty());
    }

    #[test]
    fn name_track_is_idempotent() {
        let mut s = MemSink::default();
        s.name_track(TrackId::new(1, 1), "old");
        s.name_track(TrackId::new(1, 1), "new");
        assert_eq!(s.tracks, vec![(TrackId::new(1, 1), "new".to_string())]);
    }

    #[test]
    #[should_panic(expected = "PanicSink")]
    fn panic_sink_panics_on_event() {
        PanicSink.event(Event::instant(TrackId::new(1, 1), "boom", 0.0, "t"));
    }
}
