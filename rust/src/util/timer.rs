//! Micro-benchmark timing harness (criterion is unavailable offline).
//!
//! Used by `rust/benches/*` (with `harness = false`) and by the §Perf pass:
//! warmup, fixed-duration sampling, mean/p50/p99 reporting.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.min_ns),
        )
    }
}

/// Human-friendly duration formatting.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Run `f` repeatedly: `warmup` duration of warmup, then sample batches for
/// `measure` duration (at least 10 samples). Each sample times one call.
pub fn bench<F: FnMut()>(name: &str, warmup: Duration, measure: Duration, mut f: F) -> BenchResult {
    // Warmup.
    let start = Instant::now();
    let mut warm_iters = 0u64;
    while start.elapsed() < warmup || warm_iters < 3 {
        f();
        warm_iters += 1;
    }
    // Measure.
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < measure || samples.len() < 10 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        if samples.len() >= 1_000_000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters: samples.len() as u64,
        mean_ns: mean,
        p50_ns: samples[samples.len() / 2],
        p99_ns: samples[(samples.len() as f64 * 0.99) as usize % samples.len()],
        min_ns: samples[0],
    }
}

/// Convenience wrapper with default durations, printing the report line.
pub fn bench_quick<F: FnMut()>(name: &str, f: F) -> BenchResult {
    let r = bench(name, Duration::from_millis(200), Duration::from_millis(800), f);
    println!("{}", r.report());
    r
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench(
            "spin",
            Duration::from_millis(1),
            Duration::from_millis(10),
            || {
                let mut s = 0u64;
                for i in 0..1000 {
                    s = s.wrapping_add(black_box(i));
                }
                black_box(s);
            },
        );
        assert!(r.iters >= 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p99_ns);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("us"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
