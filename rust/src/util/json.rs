//! Minimal JSON reader/writer (serde_json is unavailable offline).
//!
//! Used for: the artifact manifest written by `python/compile/aot.py`,
//! GNN training-sample interchange, cluster/search config files, and the
//! coordinator wire protocol. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (sufficient for our ASCII data).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors -----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- accessors ---------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["key"]`-style access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Vector of f64 from a numeric array.
    pub fn to_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // ---- serialization -----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- parsing -----------------------------------------------------------

    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = utf8_width(c);
                        let end = start + width;
                        if end > self.b.len() {
                            return Err(self.err("bad utf8"));
                        }
                        let s = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf8"))?;
                        out.push_str(s);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_width(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, {"b": "x\ny"}], "c": null, "d": true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x\ny"));
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo é"));
    }

    #[test]
    fn errors_are_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn get_missing_is_null() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert_eq!(v.get("zz"), &Json::Null);
        assert_eq!(v.get("a").as_usize(), Some(1));
    }

    #[test]
    fn numbers_scientific() {
        let v = Json::parse("[1e3, -2.5E-2, 0.125]").unwrap();
        assert_eq!(v.to_f64_vec().unwrap(), vec![1000.0, -0.025, 0.125]);
    }

    #[test]
    fn stable_object_order() {
        let v = Json::obj(vec![("b", Json::Num(1.0)), ("a", Json::Num(2.0))]);
        assert_eq!(v.to_string(), r#"{"a":2,"b":1}"#);
    }
}
