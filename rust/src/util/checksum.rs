//! CRC32C (Castagnoli) — the per-record checksum behind the v3 plan-store
//! framing (DESIGN.md §14).
//!
//! Std-only, table-driven, reflected-polynomial implementation. CRC32C is
//! chosen over plain CRC32 for its better error-detection spectrum on
//! short records (it is the same polynomial iSCSI and ext4 use for
//! exactly this torn/garbled-sector job); the table is built in a `const
//! fn` so the whole module stays allocation-free and dependency-free.

/// Reflected CRC32C polynomial (0x1EDC6F41 bit-reversed).
const POLY: u32 = 0x82F6_3B78;

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { POLY ^ (crc >> 1) } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// CRC32C of `data` in one shot.
pub fn crc32c(data: &[u8]) -> u32 {
    let mut c = Crc32c::new();
    c.update(data);
    c.finish()
}

/// Streaming CRC32C state, for callers that checksum incrementally
/// (e.g. a framed writer that hashes while it copies).
#[derive(Debug, Clone)]
pub struct Crc32c {
    state: u32,
}

impl Crc32c {
    pub fn new() -> Crc32c {
        Crc32c { state: !0 }
    }

    pub fn update(&mut self, data: &[u8]) {
        let mut s = self.state;
        for &b in data {
            s = TABLE[((s ^ b as u32) & 0xFF) as usize] ^ (s >> 8);
        }
        self.state = s;
    }

    pub fn finish(&self) -> u32 {
        !self.state
    }
}

impl Default for Crc32c {
    fn default() -> Self {
        Crc32c::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer_vector() {
        // The canonical CRC32C check value (RFC 3720 appendix / every
        // published implementation): crc32c("123456789") = 0xE3069283.
        assert_eq!(crc32c(b"123456789"), 0xE306_9283);
    }

    #[test]
    fn empty_and_zero_vectors() {
        assert_eq!(crc32c(b""), 0);
        // 32 zero bytes — second RFC 3720 test vector.
        assert_eq!(crc32c(&[0u8; 32]), 0x8A91_36AA);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data = b"the quick brown fox jumps over the lazy dog";
        for split in 0..data.len() {
            let mut c = Crc32c::new();
            c.update(&data[..split]);
            c.update(&data[split..]);
            assert_eq!(c.finish(), crc32c(data));
        }
    }

    #[test]
    fn single_bit_flips_change_the_checksum() {
        let base = b"v3 framed plan-store record payload".to_vec();
        let crc = crc32c(&base);
        for i in 0..base.len() * 8 {
            let mut flipped = base.clone();
            flipped[i / 8] ^= 1 << (i % 8);
            assert_ne!(crc32c(&flipped), crc, "bit flip {i} undetected");
        }
    }
}
