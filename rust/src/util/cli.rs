//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

/// Parsed command line: positionals plus `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("search --model vgg19 --alpha=1.05 out.json --fast");
        assert_eq!(a.positional, vec!["search", "out.json"]);
        assert_eq!(a.get("model"), Some("vgg19"));
        assert_eq!(a.get_f64("alpha", 0.0), 1.05);
        assert!(a.has_flag("fast"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_or("m", "d"), "d");
        assert!(!a.has_flag("fast"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b v --c");
        assert!(a.has_flag("a"));
        assert_eq!(a.get("b"), Some("v"));
        assert!(a.has_flag("c"));
    }
}
