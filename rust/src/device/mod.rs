//! Analytical device model — the substitute for the paper's real GPUs.
//!
//! The paper profiles ops on GTX 1080 Ti / Tesla T4 and lets fused-op cost
//! emerge from the hardware. Here a roofline model plays that role:
//!
//! ```text
//! t(op)  = max(flops / (peak·eff), traffic / bw) + launch
//! t(fused) = max(Σ flops_i/(peak·eff_i), boundary_traffic + spill) · I(n) + launch
//! ```
//!
//! Fusion gains exactly what it gains on a GPU: intermediate results that
//! fit the on-chip budget stop round-tripping through device memory, and
//! n−1 kernel launches disappear. Fusion *costs* what it costs on a GPU:
//! an interaction penalty `I(n)` grows mildly with group size (register
//! pressure / occupancy loss), and oversized intermediates spill. These
//! non-linear terms are what the GNN estimator has to learn — per-op
//! profiled times alone cannot predict them.
//!
//! The searcher is **never** allowed to query this model for fused ops; it
//! sees only profiled per-op times (through [`crate::profiler`]) and the
//! estimator. The device model is "the hardware".

use crate::graph::{FusedGroup, Node, OpKind};
use crate::util::rng::Rng;

/// Static description of a device.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    pub name: String,
    /// Peak f32 throughput, FLOP/s.
    pub peak_flops: f64,
    /// Device-memory bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Kernel launch + synchronization overhead per kernel, ms.
    pub launch_overhead_ms: f64,
    /// On-chip working-set budget (registers/L2/shared-memory proxy), bytes.
    pub onchip_bytes: f64,
    /// Multiplicative noise sigma for "measurements" on this device.
    pub noise_sigma: f64,
}

/// The analytical device model.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceModel {
    pub spec: DeviceSpec,
}

/// Achievable fraction of peak FLOPs by op kind (GPUs never hit peak on
/// real kernels; dense ops come closest).
fn efficiency(kind: OpKind) -> f64 {
    match kind {
        OpKind::MatMul | OpKind::BatchMatMul => 0.62,
        OpKind::Conv2D => 0.55,
        OpKind::Embedding | OpKind::Gather | OpKind::Scatter | OpKind::Sort => 0.15,
        OpKind::Reduce | OpKind::Softmax | OpKind::CrossEntropy => 0.35,
        OpKind::LayerNorm | OpKind::BatchNorm | OpKind::Pool => 0.40,
        _ => 0.85, // elementwise / data movement: effectively bw-bound anyway
    }
}

impl DeviceModel {
    /// GTX-1080-Ti-like device (the paper's Cluster A GPUs):
    /// 11.3 TFLOP/s f32, 484 GB/s GDDR5X, ~3 MB L2.
    pub fn gtx1080ti() -> DeviceModel {
        DeviceModel {
            spec: DeviceSpec {
                name: "gtx1080ti".to_string(),
                peak_flops: 11.3e12,
                mem_bw: 484.0e9,
                launch_overhead_ms: 0.005,
                onchip_bytes: 3.0 * 1024.0 * 1024.0,
                noise_sigma: 0.05,
            },
        }
    }

    /// Tesla-T4-like device (the paper's Cluster B GPUs):
    /// 8.1 TFLOP/s f32, 300 GB/s GDDR6, 4 MB L2.
    pub fn tesla_t4() -> DeviceModel {
        DeviceModel {
            spec: DeviceSpec {
                name: "tesla_t4".to_string(),
                peak_flops: 8.1e12,
                mem_bw: 300.0e9,
                launch_overhead_ms: 0.005,
                onchip_bytes: 4.0 * 1024.0 * 1024.0,
                noise_sigma: 0.05,
            },
        }
    }

    /// Interaction penalty for an `n`-op fused kernel: register pressure and
    /// occupancy degrade slowly with kernel complexity.
    fn interaction(n: usize) -> f64 {
        1.0 + 0.02 * ((1 + n) as f64).ln()
    }

    /// True execution time of a *single original* op, ms.
    pub fn single_op_time_ms(
        &self,
        kind: OpKind,
        flops: f64,
        bytes_in: f64,
        bytes_out: f64,
    ) -> f64 {
        if matches!(kind, OpKind::Parameter | OpKind::Constant) {
            return 0.0;
        }
        let compute_ms = flops / (self.spec.peak_flops * efficiency(kind)) * 1e3;
        let mem_ms = (bytes_in + bytes_out) / self.spec.mem_bw * 1e3;
        compute_ms.max(mem_ms) + self.spec.launch_overhead_ms
    }

    /// True execution time of a fused group, ms. `bytes_in`/`bytes_out` are
    /// the *boundary* traffic of the fused kernel (computed by the fusion
    /// transform); internal tensors only cost when they spill.
    pub fn fused_time_ms(&self, group: &FusedGroup, bytes_in: f64, bytes_out: f64) -> f64 {
        if group.ops.is_empty() {
            return 0.0;
        }
        let compute_ms: f64 = group
            .ops
            .iter()
            .map(|o| o.flops / (self.spec.peak_flops * efficiency(o.kind)) * 1e3)
            .sum();
        // Internal tensors: outputs of member ops consumed inside the group.
        // Working set beyond the on-chip budget spills to device memory
        // (write + read back).
        let mut internal_producers: Vec<usize> = group.edges.iter().map(|&(p, _)| p).collect();
        internal_producers.sort_unstable();
        internal_producers.dedup();
        let mut spill = 0.0;
        let mut working_set = 0.0;
        for &p in &internal_producers {
            let b = group.ops[p].bytes_out;
            if b > self.spec.onchip_bytes {
                spill += 2.0 * b; // streams through device memory entirely
            } else {
                working_set += b;
            }
        }
        if working_set > self.spec.onchip_bytes {
            // The part of the working set that doesn't fit round-trips once.
            spill += 2.0 * (working_set - self.spec.onchip_bytes);
        }
        let mem_ms = (bytes_in + bytes_out + spill) / self.spec.mem_bw * 1e3;
        compute_ms.max(mem_ms) * Self::interaction(group.ops.len()) + self.spec.launch_overhead_ms
    }

    /// True execution time of any node (dispatches on fused/unfused), ms.
    /// AllReduce is not a device op — the network model owns it.
    pub fn node_time_ms(&self, node: &Node) -> f64 {
        debug_assert_ne!(node.kind, OpKind::AllReduce);
        match &node.fused {
            Some(g) => self.fused_time_ms(g, node.bytes_in, node.bytes_out),
            None => self.single_op_time_ms(node.kind, node.flops, node.bytes_in, node.bytes_out),
        }
    }

    /// One noisy "measurement", as a profiler or a real run would observe.
    pub fn measure_ms(&self, true_ms: f64, rng: &mut Rng) -> f64 {
        true_ms * rng.gen_lognormal_factor(self.spec.noise_sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OrigOp;

    fn orig(id: usize, kind: OpKind, flops: f64, bin: f64, bout: f64) -> OrigOp {
        OrigOp { orig_id: id, kind, flops, bytes_in: bin, bytes_out: bout, time_ms: 0.0, duplicated: false }
    }

    #[test]
    fn compute_bound_matmul() {
        let d = DeviceModel::gtx1080ti();
        // 4096^3 matmul: clearly compute bound.
        let flops = 2.0 * 4096f64.powi(3);
        let bytes = 3.0 * 4096.0 * 4096.0 * 4.0;
        let t = d.single_op_time_ms(OpKind::MatMul, flops, bytes * 2.0 / 3.0, bytes / 3.0);
        let compute_only = flops / (11.3e12 * 0.62) * 1e3;
        assert!((t - compute_only - 0.005).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn bandwidth_bound_elementwise() {
        let d = DeviceModel::gtx1080ti();
        let elems = 1e7;
        let t = d.single_op_time_ms(OpKind::Add, elems, 2.0 * elems * 4.0, elems * 4.0);
        let mem_only = 3.0 * elems * 4.0 / 484.0e9 * 1e3;
        assert!((t - mem_only - 0.005).abs() < 1e-9);
    }

    #[test]
    fn leaves_are_free() {
        let d = DeviceModel::tesla_t4();
        assert_eq!(d.single_op_time_ms(OpKind::Parameter, 0.0, 0.0, 1e9), 0.0);
    }

    #[test]
    fn fusing_elementwise_chain_saves_time() {
        let d = DeviceModel::gtx1080ti();
        // a -> b -> c chain of big elementwise ops (1M elems, 4MB tensors —
        // wait, use 256KB tensors so they fit on-chip).
        let bytes = 256.0 * 1024.0;
        let elems = bytes / 4.0;
        let sum_unfused: f64 = (0..3)
            .map(|_| d.single_op_time_ms(OpKind::Mul, elems, bytes, bytes))
            .sum();
        let group = FusedGroup {
            ops: vec![
                orig(0, OpKind::Mul, elems, bytes, bytes),
                orig(1, OpKind::Mul, elems, bytes, bytes),
                orig(2, OpKind::Mul, elems, bytes, bytes),
            ],
            edges: vec![(0, 1), (1, 2)],
        };
        let fused = d.fused_time_ms(&group, bytes, bytes);
        assert!(
            fused < sum_unfused * 0.7,
            "fused={fused} unfused={sum_unfused}"
        );
    }

    #[test]
    fn oversized_intermediates_spill() {
        let d = DeviceModel::gtx1080ti();
        let big = 64.0 * 1024.0 * 1024.0; // 64 MB >> on-chip
        let elems = big / 4.0;
        let group_big = FusedGroup {
            ops: vec![
                orig(0, OpKind::Mul, elems, big, big),
                orig(1, OpKind::Mul, elems, big, big),
            ],
            edges: vec![(0, 1)],
        };
        let small = 128.0 * 1024.0;
        let group_small = FusedGroup {
            ops: vec![
                orig(0, OpKind::Mul, small / 4.0, small, small),
                orig(1, OpKind::Mul, small / 4.0, small, small),
            ],
            edges: vec![(0, 1)],
        };
        // Big group gets little relative benefit: fused ~= sum of parts.
        let fused_big = d.fused_time_ms(&group_big, big, big);
        let parts_big: f64 =
            2.0 * d.single_op_time_ms(OpKind::Mul, elems, big * 1.0, big) - 0.005;
        assert!(fused_big > parts_big * 0.8, "fused={fused_big} parts={parts_big}");
        // Small group: clear win.
        let fused_small = d.fused_time_ms(&group_small, small, small);
        let parts_small: f64 = 2.0 * d.single_op_time_ms(OpKind::Mul, small / 4.0, small * 2.0, small);
        assert!(fused_small < parts_small);
    }

    #[test]
    fn interaction_penalty_monotone() {
        assert!(DeviceModel::interaction(2) < DeviceModel::interaction(10));
        assert!(DeviceModel::interaction(10) < DeviceModel::interaction(100));
        assert!(DeviceModel::interaction(100) < 1.15);
    }

    #[test]
    fn measurement_noise_centered() {
        let d = DeviceModel::gtx1080ti();
        let mut rng = Rng::new(5);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| d.measure_ms(1.0, &mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean={mean}");
    }
}
