//! Profiler (paper §4.2 / §5.2): records per-op execution times, fits the
//! linear AllReduce model, and generates the fused-op training samples for
//! the GNN estimator.
//!
//! The profiler is the only component allowed to touch the device model
//! for *individual* ops (that's what profiling is); fused-op ground truth
//! appears only as labels of generated training samples — the search never
//! sees it directly.

use crate::device::DeviceModel;
use crate::fusion::{self, FusionKind};
use crate::graph::{FusedGroup, NodeId, OpKind, TrainingGraph};
use crate::network::{Cluster, CommModel};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::linear_regression;

/// Profiled data for one (graph, device, cluster) combination.
#[derive(Debug, Clone)]
pub struct ProfileData {
    /// Average measured time of each original op, indexed by node id.
    pub op_time_ms: Vec<f64>,
    /// Fitted AllReduce model `T = C·x + D`.
    pub comm: CommModel,
    /// Estimated per-kernel launch overhead (ms), from the elementwise-op
    /// regression intercept. Available to white-box estimators.
    pub launch_est_ms: f64,
    /// Estimated effective memory bandwidth (bytes/ms), from the
    /// elementwise-op regression slope.
    pub bw_est_bytes_per_ms: f64,
}

impl ProfileData {
    /// Profiled time of an original op (0 for out-of-range ids).
    pub fn time_of(&self, id: NodeId) -> f64 {
        self.op_time_ms.get(id).copied().unwrap_or(0.0)
    }

    /// Fill `time_ms` of every member of a fused group from the profile
    /// (the GNN's per-node feature, paper §4.3.1).
    pub fn annotate_group(&self, group: &mut FusedGroup) {
        for o in &mut group.ops {
            o.time_ms = self.time_of(o.orig_id);
        }
    }
}

/// Profile every op of `graph` on `device` (`reps` noisy measurements,
/// averaged) and fit the AllReduce linear model on `cluster`.
pub fn profile(
    graph: &TrainingGraph,
    device: &DeviceModel,
    cluster: &Cluster,
    reps: usize,
    seed: u64,
) -> ProfileData {
    let mut rng = Rng::new(seed);
    let mut op_time_ms = vec![0.0; graph.nodes.len()];
    let mut ew_points: Vec<(f64, f64)> = Vec::new(); // (bytes, ms) of elementwise ops
    for n in graph.live() {
        if n.kind == OpKind::AllReduce {
            continue;
        }
        let truth = device.node_time_ms(n);
        let avg: f64 = (0..reps.max(1))
            .map(|_| device.measure_ms(truth, &mut rng))
            .sum::<f64>()
            / reps.max(1) as f64;
        op_time_ms[n.id] = avg;
        if n.kind.is_elementwise() && avg > 0.0 {
            ew_points.push((n.bytes_in + n.bytes_out, avg));
        }
    }

    // Fit comm model from a size sweep + the graph's own gradient sizes.
    let mut samples: Vec<(f64, f64)> = Vec::new();
    for i in 1..=64usize {
        let bytes = (i * i) as f64 * 64.0 * 1024.0; // 64KB .. 256MB, quadratic sweep
        for _ in 0..reps.max(1) {
            samples.push((bytes, cluster.measure_allreduce_ms(bytes, &mut rng)));
        }
    }
    for &ar in &graph.allreduces() {
        let bytes = graph.nodes[ar].bytes_out;
        for _ in 0..reps.max(1) {
            samples.push((bytes, cluster.measure_allreduce_ms(bytes, &mut rng)));
        }
    }
    let comm = CommModel::fit(&samples);

    // White-box hardware constants from profiled elementwise ops.
    let (launch_est_ms, bw_est_bytes_per_ms) = if ew_points.len() >= 2 {
        let xs: Vec<f64> = ew_points.iter().map(|p| p.0).collect();
        let ys: Vec<f64> = ew_points.iter().map(|p| p.1).collect();
        match std::panic::catch_unwind(|| linear_regression(&xs, &ys)) {
            Ok(fit) if fit.slope > 0.0 => (fit.intercept.max(1e-4), 1.0 / fit.slope),
            _ => (0.005, 4.0e8),
        }
    } else {
        (0.005, 4.0e8)
    };

    ProfileData { op_time_ms, comm, launch_est_ms, bw_est_bytes_per_ms }
}

/// One GNN training sample: a fused-op subgraph (features) and its
/// measured execution time (label).
#[derive(Debug, Clone)]
pub struct FusedSample {
    pub group: FusedGroup,
    pub bytes_in: f64,
    pub bytes_out: f64,
    /// Ground-truth ("profiled") execution time of the fused kernel, ms.
    pub label_ms: f64,
}

impl FusedSample {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "ops",
                Json::Arr(
                    self.group
                        .ops
                        .iter()
                        .map(|o| {
                            Json::obj(vec![
                                ("kind", Json::Num(o.kind.feature_index() as f64)),
                                ("flops", Json::Num(o.flops)),
                                ("bin", Json::Num(o.bytes_in)),
                                ("bout", Json::Num(o.bytes_out)),
                                ("t", Json::Num(o.time_ms)),
                                ("dup", Json::Bool(o.duplicated)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "edges",
                Json::Arr(
                    self.group
                        .edges
                        .iter()
                        .map(|&(a, b)| Json::arr_usize(&[a, b]))
                        .collect(),
                ),
            ),
            ("bin", Json::Num(self.bytes_in)),
            ("bout", Json::Num(self.bytes_out)),
            ("label", Json::Num(self.label_ms)),
        ])
    }
}

/// Generate `count` random fused-op samples from `graph` (paper §5.2:
/// "randomly select an op and fuse it with one of its predecessors, then
/// repeatedly fuse this fused op with one predecessor"). Labels are noisy
/// measurements of the device model's fused-kernel time.
pub fn generate_fused_samples(
    graph: &TrainingGraph,
    device: &DeviceModel,
    profile: &ProfileData,
    count: usize,
    max_group: usize,
    seed: u64,
) -> Vec<FusedSample> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(count);
    let mut attempts = 0usize;
    while out.len() < count && attempts < count * 20 {
        attempts += 1;
        let mut scratch = graph.clone();
        let compute = scratch.compute_ops();
        let Some(&start) = rng.choose(&compute) else { continue };
        let mut cur = start;
        let steps = rng.gen_range_inclusive(1, max_group.saturating_sub(1).max(1));
        for _ in 0..steps {
            let preds: Vec<NodeId> = scratch.nodes[cur]
                .inputs
                .iter()
                .copied()
                .filter(|&p| {
                    !scratch.nodes[p].deleted
                        && (scratch.nodes[p].kind.is_fusible_compute()
                            || scratch.nodes[p].kind == OpKind::Fused)
                })
                .collect();
            let Some(&p) = rng.choose(&preds) else { break };
            let kind = if rng.gen_bool(0.25) {
                FusionKind::Duplicate
            } else {
                FusionKind::NonDuplicate
            };
            match fusion::fuse_ops(&mut scratch, p, cur, kind) {
                Ok(f) => cur = f,
                Err(_) => break,
            }
            if scratch.nodes[cur]
                .fused
                .as_ref()
                .map(|g| g.len() >= max_group)
                .unwrap_or(false)
            {
                break;
            }
        }
        let node = &scratch.nodes[cur];
        let Some(group) = node.fused.clone() else { continue };
        let mut group = group;
        profile.annotate_group(&mut group);
        let truth = device.fused_time_ms(&group, node.bytes_in, node.bytes_out);
        // Average of 3 noisy measurements, like real profiling.
        let label: f64 =
            (0..3).map(|_| device.measure_ms(truth, &mut rng)).sum::<f64>() / 3.0;
        out.push(FusedSample {
            group,
            bytes_in: node.bytes_in,
            bytes_out: node.bytes_out,
            label_ms: label,
        });
    }
    out
}

/// Serialize samples to the JSON file consumed by
/// `python/compile/model.py`'s data loader and by `runtime::gnn` tests.
pub fn samples_to_json(samples: &[FusedSample]) -> String {
    Json::Arr(samples.iter().map(|s| s.to_json()).collect()).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::Role;

    fn graph() -> TrainingGraph {
        let mut b = GraphBuilder::new("p", 12);
        let x = b.constant("x", &[1 << 18]);
        let mut prev = x;
        for i in 0..8 {
            let m = b.compute(OpKind::Mul, &format!("m{i}"), &[prev], &[1 << 18], Role::Forward);
            let t = b.compute(OpKind::Tanh, &format!("t{i}"), &[m], &[1 << 18], Role::Forward);
            prev = t;
        }
        let p = b.param("w", &[1 << 18]);
        b.grad_sync("w", &[prev], p, 1e6);
        b.finish()
    }

    #[test]
    fn profile_times_positive_and_reasonable() {
        let g = graph();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let p = profile(&g, &d, &c, 3, 42);
        for n in g.live() {
            if n.kind == OpKind::AllReduce || n.kind == OpKind::Parameter || n.kind == OpKind::Constant {
                continue;
            }
            let t = p.time_of(n.id);
            let truth = d.node_time_ms(n);
            assert!(t > 0.0);
            assert!((t - truth).abs() / truth < 0.2, "t={t} truth={truth}");
        }
    }

    #[test]
    fn comm_fit_close_to_cluster_truth() {
        let g = graph();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let p = profile(&g, &d, &c, 3, 42);
        let exact = CommModel::exact(&c);
        assert!((p.comm.c - exact.c).abs() / exact.c < 0.1);
        let big = 32.0 * 1024.0 * 1024.0;
        let err = (p.comm.predict_ms(big) - c.allreduce_time_ms(big)).abs()
            / c.allreduce_time_ms(big);
        assert!(err < 0.1, "err={err}");
    }

    #[test]
    fn launch_and_bw_estimates_sane() {
        let g = graph();
        let d = DeviceModel::gtx1080ti();
        let p = profile(&g, &d, &Cluster::cluster_a(), 3, 7);
        // True launch overhead is 0.005ms; bandwidth 484 GB/s = 4.84e8 B/ms.
        assert!(p.launch_est_ms > 0.001 && p.launch_est_ms < 0.02, "launch={}", p.launch_est_ms);
        assert!(
            p.bw_est_bytes_per_ms > 1e8 && p.bw_est_bytes_per_ms < 1e9,
            "bw={}",
            p.bw_est_bytes_per_ms
        );
    }

    #[test]
    fn sample_generation_produces_valid_groups() {
        let g = graph();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let p = profile(&g, &d, &c, 2, 1);
        let samples = generate_fused_samples(&g, &d, &p, 50, 8, 99);
        assert!(samples.len() >= 40, "got {}", samples.len());
        for s in &samples {
            assert!(s.group.len() >= 2, "trivial group");
            assert!(s.group.len() <= 8);
            assert!(s.label_ms > 0.0);
            // Member times were annotated from the profile.
            assert!(s.group.ops.iter().any(|o| o.time_ms > 0.0));
            // Edges reference valid member indices.
            for &(a, b) in &s.group.edges {
                assert!(a < s.group.len() && b < s.group.len());
            }
        }
        // Deterministic for a fixed seed.
        let again = generate_fused_samples(&g, &d, &p, 50, 8, 99);
        assert_eq!(samples.len(), again.len());
        assert_eq!(samples[0].label_ms, again[0].label_ms);
    }

    #[test]
    fn samples_json_parses() {
        let g = graph();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let p = profile(&g, &d, &c, 1, 1);
        let samples = generate_fused_samples(&g, &d, &p, 5, 6, 3);
        let s = samples_to_json(&samples);
        let parsed = Json::parse(&s).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), samples.len());
        let first = &parsed.as_arr().unwrap()[0];
        assert!(first.get("label").as_f64().unwrap() > 0.0);
    }
}
