//! Cluster topology and ring-AllReduce communication model — the
//! substitute for NCCL on the paper's 100 GbE testbeds.
//!
//! The paper (§4.2) models AllReduce time as `T = C·x + D` and justifies it
//! with the ring formula `T = 2(N−1)x / (B·N)` (full-duplex NICs, [42]).
//! We implement exactly that ground truth — bottleneck bandwidth `B` is the
//! per-GPU share of the machine NIC — plus a fixed negotiation overhead `D`
//! that makes small tensors expensive (the motivation for tensor fusion).
//! The profiler *fits* the linear model from noisy measurements; the fitted
//! `(C, D)` is what the estimator uses, mirroring the paper's pipeline.

pub mod ps;

use crate::util::rng::Rng;
use crate::util::stats::{linear_regression, LinearFit};

/// A homogeneous GPU cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct Cluster {
    pub name: String,
    pub machines: usize,
    pub gpus_per_machine: usize,
    /// NIC bandwidth per machine, bytes/s (100 GbE = 12.5 GB/s).
    pub nic_bw: f64,
    /// Fixed per-AllReduce negotiation/synchronization overhead, ms.
    pub overhead_ms: f64,
    /// Multiplicative noise sigma on "real" communication times.
    pub noise_sigma: f64,
}

impl Cluster {
    /// Paper Cluster A: 6 machines × 2 GTX 1080 Ti, 100 GbE.
    pub fn cluster_a() -> Cluster {
        Cluster {
            name: "A".to_string(),
            machines: 6,
            gpus_per_machine: 2,
            nic_bw: 12.5e9,
            overhead_ms: 0.35,
            noise_sigma: 0.08,
        }
    }

    /// Paper Cluster B: 8 machines × 8 Tesla T4, 100 GbE.
    pub fn cluster_b() -> Cluster {
        Cluster {
            name: "B".to_string(),
            machines: 8,
            gpus_per_machine: 8,
            nic_bw: 12.5e9,
            overhead_ms: 0.35,
            noise_sigma: 0.08,
        }
    }

    /// A single-device "cluster" (Fig. 8 single-device comparison).
    pub fn single_device() -> Cluster {
        Cluster {
            name: "single".to_string(),
            machines: 1,
            gpus_per_machine: 1,
            nic_bw: 12.5e9,
            overhead_ms: 0.0,
            noise_sigma: 0.0,
        }
    }

    pub fn num_devices(&self) -> usize {
        self.machines * self.gpus_per_machine
    }

    /// Bottleneck bandwidth along the ring, bytes/s. GPUs on one machine
    /// share its NIC, so the inter-machine hop divides the NIC bandwidth.
    pub fn bottleneck_bw(&self) -> f64 {
        if self.machines <= 1 {
            // Intra-machine ring over PCIe-like links.
            16.0e9
        } else {
            self.nic_bw / self.gpus_per_machine as f64
        }
    }

    /// True ring-AllReduce time for a tensor of `bytes`, ms.
    pub fn allreduce_time_ms(&self, bytes: f64) -> f64 {
        let n = self.num_devices() as f64;
        if n <= 1.0 {
            return 0.0;
        }
        let transfer = 2.0 * (n - 1.0) * bytes / (self.bottleneck_bw() * n);
        transfer * 1e3 + self.overhead_ms
    }

    /// A noisy "measurement" of an AllReduce, as the profiler observes.
    pub fn measure_allreduce_ms(&self, bytes: f64, rng: &mut Rng) -> f64 {
        self.allreduce_time_ms(bytes) * rng.gen_lognormal_factor(self.noise_sigma)
    }
}

/// The fitted linear communication model `T = C·x + D` the estimator uses
/// (paper §4.2 Profiler).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommModel {
    /// ms per byte.
    pub c: f64,
    /// fixed overhead, ms.
    pub d: f64,
    pub r2: f64,
}

impl CommModel {
    /// Fit from profiled (bytes, ms) samples.
    pub fn fit(samples: &[(f64, f64)]) -> CommModel {
        let xs: Vec<f64> = samples.iter().map(|s| s.0).collect();
        let ys: Vec<f64> = samples.iter().map(|s| s.1).collect();
        let LinearFit { slope, intercept, r2 } = linear_regression(&xs, &ys);
        CommModel { c: slope, d: intercept.max(0.0), r2 }
    }

    /// Exact model derived from a cluster (used in tests / oracle mode).
    pub fn exact(cluster: &Cluster) -> CommModel {
        let n = cluster.num_devices() as f64;
        let c = if n <= 1.0 {
            0.0
        } else {
            2.0 * (n - 1.0) / (cluster.bottleneck_bw() * n) * 1e3
        };
        CommModel { c, d: if n <= 1.0 { 0.0 } else { cluster.overhead_ms }, r2: 1.0 }
    }

    /// Predicted AllReduce time for a tensor of `bytes`, ms.
    pub fn predict_ms(&self, bytes: f64) -> f64 {
        self.c * bytes + self.d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_sizes() {
        assert_eq!(Cluster::cluster_a().num_devices(), 12);
        assert_eq!(Cluster::cluster_b().num_devices(), 64);
    }

    #[test]
    fn ring_formula() {
        let c = Cluster::cluster_a();
        let bytes = 100.0 * 1024.0 * 1024.0;
        let t = c.allreduce_time_ms(bytes);
        let b = 12.5e9 / 2.0;
        let expect = 2.0 * 11.0 * bytes / (b * 12.0) * 1e3 + 0.35;
        assert!((t - expect).abs() < 1e-9);
    }

    #[test]
    fn single_device_free() {
        assert_eq!(Cluster::single_device().allreduce_time_ms(1e9), 0.0);
    }

    #[test]
    fn small_tensors_dominated_by_overhead() {
        let c = Cluster::cluster_a();
        let t_small = c.allreduce_time_ms(1024.0);
        assert!(t_small < 0.36 && t_small > 0.34);
        // Fusing 10 tiny tensors beats 10 separate calls.
        let fused = c.allreduce_time_ms(10.0 * 1024.0);
        let separate = 10.0 * t_small;
        assert!(fused < separate / 5.0);
    }

    #[test]
    fn fused_transfer_never_cheaper_than_sum_of_transfers() {
        // Pure transfer time is linear; savings come only from overhead D.
        let c = Cluster::cluster_b();
        let t1 = c.allreduce_time_ms(5e6) - c.overhead_ms;
        let t2 = c.allreduce_time_ms(7e6) - c.overhead_ms;
        let tf = c.allreduce_time_ms(12e6) - c.overhead_ms;
        assert!((tf - (t1 + t2)).abs() < 1e-9);
    }

    #[test]
    fn comm_fit_recovers_exact() {
        let cluster = Cluster::cluster_a();
        let mut rng = Rng::new(42);
        let mut samples = Vec::new();
        for i in 1..200 {
            let bytes = i as f64 * 1e6;
            samples.push((bytes, cluster.measure_allreduce_ms(bytes, &mut rng)));
        }
        let fit = CommModel::fit(&samples);
        let exact = CommModel::exact(&cluster);
        assert!((fit.c - exact.c).abs() / exact.c < 0.05, "c={} vs {}", fit.c, exact.c);
        assert!((fit.d - exact.d).abs() < 0.3, "d={}", fit.d);
        assert!(fit.r2 > 0.95);
    }
}
