//! Parameter-server communication model — the paper's §8 future-work
//! extension ("replace AllReduce instructions with push and pull").
//!
//! Gradients are sharded over `servers` parameter servers; each worker
//! pushes its gradient shard-wise and pulls the updated parameters back.
//! Per tensor of `x` bytes on a `W`-worker / `S`-server cluster where
//! every server NIC sustains `B` bytes/s:
//!
//! ```text
//! T_push = W·x / (S·B) + D        (server inbound is the bottleneck)
//! T_pull = W·x / (S·B) + D
//! T      = T_push + T_pull        (pull depends on the pushed update)
//! ```
//!
//! The graph transform [`to_parameter_server`] keeps the IR unchanged —
//! each AllReduce node simply becomes a "push+pull" synchronization whose
//! time comes from [`PsModel`] instead of the ring formula, so the whole
//! pipeline (simulation, search, tensor fusion) works unmodified: DisCo's
//! method (iii) now fuses push/pull rounds exactly as the paper suggests.

use crate::network::Cluster;

/// Parameter-server topology and timing model.
#[derive(Debug, Clone, PartialEq)]
pub struct PsModel {
    pub workers: usize,
    pub servers: usize,
    /// Per-server NIC bandwidth, bytes/s.
    pub server_bw: f64,
    /// Fixed per-round (push or pull) overhead, ms.
    pub overhead_ms: f64,
}

impl PsModel {
    /// Derive a PS deployment from a cluster: servers get the same NICs
    /// as the workers' machines.
    pub fn from_cluster(cluster: &Cluster, servers: usize) -> PsModel {
        PsModel {
            workers: cluster.num_devices(),
            servers: servers.max(1),
            server_bw: cluster.nic_bw,
            overhead_ms: cluster.overhead_ms / 2.0, // per direction
        }
    }

    /// One push round for a tensor of `bytes`, ms.
    pub fn push_time_ms(&self, bytes: f64) -> f64 {
        self.workers as f64 * bytes / (self.servers as f64 * self.server_bw) * 1e3
            + self.overhead_ms
    }

    /// One pull round, ms (same volume back out).
    pub fn pull_time_ms(&self, bytes: f64) -> f64 {
        self.push_time_ms(bytes)
    }

    /// Full synchronization (push then pull), ms — the drop-in
    /// replacement for the AllReduce time in the simulator.
    pub fn sync_time_ms(&self, bytes: f64) -> f64 {
        self.push_time_ms(bytes) + self.pull_time_ms(bytes)
    }
}

/// A cost-source wrapper swapping the communication model to PS while
/// delegating compute times.
pub struct PsCostSource<'a> {
    pub inner: &'a dyn crate::sim::CostSource,
    pub ps: PsModel,
}

impl crate::sim::CostSource for PsCostSource<'_> {
    fn compute_time_ms(&self, node: &crate::graph::Node) -> f64 {
        self.inner.compute_time_ms(node)
    }

    fn comm_time_ms(&self, bytes: f64) -> f64 {
        self.ps.sync_time_ms(bytes)
    }

    fn prepare(&self, graph: &crate::graph::TrainingGraph) {
        self.inner.prepare(graph);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::CostSource;

    #[test]
    fn ps_times_scale_with_workers_and_servers() {
        let a = PsModel { workers: 12, servers: 1, server_bw: 12.5e9, overhead_ms: 0.2 };
        let b = PsModel { workers: 12, servers: 4, server_bw: 12.5e9, overhead_ms: 0.2 };
        let bytes = 100e6;
        assert!(a.sync_time_ms(bytes) > b.sync_time_ms(bytes));
        // 4x servers ≈ 4x faster transfer (minus fixed overhead).
        let ta = a.push_time_ms(bytes) - 0.2;
        let tb = b.push_time_ms(bytes) - 0.2;
        assert!((ta / tb - 4.0).abs() < 1e-9);
    }

    #[test]
    fn sync_is_push_plus_pull() {
        let m = PsModel { workers: 8, servers: 2, server_bw: 10e9, overhead_ms: 0.1 };
        assert!((m.sync_time_ms(1e6) - 2.0 * m.push_time_ms(1e6)).abs() < 1e-12);
    }

    #[test]
    fn ps_cost_source_swaps_comm_only() {
        struct Unit;
        impl CostSource for Unit {
            fn compute_time_ms(&self, _n: &crate::graph::Node) -> f64 {
                1.5
            }
            fn comm_time_ms(&self, _b: f64) -> f64 {
                99.0
            }
        }
        let ps = PsModel { workers: 4, servers: 2, server_bw: 10e9, overhead_ms: 0.1 };
        let src = PsCostSource { inner: &Unit, ps: ps.clone() };
        let node = crate::graph::Node {
            id: 0,
            name: "x".into(),
            kind: crate::graph::OpKind::Mul,
            role: crate::graph::Role::Forward,
            inputs: vec![],
            orig_inputs: vec![],
            shape: crate::graph::Shape::new(&[1]),
            dtype: crate::graph::DType::F32,
            flops: 0.0,
            bytes_in: 0.0,
            bytes_out: 0.0,
            fused: None,
            ar_constituents: vec![],
            chunk: None,
            shard: None,
            deleted: false,
        };
        assert_eq!(src.compute_time_ms(&node), 1.5);
        assert_eq!(src.comm_time_ms(1e6), ps.sync_time_ms(1e6));
    }
}
