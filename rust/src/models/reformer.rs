//! Reformer (Kitaev et al., 2020): LSH attention over long sequences with
//! shared-QK projection. Structurally distinctive pieces we model:
//! the hash/sort/gather bucketing pipeline (Sort + Gather are *opaque* ops
//! that block fusion — realistic obstacles for the search), chunked
//! attention (cost linear-ish in sequence length), and per-chunk FFN.
//! 6 layers, d=512, seq 512, vocab 32k.

use super::{ModelSpec, Net};
use crate::graph::{NodeId, OpKind, Role, TrainingGraph};

pub const D_MODEL: usize = 512;
pub const D_FF: usize = 2048;
pub const SEQ: usize = 512;
pub const LAYERS: usize = 6;
pub const VOCAB: usize = 32_768;
pub const CHUNK: usize = 64;

pub fn build(spec: &ModelSpec, num_workers: usize) -> TrainingGraph {
    let mut net = Net::new("reformer", num_workers);
    let b = spec.batch;
    let (d, s, v, ff) = (D_MODEL, SEQ, VOCAB, D_FF);

    let tokens = net.b.constant("tokens", &[b, s]);
    let emb_flops = (b * s * d) as f64;
    net.checkpoint("embed", &[b, s, d], emb_flops, OpKind::Embedding);
    net.track_param("embed.w", &[v, d], emb_flops);
    let mut x: NodeId =
        net.b
            .compute_flops(OpKind::Embedding, "embed", &[tokens], &[b, s, d], Role::Forward, emb_flops);

    for l in 0..spec.scaled(LAYERS) {
        x = lsh_layer(&mut net, x, &format!("l{l}"), b, s, d, ff);
    }

    let proj_flops = 2.0 * (b * s * d * v) as f64;
    net.track_param("lm_head.w", &[d, v], proj_flops);
    let logits =
        net.b
            .compute_flops(OpKind::MatMul, "lm_head", &[x], &[b, s, v], Role::Forward, proj_flops);
    net.checkpoint("lm_head", &[b, s, v], proj_flops, OpKind::MatMul);

    net.finish_with_backprop(logits)
}

/// One Reformer layer: shared-QK LSH attention + chunked FFN.
fn lsh_layer(net: &mut Net, x: NodeId, name: &str, b: usize, s: usize, d: usize, ff: usize) -> NodeId {
    let proj_flops = 2.0 * (b * s * d * d) as f64;

    // Shared QK projection + V projection.
    net.track_param(&format!("{name}.wqk"), &[d, d], proj_flops);
    let qk = net.b.compute_flops(OpKind::MatMul, &format!("{name}.qk"), &[x], &[b, s, d], Role::Forward, proj_flops);
    net.checkpoint(&format!("{name}.qk"), &[b, s, d], proj_flops, OpKind::MatMul);
    net.track_param(&format!("{name}.wv"), &[d, d], proj_flops);
    let vv = net.b.compute_flops(OpKind::MatMul, &format!("{name}.v"), &[x], &[b, s, d], Role::Forward, proj_flops);
    net.checkpoint(&format!("{name}.v"), &[b, s, d], proj_flops, OpKind::MatMul);

    // LSH bucketing: random rotations (matmul), argmax hash, sort, gather.
    let n_hashes = 4usize;
    let rot_flops = 2.0 * (b * s * d * n_hashes * 16) as f64;
    let rot = net.b.compute_flops(OpKind::MatMul, &format!("{name}.rot"), &[qk], &[b, s, n_hashes * 16], Role::Forward, rot_flops);
    net.checkpoint(&format!("{name}.rot"), &[b, s, n_hashes * 16], rot_flops, OpKind::MatMul);
    let hash = net.b.compute(OpKind::Reduce, &format!("{name}.hash"), &[rot], &[b, s], Role::Forward);
    let sorted = net.b.compute(OpKind::Sort, &format!("{name}.sort"), &[hash], &[b, s], Role::Forward);
    let gathered = net.b.compute(OpKind::Gather, &format!("{name}.gather"), &[sorted, qk, vv], &[b, s, 2 * d], Role::Forward);
    net.checkpoint(&format!("{name}.gather"), &[b, s, 2 * d], (b * s * 2 * d) as f64, OpKind::Gather);

    // Chunked attention: per 64-token chunk, attend within chunk and one
    // neighbour → cost ∝ s * (2*CHUNK) * d instead of s².
    let att_flops = 2.0 * (b * s * 2 * CHUNK * d) as f64;
    let scores = net.b.compute_flops(
        OpKind::BatchMatMul,
        &format!("{name}.scores"),
        &[gathered],
        &[b, s, 2 * CHUNK],
        Role::Forward,
        att_flops,
    );
    net.checkpoint(&format!("{name}.scores"), &[b, s, 2 * CHUNK], att_flops, OpKind::BatchMatMul);
    let probs = net.b.compute(OpKind::Softmax, &format!("{name}.softmax"), &[scores], &[b, s, 2 * CHUNK], Role::Forward);
    let ctx = net.b.compute_flops(
        OpKind::BatchMatMul,
        &format!("{name}.ctx"),
        &[probs, gathered],
        &[b, s, d],
        Role::Forward,
        att_flops,
    );
    net.checkpoint(&format!("{name}.ctx"), &[b, s, d], att_flops, OpKind::BatchMatMul);
    // Undo the sort.
    let unsorted = net.b.compute(OpKind::Scatter, &format!("{name}.unsort"), &[ctx], &[b, s, d], Role::Forward);

    net.track_param(&format!("{name}.wo"), &[d, d], proj_flops);
    let out = net.b.compute_flops(OpKind::MatMul, &format!("{name}.o"), &[unsorted], &[b, s, d], Role::Forward, proj_flops);
    net.checkpoint(&format!("{name}.o"), &[b, s, d], proj_flops, OpKind::MatMul);

    // Reversible residual (modelled as plain residual + LN).
    let res = net.b.compute(OpKind::Add, &format!("{name}.res1"), &[out, x], &[b, s, d], Role::Forward);
    net.track_param(&format!("{name}.ln1"), &[2 * d], (b * s * d) as f64);
    let ln1 = net.b.compute(OpKind::LayerNorm, &format!("{name}.ln1"), &[res], &[b, s, d], Role::Forward);
    net.checkpoint(&format!("{name}.ln1"), &[b, s, d], 6.0 * (b * s * d) as f64, OpKind::LayerNorm);

    // Chunked FFN.
    let ff_flops = 2.0 * (b * s * d * ff) as f64;
    net.track_param(&format!("{name}.ff1"), &[d, ff], ff_flops);
    let h1 = net.b.compute_flops(OpKind::MatMul, &format!("{name}.ff1"), &[ln1], &[b, s, ff], Role::Forward, ff_flops);
    net.checkpoint(&format!("{name}.ff1"), &[b, s, ff], ff_flops, OpKind::MatMul);
    let act = net.b.compute(OpKind::Gelu, &format!("{name}.gelu"), &[h1], &[b, s, ff], Role::Forward);
    net.track_param(&format!("{name}.ff2"), &[ff, d], ff_flops);
    let h2 = net.b.compute_flops(OpKind::MatMul, &format!("{name}.ff2"), &[act], &[b, s, d], Role::Forward, ff_flops);
    net.checkpoint(&format!("{name}.ff2"), &[b, s, d], ff_flops, OpKind::MatMul);
    let res2 = net.b.compute(OpKind::Add, &format!("{name}.res2"), &[h2, ln1], &[b, s, d], Role::Forward);
    net.track_param(&format!("{name}.ln2"), &[2 * d], (b * s * d) as f64);
    let ln2 = net.b.compute(OpKind::LayerNorm, &format!("{name}.ln2"), &[res2], &[b, s, d], Role::Forward);
    net.checkpoint(&format!("{name}.ln2"), &[b, s, d], 6.0 * (b * s * d) as f64, OpKind::LayerNorm);
    ln2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reformer_has_opaque_ops() {
        let g = build(&ModelSpec::reformer(), 12);
        assert!(g.live().any(|n| n.kind == OpKind::Sort));
        assert!(g.live().any(|n| n.kind == OpKind::Gather));
        assert!(g.live().any(|n| n.kind == OpKind::Scatter));
    }

    #[test]
    fn parameter_count() {
        let g = build(&ModelSpec::reformer(), 12);
        let params = g.total_gradient_bytes() / 4.0;
        // 2 vocab matrices (33.5M) + 6 layers x ~3.2M ≈ 53M.
        assert!(params > 40e6 && params < 65e6, "{:.1}M", params / 1e6);
    }

    #[test]
    fn chunked_attention_cheaper_than_full() {
        // LSH attention FLOPs should be well below s^2 full attention.
        let g = build(&ModelSpec::reformer(), 12);
        let att: f64 = g
            .live()
            .filter(|n| {
                n.kind == OpKind::BatchMatMul && n.role == crate::graph::Role::Forward
            })
            .map(|n| n.flops)
            .sum();
        let b = 16.0;
        let full = 2.0 * 2.0 * b * (SEQ * SEQ * D_MODEL) as f64 * spec_layers() as f64;
        assert!(att < full / 2.0, "att={att} full={full}");
    }

    fn spec_layers() -> usize {
        LAYERS
    }
}
