//! BERT-base (Devlin et al., 2018): 12 layers, d=768, 12 heads, FFN 3072,
//! seq 128, vocab 30522 — ~110M parameters.
//!
//! Reuses the transformer encoder layer with BERT dimensions plus the
//! token-type/position embeddings and the MLM head.

use super::{transformer::encoder_layer, ModelSpec, Net};
use crate::graph::{OpKind, Role, TrainingGraph};

pub const D_MODEL: usize = 768;
pub const D_FF: usize = 3072;
pub const SEQ: usize = 128;
pub const LAYERS: usize = 12;
pub const VOCAB: usize = 30_522;

pub fn build(spec: &ModelSpec, num_workers: usize) -> TrainingGraph {
    let mut net = Net::new("bert", num_workers);
    let b = spec.batch;
    let (d, s, v, ff) = (D_MODEL, SEQ, VOCAB, D_FF);

    let tokens = net.b.constant("tokens", &[b, s]);
    let emb_flops = (b * s * d) as f64;
    net.checkpoint("embed", &[b, s, d], emb_flops, OpKind::Embedding);
    net.track_param("embed.word", &[v, d], emb_flops);
    net.track_param("embed.pos", &[512, d], emb_flops);
    net.track_param("embed.type", &[2, d], emb_flops);
    let we = net.b.compute_flops(OpKind::Embedding, "embed.word", &[tokens], &[b, s, d], Role::Forward, emb_flops);
    let pe = net.b.compute_flops(OpKind::Embedding, "embed.pos", &[tokens], &[b, s, d], Role::Forward, emb_flops);
    let sum = net.b.compute(OpKind::Add, "embed.sum", &[we, pe], &[b, s, d], Role::Forward);
    net.track_param("embed.ln", &[2 * d], (b * s * d) as f64);
    let mut x = net.b.compute(OpKind::LayerNorm, "embed.ln", &[sum], &[b, s, d], Role::Forward);
    net.checkpoint("embed.ln", &[b, s, d], 6.0 * (b * s * d) as f64, OpKind::LayerNorm);

    for l in 0..spec.scaled(LAYERS) {
        x = encoder_layer(&mut net, x, &format!("l{l}"), b, s, d, ff);
    }

    // MLM head: dense d->d + GELU + LN, then decode to vocab.
    let head_flops = 2.0 * (b * s * d * d) as f64;
    net.track_param("mlm.dense", &[d, d], head_flops);
    let h = net.b.compute_flops(OpKind::MatMul, "mlm.dense", &[x], &[b, s, d], Role::Forward, head_flops);
    net.checkpoint("mlm.dense", &[b, s, d], head_flops, OpKind::MatMul);
    let gelu = net.b.compute(OpKind::Gelu, "mlm.gelu", &[h], &[b, s, d], Role::Forward);
    net.track_param("mlm.ln", &[2 * d], (b * s * d) as f64);
    let ln = net.b.compute(OpKind::LayerNorm, "mlm.ln", &[gelu], &[b, s, d], Role::Forward);
    net.checkpoint("mlm.ln", &[b, s, d], 6.0 * (b * s * d) as f64, OpKind::LayerNorm);
    let dec_flops = 2.0 * (b * s * d * v) as f64;
    net.track_param("mlm.decoder", &[d, v], dec_flops);
    let logits = net.b.compute_flops(OpKind::MatMul, "mlm.decoder", &[ln], &[b, s, v], Role::Forward, dec_flops);
    net.checkpoint("mlm.decoder", &[b, s, v], dec_flops, OpKind::MatMul);

    net.finish_with_backprop(logits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_parameter_count() {
        let g = build(&ModelSpec::bert_base(), 12);
        let params = g.total_gradient_bytes() / 4.0;
        // BERT-base ≈ 110M (+23M tied decoder here since we keep it
        // separate) → expect 108-135M.
        assert!(params > 100e6 && params < 140e6, "{:.1}M", params / 1e6);
    }

    #[test]
    fn deeper_than_transformer_base() {
        let gb = build(&ModelSpec::bert_base(), 8);
        let gt = super::super::transformer::build(&ModelSpec::transformer_base(), 8);
        assert!(gb.live_count() > gt.live_count() / 2);
        assert!(gb.total_flops() > gt.total_flops() * 0.5);
    }
}
