//! Model zoo — training-graph generators for the paper's six benchmark
//! models (§6.1): VGG19, ResNet50, Transformer, RNNLM, BERT, Reformer.
//!
//! Each generator builds the forward pass at HLO-ish granularity (conv /
//! matmul ops plus their elementwise epilogues and normalizations as
//! separate instructions — the raw material op fusion works on), then a
//! structurally faithful backward pass: a reverse chain of activation-
//! gradient ops, with one weight-gradient op + AllReduce + optimizer
//! update per parameter tensor. Gradients of *later* layers are produced
//! *earlier* in backprop, which is what makes communication scheduling
//! interesting.
//!
//! Shapes, parameter counts and FLOPs follow the published architectures;
//! see each submodule.

pub mod vgg;
pub mod resnet;
pub mod transformer;
pub mod rnnlm;
pub mod bert;
pub mod reformer;

use crate::graph::builder::GraphBuilder;
use crate::graph::{NodeId, OpKind, Role, Shape, TrainingGraph};

/// Which benchmark model to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    Vgg19,
    ResNet50,
    Transformer,
    Rnnlm,
    Bert,
    Reformer,
}

impl ModelKind {
    pub const ALL: [ModelKind; 6] = [
        ModelKind::Vgg19,
        ModelKind::ResNet50,
        ModelKind::Transformer,
        ModelKind::Rnnlm,
        ModelKind::Bert,
        ModelKind::Reformer,
    ];

    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Vgg19 => "vgg19",
            ModelKind::ResNet50 => "resnet50",
            ModelKind::Transformer => "transformer",
            ModelKind::Rnnlm => "rnnlm",
            ModelKind::Bert => "bert",
            ModelKind::Reformer => "reformer",
        }
    }

    pub fn from_name(s: &str) -> Option<ModelKind> {
        ModelKind::ALL.iter().copied().find(|m| m.name() == s)
    }
}

/// Model + batch configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub kind: ModelKind,
    pub batch: usize,
    /// Scale factor on depth (1.0 = published architecture). Lets tests
    /// and quick benches use smaller variants.
    pub depth_scale: f64,
}

impl ModelSpec {
    pub fn new(kind: ModelKind, batch: usize) -> ModelSpec {
        ModelSpec { kind, batch, depth_scale: 1.0 }
    }

    pub fn vgg19() -> ModelSpec {
        ModelSpec::new(ModelKind::Vgg19, 32)
    }

    pub fn resnet50() -> ModelSpec {
        ModelSpec::new(ModelKind::ResNet50, 32)
    }

    pub fn transformer_base() -> ModelSpec {
        ModelSpec::new(ModelKind::Transformer, 32)
    }

    pub fn rnnlm() -> ModelSpec {
        ModelSpec::new(ModelKind::Rnnlm, 64)
    }

    pub fn bert_base() -> ModelSpec {
        ModelSpec::new(ModelKind::Bert, 16)
    }

    pub fn reformer() -> ModelSpec {
        ModelSpec::new(ModelKind::Reformer, 16)
    }

    /// All six paper models at their default batch sizes.
    pub fn all() -> Vec<ModelSpec> {
        vec![
            Self::vgg19(),
            Self::resnet50(),
            Self::transformer_base(),
            Self::rnnlm(),
            Self::bert_base(),
            Self::reformer(),
        ]
    }

    /// Scaled number of repeated layers/blocks.
    pub(crate) fn scaled(&self, layers: usize) -> usize {
        ((layers as f64 * self.depth_scale).round() as usize).max(1)
    }
}

/// Build the training graph of `spec` for `num_workers` data-parallel
/// workers.
pub fn build(spec: &ModelSpec, num_workers: usize) -> TrainingGraph {
    match spec.kind {
        ModelKind::Vgg19 => vgg::build(spec, num_workers),
        ModelKind::ResNet50 => resnet::build(spec, num_workers),
        ModelKind::Transformer => transformer::build(spec, num_workers),
        ModelKind::Rnnlm => rnnlm::build(spec, num_workers),
        ModelKind::Bert => bert::build(spec, num_workers),
        ModelKind::Reformer => reformer::build(spec, num_workers),
    }
}

// ---------------------------------------------------------------------------
// Shared forward/backward construction machinery.
// ---------------------------------------------------------------------------

/// A tracked parameter: its graph node and how expensive its weight
/// gradient is to compute.
pub(crate) struct ParamInfo {
    pub name: String,
    pub id: NodeId,
    pub dims: Vec<usize>,
    pub grad_flops: f64,
    /// Index of the backward-chain checkpoint this weight gradient hangs
    /// off (set by `track_param`).
    pub checkpoint: usize,
}

/// A step of the backward activation-gradient chain.
pub(crate) struct Checkpoint {
    pub name: String,
    pub act_dims: Vec<usize>,
    pub bwd_flops: f64,
    pub kind: OpKind,
}

/// Forward-pass builder that records everything needed to synthesize a
/// faithful backward pass.
pub(crate) struct Net {
    pub b: GraphBuilder,
    params: Vec<ParamInfo>,
    checkpoints: Vec<Checkpoint>,
}

impl Net {
    pub fn new(name: &str, num_workers: usize) -> Net {
        Net { b: GraphBuilder::new(name, num_workers), params: Vec::new(), checkpoints: Vec::new() }
    }

    /// Record a backward-chain step mirroring a forward op: the backward
    /// op has the given output (activation-gradient) dims and FLOPs.
    pub fn checkpoint(&mut self, name: &str, act_dims: &[usize], bwd_flops: f64, kind: OpKind) -> usize {
        self.checkpoints.push(Checkpoint {
            name: name.to_string(),
            act_dims: act_dims.to_vec(),
            bwd_flops,
            kind,
        });
        self.checkpoints.len() - 1
    }

    /// Declare a parameter whose weight gradient is produced at the most
    /// recent checkpoint.
    pub fn track_param(&mut self, name: &str, dims: &[usize], grad_flops: f64) -> NodeId {
        let id = self.b.param(name, dims);
        let checkpoint = self.checkpoints.len().saturating_sub(1);
        self.params.push(ParamInfo {
            name: name.to_string(),
            id,
            dims: dims.to_vec(),
            grad_flops,
            checkpoint,
        });
        id
    }

    /// Number of parameter elements tracked so far.
    #[allow(dead_code)]
    pub fn param_elems(&self) -> usize {
        self.params.iter().map(|p| Shape::new(&p.dims).elems()).sum()
    }

    /// Synthesize the backward pass from the recorded checkpoints and
    /// parameters, then finish the graph. `loss_input` is the last forward
    /// node (logits); a loss op is appended first.
    pub fn finish_with_backprop(mut self, loss_input: NodeId) -> TrainingGraph {
        let loss_dims: Vec<usize> = self.b.graph().nodes[loss_input].shape.dims.clone();
        let loss =
            self.b
                .compute(OpKind::CrossEntropy, "loss", &[loss_input], &[1], Role::Forward);
        let mut grad = self.b.compute(
            OpKind::Sub,
            "loss.grad",
            &[loss],
            &loss_dims,
            Role::Backward,
        );

        // Group parameters by checkpoint for quick lookup.
        let mut by_ck: Vec<Vec<usize>> = vec![Vec::new(); self.checkpoints.len().max(1)];
        for (i, p) in self.params.iter().enumerate() {
            by_ck[p.checkpoint].push(i);
        }

        for ck_idx in (0..self.checkpoints.len()).rev() {
            // Weight gradients for parameters attached to this checkpoint.
            for &pi in &by_ck[ck_idx] {
                let (pname, pid, pdims, gflops) = {
                    let p = &self.params[pi];
                    (p.name.clone(), p.id, p.dims.clone(), p.grad_flops)
                };
                let gw = self.b.compute_flops(
                    OpKind::MatMul,
                    &format!("{pname}.grad_w"),
                    &[grad],
                    &pdims,
                    Role::Backward,
                    gflops,
                );
                let ar = self.b.allreduce(&format!("{pname}.allreduce"), gw, &pdims);
                self.b.optimizer_update(&format!("{pname}.apply"), &[ar, pid]);
            }
            // Activation gradient flowing to the previous checkpoint.
            let ck = &self.checkpoints[ck_idx];
            let (name, dims, flops, kind) =
                (format!("{}.grad_a", ck.name), ck.act_dims.clone(), ck.bwd_flops, ck.kind);
            grad = self.b.compute_flops(kind, &name, &[grad], &dims, Role::Backward, flops);
        }
        self.b.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    #[test]
    fn all_models_build_and_validate() {
        for spec in ModelSpec::all() {
            let g = build(&spec, 12);
            assert!(g.validate().is_ok(), "{:?}", spec.kind);
            assert!(g.allreduces().len() > 3, "{:?} has too few gradients", spec.kind);
            assert!(g.live_count() > 50, "{:?} too small ({} nodes)", spec.kind, g.live_count());
            assert_eq!(g.num_workers, 12);
        }
    }

    #[test]
    fn model_names_roundtrip() {
        for m in ModelKind::ALL {
            assert_eq!(ModelKind::from_name(m.name()), Some(m));
        }
    }

    #[test]
    fn parameter_sizes_realistic() {
        // Published parameter counts (approximate): VGG19 ≈ 143M,
        // ResNet50 ≈ 25M, BERT-base ≈ 110M.
        let cases = [
            (ModelSpec::vgg19(), 120e6, 160e6),
            (ModelSpec::resnet50(), 18e6, 33e6),
            // BERT-base is ~110M with a tied decoder; ours keeps the
            // 23M-element decoder separate → ~133M.
            (ModelSpec::bert_base(), 85e6, 140e6),
        ];
        for (spec, lo, hi) in cases {
            let g = build(&spec, 8);
            let grad_elems = g.total_gradient_bytes() / 4.0;
            assert!(
                grad_elems > lo && grad_elems < hi,
                "{:?}: {:.1}M params",
                spec.kind,
                grad_elems / 1e6
            );
        }
    }

    #[test]
    fn backward_produces_one_allreduce_per_param() {
        let spec = ModelSpec::transformer_base();
        let g = build(&spec, 8);
        let params = g.live().filter(|n| n.kind == OpKind::Parameter).count();
        assert_eq!(g.allreduces().len(), params);
        let opts = g.live().filter(|n| n.kind == OpKind::ApplyOptimizer).count();
        assert_eq!(opts, params);
    }

    #[test]
    fn depth_scale_shrinks_model() {
        let mut spec = ModelSpec::bert_base();
        let full = build(&spec, 4).live_count();
        spec.depth_scale = 0.25;
        let small = build(&spec, 4).live_count();
        assert!(small < full / 2, "small={small} full={full}");
    }

    #[test]
    fn gradients_available_progressively() {
        // The first AllReduce's producer must be schedulable before the
        // whole backward pass completes: check that at least one AR does
        // not depend (transitively) on the last backward op.
        let g = build(&ModelSpec::vgg19(), 8);
        let order = g.topo_order().unwrap();
        let last_bwd = order
            .iter()
            .rev()
            .find(|&&id| g.nodes[id].role == crate::graph::Role::Backward)
            .copied()
            .unwrap();
        let first_ar = g
            .allreduces()
            .into_iter()
            .min_by_key(|&ar| order.iter().position(|&x| x == ar).unwrap())
            .unwrap();
        let pos_ar = order.iter().position(|&x| x == first_ar).unwrap();
        let pos_last = order.iter().position(|&x| x == last_bwd).unwrap();
        assert!(pos_ar < pos_last, "no early gradient availability");
    }
}
