//! VGG19 (Simonyan & Zisserman, 2014): 16 conv layers + 3 FC layers,
//! ~143M parameters — the paper's communication-bound CNN (most of the
//! gradient volume sits in the first FC layer's 102M-parameter matrix,
//! transferred at the *start* of backprop).

use super::{ModelSpec, Net};
use crate::graph::{NodeId, OpKind, Role, TrainingGraph};

/// Per-block (conv count, channels). All five pools are always applied so
/// the classifier input stays 512×7×7 even at reduced depth scale (a
/// truncated conv list would otherwise leave a gigantic feature map on
/// the first FC layer).
const BLOCKS: [(usize, usize); 5] = [(2, 64), (2, 128), (4, 256), (4, 512), (4, 512)];

pub fn build(spec: &ModelSpec, num_workers: usize) -> TrainingGraph {
    let mut net = Net::new("vgg19", num_workers);
    let b = spec.batch;
    let mut h = 224usize;
    let mut c = 3usize;

    let mut x: NodeId = net.b.constant("input", &[b, c, h, h]);
    let mut li = 0usize;
    let mut plan: Vec<usize> = Vec::new();
    for (convs, ch) in BLOCKS {
        for _ in 0..spec.scaled(convs) {
            plan.push(ch);
        }
        plan.push(0); // pool
    }
    for &plan_c in &plan {
        if plan_c == 0 {
            h /= 2;
            x = net.b.compute(OpKind::Pool, &format!("pool{li}"), &[x], &[b, c, h, h], Role::Forward);
            net.checkpoint(&format!("pool{li}"), &[b, c, h, h], (b * c * h * h) as f64, OpKind::Pool);
            continue;
        }
        let k = plan_c;
        let conv = net.b.conv2d(&format!("conv{li}"), &[x], b, c, h, h, k, 3, 1, Role::Forward);
        let conv_flops = 2.0 * (b * k * c * 3 * 3 * h * h) as f64;
        let bias = net.b.compute(OpKind::Add, &format!("conv{li}.bias"), &[conv], &[b, k, h, h], Role::Forward);
        let relu = net.b.compute(OpKind::Relu, &format!("conv{li}.relu"), &[bias], &[b, k, h, h], Role::Forward);
        // Backward through this conv (grad-input) costs about one forward.
        net.checkpoint(&format!("conv{li}"), &[b, k, h, h], conv_flops, OpKind::Conv2D);
        // Weight gradient: one more conv-sized contraction.
        net.track_param(&format!("conv{li}.w"), &[k, c, 3, 3], conv_flops);
        net.track_param(&format!("conv{li}.b"), &[k], (b * k * h * h) as f64);
        x = relu;
        c = k;
        li += 1;
    }

    // Classifier head: flatten -> 4096 -> 4096 -> 1000.
    let feat = c * h * h; // 512 * 7 * 7 = 25088 at full depth
    x = net.b.compute(OpKind::Reshape, "flatten", &[x], &[b, feat], Role::Forward);
    net.checkpoint("flatten", &[b, feat], 0.0, OpKind::Reshape);
    let mut dim_in = feat;
    for (i, dim_out) in [4096usize, 4096, 1000].into_iter().enumerate() {
        let mm = net.b.matmul(&format!("fc{i}"), &[x], 1, b, dim_in, dim_out, Role::Forward);
        let bias = net.b.compute(OpKind::Add, &format!("fc{i}.bias"), &[mm], &[b, dim_out], Role::Forward);
        let act = if i < 2 {
            net.b.compute(OpKind::Relu, &format!("fc{i}.relu"), &[bias], &[b, dim_out], Role::Forward)
        } else {
            net.b.compute(OpKind::Softmax, "logits", &[bias], &[b, dim_out], Role::Forward)
        };
        let mm_flops = 2.0 * (b * dim_in * dim_out) as f64;
        net.checkpoint(&format!("fc{i}"), &[b, dim_out], mm_flops, OpKind::MatMul);
        net.track_param(&format!("fc{i}.w"), &[dim_in, dim_out], mm_flops);
        net.track_param(&format!("fc{i}.b"), &[dim_out], (b * dim_out) as f64);
        x = act;
        dim_in = dim_out;
    }

    net.finish_with_backprop(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg19_parameter_count() {
        let g = build(&ModelSpec::vgg19(), 12);
        let params: f64 = g.total_gradient_bytes() / 4.0;
        // Published: ~143.7M parameters.
        assert!((params - 143.7e6).abs() / 143.7e6 < 0.03, "{:.1}M", params / 1e6);
    }

    #[test]
    fn fc0_dominates_gradient_volume() {
        let g = build(&ModelSpec::vgg19(), 12);
        let biggest = g
            .allreduces()
            .into_iter()
            .map(|ar| g.nodes[ar].bytes_out)
            .fold(0.0f64, f64::max);
        // fc0: 25088 x 4096 = 102.8M params = 411 MB.
        assert!((biggest - 25088.0 * 4096.0 * 4.0).abs() < 1.0);
        assert!(biggest > 0.5 * g.total_gradient_bytes());
    }

    #[test]
    fn has_conv_epilogues_to_fuse() {
        let g = build(&ModelSpec::vgg19(), 12);
        let relus = g.live().filter(|n| n.kind == OpKind::Relu).count();
        assert!(relus >= 16);
    }
}
