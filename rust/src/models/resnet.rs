//! ResNet50 (He et al., 2016): bottleneck residual network, ~25.5M
//! parameters — the paper's computation-bound CNN (many ops, modest
//! gradient volume, lots of BN/ReLU epilogues for op fusion).

use super::{ModelSpec, Net};
use crate::graph::{NodeId, OpKind, Role, TrainingGraph};

struct Stage {
    blocks: usize,
    mid: usize,
    out: usize,
    stride: usize,
}

pub fn build(spec: &ModelSpec, num_workers: usize) -> TrainingGraph {
    let mut net = Net::new("resnet50", num_workers);
    let b = spec.batch;

    // Stem: 7x7/2 conv, BN, ReLU, 3x3/2 pool.
    let mut h = 224usize;
    let mut x: NodeId = net.b.constant("input", &[b, 3, h, h]);
    h /= 2;
    x = net.b.conv2d("stem.conv", &[x], b, 3, 224, 224, 64, 7, 2, Role::Forward);
    let stem_flops = 2.0 * (b * 64 * 3 * 7 * 7 * h * h) as f64;
    net.checkpoint("stem", &[b, 64, h, h], stem_flops, OpKind::Conv2D);
    net.track_param("stem.w", &[64, 3, 7, 7], stem_flops);
    x = bn_relu(&mut net, x, "stem", b, 64, h);
    h /= 2;
    x = net.b.compute(OpKind::Pool, "stem.pool", &[x], &[b, 64, h, h], Role::Forward);
    net.checkpoint("stem.pool", &[b, 64, h, h], (b * 64 * h * h) as f64, OpKind::Pool);

    let stages = [
        Stage { blocks: 3, mid: 64, out: 256, stride: 1 },
        Stage { blocks: 4, mid: 128, out: 512, stride: 2 },
        Stage { blocks: 6, mid: 256, out: 1024, stride: 2 },
        Stage { blocks: 3, mid: 512, out: 2048, stride: 2 },
    ];
    let mut c_in = 64usize;
    for (si, st) in stages.iter().enumerate() {
        let blocks = spec.scaled(st.blocks);
        for bi in 0..blocks {
            let stride = if bi == 0 { st.stride } else { 1 };
            let name = format!("s{si}b{bi}");
            let h_out = h / stride;
            let skip = x;

            // 1x1 reduce.
            x = conv_bn_relu(&mut net, x, &format!("{name}.c1"), b, c_in, h, st.mid, 1, stride);
            // 3x3.
            x = conv_bn_relu(&mut net, x, &format!("{name}.c2"), b, st.mid, h_out, st.mid, 3, 1);
            // 1x1 expand (BN, no relu before the add).
            x = conv_bn(&mut net, x, &format!("{name}.c3"), b, st.mid, h_out, st.out, 1, 1);

            // Projection shortcut when shape changes.
            let skip_out = if bi == 0 {
                conv_bn(&mut net, skip, &format!("{name}.proj"), b, c_in, h, st.out, 1, stride)
            } else {
                skip
            };
            let add = net.b.compute(
                OpKind::Add,
                &format!("{name}.add"),
                &[x, skip_out],
                &[b, st.out, h_out, h_out],
                Role::Forward,
            );
            x = net.b.compute(
                OpKind::Relu,
                &format!("{name}.relu"),
                &[add],
                &[b, st.out, h_out, h_out],
                Role::Forward,
            );
            net.checkpoint(
                &format!("{name}.res"),
                &[b, st.out, h_out, h_out],
                (2 * b * st.out * h_out * h_out) as f64,
                OpKind::Add,
            );
            c_in = st.out;
            h = h_out;
        }
    }

    // Head: global average pool + FC to 1000 classes.
    x = net.b.compute(OpKind::Pool, "gap", &[x], &[b, c_in], Role::Forward);
    net.checkpoint("gap", &[b, c_in], (b * c_in * h * h) as f64, OpKind::Pool);
    let logits = net.b.matmul("fc", &[x], 1, b, c_in, 1000, Role::Forward);
    let fc_flops = 2.0 * (b * c_in * 1000) as f64;
    net.checkpoint("fc", &[b, 1000], fc_flops, OpKind::MatMul);
    net.track_param("fc.w", &[c_in, 1000], fc_flops);
    net.track_param("fc.b", &[1000], (b * 1000) as f64);

    net.finish_with_backprop(logits)
}

/// conv -> BN -> ReLU, with parameter tracking and a backward checkpoint.
#[allow(clippy::too_many_arguments)]
fn conv_bn_relu(
    net: &mut Net,
    x: NodeId,
    name: &str,
    b: usize,
    c_in: usize,
    h: usize,
    c_out: usize,
    k: usize,
    stride: usize,
) -> NodeId {
    let y = conv_bn(net, x, name, b, c_in, h, c_out, k, stride);
    let ho = h / stride;
    net.b
        .compute(OpKind::Relu, &format!("{name}.relu"), &[y], &[b, c_out, ho, ho], Role::Forward)
}

/// conv -> BN (no activation).
#[allow(clippy::too_many_arguments)]
fn conv_bn(
    net: &mut Net,
    x: NodeId,
    name: &str,
    b: usize,
    c_in: usize,
    h: usize,
    c_out: usize,
    k: usize,
    stride: usize,
) -> NodeId {
    let conv = net.b.conv2d(&format!("{name}.conv"), &[x], b, c_in, h, h, c_out, k, stride, Role::Forward);
    let ho = h / stride;
    let flops = 2.0 * (b * c_out * c_in * k * k * ho * ho) as f64;
    net.checkpoint(name, &[b, c_out, ho, ho], flops, OpKind::Conv2D);
    net.track_param(&format!("{name}.w"), &[c_out, c_in, k, k], flops);
    net.track_param(&format!("{name}.bn"), &[2 * c_out], (b * c_out * ho * ho) as f64);
    net.b
        .compute(OpKind::BatchNorm, &format!("{name}.bn"), &[conv], &[b, c_out, ho, ho], Role::Forward)
}

/// BN -> ReLU epilogue used by the stem.
fn bn_relu(net: &mut Net, x: NodeId, name: &str, b: usize, c: usize, h: usize) -> NodeId {
    net.track_param(&format!("{name}.bn"), &[2 * c], (b * c * h * h) as f64);
    let bn = net
        .b
        .compute(OpKind::BatchNorm, &format!("{name}.bn"), &[x], &[b, c, h, h], Role::Forward);
    net.b
        .compute(OpKind::Relu, &format!("{name}.relu"), &[bn], &[b, c, h, h], Role::Forward)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_parameter_count() {
        let g = build(&ModelSpec::resnet50(), 12);
        let params = g.total_gradient_bytes() / 4.0;
        // Published: ~25.5M (we model BN as 2c-element params).
        assert!((params - 25.5e6).abs() / 25.5e6 < 0.08, "{:.1}M", params / 1e6);
    }

    #[test]
    fn many_small_gradients() {
        // The tensor-fusion motivation: most ResNet50 gradients are small.
        let g = build(&ModelSpec::resnet50(), 12);
        let small = g
            .allreduces()
            .iter()
            .filter(|&&ar| g.nodes[ar].bytes_out < 1024.0 * 1024.0)
            .count();
        assert!(small * 2 > g.allreduces().len(), "{small} small tensors");
    }

    #[test]
    fn op_count_in_expected_range() {
        let g = build(&ModelSpec::resnet50(), 12);
        // 53 convs * (conv+bn+...) fwd + bwd chain + per-param AR/apply.
        assert!(g.live_count() > 500, "{}", g.live_count());
        assert!(g.live_count() < 2500, "{}", g.live_count());
    }
}
