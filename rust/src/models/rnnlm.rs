//! RNNLM (Ji et al., 2016): a 2-layer LSTM language model, hidden 1024,
//! unrolled 20 steps. The graph is dominated by long chains of small
//! elementwise ops (the gates), exactly the paper's Fig. 2 scenario where
//! fusion-order heuristics go wrong. Weight gradients accumulate across
//! the unrolled steps (BPTT), so all AllReduces fire late in backprop.

use super::{ModelSpec, Net};
use crate::graph::{NodeId, OpKind, Role, TrainingGraph};

pub const HIDDEN: usize = 1024;
pub const LAYERS: usize = 2;
pub const STEPS: usize = 20;
pub const VOCAB: usize = 10_000;

pub fn build(spec: &ModelSpec, num_workers: usize) -> TrainingGraph {
    let mut net = Net::new("rnnlm", num_workers);
    let b = spec.batch;
    let (hsz, v) = (HIDDEN, VOCAB);
    let steps = spec.scaled(STEPS);

    // Parameters are declared before any checkpoints so their (BPTT-
    // accumulated) gradients are produced only when backprop reaches the
    // first step — checkpoint index 0.
    let emb_flops = (b * steps * hsz) as f64;
    net.track_param("embed.w", &[v, hsz], emb_flops);
    for l in 0..LAYERS {
        let gate_flops = 2.0 * (b * steps * hsz * 4 * hsz) as f64;
        net.track_param(&format!("lstm{l}.wx"), &[hsz, 4 * hsz], gate_flops);
        net.track_param(&format!("lstm{l}.wh"), &[hsz, 4 * hsz], gate_flops);
        net.track_param(&format!("lstm{l}.b"), &[4 * hsz], (b * steps * 4 * hsz) as f64);
    }
    let proj_flops = 2.0 * (b * steps * hsz * v) as f64;
    net.track_param("proj.w", &[hsz, v], proj_flops);

    let tokens = net.b.constant("tokens", &[b, steps]);
    // Embedded inputs for all steps (one gather).
    let emb = net.b.compute_flops(
        OpKind::Embedding,
        "embed",
        &[tokens],
        &[b, steps, hsz],
        Role::Forward,
        emb_flops,
    );
    net.checkpoint("embed", &[b, steps, hsz], emb_flops, OpKind::Embedding);

    // Unrolled LSTM.
    let mut h_prev: Vec<NodeId> = Vec::new();
    let mut c_prev: Vec<NodeId> = Vec::new();
    for l in 0..LAYERS {
        h_prev.push(net.b.constant(&format!("h0.{l}"), &[b, hsz]));
        c_prev.push(net.b.constant(&format!("c0.{l}"), &[b, hsz]));
    }
    let mut outputs: Vec<NodeId> = Vec::new();
    for t in 0..steps {
        let mut input = net.b.compute(
            OpKind::Slice,
            &format!("x.{t}"),
            &[emb],
            &[b, hsz],
            Role::Forward,
        );
        for l in 0..LAYERS {
            let name = format!("t{t}.l{l}");
            let (h, c) = lstm_cell(&mut net, &name, input, h_prev[l], c_prev[l], b, hsz);
            h_prev[l] = h;
            c_prev[l] = c;
            input = h;
        }
        outputs.push(input);
    }

    // Concatenate step outputs and project to vocab.
    let cat = net.b.compute(
        OpKind::Concat,
        "concat",
        &outputs,
        &[b, steps, hsz],
        Role::Forward,
    );
    net.checkpoint("concat", &[b, steps, hsz], (b * steps * hsz) as f64, OpKind::Concat);
    let logits = net.b.compute_flops(
        OpKind::MatMul,
        "proj",
        &[cat],
        &[b, steps, v],
        Role::Forward,
        proj_flops,
    );
    net.checkpoint("proj", &[b, steps, v], proj_flops, OpKind::MatMul);

    net.finish_with_backprop(logits)
}

/// One LSTM cell at HLO granularity: two gate matmuls, bias add, then the
/// sigmoid/tanh/mul elementwise cascade (8 small ops — fusion fodder).
fn lstm_cell(
    net: &mut Net,
    name: &str,
    x: NodeId,
    h: NodeId,
    c: NodeId,
    b: usize,
    hsz: usize,
) -> (NodeId, NodeId) {
    let gflops = 2.0 * (b * hsz * 4 * hsz) as f64;
    let gx = net.b.compute_flops(OpKind::MatMul, &format!("{name}.gx"), &[x], &[b, 4 * hsz], Role::Forward, gflops);
    let gh = net.b.compute_flops(OpKind::MatMul, &format!("{name}.gh"), &[h], &[b, 4 * hsz], Role::Forward, gflops);
    let gates = net.b.compute(OpKind::Add, &format!("{name}.gsum"), &[gx, gh], &[b, 4 * hsz], Role::Forward);
    let gates = net.b.compute(OpKind::Add, &format!("{name}.gbias"), &[gates], &[b, 4 * hsz], Role::Forward);

    let i = net.b.compute(OpKind::Sigmoid, &format!("{name}.i"), &[gates], &[b, hsz], Role::Forward);
    let f = net.b.compute(OpKind::Sigmoid, &format!("{name}.f"), &[gates], &[b, hsz], Role::Forward);
    let o = net.b.compute(OpKind::Sigmoid, &format!("{name}.o"), &[gates], &[b, hsz], Role::Forward);
    let gq = net.b.compute(OpKind::Tanh, &format!("{name}.g"), &[gates], &[b, hsz], Role::Forward);
    let fc = net.b.compute(OpKind::Mul, &format!("{name}.fc"), &[f, c], &[b, hsz], Role::Forward);
    let ig = net.b.compute(OpKind::Mul, &format!("{name}.ig"), &[i, gq], &[b, hsz], Role::Forward);
    let c_new = net.b.compute(OpKind::Add, &format!("{name}.c"), &[fc, ig], &[b, hsz], Role::Forward);
    let ct = net.b.compute(OpKind::Tanh, &format!("{name}.ct"), &[c_new], &[b, hsz], Role::Forward);
    let h_new = net.b.compute(OpKind::Mul, &format!("{name}.h"), &[o, ct], &[b, hsz], Role::Forward);

    // Backward through the cell: roughly 2x the gate matmul cost.
    net.checkpoint(name, &[b, hsz], 2.0 * gflops, OpKind::MatMul);
    (h_new, c_new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rnnlm_parameter_count() {
        let g = build(&ModelSpec::rnnlm(), 12);
        let params = g.total_gradient_bytes() / 4.0;
        // emb 10.24M + 2x(4.19M+4.19M) + proj 10.24M ≈ 37.3M.
        assert!((params - 37.3e6).abs() / 37.3e6 < 0.05, "{:.1}M", params / 1e6);
    }

    #[test]
    fn few_allreduces_fired_late() {
        let g = build(&ModelSpec::rnnlm(), 12);
        // One AR per weight tensor, not per step.
        assert_eq!(g.allreduces().len(), 8);
    }

    #[test]
    fn dominated_by_elementwise_ops() {
        let g = build(&ModelSpec::rnnlm(), 12);
        let ew = g.live().filter(|n| n.kind.is_elementwise()).count();
        assert!(ew > 150, "ew={ew}");
    }
}
