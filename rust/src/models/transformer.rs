//! Transformer (Vaswani et al., 2017) — 12 layers, d=512, 8 heads,
//! FFN 2048, seq 128, vocab 32k: ~70M parameters. The paper's most
//! communication-bound NLP model (26.7% speed-up in Table 1).

use super::{ModelSpec, Net};
use crate::graph::{NodeId, OpKind, Role, TrainingGraph};

pub const D_MODEL: usize = 512;
pub const N_HEADS: usize = 8;
pub const D_FF: usize = 2048;
pub const SEQ: usize = 128;
pub const LAYERS: usize = 12;
pub const VOCAB: usize = 32_768;

pub fn build(spec: &ModelSpec, num_workers: usize) -> TrainingGraph {
    let mut net = Net::new("transformer", num_workers);
    let b = spec.batch;
    let (d, s, v, ff) = (D_MODEL, SEQ, VOCAB, D_FF);

    // Embedding lookup.
    let tokens = net.b.constant("tokens", &[b, s]);
    let emb_flops = (b * s * d) as f64;
    net.checkpoint("embed", &[b, s, d], emb_flops, OpKind::Embedding);
    net.track_param("embed.w", &[v, d], emb_flops);
    let mut x: NodeId =
        net.b
            .compute_flops(OpKind::Embedding, "embed", &[tokens], &[b, s, d], Role::Forward, emb_flops);

    for l in 0..spec.scaled(LAYERS) {
        x = encoder_layer(&mut net, x, &format!("l{l}"), b, s, d, ff);
    }

    // Output projection to the vocabulary.
    let proj_flops = 2.0 * (b * s * d * v) as f64;
    let logits = net.b.compute_flops(
        OpKind::MatMul,
        "lm_head",
        &[x],
        &[b, s, v],
        Role::Forward,
        proj_flops,
    );
    net.checkpoint("lm_head", &[b, s, v], proj_flops, OpKind::MatMul);
    net.track_param("lm_head.w", &[d, v], proj_flops);

    net.finish_with_backprop(logits)
}

/// One post-LN encoder layer: MHA + residual + LN, FFN + residual + LN.
pub(crate) fn encoder_layer(
    net: &mut Net,
    x: NodeId,
    name: &str,
    b: usize,
    s: usize,
    d: usize,
    ff: usize,
) -> NodeId {
    let qkv_flops = 2.0 * (b * s * d * d) as f64;

    // Q, K, V projections.
    let mut proj = Vec::new();
    for t in ["q", "k", "v"] {
        net.checkpoint(&format!("{name}.{t}"), &[b, s, d], qkv_flops, OpKind::MatMul);
        net.track_param(&format!("{name}.w{t}"), &[d, d], qkv_flops);
        proj.push(net.b.compute_flops(
            OpKind::MatMul,
            &format!("{name}.{t}"),
            &[x],
            &[b, s, d],
            Role::Forward,
            qkv_flops,
        ));
    }
    let (q, k, v) = (proj[0], proj[1], proj[2]);

    // Scaled dot-product attention.
    let scores_flops = 2.0 * (b * s * s * d) as f64;
    let scores = net.b.compute_flops(
        OpKind::BatchMatMul,
        &format!("{name}.qk"),
        &[q, k],
        &[b, N_HEADS, s, s],
        Role::Forward,
        scores_flops,
    );
    net.checkpoint(&format!("{name}.qk"), &[b, N_HEADS, s, s], scores_flops, OpKind::BatchMatMul);
    let probs = net.b.compute(
        OpKind::Softmax,
        &format!("{name}.softmax"),
        &[scores],
        &[b, N_HEADS, s, s],
        Role::Forward,
    );
    net.checkpoint(&format!("{name}.softmax"), &[b, N_HEADS, s, s], 5.0 * (b * N_HEADS * s * s) as f64, OpKind::Softmax);
    let ctx = net.b.compute_flops(
        OpKind::BatchMatMul,
        &format!("{name}.av"),
        &[probs, v],
        &[b, s, d],
        Role::Forward,
        scores_flops,
    );
    net.checkpoint(&format!("{name}.av"), &[b, s, d], scores_flops, OpKind::BatchMatMul);

    // Output projection + residual + LN.
    net.track_param(&format!("{name}.wo"), &[d, d], qkv_flops);
    let out = net.b.compute_flops(
        OpKind::MatMul,
        &format!("{name}.o"),
        &[ctx],
        &[b, s, d],
        Role::Forward,
        qkv_flops,
    );
    net.checkpoint(&format!("{name}.o"), &[b, s, d], qkv_flops, OpKind::MatMul);
    let res1 = net.b.compute(OpKind::Add, &format!("{name}.res1"), &[out, x], &[b, s, d], Role::Forward);
    net.track_param(&format!("{name}.ln1"), &[2 * d], (b * s * d) as f64);
    let ln1 = net.b.compute(OpKind::LayerNorm, &format!("{name}.ln1"), &[res1], &[b, s, d], Role::Forward);
    net.checkpoint(&format!("{name}.ln1"), &[b, s, d], 6.0 * (b * s * d) as f64, OpKind::LayerNorm);

    // FFN.
    let ff1_flops = 2.0 * (b * s * d * ff) as f64;
    net.track_param(&format!("{name}.ff1"), &[d, ff], ff1_flops);
    let h1 = net.b.compute_flops(
        OpKind::MatMul,
        &format!("{name}.ff1"),
        &[ln1],
        &[b, s, ff],
        Role::Forward,
        ff1_flops,
    );
    net.checkpoint(&format!("{name}.ff1"), &[b, s, ff], ff1_flops, OpKind::MatMul);
    let act = net.b.compute(OpKind::Relu, &format!("{name}.ffact"), &[h1], &[b, s, ff], Role::Forward);
    net.track_param(&format!("{name}.ff2"), &[ff, d], ff1_flops);
    let h2 = net.b.compute_flops(
        OpKind::MatMul,
        &format!("{name}.ff2"),
        &[act],
        &[b, s, d],
        Role::Forward,
        ff1_flops,
    );
    net.checkpoint(&format!("{name}.ff2"), &[b, s, d], ff1_flops, OpKind::MatMul);
    let res2 = net.b.compute(OpKind::Add, &format!("{name}.res2"), &[h2, ln1], &[b, s, d], Role::Forward);
    net.track_param(&format!("{name}.ln2"), &[2 * d], (b * s * d) as f64);
    let ln2 = net.b.compute(OpKind::LayerNorm, &format!("{name}.ln2"), &[res2], &[b, s, d], Role::Forward);
    net.checkpoint(&format!("{name}.ln2"), &[b, s, d], 6.0 * (b * s * d) as f64, OpKind::LayerNorm);
    ln2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transformer_parameter_count() {
        let g = build(&ModelSpec::transformer_base(), 12);
        let params = g.total_gradient_bytes() / 4.0;
        // 12 layers x ~3.15M + 2 x 16.8M vocab matrices ≈ 71.5M.
        assert!((params - 71.5e6).abs() / 71.5e6 < 0.05, "{:.1}M", params / 1e6);
    }

    #[test]
    fn mixture_of_small_and_large_gradients() {
        let g = build(&ModelSpec::transformer_base(), 12);
        let sizes: Vec<f64> = g.allreduces().iter().map(|&ar| g.nodes[ar].bytes_out).collect();
        let small = sizes.iter().filter(|&&s| s < 1024.0 * 1024.0).count();
        let large = sizes.iter().filter(|&&s| s > 16.0 * 1024.0 * 1024.0).count();
        assert!(small > 10, "small={small}");
        assert!(large >= 2, "large={large} (vocab matrices)");
    }

    #[test]
    fn has_softmax_and_batchmatmul() {
        let g = build(&ModelSpec::transformer_base(), 12);
        assert!(g.live().any(|n| n.kind == OpKind::Softmax));
        assert!(g.live().any(|n| n.kind == OpKind::BatchMatMul));
    }
}
