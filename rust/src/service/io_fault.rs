//! Seeded disk-fault injection for the plan store (DESIGN.md §14).
//!
//! The enactment path earned its fault-tolerance claims through
//! [`crate::coordinator::fault`]'s deterministic chaos plans; this module
//! applies the same discipline to store durability. A [`DiskFaultPlan`]
//! is parsed from a compact spec — `torn@N:BYTES,err@N,slow@N:MS` — and
//! threaded into [`super::store::PlanStore::open_with`]. Every *logical*
//! store I/O operation (one file read, one record append, one snapshot
//! write, one rename) consumes one slot of a shared 1-based op counter;
//! when the counter hits a fault's `N`, that operation fails (or stalls)
//! deterministically.
//!
//! Counting logical operations rather than raw syscalls keeps op indices
//! stable across buffer sizes and platforms, which is what makes the
//! crash-recovery tests in `tests/service.rs` reproducible. The op order
//! is documented on [`DiskFaultPlan`].
//!
//! Fault semantics:
//! * `err@N` — the Nth op returns `io::ErrorKind::Other` ("injected disk
//!   error"), modeling a read-only or failing disk.
//! * `torn@N:BYTES` — the Nth op, if it is a write, lands only its first
//!   `BYTES` bytes — with the final landed byte garbled by a seeded XOR —
//!   then errors, modeling a crash mid-append (the classic torn tail).
//! * `slow@N:MS` — the Nth op sleeps `MS` milliseconds first, modeling a
//!   saturated device (lock-contention and deadline tests).

use crate::util::rng::Rng;
use std::io::{self, Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};

/// One injected disk fault, armed at a specific logical op index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DiskFault {
    /// Crash mid-write: land `bytes` bytes (last one garbled), then fail.
    Torn { op: u64, bytes: usize },
    /// Hard I/O error.
    Err { op: u64 },
    /// Stall for `ms` milliseconds, then proceed normally.
    Slow { op: u64, ms: u64 },
}

impl DiskFault {
    pub fn op(&self) -> u64 {
        match *self {
            DiskFault::Torn { op, .. } | DiskFault::Err { op } | DiskFault::Slow { op, .. } => op,
        }
    }
}

/// A seeded, shareable schedule of disk faults over the store's logical
/// op sequence.
///
/// Op numbering (1-based, incremented per logical store operation):
/// * `PlanStore::open_with` on an existing file: one **read** op (plus a
///   compaction's read/snapshot/rename ops when recovery rewrites).
/// * `PlanStore::put`: one **append** op; if the compaction threshold
///   trips, a **read**, a **snapshot write**, and a **rename** op follow.
/// * `PlanStore::compact`: **read**, **snapshot write**, **rename**.
///
/// Lock-file housekeeping is deliberately *not* counted: it would make
/// indices depend on lock contention and stale-steal timing.
#[derive(Debug)]
pub struct DiskFaultPlan {
    pub seed: u64,
    pub faults: Vec<DiskFault>,
    ops: AtomicU64,
}

impl DiskFaultPlan {
    pub fn new(seed: u64, faults: Vec<DiskFault>) -> DiskFaultPlan {
        DiskFaultPlan { seed, faults, ops: AtomicU64::new(0) }
    }

    /// Parse a spec like `"torn@2:10,err@5,slow@1:40"`. Clauses separate
    /// on `,` or `|`; each is `kind@op[:arg]` with a 1-based op index —
    /// the same grammar family as `FaultPlan::parse` (DESIGN.md §12).
    pub fn parse(spec: &str, seed: u64) -> Result<DiskFaultPlan, String> {
        let mut faults = Vec::new();
        for clause in spec.split([',', '|']) {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (kind, rest) = clause
                .split_once('@')
                .ok_or_else(|| format!("disk-fault clause `{clause}`: missing `@`"))?;
            let num = |what: &str, s: &str| -> Result<u64, String> {
                s.trim()
                    .parse::<u64>()
                    .map_err(|_| format!("disk-fault clause `{clause}`: bad {what} `{s}`"))
            };
            let fault = match kind.trim() {
                "torn" => {
                    let (op, bytes) = rest
                        .split_once(':')
                        .ok_or_else(|| format!("disk-fault clause `{clause}`: torn needs `:BYTES`"))?;
                    DiskFault::Torn { op: num("op", op)?, bytes: num("bytes", bytes)? as usize }
                }
                "err" => DiskFault::Err { op: num("op", rest)? },
                "slow" => {
                    let (op, ms) = rest
                        .split_once(':')
                        .ok_or_else(|| format!("disk-fault clause `{clause}`: slow needs `:MS`"))?;
                    DiskFault::Slow { op: num("op", op)?, ms: num("ms", ms)? }
                }
                other => return Err(format!("unknown disk-fault kind `{other}` in `{clause}`")),
            };
            if fault.op() == 0 {
                return Err(format!("disk-fault clause `{clause}`: op index is 1-based"));
            }
            faults.push(fault);
        }
        Ok(DiskFaultPlan::new(seed, faults))
    }

    /// Canonical spec text (parse∘to_spec is identity up to separators).
    pub fn to_spec(&self) -> String {
        self.faults
            .iter()
            .map(|f| match *f {
                DiskFault::Torn { op, bytes } => format!("torn@{op}:{bytes}"),
                DiskFault::Err { op } => format!("err@{op}"),
                DiskFault::Slow { op, ms } => format!("slow@{op}:{ms}"),
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Consume one logical-op slot and return the fault armed for it, if
    /// any. Thread-safe; every store I/O path calls this exactly once.
    pub fn begin_op(&self) -> Option<DiskFault> {
        let op = self.ops.fetch_add(1, Ordering::SeqCst) + 1;
        self.faults.iter().find(|f| f.op() == op).cloned()
    }

    /// Logical ops issued so far (test introspection).
    pub fn ops_issued(&self) -> u64 {
        self.ops.load(Ordering::SeqCst)
    }
}

/// The injected-error constructor, shared so tests can match on the text.
pub fn injected_error() -> io::Error {
    io::Error::other("injected disk fault")
}

/// Read/Write/flush shim wrapping one file handle for one logical op,
/// applying at most one [`DiskFault`] to it. The store constructs one
/// `FaultFile` per logical operation with the fault (if any) that
/// [`DiskFaultPlan::begin_op`] armed for it; with no plan attached the
/// wrapper is a transparent pass-through.
#[derive(Debug)]
pub struct FaultFile<F> {
    inner: F,
    fault: Option<DiskFault>,
    seed: u64,
    /// One-shot latch: a fault fires on the first I/O call it applies to.
    tripped: bool,
}

impl<F> FaultFile<F> {
    pub fn new(inner: F, fault: Option<DiskFault>, seed: u64) -> FaultFile<F> {
        FaultFile { inner, fault, seed, tripped: false }
    }

    pub fn into_inner(self) -> F {
        self.inner
    }

    /// Take the armed fault if it should fire now, marking it tripped.
    fn trip(&mut self) -> Option<DiskFault> {
        if self.tripped {
            return None;
        }
        self.tripped = self.fault.is_some();
        self.fault.clone()
    }
}

impl<F: Read> Read for FaultFile<F> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self.trip() {
            Some(DiskFault::Err { .. }) => Err(injected_error()),
            Some(DiskFault::Slow { ms, .. }) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                self.inner.read(buf)
            }
            // Torn is a write-side fault; reads pass through.
            Some(DiskFault::Torn { .. }) | None => self.inner.read(buf),
        }
    }
}

impl<F: Write> Write for FaultFile<F> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self.trip() {
            None => self.inner.write(buf),
            Some(DiskFault::Err { .. }) => Err(injected_error()),
            Some(DiskFault::Slow { ms, .. }) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                self.inner.write(buf)
            }
            Some(DiskFault::Torn { op, bytes }) => {
                let n = bytes.min(buf.len());
                let mut partial = buf[..n].to_vec();
                if let Some(last) = partial.last_mut() {
                    // Seeded garble of the final landed byte: a torn
                    // sector rarely ends on a clean byte boundary, and
                    // the XOR is derived from (seed, op) so the damage
                    // is reproducible but varies across seeds.
                    let mut rng = Rng::new(self.seed ^ op.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                    *last ^= (rng.gen_range(255) + 1) as u8;
                }
                self.inner.write_all(&partial)?;
                let _ = self.inner.flush();
                Err(injected_error())
            }
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self.trip() {
            Some(DiskFault::Err { .. }) => Err(injected_error()),
            Some(DiskFault::Slow { ms, .. }) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                self.inner.flush()
            }
            _ => self.inner.flush(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_roundtrips_through_to_spec() {
        let plan = DiskFaultPlan::parse("torn@2:10, err@5 | slow@1:40", 7).unwrap();
        assert_eq!(plan.to_spec(), "torn@2:10,err@5,slow@1:40");
        assert_eq!(plan.faults.len(), 3);
        assert_eq!(plan.seed, 7);
    }

    #[test]
    fn parse_rejects_malformed_clauses() {
        for bad in ["torn@2", "slow@1", "err@x", "boom@1", "torn@0:4", "err"] {
            assert!(DiskFaultPlan::parse(bad, 0).is_err(), "`{bad}` should not parse");
        }
        assert!(DiskFaultPlan::parse("", 0).unwrap().faults.is_empty());
    }

    #[test]
    fn op_counter_arms_the_right_operation() {
        let plan = DiskFaultPlan::parse("err@2", 0).unwrap();
        assert!(plan.begin_op().is_none()); // op 1
        assert!(matches!(plan.begin_op(), Some(DiskFault::Err { op: 2 }))); // op 2
        assert!(plan.begin_op().is_none()); // op 3
        assert_eq!(plan.ops_issued(), 3);
    }

    #[test]
    fn torn_write_lands_garbled_prefix_then_errors() {
        let mut sink = FaultFile::new(Vec::new(), Some(DiskFault::Torn { op: 1, bytes: 4 }), 42);
        let err = sink.write_all(b"abcdefgh").unwrap_err();
        assert_eq!(err.to_string(), injected_error().to_string());
        let landed = sink.into_inner();
        assert_eq!(landed.len(), 4);
        assert_eq!(&landed[..3], b"abc");
        assert_ne!(landed[3], b'd', "final landed byte must be garbled");
        // Same seed → same garble; different seed → (almost surely) different.
        let mut again = FaultFile::new(Vec::new(), Some(DiskFault::Torn { op: 1, bytes: 4 }), 42);
        let _ = again.write_all(b"abcdefgh");
        assert_eq!(again.into_inner(), landed);
    }

    #[test]
    fn err_fault_fails_reads_writes_and_flushes_once() {
        let mut f = FaultFile::new(Cursor::new(b"data".to_vec()), Some(DiskFault::Err { op: 1 }), 0);
        let mut buf = [0u8; 4];
        assert!(f.read(&mut buf).is_err());
        // The latch tripped: subsequent calls pass through.
        assert_eq!(f.read(&mut buf).unwrap(), 4);
        let mut w = FaultFile::new(Vec::new(), Some(DiskFault::Err { op: 3 }), 0);
        assert!(w.flush().is_err());
        assert!(w.write_all(b"ok").is_ok());
    }

    #[test]
    fn passthrough_without_fault() {
        let mut f = FaultFile::new(Vec::new(), None, 0);
        f.write_all(b"hello").unwrap();
        f.flush().unwrap();
        assert_eq!(f.into_inner(), b"hello");
    }
}
