//! `disco serve` — a std-only threaded TCP front-end for the strategy
//! service (DESIGN.md §11).
//!
//! Wire protocol: length-prefixed JSON — each message is a big-endian
//! `u32` byte count followed by one UTF-8 JSON document. A connection may
//! carry any number of request/response pairs. Commands:
//!
//! * `{"cmd":"plan", "graph":{…}, "cluster":"a|b|single",
//!   "estimator":"analytical|oracle|gnn", "seed":"N", "alpha":F,
//!   "beta":N, "unchanged":N, "warm":bool, "budget_ms":F}` — resolve a
//!   strategy for the serialized [`TrainingGraph`]; everything but
//!   `graph` is optional.
//!   `seed` travels as a decimal *string* (JSON numbers are f64 and
//!   would round u64 seeds above 2^53); plain numbers are also accepted.
//!   `warm`/`nearest` override the server's warm-start policy per
//!   request; `budget_ms` caps the cold-search deadline (default is the
//!   server's `--cold-budget-ms`, 0 = unlimited).
//! * `{"cmd":"stats"}` — counters + store occupancy + resolve-latency
//!   percentiles (the `disco serve --metrics` surface). Backed by the
//!   [`crate::util::metrics`] registry (DESIGN.md §15); field names are
//!   stable API.
//! * `{"cmd":"metrics"}` — Prometheus-style text exposition of the same
//!   registry (`disco serve --prom` prints one scrape of it).
//!
//! **Admission control (DESIGN.md §14):** store hits are always served,
//! but the expensive cold path is gated twice. A per-request deadline
//! budget bounds how long a cold resolve may take (it also caps the
//! search's own `max_seconds`, and because `max_seconds` is part of the
//! environment fingerprint, budgeted and unbudgeted requests get honest,
//! distinct store keys). A cold-search concurrency cap — separate from
//! `max_conns`, which bounds cheap connection handlers — sheds excess
//! cold searches with a typed `retry_after` error frame instead of
//! letting a miss storm pile up unbounded search threads.
//! * `{"cmd":"ping"}` — liveness.
//! * `{"cmd":"shutdown"}` — drain and stop accepting.
//!
//! **Request coalescing:** concurrent `plan` requests with the same plan
//! fingerprint trigger exactly one search. The first thread to register
//! the key in the in-flight table becomes the leader; followers block on
//! the key's gate and re-resolve from the store once the leader
//! publishes. The leader re-checks the store after winning leadership
//! (classic double-checked locking), and the record is stored *before*
//! the gate is removed, so a second search for the same key is impossible
//! — asserted by the coalescing test. Store hits never profile, estimate
//! or simulate anything.

use super::fingerprint::{env_fingerprint, graph_fingerprint, plan_key, EstimatorFp, GraphSketch};
use super::store::PlanStore;
use super::warm::{record_from, seeds_from_store, try_replay_hit, PlanSource, WarmOptions};
use crate::device::DeviceModel;
use crate::estimator::CostEstimator;
use crate::graph::TrainingGraph;
use crate::network::Cluster;
use crate::profiler;
use crate::search::{backtracking_search_seeded, SearchConfig};
use crate::util::frame::{FrameError, FrameReader};
use crate::util::json::Json;
use crate::util::metrics::{Counter, Gauge, Histogram, Registry};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Frames larger than this are rejected (a corrupt length prefix must
/// not make the server try to allocate gigabytes). The cap is enforced
/// by [`FrameReader`] *before* any buffer is allocated — the same
/// hardened idiom the coordinator uses (DESIGN.md §12).
const MAX_FRAME_BYTES: usize = 64 << 20;

/// A started frame must complete within this budget — defeats slowloris
/// clients that dribble one byte per read-timeout tick and would
/// otherwise pin a handler thread forever.
const REQUEST_DEADLINE: Duration = Duration::from_secs(30);

/// Default `unchanged_limit` for served searches — service latency over
/// paper-budget exhaustiveness; requests override per call.
const SERVE_UNCHANGED_LIMIT: usize = 150;

/// Write one length-prefixed JSON frame.
pub fn write_frame(stream: &mut TcpStream, body: &str) -> std::io::Result<()> {
    let bytes = body.as_bytes();
    stream.write_all(&(bytes.len() as u32).to_be_bytes())?;
    stream.write_all(bytes)?;
    stream.flush()
}

/// Read one length-prefixed JSON frame (plain blocking form — the
/// client side, whose streams have no read timeout). Shares the capped,
/// incremental decoder with the server side; error kinds are preserved
/// for callers matching on `io::ErrorKind`.
pub fn read_frame(stream: &mut TcpStream) -> std::io::Result<String> {
    let mut reader = FrameReader::with_cap(MAX_FRAME_BYTES);
    loop {
        match reader.poll(stream) {
            Ok(Some(body)) => return Ok(body),
            Ok(None) => continue, // blocking stream: spurious wakeup only
            Err(FrameError::Io(e)) => return Err(e),
            Err(e @ (FrameError::Closed | FrameError::Eof)) => {
                return Err(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, e.to_string()))
            }
            Err(e @ FrameError::Deadline { .. }) => {
                return Err(std::io::Error::new(std::io::ErrorKind::TimedOut, e.to_string()))
            }
            Err(e) => {
                return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
            }
        }
    }
}

/// One request/response round-trip against a running server.
pub fn request(addr: &str, req: &Json) -> Result<Json> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to disco serve at {addr}"))?;
    write_frame(&mut stream, &req.to_string())?;
    let reply = read_frame(&mut stream)?;
    Json::parse(&reply).map_err(|e| anyhow!("bad server reply: {e}"))
}

/// Server configuration (CLI flags / config-file `service` section).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub addr: String,
    /// JSONL store path; `None` = memory-only.
    pub store_path: Option<String>,
    pub capacity: usize,
    pub warm: WarmOptions,
    /// Connections beyond this are shed with an `overloaded` error frame
    /// instead of spawning a handler — bounded thread usage under load.
    pub max_conns: usize,
    /// Default per-request cold-search deadline budget in milliseconds;
    /// `0` = unlimited. Requests override with `budget_ms`.
    pub cold_budget_ms: f64,
    /// Cold searches running concurrently beyond this are shed with a
    /// typed `retry_after` frame. Separate from `max_conns`: connection
    /// handlers are cheap (hits, stats, pings), searches are not. `0`
    /// admits no cold searches at all (a replay-only server).
    pub max_cold: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:7077".to_string(),
            store_path: Some("plans.jsonl".to_string()),
            capacity: 512,
            warm: WarmOptions::default(),
            max_conns: 256,
            cold_budget_ms: 0.0,
            max_cold: 8,
        }
    }
}

/// Gate a coalesced key's followers wait on.
#[derive(Default)]
struct Gate {
    done: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn wait(&self) {
        let mut done = self.done.lock().unwrap();
        while !*done {
            done = self.cv.wait(done).unwrap();
        }
    }

    fn open(&self) {
        *self.done.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

/// Registry-backed service metrics (DESIGN.md §15). Handles are resolved
/// once at bind and observed lock-free on the hot path; the registry
/// itself stays around for the `metrics` wire op's text exposition.
struct Metrics {
    registry: Registry,
    /// `disco_requests_total` — every dispatched frame.
    requests: Arc<Counter>,
    /// `disco_searches_total` — cold + warm searches actually run.
    searches: Arc<Counter>,
    /// `disco_store_hits_total` — plans replayed from the store.
    store_hits: Arc<Counter>,
    /// `disco_warm_starts_total` — searches that reused a warm seed.
    warm_starts: Arc<Counter>,
    /// `disco_coalesced_total` — followers parked behind a leader.
    coalesced: Arc<Counter>,
    /// `disco_shed_total` — connections shed at the `max_conns` gate.
    shed: Arc<Counter>,
    /// `disco_shed_cold_total` — cold searches shed by the admission cap
    /// (`retry_after` frames).
    shed_cold: Arc<Counter>,
    /// `disco_deadline_exceeded_total` — requests whose budget ran out
    /// before the search could start.
    deadline_exceeded: Arc<Counter>,
    /// `disco_active_conns` — live handler threads (shed watermark).
    active: Arc<Gauge>,
    /// `disco_cold_active` — cold searches running (admission watermark).
    cold_active: Arc<Gauge>,
    /// `disco_resolve_ms` — end-to-end `plan` latency, every outcome.
    resolve_ms: Arc<Histogram>,
    /// `disco_resolve_hit_ms` / `_warm_ms` / `_cold_ms` — the same
    /// latency split by resolution path.
    resolve_hit_ms: Arc<Histogram>,
    resolve_warm_ms: Arc<Histogram>,
    resolve_cold_ms: Arc<Histogram>,
    /// `disco_store_put_ms` — store write+persist time (disk I/O).
    store_put_ms: Arc<Histogram>,
}

impl Metrics {
    fn new() -> Metrics {
        let registry = Registry::new();
        Metrics {
            requests: registry.counter("disco_requests_total"),
            searches: registry.counter("disco_searches_total"),
            store_hits: registry.counter("disco_store_hits_total"),
            warm_starts: registry.counter("disco_warm_starts_total"),
            coalesced: registry.counter("disco_coalesced_total"),
            shed: registry.counter("disco_shed_total"),
            shed_cold: registry.counter("disco_shed_cold_total"),
            deadline_exceeded: registry.counter("disco_deadline_exceeded_total"),
            active: registry.gauge("disco_active_conns"),
            cold_active: registry.gauge("disco_cold_active"),
            resolve_ms: registry.histogram("disco_resolve_ms"),
            resolve_hit_ms: registry.histogram("disco_resolve_hit_ms"),
            resolve_warm_ms: registry.histogram("disco_resolve_warm_ms"),
            resolve_cold_ms: registry.histogram("disco_resolve_cold_ms"),
            store_put_ms: registry.histogram("disco_store_put_ms"),
            registry,
        }
    }
}

/// Shared server state.
struct State {
    store: Mutex<PlanStore>,
    inflight: Mutex<HashMap<String, Arc<Gate>>>,
    warm: WarmOptions,
    shutdown: AtomicBool,
    addr: SocketAddr,
    max_conns: usize,
    /// Default cold-search deadline budget (ms, 0 = unlimited).
    cold_budget_ms: f64,
    /// Cold-search concurrency cap (0 = admit none).
    max_cold: usize,
    m: Metrics,
}

/// RAII admission ticket for the cold-search path: at most `max_cold`
/// may exist at once. Admission is the gauge's CAS (`inc_if_below`), so
/// the watermark the scrape sees *is* the admission state — they can't
/// drift apart.
struct ColdGuard<'a>(&'a State);

impl<'a> ColdGuard<'a> {
    fn admit(state: &'a State) -> Option<ColdGuard<'a>> {
        state.m.cold_active.inc_if_below(state.max_cold as u64).then(|| ColdGuard(state))
    }
}

impl Drop for ColdGuard<'_> {
    fn drop(&mut self) {
        self.0.m.cold_active.dec();
    }
}

/// Decrements the live-handler gauge when a handler exits, however it
/// exits.
struct ActiveGuard<'a>(&'a State);

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        self.0.m.active.dec();
    }
}

/// Removes the in-flight entry and opens the gate even if the leader's
/// search fails or panics — followers must never wait forever.
struct InflightGuard<'a> {
    state: &'a State,
    key: String,
    gate: Arc<Gate>,
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.state.inflight.lock().unwrap().remove(&self.key);
        self.gate.open();
    }
}

/// The strategy server. `bind` then `run`; `run` returns after a
/// `shutdown` command has been served and live handlers drained.
pub struct Server {
    listener: TcpListener,
    state: Arc<State>,
}

impl Server {
    pub fn bind(opts: &ServeOptions) -> Result<Server> {
        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding disco serve to {}", opts.addr))?;
        let addr = listener.local_addr()?;
        let store = super::store::open_store(opts.store_path.as_deref(), opts.capacity)?;
        Ok(Server {
            listener,
            state: Arc::new(State {
                store: Mutex::new(store),
                inflight: Mutex::new(HashMap::new()),
                warm: opts.warm.clone(),
                shutdown: AtomicBool::new(false),
                addr,
                max_conns: opts.max_conns.max(1),
                cold_budget_ms: opts.cold_budget_ms.max(0.0),
                max_cold: opts.max_cold,
                m: Metrics::new(),
            }),
        })
    }

    /// The bound address (useful with `--addr 127.0.0.1:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.state.addr
    }

    /// Accept-and-dispatch loop; one thread per connection.
    pub fn run(self) -> Result<()> {
        let mut handles = Vec::new();
        for stream in self.listener.incoming() {
            if self.state.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(mut s) => {
                    // Shed on overload: beyond `max_conns` live handlers,
                    // reply inline with a typed error and drop — bounded
                    // threads beat an unbounded spawn storm.
                    if self.state.m.active.get() >= self.state.max_conns as u64 {
                        self.state.m.shed.inc();
                        let _ = s.set_write_timeout(Some(Duration::from_millis(500)));
                        let _ = write_frame(
                            &mut s,
                            &err_json("overloaded: connection limit reached, retry later")
                                .to_string(),
                        );
                        continue;
                    }
                    // Bounded read blocking so idle keep-alive connections
                    // notice shutdown instead of pinning the final join
                    // forever.
                    let _ = s.set_read_timeout(Some(Duration::from_millis(500)));
                    let state = Arc::clone(&self.state);
                    // Counted before spawn so a burst can't race past the
                    // limit; the handler's guard decrements on any exit.
                    state.m.active.inc();
                    // Reap finished handlers so a long-running server
                    // doesn't accumulate one dead JoinHandle per
                    // connection ever accepted.
                    handles.retain(|h: &std::thread::JoinHandle<()>| !h.is_finished());
                    handles.push(std::thread::spawn(move || {
                        let _guard = ActiveGuard(&state);
                        handle_conn(&state, s)
                    }));
                }
                Err(e) => eprintln!("disco serve: accept failed: {e}"),
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

fn handle_conn(state: &State, mut stream: TcpStream) {
    let mut reader = FrameReader::with_cap(MAX_FRAME_BYTES);
    // Set when the first byte of a frame arrives; a frame must complete
    // within REQUEST_DEADLINE of this instant (slowloris defense).
    let mut frame_started: Option<Instant> = None;
    loop {
        let body = match reader.poll(&mut stream) {
            // Idle tick (read timeout). Keep serving — unless the server
            // is shutting down, or a started frame has dribbled past its
            // deadline.
            Ok(None) => {
                if state.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if reader.mid_frame() {
                    let started = *frame_started.get_or_insert_with(Instant::now);
                    if started.elapsed() > REQUEST_DEADLINE {
                        let _ = write_frame(
                            &mut stream,
                            &err_json("request deadline exceeded mid-frame").to_string(),
                        );
                        return;
                    }
                } else {
                    frame_started = None;
                }
                continue;
            }
            Ok(Some(b)) => {
                frame_started = None;
                b
            }
            // A typed rejection frame tells well-meaning-but-broken
            // clients *why* before the drop; hangups just drop.
            Err(e @ (FrameError::TooLarge { .. } | FrameError::Utf8(_))) => {
                let _ = write_frame(&mut stream, &err_json(&e.to_string()).to_string());
                return;
            }
            Err(_) => return, // closed / reset / mid-frame EOF: drop
        };
        let reply = dispatch(state, &body);
        if write_frame(&mut stream, &reply.to_string()).is_err() {
            return;
        }
        if state.shutdown.load(Ordering::SeqCst) {
            // Nudge the acceptor out of its blocking `accept`.
            let _ = TcpStream::connect(state.addr);
            return;
        }
    }
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.to_string()))])
}

fn dispatch(state: &State, body: &str) -> Json {
    state.m.requests.inc();
    let req = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return err_json(&format!("bad request json: {e}")),
    };
    match req.get("cmd").as_str() {
        Some("ping") => Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))]),
        Some("stats") => stats_json(state),
        Some("metrics") => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("exposition", Json::Str(state.m.registry.expose())),
        ]),
        Some("shutdown") => {
            state.shutdown.store(true, Ordering::SeqCst);
            Json::obj(vec![("ok", Json::Bool(true)), ("stopping", Json::Bool(true))])
        }
        Some("plan") => {
            let t0 = Instant::now();
            let resp = match handle_plan(state, &req) {
                Ok(resp) => resp,
                Err(e) => err_json(&format!("{e:#}")),
            };
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            state.m.resolve_ms.observe(ms);
            // Split the same latency by resolution path so hit storms
            // can't hide a slow cold tail (and vice versa).
            match resp.get("source").as_str() {
                Some("store") => state.m.resolve_hit_ms.observe(ms),
                Some("warm") => state.m.resolve_warm_ms.observe(ms),
                Some("cold") => state.m.resolve_cold_ms.observe(ms),
                _ => {} // error / shed / deadline frames
            }
            resp
        }
        _ => err_json("unknown cmd (expected plan|stats|metrics|ping|shutdown)"),
    }
}

fn stats_json(state: &State) -> Json {
    // Same field names as the pre-registry surface (`--metrics` is
    // stable API); percentiles now come from the lock-free histogram,
    // so they are bucket upper bounds (sample ≤ estimate < 2·sample)
    // over the full history instead of a 4096-sample ring.
    let m = &state.m;
    let (p50, p99, samples) =
        (m.resolve_ms.percentile(50.0), m.resolve_ms.percentile(99.0), m.resolve_ms.count());
    let searches = m.searches.get();
    let warm_starts = m.warm_starts.get();
    let store = state.store.lock().unwrap();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("requests", Json::Num(m.requests.get() as f64)),
        ("searches", Json::Num(searches as f64)),
        ("store_hits", Json::Num(m.store_hits.get() as f64)),
        ("warm_starts", Json::Num(warm_starts as f64)),
        ("cold_searches", Json::Num(searches.saturating_sub(warm_starts) as f64)),
        ("coalesced", Json::Num(m.coalesced.get() as f64)),
        ("active_conns", Json::Num(m.active.get() as f64)),
        ("shed", Json::Num(m.shed.get() as f64)),
        ("shed_cold", Json::Num(m.shed_cold.get() as f64)),
        ("deadline_exceeded", Json::Num(m.deadline_exceeded.get() as f64)),
        ("max_conns", Json::Num(state.max_conns as f64)),
        ("max_cold", Json::Num(state.max_cold as f64)),
        ("cold_budget_ms", Json::Num(state.cold_budget_ms)),
        ("resolve_p50_ms", Json::Num(p50)),
        ("resolve_p99_ms", Json::Num(p99)),
        ("resolve_samples", Json::Num(samples as f64)),
        ("store_len", Json::Num(store.len() as f64)),
        ("store_capacity", Json::Num(store.capacity() as f64)),
        ("store_evictions", Json::Num(store.evictions as f64)),
        (
            "store_corrupt_skipped",
            Json::Num((store.recovery.corrupt + usize::from(store.recovery.torn_tail)) as f64),
        ),
        ("store_write_errors", Json::Num(store.write_errors as f64)),
        ("store_degraded", Json::Bool(store.degraded)),
        (
            "store_path",
            match store.path() {
                Some(p) => Json::Str(p.display().to_string()),
                None => Json::Null,
            },
        ),
    ])
}

/// Typed shed frame for a saturated cold-search path: clients should
/// retry after `retry_after_ms` (by then either capacity freed up or a
/// peer's identical search landed in the store).
fn retry_after_json(retry_after_ms: f64) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("kind", Json::Str("retry_after".into())),
        ("error", Json::Str("cold-search capacity saturated".into())),
        ("retry_after_ms", Json::Num(retry_after_ms)),
    ])
}

/// Typed deadline frame: the request's budget ran out before the cold
/// search could start.
fn deadline_json(budget_ms: f64) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("kind", Json::Str("deadline".into())),
        ("error", Json::Str("cold-search deadline budget exhausted".into())),
        ("budget_ms", Json::Num(budget_ms)),
    ])
}

/// Cluster preset + matching device (mirrors the CLI's convention:
/// cluster B runs T4s, everything else 1080 Tis).
fn cluster_device(name: &str) -> Result<(Cluster, DeviceModel)> {
    let cluster = match name {
        "a" => Cluster::cluster_a(),
        "b" => Cluster::cluster_b(),
        "single" => Cluster::single_device(),
        other => return Err(anyhow!("unknown cluster '{other}' (expected a|b|single)")),
    };
    let device =
        if cluster.name == "B" { DeviceModel::tesla_t4() } else { DeviceModel::gtx1080ti() };
    Ok((cluster, device))
}

/// Store-hit resolution shared by the fast path and the leader's
/// double-check: replay the cached record if present and exact. Counts
/// a `store_hits` and builds the response; `None` means "no usable
/// record — keep going".
fn try_store_hit(
    state: &State,
    key_hex: &str,
    gfp_hex: &str,
    graph: &TrainingGraph,
    start: Instant,
) -> Option<Json> {
    let mut store = state.store.lock().unwrap();
    let rec = store.get(key_hex)?;
    let best = try_replay_hit(rec, graph)?;
    let (best_ms, init_ms) = (rec.best_cost_ms, rec.initial_cost_ms);
    drop(store);
    state.m.store_hits.inc();
    Some(plan_json(
        key_hex,
        gfp_hex,
        PlanSource::Store,
        &best,
        best_ms,
        init_ms,
        0,
        0,
        0,
        start.elapsed().as_secs_f64() * 1e3,
    ))
}

fn handle_plan(state: &State, req: &Json) -> Result<Json> {
    let start = Instant::now();
    let graph = TrainingGraph::from_json_value(req.get("graph"))
        .map_err(|e| anyhow!("bad graph: {e}"))?;
    let (cluster, device) = cluster_device(req.get("cluster").as_str().unwrap_or("a"))?;
    let requested = req.get("estimator").as_str().unwrap_or("analytical");
    let estimator = match requested {
        "analytical" => "analytical",
        // As in the bench harness, GNN falls back to oracle when no
        // trained predictor is wired into the process.
        "oracle" | "gnn" => "oracle",
        other => return Err(anyhow!("unknown estimator '{other}'")),
    };
    // Estimator *content* enters the plan key: a "gnn" request folds the
    // trained-parameter artifact state, so retraining invalidates every
    // stale cached plan instead of serving costs from dead weights.
    let est_fp =
        EstimatorFp::resolve(requested, estimator, &crate::runtime::Manifest::default_dir());
    // `seed` is a u64; JSON numbers are f64 and round above 2^53, so the
    // CLI transmits it as a decimal string. Plain numbers stay accepted
    // for hand-written clients with small seeds.
    let seed = match req.get("seed") {
        Json::Null => 0xD15C0,
        Json::Str(s) => s.parse::<u64>().map_err(|_| anyhow!("bad seed '{s}'"))?,
        n => n.as_usize().ok_or_else(|| anyhow!("seed must be a number or string"))? as u64,
    };
    let mut cfg = SearchConfig {
        alpha: req.get("alpha").as_f64().unwrap_or(1.05),
        beta: req.get("beta").as_usize().unwrap_or(10),
        unchanged_limit: req.get("unchanged").as_usize().unwrap_or(SERVE_UNCHANGED_LIMIT),
        seed,
        track_best_path: true,
        ..SearchConfig::default()
    };
    // Chunked-collective vocabulary (DESIGN.md §13), per-request opt-in.
    // Both fields fold into the environment fingerprint, so chunked and
    // unchunked plans for the same graph get distinct store keys.
    if let Some(ck) = req.get("chunking").as_bool() {
        cfg.methods.chunking = ck;
    }
    if let Some(mc) = req.get("max_chunks").as_usize() {
        cfg.max_chunks = mc as u32;
    }
    // Gradient-sharding vocabulary (DESIGN.md §16), per-request opt-in
    // with the same key-separation rule as chunking.
    if let Some(sh) = req.get("sharding").as_bool() {
        cfg.methods.sharding = sh;
    }
    // Deadline budget: request field wins, else the server default;
    // 0 = unlimited. Applied to `max_seconds` BEFORE the environment
    // fingerprint so a budgeted search (which may stop early with a
    // worse plan) never shares a store key with an unbudgeted one.
    let budget_ms = req.get("budget_ms").as_f64().unwrap_or(state.cold_budget_ms).max(0.0);
    if budget_ms > 0.0 {
        let budget_s = budget_ms / 1e3;
        cfg.max_seconds =
            if cfg.max_seconds > 0.0 { cfg.max_seconds.min(budget_s) } else { budget_s };
    }
    let mut warm = state.warm.clone();
    if let Some(enabled) = req.get("warm").as_bool() {
        warm.enabled = enabled;
    }
    if let Some(nearest) = req.get("nearest").as_bool() {
        warm.nearest = nearest;
    }

    let gfp = graph_fingerprint(&graph).map_err(|e| anyhow!("unfingerprintable graph: {e}"))?;
    let gfp_hex = gfp.hex();
    let env = env_fingerprint(&cluster, &device, &est_fp, &cfg);
    let key = plan_key(gfp, env);
    let key_hex = key.hex();
    let sketch = GraphSketch::of(&graph);

    loop {
        // Fast path: serve from the store — no profiling, no simulation.
        if let Some(resp) = try_store_hit(state, &key_hex, &gfp_hex, &graph, start) {
            return Ok(resp);
        }

        // Coalesce: exactly one leader per in-flight key.
        let follower_gate = {
            let mut inflight = state.inflight.lock().unwrap();
            match inflight.get(&key_hex) {
                Some(gate) => Some(Arc::clone(gate)),
                None => {
                    inflight.insert(key_hex.clone(), Arc::new(Gate::default()));
                    None
                }
            }
        };
        if let Some(gate) = follower_gate {
            state.m.coalesced.inc();
            gate.wait();
            continue; // leader published (or failed) — re-resolve
        }

        let gate = Arc::clone(state.inflight.lock().unwrap().get(&key_hex).expect("own gate"));
        let _guard = InflightGuard { state, key: key_hex.clone(), gate };

        // Double-check: a previous leader may have published between our
        // store miss and winning leadership.
        if let Some(resp) = try_store_hit(state, &key_hex, &gfp_hex, &graph, start) {
            return Ok(resp);
        }

        // Admission control — only the expensive path below is gated;
        // store hits above are always served. Deadline first (cheap
        // signal), then the cold-concurrency cap.
        if budget_ms > 0.0 && start.elapsed().as_secs_f64() * 1e3 >= budget_ms {
            state.m.deadline_exceeded.inc();
            return Ok(deadline_json(budget_ms));
        }
        let Some(_cold) = ColdGuard::admit(state) else {
            state.m.shed_cold.inc();
            return Ok(retry_after_json(1000.0));
        };

        let seeds = {
            let store = state.store.lock().unwrap();
            seeds_from_store(&store, &key_hex, &gfp_hex, &sketch, &warm)
        };

        // Leader search — outside every lock, so distinct keys plan
        // concurrently.
        let profile = profiler::profile(&graph, &device, &cluster, 3, cfg.seed);
        let est = match estimator {
            "analytical" => CostEstimator::analytical(&profile, &cluster),
            _ => CostEstimator::oracle(&profile, &device),
        };
        let r = backtracking_search_seeded(&graph, &est, &cfg, &seeds);
        state.m.searches.inc();
        if r.warm_hits > 0 {
            state.m.warm_starts.inc();
        }
        let rec = record_from(&key, &gfp, &graph, sketch.clone(), &r);
        let put_t0 = Instant::now();
        state.store.lock().unwrap().put(rec)?;
        state.m.store_put_ms.observe(put_t0.elapsed().as_secs_f64() * 1e3);
        // `_guard` drops here: inflight entry removed AFTER the record is
        // in the store, so followers always resolve to a hit.
        let source = if r.warm_hits > 0 { PlanSource::Warm } else { PlanSource::Cold };
        return Ok(plan_json(
            &key_hex,
            &gfp_hex,
            source,
            &r.best,
            r.best_cost_ms,
            r.initial_cost_ms,
            r.evals,
            r.warm_hits,
            r.steps_saved,
            start.elapsed().as_secs_f64() * 1e3,
        ));
    }
}

#[allow(clippy::too_many_arguments)]
fn plan_json(
    key: &str,
    graph_fp: &str,
    source: PlanSource,
    best: &TrainingGraph,
    best_cost_ms: f64,
    initial_cost_ms: f64,
    evals: u64,
    warm_hits: u64,
    steps_saved: u64,
    elapsed_ms: f64,
) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("key", Json::Str(key.to_string())),
        ("graph_fp", Json::Str(graph_fp.to_string())),
        ("source", Json::Str(source.name().to_string())),
        ("best_cost_ms", Json::Num(best_cost_ms)),
        ("initial_cost_ms", Json::Num(initial_cost_ms)),
        ("evals", Json::Num(evals as f64)),
        ("warm_hits", Json::Num(warm_hits as f64)),
        ("steps_saved", Json::Num(steps_saved as f64)),
        ("elapsed_ms", Json::Num(elapsed_ms)),
        ("strategy", best.to_json_value()),
    ])
}
