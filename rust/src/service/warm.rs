//! Plan resolution: store hit → warm-started search → cold search
//! (DESIGN.md §11).
//!
//! Three outcomes, in strictly decreasing cheapness:
//!
//! 1. **Store hit** — the plan key (canonical graph fingerprint ⊕
//!    environment fingerprint) is cached *and* the record's id-sensitive
//!    arena fingerprint matches, so the recorded mutation sequence
//!    replays exactly. The strategy is reproduced with **zero simulator
//!    invocations** — no profiling, no cost estimation, no scheduling.
//! 2. **Warm start** — no exact record, but the store holds plans for
//!    the same canonical graph under other environments, or for the
//!    nearest-sketch graph. Their mutation sequences seed
//!    [`backtracking_search_seeded`], which replays whatever still
//!    applies and keeps searching from there
//!    ([`crate::search::SearchResult::steps_saved`] counts the replayed
//!    rewrites).
//! 3. **Cold** — nothing usable cached; ordinary search. Either way the
//!    result is recorded, so the next identical request is outcome 1.

use super::fingerprint::{
    arena_fingerprint, graph_fingerprint, plan_key, Fingerprint, GraphSketch,
};
use super::store::{PlanRecord, PlanStore};
use crate::fusion::Mutation;
use crate::graph::TrainingGraph;
use crate::search::{backtracking_search_seeded, SearchConfig, SearchResult};
use crate::sim::CostSource;
use anyhow::{anyhow, Result};
use std::time::{Duration, Instant};

/// Warm-start policy knobs (config-file section `service`).
#[derive(Debug, Clone)]
pub struct WarmOptions {
    /// Master switch: when false, misses go straight to a cold search.
    pub enabled: bool,
    /// Also consider the nearest-sketch plan of a *different* graph.
    pub nearest: bool,
    /// Maximum number of cached plans used as seeds.
    pub max_seeds: usize,
    /// Sketch-distance radius beyond which a nearest plan is ignored
    /// (seeding from a wildly different workload is wasted replay work).
    pub max_distance: f64,
}

impl Default for WarmOptions {
    fn default() -> Self {
        WarmOptions { enabled: true, nearest: true, max_seeds: 2, max_distance: 256.0 }
    }
}

/// Where a served plan came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanSource {
    /// Exact record replayed; zero simulator invocations.
    Store,
    /// Searched, seeded by at least one cached plan.
    Warm,
    /// Searched from scratch.
    Cold,
}

impl PlanSource {
    pub fn name(self) -> &'static str {
        match self {
            PlanSource::Store => "store",
            PlanSource::Warm => "warm",
            PlanSource::Cold => "cold",
        }
    }
}

/// A resolved plan, however it was obtained.
#[derive(Debug)]
pub struct PlanOutcome {
    /// Plan-store key (hex).
    pub key: String,
    /// Canonical graph fingerprint (hex).
    pub graph_fp: String,
    pub source: PlanSource,
    /// The optimized module.
    pub best: TrainingGraph,
    pub best_cost_ms: f64,
    pub initial_cost_ms: f64,
    /// Candidate evaluations performed serving this request (0 on a
    /// store hit — the acceptance criterion's "zero simulator
    /// invocations" is observable here and asserted with a panicking
    /// cost source in the tests).
    pub evals: u64,
    pub steps: u64,
    pub warm_hits: u64,
    pub steps_saved: u64,
    pub elapsed: Duration,
}

/// Replay a cached record onto `graph` if and only if it was recorded
/// against this exact arena (stable id-sensitive
/// [`arena_fingerprint`]) and every mutation re-applies onto a valid
/// module. `None` means "treat as a miss".
pub fn try_replay_hit(rec: &PlanRecord, graph: &TrainingGraph) -> Option<TrainingGraph> {
    if rec.arena_fp != arena_fingerprint(graph) {
        return None;
    }
    let mut g = graph.clone();
    for m in &rec.muts {
        m.replay(&mut g).ok()?;
    }
    g.validate().ok()?;
    Some(g)
}

/// Collect warm-start seeds for a missed key: plans of the same canonical
/// graph under other environments first (their rewrites are known-legal
/// on an identical structure), then the nearest-sketch plan. Deduped,
/// capped at `warm.max_seeds`, deterministic order.
pub fn seeds_from_store(
    store: &PlanStore,
    key: &str,
    graph_fp: &str,
    sketch: &GraphSketch,
    warm: &WarmOptions,
) -> Vec<Vec<Mutation>> {
    if !warm.enabled {
        return Vec::new();
    }
    let mut seen_keys: Vec<&str> = vec![key];
    let mut seeds: Vec<Vec<Mutation>> = Vec::new();
    for rec in store.by_graph_fp(graph_fp) {
        if seeds.len() >= warm.max_seeds {
            return seeds;
        }
        if rec.muts.is_empty() || seen_keys.contains(&rec.key.as_str()) {
            continue;
        }
        seen_keys.push(&rec.key);
        seeds.push(rec.muts.clone());
    }
    if warm.nearest && seeds.len() < warm.max_seeds {
        if let Some(rec) = store.nearest(sketch, key, warm.max_distance) {
            if !rec.muts.is_empty() && !seen_keys.contains(&rec.key.as_str()) {
                seeds.push(rec.muts.clone());
            }
        }
    }
    seeds
}

/// Build the persistent record for a finished search.
pub fn record_from(
    key: &Fingerprint,
    graph_fp: &Fingerprint,
    graph: &TrainingGraph,
    sketch: GraphSketch,
    r: &SearchResult,
) -> PlanRecord {
    PlanRecord {
        key: key.hex(),
        graph_fp: graph_fp.hex(),
        arena_fp: arena_fingerprint(graph),
        model: graph.name.clone(),
        sketch,
        muts: r.best_path.clone(),
        best_cost_ms: r.best_cost_ms,
        initial_cost_ms: r.initial_cost_ms,
        evals: r.evals,
        steps: r.steps,
        elapsed_ms: r.elapsed.as_secs_f64() * 1e3,
    }
}

/// Resolve a plan for `graph` through the store: hit → warm → cold, then
/// record. Single-threaded convenience used by `disco plan` local mode
/// and the tests; the server composes the same helpers around its own
/// locking and request coalescing.
///
/// `env_fp` must come from [`super::fingerprint::env_fingerprint`] over
/// the same estimator/cluster/config the caller passes here — the store
/// key is only as honest as that pairing.
pub fn plan_with_store(
    graph: &TrainingGraph,
    costs: &(dyn CostSource + Sync),
    cfg: &SearchConfig,
    env_fp: Fingerprint,
    store: &mut PlanStore,
    warm: &WarmOptions,
) -> Result<PlanOutcome> {
    let start = Instant::now();
    let gfp = graph_fingerprint(graph).map_err(|e| anyhow!("unfingerprintable graph: {e}"))?;
    let key = plan_key(gfp, env_fp);
    let key_hex = key.hex();

    if let Some(rec) = store.get(&key_hex) {
        if let Some(best) = try_replay_hit(rec, graph) {
            return Ok(PlanOutcome {
                key: key_hex,
                graph_fp: gfp.hex(),
                source: PlanSource::Store,
                best,
                best_cost_ms: rec.best_cost_ms,
                initial_cost_ms: rec.initial_cost_ms,
                evals: 0,
                steps: 0,
                warm_hits: 0,
                steps_saved: 0,
                elapsed: start.elapsed(),
            });
        }
    }

    let sketch = GraphSketch::of(graph);
    let seeds = seeds_from_store(store, &key_hex, &gfp.hex(), &sketch, warm);
    let cfg = SearchConfig { track_best_path: true, ..cfg.clone() };
    let r = backtracking_search_seeded(graph, costs, &cfg, &seeds);
    store.put(record_from(&key, &gfp, graph, sketch, &r))?;
    Ok(PlanOutcome {
        key: key_hex,
        graph_fp: gfp.hex(),
        source: if r.warm_hits > 0 { PlanSource::Warm } else { PlanSource::Cold },
        best: r.best,
        best_cost_ms: r.best_cost_ms,
        initial_cost_ms: r.initial_cost_ms,
        evals: r.evals,
        steps: r.steps,
        warm_hits: r.warm_hits,
        steps_saved: r.steps_saved,
        elapsed: start.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceModel;
    use crate::estimator::CostEstimator;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::{OpKind, Role};
    use crate::network::Cluster;
    use crate::profiler;
    use crate::service::fingerprint::{env_fingerprint, EstimatorFp};

    fn workload() -> TrainingGraph {
        let mut b = GraphBuilder::new("warm-wl", 12);
        let x = b.constant("x", &[1 << 16]);
        let mut prev = x;
        for i in 0..5 {
            let m = b.compute(OpKind::Mul, &format!("m{i}"), &[prev], &[1 << 16], Role::Forward);
            let t = b.compute(OpKind::Tanh, &format!("t{i}"), &[m], &[1 << 16], Role::Forward);
            prev = t;
        }
        let mut grad = prev;
        for i in 0..5 {
            let gop =
                b.compute(OpKind::Mul, &format!("bg{i}"), &[grad], &[1 << 12], Role::Backward);
            let p = b.param(&format!("w{i}"), &[1 << 12]);
            let ar = b.allreduce(&format!("ar{i}"), gop, &[1 << 12]);
            b.optimizer_update(&format!("u{i}"), &[ar, p]);
            grad = gop;
        }
        b.finish()
    }

    fn quick_cfg() -> SearchConfig {
        SearchConfig { unchanged_limit: 50, max_queue: 64, seed: 7, ..Default::default() }
    }

    #[test]
    fn miss_then_hit_roundtrip() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let cfg = quick_cfg();
        let env = env_fingerprint(&c, &d, &EstimatorFp::named("oracle"), &cfg);
        let mut store = PlanStore::in_memory(8);
        let warm = WarmOptions::default();
        let first = plan_with_store(&g, &est, &cfg, env, &mut store, &warm).unwrap();
        assert_eq!(first.source, PlanSource::Cold);
        assert!(first.evals > 0);
        let second = plan_with_store(&g, &est, &cfg, env, &mut store, &warm).unwrap();
        assert_eq!(second.source, PlanSource::Store);
        assert_eq!(second.evals, 0);
        assert_eq!(second.best_cost_ms, first.best_cost_ms);
        assert_eq!(second.best.fingerprint(), first.best.fingerprint());
        assert!(second.best.validate().is_ok());
    }

    #[test]
    fn env_change_is_a_miss() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let cfg = quick_cfg();
        let mut store = PlanStore::in_memory(8);
        let warm = WarmOptions::default();
        let env_a = env_fingerprint(&c, &d, &EstimatorFp::named("oracle"), &cfg);
        let _ = plan_with_store(&g, &est, &cfg, env_a, &mut store, &warm).unwrap();
        // Same graph, different seed → different env key → not a store
        // hit, but warm-started from the sibling plan.
        let cfg2 = SearchConfig { seed: 11, ..quick_cfg() };
        let env_b = env_fingerprint(&c, &d, &EstimatorFp::named("oracle"), &cfg2);
        let out = plan_with_store(&g, &est, &cfg2, env_b, &mut store, &warm).unwrap();
        assert_eq!(out.source, PlanSource::Warm);
        assert!(out.warm_hits > 0);
        assert!(out.steps_saved > 0);
    }

    #[test]
    fn replay_hit_rejects_relabeled_arena() {
        let g = workload();
        let rec = PlanRecord {
            key: "k".into(),
            graph_fp: "g".into(),
            arena_fp: arena_fingerprint(&g) ^ 1, // wrong arena
            model: g.name.clone(),
            sketch: GraphSketch::of(&g),
            muts: Vec::new(),
            best_cost_ms: 1.0,
            initial_cost_ms: 2.0,
            evals: 1,
            steps: 1,
            elapsed_ms: 0.1,
        };
        assert!(try_replay_hit(&rec, &g).is_none());
        let rec2 = PlanRecord { arena_fp: arena_fingerprint(&g), ..rec };
        // Empty plan replays to the input itself.
        assert_eq!(try_replay_hit(&rec2, &g).unwrap().fingerprint(), g.fingerprint());
    }

    #[test]
    fn disabled_warm_start_stays_cold() {
        let g = workload();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let prof = profiler::profile(&g, &d, &c, 2, 5);
        let est = CostEstimator::oracle(&prof, &d);
        let cfg = quick_cfg();
        let mut store = PlanStore::in_memory(8);
        let warm_off = WarmOptions { enabled: false, ..WarmOptions::default() };
        let env_a = env_fingerprint(&c, &d, &EstimatorFp::named("oracle"), &cfg);
        let _ = plan_with_store(&g, &est, &cfg, env_a, &mut store, &warm_off).unwrap();
        let cfg2 = SearchConfig { seed: 11, ..quick_cfg() };
        let env_b = env_fingerprint(&c, &d, &EstimatorFp::named("oracle"), &cfg2);
        let out = plan_with_store(&g, &est, &cfg2, env_b, &mut store, &warm_off).unwrap();
        assert_eq!(out.source, PlanSource::Cold);
        assert_eq!(out.steps_saved, 0);
    }
}
