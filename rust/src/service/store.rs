//! Persistent, content-addressed plan store (DESIGN.md §11).
//!
//! One [`PlanRecord`] per (graph, environment) fingerprint, holding the
//! winning [`Mutation`] sequence and its costs — the auto-tuning-record
//! pattern: a strategy is an artifact keyed by the program, computed once
//! and replayed thereafter. Storage is JSON-lines on disk (append-only
//! via [`crate::util::json`], last write per key wins on load, corrupt or
//! version-mismatched lines are skipped, the file is compacted when
//! appends outgrow the live set) with a bounded in-memory LRU index, so a
//! long-running `disco serve` process stays within a fixed *memory*
//! footprint no matter how many distinct workloads pass through it (the
//! disk file keeps one line per distinct key — it grows with the union
//! of live plans, not with traffic).
//!
//! Two processes (or two [`PlanStore`]s) may share one JSONL path: every
//! append and compaction runs under an advisory flock-style sidecar lock
//! ([`StoreLock`]), and compaction merges from the *file*, never from one
//! process's in-memory view — so a compaction in one server can't drop
//! records another server appended. Concurrency is integration-tested in
//! `tests/service.rs` (`store_shared_path_concurrent_appends`).

use super::fingerprint::GraphSketch;
use crate::fusion::{FusionKind, Mutation};
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};

/// On-disk record layout version; bump on breaking changes. Loading skips
/// records with any *unknown* version (they just get re-searched).
///
/// Version history:
/// * **1** — fusion-only plans (`"ops"` / `"ar"` mutation tags).
/// * **2** — adds the `"ck"` (re-chunk) mutation tag for chunked
///   collectives (DESIGN.md §13). v1 lines are still accepted: they
///   contain no `"ck"` mutations, so they replay exactly as the
///   unchunked plans they were recorded as — never corrupted, never
///   silently re-interpreted.
pub const RECORD_VERSION: u64 = 2;

/// Versions [`PlanRecord::from_json`] accepts (see the history above).
const COMPAT_VERSIONS: [u64; 2] = [1, RECORD_VERSION];

/// When the JSONL file holds more than this many lines per live record,
/// `put` rewrites it from the on-disk record set (append-only compaction
/// threshold).
const COMPACT_FACTOR: usize = 4;

/// How long [`StoreLock::acquire`] keeps retrying before giving up.
const LOCK_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(5);

/// A sidecar lock older than this is considered leaked by a dead
/// process and is stolen. Critical sections are sub-second (one append
/// or one file rewrite), so a healthy holder can't plausibly age this
/// far — every acquire writes the lock file fresh.
const LOCK_STALE: std::time::Duration = std::time::Duration::from_secs(30);

/// Advisory cross-process lock on one store file (flock-style, std-only:
/// a sidecar `<store>.lock` created with `create_new`, which is atomic
/// on every platform std supports). Held across any append/compaction
/// so two `disco serve` processes can share one JSONL path without a
/// compaction in one clobbering an append in the other. `Drop` releases.
///
/// Stale locks (crashed holder) are stolen after [`LOCK_STALE`] by
/// atomically *renaming* the lock aside — never by a blind delete, so
/// two would-be stealers can't both proceed, and a lock that turns out
/// to be freshly re-created by a live holder (the check→steal race) is
/// detected after the claim and restored. The restore path uses
/// `hard_link`, which fails rather than clobbers if a third process
/// locked in the meantime; the residual unprotected window needs three
/// processes racing within the same few milliseconds on a path that
/// just crossed the 30 s staleness line — acceptable for an advisory
/// lock.
struct StoreLock {
    path: PathBuf,
}

impl StoreLock {
    fn lock_path(store_path: &Path) -> PathBuf {
        let mut os = store_path.as_os_str().to_os_string();
        os.push(".lock");
        PathBuf::from(os)
    }

    /// Atomically claim a stale-looking lock file by renaming it aside.
    /// Returns true when a genuinely stale lock was removed; restores
    /// the file when the claim turns out to have caught a live lock.
    fn steal_stale(path: &Path) -> bool {
        use std::sync::atomic::{AtomicU64, Ordering};
        static STEAL_SEQ: AtomicU64 = AtomicU64::new(0);
        let claim = {
            let mut os = path.as_os_str().to_os_string();
            os.push(format!(
                ".steal.{}.{}",
                std::process::id(),
                STEAL_SEQ.fetch_add(1, Ordering::Relaxed)
            ));
            PathBuf::from(os)
        };
        if std::fs::rename(path, &claim).is_err() {
            return false; // already released or claimed by someone else
        }
        let still_stale = std::fs::metadata(&claim)
            .and_then(|m| m.modified())
            .ok()
            .and_then(|m| m.elapsed().ok())
            .is_some_and(|age| age > LOCK_STALE);
        if !still_stale {
            // We raced a live holder re-creating the lock: put it back
            // (hard_link errors instead of clobbering a newer lock).
            let _ = std::fs::hard_link(&claim, path);
        }
        let _ = std::fs::remove_file(&claim);
        still_stale
    }

    fn acquire(store_path: &Path) -> Result<StoreLock> {
        let path = Self::lock_path(store_path);
        let deadline = std::time::Instant::now() + LOCK_TIMEOUT;
        loop {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    let _ = write!(f, "{}", std::process::id());
                    return Ok(StoreLock { path });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let looks_stale = std::fs::metadata(&path)
                        .and_then(|m| m.modified())
                        .ok()
                        .and_then(|m| m.elapsed().ok())
                        .is_some_and(|age| age > LOCK_STALE);
                    if looks_stale && Self::steal_stale(&path) {
                        continue;
                    }
                    if std::time::Instant::now() > deadline {
                        return Err(anyhow!(
                            "timed out waiting for plan-store lock {}",
                            path.display()
                        ));
                    }
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!("creating plan-store lock {}", path.display())
                    })
                }
            }
        }
    }
}

impl Drop for StoreLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

fn mutation_json(m: &Mutation) -> Json {
    match *m {
        Mutation::FuseOps { pred, succ, kind } => Json::obj(vec![
            ("t", Json::Str("ops".into())),
            ("p", Json::Num(pred as f64)),
            ("s", Json::Num(succ as f64)),
            (
                "k",
                Json::Str(
                    match kind {
                        FusionKind::NonDuplicate => "nd",
                        FusionKind::Duplicate => "d",
                    }
                    .into(),
                ),
            ),
        ]),
        Mutation::FuseAllReduce { a, b } => Json::obj(vec![
            ("t", Json::Str("ar".into())),
            ("a", Json::Num(a as f64)),
            ("b", Json::Num(b as f64)),
        ]),
        Mutation::SetChunks { ar, count } => Json::obj(vec![
            ("t", Json::Str("ck".into())),
            ("a", Json::Num(ar as f64)),
            ("n", Json::Num(count as f64)),
        ]),
    }
}

fn mutation_from(j: &Json) -> Option<Mutation> {
    match j.get("t").as_str()? {
        "ops" => Some(Mutation::FuseOps {
            pred: j.get("p").as_usize()?,
            succ: j.get("s").as_usize()?,
            kind: match j.get("k").as_str()? {
                "nd" => FusionKind::NonDuplicate,
                "d" => FusionKind::Duplicate,
                _ => return None,
            },
        }),
        "ar" => Some(Mutation::FuseAllReduce {
            a: j.get("a").as_usize()?,
            b: j.get("b").as_usize()?,
        }),
        "ck" => Some(Mutation::SetChunks {
            ar: j.get("a").as_usize()?,
            count: j.get("n").as_usize()? as u32,
        }),
        _ => None,
    }
}

/// One cached strategy: the plan (mutation sequence), its provenance and
/// its search statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanRecord {
    /// Plan-store key: `plan_key(graph_fp, env_fp)` in hex.
    pub key: String,
    /// Canonical graph-only fingerprint in hex (warm-start lookup across
    /// environments).
    pub graph_fp: String,
    /// The id-*sensitive*, FNV-stable
    /// [`super::fingerprint::arena_fingerprint`] of the exact input
    /// arena the mutations were recorded against. Exact replay (the
    /// zero-simulation cache-hit path) requires this to match; an
    /// isomorphic-but-relabeled graph falls back to warm-starting
    /// instead.
    pub arena_fp: u64,
    /// Graph name at record time — informational only.
    pub model: String,
    pub sketch: GraphSketch,
    /// The winning mutation sequence, replayable on the recorded graph.
    pub muts: Vec<Mutation>,
    pub best_cost_ms: f64,
    pub initial_cost_ms: f64,
    pub evals: u64,
    pub steps: u64,
    pub elapsed_ms: f64,
}

impl PlanRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("v", Json::Num(RECORD_VERSION as f64)),
            ("key", Json::Str(self.key.clone())),
            ("graph_fp", Json::Str(self.graph_fp.clone())),
            // u64 doesn't fit f64 exactly; store as hex text.
            ("arena_fp", Json::Str(format!("{:016x}", self.arena_fp))),
            ("model", Json::Str(self.model.clone())),
            ("sketch", self.sketch.to_json()),
            ("muts", Json::Arr(self.muts.iter().map(mutation_json).collect())),
            ("best_ms", Json::Num(self.best_cost_ms)),
            ("initial_ms", Json::Num(self.initial_cost_ms)),
            ("evals", Json::Num(self.evals as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("elapsed_ms", Json::Num(self.elapsed_ms)),
        ])
    }

    /// Parse one record; `None` for any malformed or version-mismatched
    /// value (the loader's skip-don't-fail contract).
    pub fn from_json(j: &Json) -> Option<PlanRecord> {
        if !COMPAT_VERSIONS.contains(&(j.get("v").as_usize()? as u64)) {
            return None;
        }
        Some(PlanRecord {
            key: j.get("key").as_str()?.to_string(),
            graph_fp: j.get("graph_fp").as_str()?.to_string(),
            arena_fp: u64::from_str_radix(j.get("arena_fp").as_str()?, 16).ok()?,
            model: j.get("model").as_str()?.to_string(),
            sketch: GraphSketch::from_json(j.get("sketch"))?,
            muts: j
                .get("muts")
                .as_arr()?
                .iter()
                .map(mutation_from)
                .collect::<Option<Vec<Mutation>>>()?,
            best_cost_ms: j.get("best_ms").as_f64()?,
            initial_cost_ms: j.get("initial_ms").as_f64()?,
            evals: j.get("evals").as_usize()? as u64,
            steps: j.get("steps").as_usize()? as u64,
            elapsed_ms: j.get("elapsed_ms").as_f64()?,
        })
    }
}

/// Bounded plan cache: in-memory LRU index over an append-only JSONL file
/// (or memory-only when opened without a path).
#[derive(Debug)]
pub struct PlanStore {
    path: Option<PathBuf>,
    capacity: usize,
    map: HashMap<String, PlanRecord>,
    /// Last-access stamp per live key (monotonic `clock` values): O(1)
    /// recency bumps on every get/put; the O(capacity) scan for the
    /// minimum happens only when evicting, which is rare relative to
    /// lookups.
    recency: HashMap<String, u64>,
    clock: u64,
    /// Lines currently on disk (appends since the last compaction plus
    /// the loaded base) — drives the compaction heuristic.
    disk_lines: usize,
    /// Distinct keys on disk as of the last load/compaction (best-effort
    /// across processes). The compaction threshold compares lines
    /// against THIS, not against the capacity-bounded in-memory map —
    /// otherwise a store whose file legitimately holds more keys than
    /// its own capacity would rewrite the whole file on every put.
    disk_keys: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Lines skipped at load time (corrupt / old version).
    pub skipped: u64,
}

impl PlanStore {
    /// Memory-only store (tests, `--store none`).
    pub fn in_memory(capacity: usize) -> PlanStore {
        PlanStore {
            path: None,
            capacity: capacity.max(1),
            map: HashMap::new(),
            recency: HashMap::new(),
            clock: 0,
            disk_lines: 0,
            disk_keys: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            skipped: 0,
        }
    }

    /// Open (creating if absent) a JSONL-backed store. Later lines win on
    /// duplicate keys; unreadable lines are counted in `skipped` and
    /// dropped; anything beyond `capacity` is evicted oldest-first (from
    /// the in-memory index only — the file keeps every live record, so a
    /// second process with a larger capacity loses nothing).
    pub fn open(path: &Path, capacity: usize) -> Result<PlanStore> {
        let mut store = PlanStore::in_memory(capacity);
        store.path = Some(path.to_path_buf());
        if path.exists() {
            let _lock = StoreLock::acquire(path)?;
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading plan store {}", path.display()))?;
            let mut lines = 0usize;
            let mut unique: std::collections::HashSet<String> = std::collections::HashSet::new();
            for line in text.lines() {
                if line.trim().is_empty() {
                    continue;
                }
                lines += 1;
                match Json::parse(line).ok().and_then(|j| PlanRecord::from_json(&j)) {
                    Some(rec) => {
                        unique.insert(rec.key.clone());
                        store.index(rec);
                    }
                    None => store.skipped += 1,
                }
            }
            store.disk_lines = lines;
            store.disk_keys = unique.len();
            // Reclaim the file when the load found duplicate or corrupt
            // lines (NOT when records merely exceeded our capacity —
            // those stay on disk for other readers).
            if lines != unique.len() {
                store.compact_locked()?;
            }
        }
        Ok(store)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    fn touch(&mut self, key: &str) {
        self.clock += 1;
        self.recency.insert(key.to_string(), self.clock);
    }

    /// Insert into the index (no disk IO), evicting LRU overflow.
    fn index(&mut self, rec: PlanRecord) {
        let key = rec.key.clone();
        self.map.insert(key.clone(), rec);
        self.touch(&key);
        while self.map.len() > self.capacity {
            let oldest = self
                .recency
                .iter()
                .min_by_key(|&(_, &stamp)| stamp)
                .map(|(k, _)| k.clone());
            match oldest {
                Some(k) => {
                    self.map.remove(&k);
                    self.recency.remove(&k);
                    self.evictions += 1;
                }
                None => break,
            }
        }
    }

    /// Cache lookup; bumps LRU recency and the hit/miss counters.
    pub fn get(&mut self, key: &str) -> Option<&PlanRecord> {
        if self.map.contains_key(key) {
            self.hits += 1;
            self.touch(key);
            self.map.get(key)
        } else {
            self.misses += 1;
            None
        }
    }

    /// Lookup without touching recency or counters.
    pub fn peek(&self, key: &str) -> Option<&PlanRecord> {
        self.map.get(key)
    }

    /// Insert (or overwrite) a record and persist it. The append and any
    /// resulting compaction happen under the cross-process file lock.
    pub fn put(&mut self, rec: PlanRecord) -> Result<()> {
        let line = rec.to_json().to_string();
        self.index(rec);
        if let Some(path) = self.path.clone() {
            let _lock = StoreLock::acquire(&path)?;
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
                .with_context(|| format!("appending to plan store {}", path.display()))?;
            writeln!(f, "{line}")?;
            drop(f);
            self.disk_lines += 1;
            // disk_keys is only ever set from an exact disk scan (open /
            // compaction), never guessed at put time: a guess based on
            // the capacity-bounded map over-counts once eviction starts
            // (every re-put of an evicted key would look new), inflating
            // the threshold until compaction never fires. A stale-LOW
            // disk_keys merely compacts a little early — the safe
            // direction, and it amortizes geometrically either way.
            if self.disk_lines > COMPACT_FACTOR * self.disk_keys.max(4) {
                self.compact_locked()?;
            }
        }
        Ok(())
    }

    /// Compact the backing file under the cross-process lock.
    pub fn compact(&mut self) -> Result<()> {
        let Some(path) = self.path.clone() else { return Ok(()) };
        let _lock = StoreLock::acquire(&path)?;
        self.compact_locked()
    }

    /// Rewrite the backing file with exactly the live on-disk record set
    /// (one line per key, last write wins, corrupt lines dropped). The
    /// caller must hold the store lock. Compaction deliberately merges
    /// from *disk*, not from this process's in-memory index: a second
    /// process sharing the path may have appended records this index has
    /// never seen (or has evicted), and rewriting from memory would
    /// silently delete them. Every record this process has put is on
    /// disk already (`put` appends before compacting), so the disk set
    /// is a superset of this index.
    fn compact_locked(&mut self) -> Result<()> {
        let Some(path) = self.path.clone() else { return Ok(()) };
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("re-reading plan store {}", path.display()))
            }
        };
        // Last-write-wins in file order, preserving first-seen order so
        // the rewrite is stable.
        let mut order: Vec<String> = Vec::new();
        let mut live: HashMap<String, String> = HashMap::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            if let Some(rec) = Json::parse(line).ok().and_then(|j| PlanRecord::from_json(&j)) {
                if !live.contains_key(&rec.key) {
                    order.push(rec.key.clone());
                }
                live.insert(rec.key, line.to_string());
            }
        }
        let mut out = String::new();
        for key in &order {
            out.push_str(&live[key]);
            out.push('\n');
        }
        // Write-then-rename: the shared file is every process's source
        // of truth, so it must never be observable (or left, on a
        // crash) in a truncated in-place-rewrite state.
        let tmp = {
            let mut os = path.as_os_str().to_os_string();
            os.push(format!(".compact.{}", std::process::id()));
            PathBuf::from(os)
        };
        std::fs::write(&tmp, out)
            .with_context(|| format!("writing compacted plan store {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("compacting plan store {}", path.display()))?;
        self.disk_lines = order.len();
        self.disk_keys = order.len();
        Ok(())
    }

    /// All records for one canonical graph fingerprint (any environment),
    /// in deterministic key order — warm-start seed candidates.
    pub fn by_graph_fp(&self, graph_fp: &str) -> Vec<&PlanRecord> {
        let mut out: Vec<&PlanRecord> =
            self.map.values().filter(|r| r.graph_fp == graph_fp).collect();
        out.sort_by(|a, b| a.key.cmp(&b.key));
        out
    }

    /// The record whose sketch is nearest to `sketch` (ties broken by
    /// key for determinism), excluding `exclude_key`, within
    /// `max_distance`.
    pub fn nearest(
        &self,
        sketch: &GraphSketch,
        exclude_key: &str,
        max_distance: f64,
    ) -> Option<&PlanRecord> {
        self.map
            .values()
            .filter(|r| r.key != exclude_key)
            .map(|r| (r.sketch.distance(sketch), r))
            .filter(|(d, _)| *d <= max_distance)
            .min_by(|(da, a), (db, b)| {
                da.partial_cmp(db)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then_with(|| a.key.cmp(&b.key))
            })
            .map(|(_, r)| r)
    }
}

/// Convenience for CLI/config plumbing: `None`/`"none"` → memory-only.
pub fn open_store(path: Option<&str>, capacity: usize) -> Result<PlanStore> {
    match path {
        None => Ok(PlanStore::in_memory(capacity)),
        Some("none") => Ok(PlanStore::in_memory(capacity)),
        Some(p) if p.is_empty() => Err(anyhow!("empty plan-store path")),
        Some(p) => PlanStore::open(Path::new(p), capacity),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(key: &str, gfp: &str, cost: f64) -> PlanRecord {
        PlanRecord {
            key: key.to_string(),
            graph_fp: gfp.to_string(),
            arena_fp: 0xABCD,
            model: "m".into(),
            sketch: GraphSketch {
                kind_counts: vec![1, 2, 0],
                live: 3,
                allreduces: 1,
                num_workers: 4,
                total_flops: cost * 10.0,
                grad_bytes: 64.0,
            },
            muts: vec![
                Mutation::FuseOps { pred: 1, succ: 2, kind: FusionKind::NonDuplicate },
                Mutation::FuseAllReduce { a: 4, b: 5 },
            ],
            best_cost_ms: cost,
            initial_cost_ms: cost * 2.0,
            evals: 10,
            steps: 5,
            elapsed_ms: 1.5,
        }
    }

    #[test]
    fn record_json_roundtrip() {
        let r = record("k1", "g1", 3.25);
        let j = r.to_json().to_string();
        let r2 = PlanRecord::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(r, r2);
    }

    #[test]
    fn version_mismatch_is_skipped() {
        let mut j = record("k1", "g1", 1.0).to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("v".into(), Json::Num((RECORD_VERSION + 1) as f64));
        }
        assert!(PlanRecord::from_json(&j).is_none());
    }

    #[test]
    fn v1_records_still_load() {
        // A pre-chunk (v1) record has only "ops"/"ar" mutation tags; it
        // must parse under the bumped version and keep its plan intact —
        // replaying it produces exactly the unchunked strategy it stored.
        let mut j = record("k1", "g1", 1.0).to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("v".into(), Json::Num(1.0));
        }
        let r = PlanRecord::from_json(&j).expect("v1 record rejected");
        assert_eq!(r.muts, record("k1", "g1", 1.0).muts);
        assert!(!r.muts.iter().any(|m| matches!(m, Mutation::SetChunks { .. })));
    }

    #[test]
    fn chunk_mutation_roundtrips() {
        let mut r = record("k2", "g1", 2.0);
        r.muts.push(Mutation::SetChunks { ar: 7, count: 8 });
        let j = r.to_json().to_string();
        let r2 = PlanRecord::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(r, r2);
        assert!(j.contains("\"ck\""));
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut s = PlanStore::in_memory(2);
        s.put(record("a", "g", 1.0)).unwrap();
        s.put(record("b", "g", 2.0)).unwrap();
        assert!(s.get("a").is_some()); // bump a → b is now LRU
        s.put(record("c", "g", 3.0)).unwrap();
        assert_eq!(s.len(), 2);
        assert!(s.peek("b").is_none(), "b should have been evicted");
        assert!(s.peek("a").is_some() && s.peek("c").is_some());
        assert_eq!(s.evictions, 1);
        assert_eq!((s.hits, s.misses), (1, 0));
        assert!(s.get("zz").is_none());
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn nearest_picks_minimal_distance_deterministically() {
        let mut s = PlanStore::in_memory(8);
        let mut far = record("far", "g1", 1.0);
        far.sketch.total_flops = 1e12;
        far.sketch.allreduces = 9;
        s.put(far).unwrap();
        s.put(record("near", "g2", 1.0)).unwrap();
        let probe = record("probe", "g3", 1.0).sketch;
        let n = s.nearest(&probe, "none", f64::INFINITY).unwrap();
        assert_eq!(n.key, "near");
        // Excluding the winner falls back to the next one.
        let n2 = s.nearest(&probe, "near", f64::INFINITY).unwrap();
        assert_eq!(n2.key, "far");
        // A tight radius excludes everything.
        assert!(s.nearest(&probe, "none", -1.0).is_none());
    }

    #[test]
    fn by_graph_fp_sorted() {
        let mut s = PlanStore::in_memory(8);
        s.put(record("b", "g1", 1.0)).unwrap();
        s.put(record("a", "g1", 1.0)).unwrap();
        s.put(record("c", "g2", 1.0)).unwrap();
        let got: Vec<&str> = s.by_graph_fp("g1").iter().map(|r| r.key.as_str()).collect();
        assert_eq!(got, vec!["a", "b"]);
    }

    #[test]
    fn persistence_last_write_wins_and_corrupt_lines_skipped() {
        let dir = std::env::temp_dir().join(format!("disco-store-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plans.jsonl");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = PlanStore::open(&path, 8).unwrap();
            s.put(record("a", "g", 1.0)).unwrap();
            s.put(record("b", "g", 2.0)).unwrap();
            s.put(record("a", "g", 9.0)).unwrap(); // overwrite
        }
        // Corrupt trailing line must not poison the load.
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            writeln!(f, "{{ not json").unwrap();
        }
        let s = PlanStore::open(&path, 8).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.peek("a").unwrap().best_cost_ms, 9.0);
        assert_eq!(s.skipped, 1);
        // Load compacted away the duplicate and the corrupt line.
        let reread = std::fs::read_to_string(&path).unwrap();
        assert_eq!(reread.lines().count(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn reopen_respects_capacity() {
        let dir = std::env::temp_dir().join(format!("disco-store-cap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plans.jsonl");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = PlanStore::open(&path, 8).unwrap();
            for i in 0..6 {
                s.put(record(&format!("k{i}"), "g", i as f64)).unwrap();
            }
        }
        let s = PlanStore::open(&path, 3).unwrap();
        assert_eq!(s.len(), 3);
        // Oldest-first eviction: the newest three survive.
        assert!(s.peek("k5").is_some() && s.peek("k4").is_some() && s.peek("k3").is_some());
        let _ = std::fs::remove_file(&path);
    }
}
