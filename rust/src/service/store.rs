//! Persistent, content-addressed plan store (DESIGN.md §11, durability
//! model in §14).
//!
//! One [`PlanRecord`] per (graph, environment) fingerprint, holding the
//! winning [`Mutation`] sequence and its costs — the auto-tuning-record
//! pattern: a strategy is an artifact keyed by the program, computed once
//! and replayed thereafter. Storage is JSON-lines on disk with a bounded
//! in-memory LRU index, so a long-running `disco serve` process stays
//! within a fixed *memory* footprint no matter how many distinct
//! workloads pass through it (the disk file keeps one line per distinct
//! key — it grows with the union of live plans, not with traffic).
//!
//! Since format v3 every line is framed
//! `v3:<generation>:<payload-len>:<crc32c-hex>:<json-payload>` so that a
//! torn append, a garbled sector, or a stale duplicate is *detected*
//! rather than silently served: [`PlanStore::open`] scans byte-by-byte,
//! verifies length + [`crate::util::checksum::crc32c`] per line,
//! truncates a torn tail, skips corrupt interior lines, resolves
//! duplicate keys by highest generation, and reports it all in a typed
//! [`RecoveryReport`] — never a panic, never a record served that failed
//! its checksum. Bare legacy v1/v2 JSON lines (no framing) still load,
//! verified by parse only and flagged as `legacy` in the report.
//!
//! Two processes (or two [`PlanStore`]s) may share one JSONL path: every
//! append and compaction runs under an advisory flock-style sidecar lock
//! ([`StoreLock`]), and compaction merges from the *file*, never from one
//! process's in-memory view — so a compaction in one server can't drop
//! records another server appended. Compaction writes a snapshot to
//! `<store>.snap.<pid>` and renames it into place; a crash at any point
//! leaves either the old consistent file (plus an orphan snapshot that
//! the next open sweeps) or the new one. Disk failures during `put`
//! degrade the store to memory-only for that record instead of failing
//! the plan request; the degradation is counted and surfaced in server
//! stats. All I/O is threaded through the seeded fault shim in
//! [`super::io_fault`] (constructor hook [`PlanStore::open_with`]) and the
//! failure modes are property-tested in `tests/service.rs`.

use super::fingerprint::GraphSketch;
use super::io_fault::{DiskFault, DiskFaultPlan, FaultFile};
use crate::fusion::{FusionKind, Mutation};
use crate::graph::CollectiveKind;
use crate::util::checksum::crc32c;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// On-disk record layout version; bump on breaking changes. Loading skips
/// records with any *unknown* version (they just get re-searched).
///
/// Version history:
/// * **1** — fusion-only plans (`"ops"` / `"ar"` mutation tags).
/// * **2** — adds the `"ck"` (re-chunk) mutation tag for chunked
///   collectives (DESIGN.md §13). v1 lines are still accepted: they
///   contain no `"ck"` mutations, so they replay exactly as the
///   unchunked plans they were recorded as — never corrupted, never
///   silently re-interpreted.
/// * **3** — durability framing (DESIGN.md §14): each line carries a
///   generation counter, payload length and CRC32C outside the JSON
///   payload. Bare v1/v2 lines (which always start with `{`) still
///   load, verified by parse only.
/// * **4** — adds the `"sh"` (gradient-sharding toggle) mutation tag for
///   ZeRO/FSDP-style reduce-scatter + all-gather collectives (DESIGN.md
///   §16). v≤3 lines contain no `"sh"` mutations, so they replay exactly
///   as the unsharded plans they were recorded as.
pub const RECORD_VERSION: u64 = 4;

/// Versions [`PlanRecord::from_json`] accepts (see the history above).
const COMPAT_VERSIONS: [u64; 4] = [1, 2, 3, RECORD_VERSION];

/// When the JSONL file holds more than this many lines per live record,
/// `put` rewrites it from the on-disk record set (append-only compaction
/// threshold).
const COMPACT_FACTOR: usize = 4;

/// How long [`StoreLock::acquire`] keeps retrying before giving up.
const LOCK_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(5);

/// A sidecar lock older than this is considered leaked by a dead
/// process and is stolen. Critical sections are sub-second (one append
/// or one file rewrite), so a healthy holder can't plausibly age this
/// far — every acquire writes the lock file fresh.
const LOCK_STALE: std::time::Duration = std::time::Duration::from_secs(30);

/// Typed store I/O failure: which operation, on which path, with the
/// underlying error — so `compact`'s rename landing step (and every
/// other disk step) surfaces as something callers can match on instead
/// of a stringly-typed context chain.
#[derive(Debug)]
pub enum StoreError {
    /// Could not acquire (or create) the sidecar lock.
    Lock { path: PathBuf, reason: String },
    /// A data-file operation failed. `op` is one of `"read"`,
    /// `"append"`, `"snapshot"`, `"rename"`.
    Io { op: &'static str, path: PathBuf, source: std::io::Error },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Lock { path, reason } => {
                write!(f, "plan-store lock {}: {reason}", path.display())
            }
            StoreError::Io { op, path, source } => {
                write!(f, "plan-store {op} {}: {source}", path.display())
            }
        }
    }
}

impl std::error::Error for StoreError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            StoreError::Lock { .. } => None,
            StoreError::Io { source, .. } => Some(source),
        }
    }
}

/// What [`PlanStore::open`] / [`fsck`] found and did while loading a
/// store file — the documented outcome for every hostile input
/// (DESIGN.md §14). All counters are per load, not cumulative.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RecoveryReport {
    /// Non-empty lines seen in the file.
    pub total_lines: usize,
    /// Lines that passed v3 frame verification (length + CRC32C + parse).
    pub verified: usize,
    /// Bare v1/v2 lines accepted by parse alone (no checksum on disk).
    pub legacy: usize,
    /// Interior lines that failed verification and were skipped.
    pub corrupt: usize,
    /// Whether the final line was an unterminated/invalid torn tail.
    pub torn_tail: bool,
    /// Bytes dropped by truncating the torn tail.
    pub torn_bytes: usize,
    /// Valid lines superseded by a same-key line of higher generation
    /// (or equal generation later in the file) — normal last-write-wins
    /// traffic, folded away at compaction.
    pub duplicates: usize,
    /// Orphan `<store>.snap.*` files from a crash between snapshot write
    /// and rename (the main file is still the consistent truth).
    pub orphan_snapshots: usize,
    /// Live records after duplicate resolution.
    pub live: usize,
    /// Whether this load/fsck rewrote the file to a clean state.
    pub repaired: bool,
}

impl RecoveryReport {
    /// No damage and nothing to fold: the file is byte-for-byte what a
    /// fresh compaction would write (legacy lines are clean — old, not
    /// damaged).
    pub fn is_clean(&self) -> bool {
        self.corrupt == 0 && !self.torn_tail && self.duplicates == 0 && self.orphan_snapshots == 0
    }
}

impl std::fmt::Display for RecoveryReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "{} line(s): {} live record(s) ({} v3-verified, {} legacy verified-by-parse)",
            self.total_lines, self.live, self.verified, self.legacy
        )?;
        writeln!(f, "  corrupt lines skipped:          {}", self.corrupt)?;
        writeln!(
            f,
            "  torn tail truncated:            {}",
            if self.torn_tail { format!("yes ({} byte(s))", self.torn_bytes) } else { "no".into() }
        )?;
        writeln!(f, "  duplicate records superseded:   {}", self.duplicates)?;
        writeln!(f, "  orphan snapshots swept:         {}", self.orphan_snapshots)?;
        write!(
            f,
            "  status: {}",
            if self.is_clean() {
                "clean"
            } else if self.repaired {
                "repaired"
            } else {
                "damaged (run `disco store fsck --repair`)"
            }
        )
    }
}

/// Advisory cross-process lock on one store file (flock-style, std-only:
/// a sidecar `<store>.lock` created with `create_new`, which is atomic
/// on every platform std supports). Held across any append/compaction
/// so two `disco serve` processes can share one JSONL path without a
/// compaction in one clobbering an append in the other. `Drop` releases.
///
/// Stale locks (crashed holder) are stolen after [`LOCK_STALE`] by
/// atomically *renaming* the lock aside — never by a blind delete, so
/// two would-be stealers can't both proceed, and a lock that turns out
/// to be freshly re-created by a live holder (the check→steal race) is
/// detected after the claim and restored. The restore path uses
/// `hard_link`, which fails rather than clobbers if a third process
/// locked in the meantime; the residual unprotected window needs three
/// processes racing within the same few milliseconds on a path that
/// just crossed the 30 s staleness line — acceptable for an advisory
/// lock.
struct StoreLock {
    path: PathBuf,
}

impl StoreLock {
    fn lock_path(store_path: &Path) -> PathBuf {
        let mut os = store_path.as_os_str().to_os_string();
        os.push(".lock");
        PathBuf::from(os)
    }

    /// Atomically claim a stale-looking lock file by renaming it aside.
    /// Returns true when a genuinely stale lock was removed; restores
    /// the file when the claim turns out to have caught a live lock.
    fn steal_stale(path: &Path) -> bool {
        use std::sync::atomic::{AtomicU64, Ordering};
        static STEAL_SEQ: AtomicU64 = AtomicU64::new(0);
        let claim = {
            let mut os = path.as_os_str().to_os_string();
            os.push(format!(
                ".steal.{}.{}",
                std::process::id(),
                STEAL_SEQ.fetch_add(1, Ordering::Relaxed)
            ));
            PathBuf::from(os)
        };
        if std::fs::rename(path, &claim).is_err() {
            return false; // already released or claimed by someone else
        }
        let still_stale = std::fs::metadata(&claim)
            .and_then(|m| m.modified())
            .ok()
            .and_then(|m| m.elapsed().ok())
            .is_some_and(|age| age > LOCK_STALE);
        if !still_stale {
            // We raced a live holder re-creating the lock: put it back
            // (hard_link errors instead of clobbering a newer lock).
            let _ = std::fs::hard_link(&claim, path);
        }
        let _ = std::fs::remove_file(&claim);
        still_stale
    }

    fn acquire(store_path: &Path) -> Result<StoreLock, StoreError> {
        let path = Self::lock_path(store_path);
        let deadline = std::time::Instant::now() + LOCK_TIMEOUT;
        loop {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    let _ = write!(f, "{}", std::process::id());
                    return Ok(StoreLock { path });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let looks_stale = std::fs::metadata(&path)
                        .and_then(|m| m.modified())
                        .ok()
                        .and_then(|m| m.elapsed().ok())
                        .is_some_and(|age| age > LOCK_STALE);
                    if looks_stale && Self::steal_stale(&path) {
                        continue;
                    }
                    if std::time::Instant::now() > deadline {
                        return Err(StoreError::Lock {
                            path,
                            reason: "timed out waiting for holder".into(),
                        });
                    }
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                Err(e) => {
                    return Err(StoreError::Lock { path, reason: format!("create failed: {e}") })
                }
            }
        }
    }
}

impl Drop for StoreLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

fn mutation_json(m: &Mutation) -> Json {
    match *m {
        Mutation::FuseOps { pred, succ, kind } => Json::obj(vec![
            ("t", Json::Str("ops".into())),
            ("p", Json::Num(pred as f64)),
            ("s", Json::Num(succ as f64)),
            (
                "k",
                Json::Str(
                    match kind {
                        FusionKind::NonDuplicate => "nd",
                        FusionKind::Duplicate => "d",
                    }
                    .into(),
                ),
            ),
        ]),
        Mutation::FuseAllReduce { a, b } => Json::obj(vec![
            ("t", Json::Str("ar".into())),
            ("a", Json::Num(a as f64)),
            ("b", Json::Num(b as f64)),
        ]),
        Mutation::SetChunks { ar, count } => Json::obj(vec![
            ("t", Json::Str("ck".into())),
            ("a", Json::Num(ar as f64)),
            ("n", Json::Num(count as f64)),
        ]),
        Mutation::SetSharding { ar, kind } => Json::obj(vec![
            ("t", Json::Str("sh".into())),
            ("a", Json::Num(ar as f64)),
            (
                "k",
                Json::Num(match kind {
                    CollectiveKind::AllReduce => 0.0,
                    CollectiveKind::ReduceScatterAllGather => 1.0,
                }),
            ),
        ]),
    }
}

fn mutation_from(j: &Json) -> Option<Mutation> {
    match j.get("t").as_str()? {
        "ops" => Some(Mutation::FuseOps {
            pred: j.get("p").as_usize()?,
            succ: j.get("s").as_usize()?,
            kind: match j.get("k").as_str()? {
                "nd" => FusionKind::NonDuplicate,
                "d" => FusionKind::Duplicate,
                _ => return None,
            },
        }),
        "ar" => Some(Mutation::FuseAllReduce {
            a: j.get("a").as_usize()?,
            b: j.get("b").as_usize()?,
        }),
        "ck" => Some(Mutation::SetChunks {
            ar: j.get("a").as_usize()?,
            count: j.get("n").as_usize()? as u32,
        }),
        "sh" => Some(Mutation::SetSharding {
            ar: j.get("a").as_usize()?,
            kind: match j.get("k").as_usize()? {
                0 => CollectiveKind::AllReduce,
                1 => CollectiveKind::ReduceScatterAllGather,
                _ => return None,
            },
        }),
        _ => None,
    }
}

/// One cached strategy: the plan (mutation sequence), its provenance and
/// its search statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanRecord {
    /// Plan-store key: `plan_key(graph_fp, env_fp)` in hex.
    pub key: String,
    /// Canonical graph-only fingerprint in hex (warm-start lookup across
    /// environments).
    pub graph_fp: String,
    /// The id-*sensitive*, FNV-stable
    /// [`super::fingerprint::arena_fingerprint`] of the exact input
    /// arena the mutations were recorded against. Exact replay (the
    /// zero-simulation cache-hit path) requires this to match; an
    /// isomorphic-but-relabeled graph falls back to warm-starting
    /// instead.
    pub arena_fp: u64,
    /// Graph name at record time — informational only.
    pub model: String,
    pub sketch: GraphSketch,
    /// The winning mutation sequence, replayable on the recorded graph.
    pub muts: Vec<Mutation>,
    pub best_cost_ms: f64,
    pub initial_cost_ms: f64,
    pub evals: u64,
    pub steps: u64,
    pub elapsed_ms: f64,
}

impl PlanRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("v", Json::Num(RECORD_VERSION as f64)),
            ("key", Json::Str(self.key.clone())),
            ("graph_fp", Json::Str(self.graph_fp.clone())),
            // u64 doesn't fit f64 exactly; store as hex text.
            ("arena_fp", Json::Str(format!("{:016x}", self.arena_fp))),
            ("model", Json::Str(self.model.clone())),
            ("sketch", self.sketch.to_json()),
            ("muts", Json::Arr(self.muts.iter().map(mutation_json).collect())),
            ("best_ms", Json::Num(self.best_cost_ms)),
            ("initial_ms", Json::Num(self.initial_cost_ms)),
            ("evals", Json::Num(self.evals as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("elapsed_ms", Json::Num(self.elapsed_ms)),
        ])
    }

    /// Parse one record; `None` for any malformed or version-mismatched
    /// value (the loader's skip-don't-fail contract).
    pub fn from_json(j: &Json) -> Option<PlanRecord> {
        if !COMPAT_VERSIONS.contains(&(j.get("v").as_usize()? as u64)) {
            return None;
        }
        Some(PlanRecord {
            key: j.get("key").as_str()?.to_string(),
            graph_fp: j.get("graph_fp").as_str()?.to_string(),
            arena_fp: u64::from_str_radix(j.get("arena_fp").as_str()?, 16).ok()?,
            model: j.get("model").as_str()?.to_string(),
            sketch: GraphSketch::from_json(j.get("sketch"))?,
            muts: j
                .get("muts")
                .as_arr()?
                .iter()
                .map(mutation_from)
                .collect::<Option<Vec<Mutation>>>()?,
            best_cost_ms: j.get("best_ms").as_f64()?,
            initial_cost_ms: j.get("initial_ms").as_f64()?,
            evals: j.get("evals").as_usize()? as u64,
            steps: j.get("steps").as_usize()? as u64,
            elapsed_ms: j.get("elapsed_ms").as_f64()?,
        })
    }
}

/// Frame one record payload as a v3 store line (no trailing newline):
/// `v3:<generation>:<payload-len>:<crc32c-hex>:<payload>`. Public so
/// tests (and fsck tooling) can author byte-exact lines.
pub fn frame_line(generation: u64, payload: &str) -> String {
    format!("v3:{generation}:{}:{:08x}:{payload}", payload.len(), crc32c(payload.as_bytes()))
}

/// One line that survived the verification scan.
#[derive(Debug, Clone)]
struct ScannedRecord {
    rec: PlanRecord,
    /// The raw JSON payload text, preserved verbatim so compaction
    /// re-frames without re-serialising (legacy v1/v2 payloads keep
    /// their inner version and replay semantics).
    payload: String,
    generation: u64,
    /// File position (line index among non-empty lines) — recency and
    /// tie-breaking.
    position: usize,
}

struct Scan {
    records: Vec<ScannedRecord>,
    report: RecoveryReport,
    max_generation: u64,
}

enum LineVerdict {
    Valid(ScannedRecord),
    Invalid,
}

/// Verify one line. `position` feeds the scanned record; classification
/// of *invalid* lines (corrupt vs. torn tail) is positional and handled
/// by the caller.
fn verify_line(line: &[u8], position: usize, legacy: &mut bool) -> LineVerdict {
    // v3 framed line: header fields are ASCII, so byte-split is safe.
    if let Some(rest) = line.strip_prefix(b"v3:") {
        let Some(c1) = rest.iter().position(|&b| b == b':') else { return LineVerdict::Invalid };
        let Some(c2off) = rest[c1 + 1..].iter().position(|&b| b == b':') else {
            return LineVerdict::Invalid;
        };
        let c2 = c1 + 1 + c2off;
        let Some(c3off) = rest[c2 + 1..].iter().position(|&b| b == b':') else {
            return LineVerdict::Invalid;
        };
        let c3 = c2 + 1 + c3off;
        let gen_s = std::str::from_utf8(&rest[..c1]).ok();
        let len_s = std::str::from_utf8(&rest[c1 + 1..c2]).ok();
        let crc_s = std::str::from_utf8(&rest[c2 + 1..c3]).ok();
        let (Some(gen_s), Some(len_s), Some(crc_s)) = (gen_s, len_s, crc_s) else {
            return LineVerdict::Invalid;
        };
        let (Ok(generation), Ok(len), Ok(crc)) = (
            gen_s.parse::<u64>(),
            len_s.parse::<usize>(),
            u32::from_str_radix(crc_s, 16),
        ) else {
            return LineVerdict::Invalid;
        };
        let payload = &rest[c3 + 1..];
        if payload.len() != len || crc32c(payload) != crc {
            return LineVerdict::Invalid;
        }
        let Ok(payload) = std::str::from_utf8(payload) else { return LineVerdict::Invalid };
        match Json::parse(payload).ok().and_then(|j| PlanRecord::from_json(&j)) {
            Some(rec) => LineVerdict::Valid(ScannedRecord {
                rec,
                payload: payload.to_string(),
                generation,
                position,
            }),
            None => LineVerdict::Invalid,
        }
    } else {
        // Legacy bare JSON line (v1/v2): verified by parse only.
        let Ok(text) = std::str::from_utf8(line) else { return LineVerdict::Invalid };
        match Json::parse(text).ok().and_then(|j| PlanRecord::from_json(&j)) {
            Some(rec) => {
                *legacy = true;
                LineVerdict::Valid(ScannedRecord {
                    rec,
                    payload: text.to_string(),
                    generation: 0,
                    position,
                })
            }
            None => LineVerdict::Invalid,
        }
    }
}

/// Byte-level verification scan of a whole store file. Pure and total:
/// any input classifies every line as verified / legacy / corrupt /
/// torn-tail without panicking. The recovery state machine (DESIGN.md
/// §14): an invalid line that is the *final* line and lacks its
/// terminating newline is a torn tail (truncate); an invalid line
/// anywhere else — or a terminated final line — is corrupt (skip).
fn scan_bytes(data: &[u8]) -> Scan {
    let mut scan =
        Scan { records: Vec::new(), report: RecoveryReport::default(), max_generation: 0 };
    let mut pos = 0usize;
    let mut position = 0usize;
    while pos < data.len() {
        let (line, next, terminated) = match data[pos..].iter().position(|&b| b == b'\n') {
            Some(i) => (&data[pos..pos + i], pos + i + 1, true),
            None => (&data[pos..], data.len(), false),
        };
        pos = next;
        if line.iter().all(|b| b.is_ascii_whitespace()) {
            continue;
        }
        scan.report.total_lines += 1;
        let mut legacy = false;
        match verify_line(line, position, &mut legacy) {
            LineVerdict::Valid(sr) => {
                scan.max_generation = scan.max_generation.max(sr.generation);
                if legacy {
                    scan.report.legacy += 1;
                } else {
                    scan.report.verified += 1;
                }
                scan.records.push(sr);
                position += 1;
            }
            LineVerdict::Invalid => {
                if !terminated && pos >= data.len() {
                    scan.report.torn_tail = true;
                    scan.report.torn_bytes = line.len();
                } else {
                    scan.report.corrupt += 1;
                }
            }
        }
    }
    scan
}

/// Resolve duplicates: highest generation wins; equal generations fall
/// back to file order (later wins — legacy lines are all generation 0,
/// which reduces to the historical last-write-wins). Returns winners in
/// file order of the winning line, and counts the superseded.
fn fold_records(records: Vec<ScannedRecord>, report: &mut RecoveryReport) -> Vec<ScannedRecord> {
    let mut winners: HashMap<String, ScannedRecord> = HashMap::new();
    for sr in records {
        match winners.get(&sr.rec.key) {
            Some(prev) if prev.generation > sr.generation => report.duplicates += 1,
            Some(_) => {
                report.duplicates += 1;
                winners.insert(sr.rec.key.clone(), sr);
            }
            None => {
                winners.insert(sr.rec.key.clone(), sr);
            }
        }
    }
    let mut out: Vec<ScannedRecord> = winners.into_values().collect();
    out.sort_by_key(|sr| sr.position);
    report.live = out.len();
    out
}

/// Find (and optionally remove) orphan `<store>.snap.*` files left by a
/// crash between snapshot write and rename. The main file is still the
/// consistent truth in that state; the snapshot is garbage.
fn sweep_orphan_snapshots(path: &Path, remove: bool) -> usize {
    let Some(name) = path.file_name().and_then(|s| s.to_str()) else { return 0 };
    let parent = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => PathBuf::from("."),
    };
    let prefix = format!("{name}.snap.");
    let mut found = 0usize;
    if let Ok(entries) = std::fs::read_dir(parent) {
        for entry in entries.flatten() {
            if entry.file_name().to_str().is_some_and(|f| f.starts_with(&prefix)) {
                found += 1;
                if remove {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }
    }
    found
}

/// Bounded plan cache: in-memory LRU index over an append-only JSONL file
/// (or memory-only when opened without a path).
#[derive(Debug)]
pub struct PlanStore {
    path: Option<PathBuf>,
    capacity: usize,
    map: HashMap<String, PlanRecord>,
    /// Last-access stamp per live key (monotonic `clock` values): O(1)
    /// recency bumps on every get/put; the O(capacity) scan for the
    /// minimum happens only when evicting, which is rare relative to
    /// lookups.
    recency: HashMap<String, u64>,
    clock: u64,
    /// Lines currently on disk (appends since the last compaction plus
    /// the loaded base) — drives the compaction heuristic.
    disk_lines: usize,
    /// Distinct keys on disk as of the last load/compaction (best-effort
    /// across processes). The compaction threshold compares lines
    /// against THIS, not against the capacity-bounded in-memory map —
    /// otherwise a store whose file legitimately holds more keys than
    /// its own capacity would rewrite the whole file on every put.
    disk_keys: usize,
    /// Next generation this store stamps on an appended record; seeded
    /// past the highest generation seen at load so re-puts always
    /// supersede what is on disk.
    next_generation: u64,
    /// Seeded disk-fault schedule (tests); `None` = real I/O.
    fault: Option<Arc<DiskFaultPlan>>,
    /// What the load-time verification scan found.
    pub recovery: RecoveryReport,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Lines skipped at load time (corrupt, torn or old-version).
    pub skipped: u64,
    /// Disk writes that failed and were degraded to memory-only.
    pub write_errors: u64,
    /// Set once any disk write has failed: the in-memory index is ahead
    /// of the file (surfaced in `disco serve` stats).
    pub degraded: bool,
}

impl PlanStore {
    /// Memory-only store (tests, `--store none`).
    pub fn in_memory(capacity: usize) -> PlanStore {
        PlanStore {
            path: None,
            capacity: capacity.max(1),
            map: HashMap::new(),
            recency: HashMap::new(),
            clock: 0,
            disk_lines: 0,
            disk_keys: 0,
            next_generation: 1,
            fault: None,
            recovery: RecoveryReport::default(),
            hits: 0,
            misses: 0,
            evictions: 0,
            skipped: 0,
            write_errors: 0,
            degraded: false,
        }
    }

    /// Open (creating if absent) a JSONL-backed store with real I/O.
    pub fn open(path: &Path, capacity: usize) -> Result<PlanStore> {
        Self::open_with(path, capacity, None)
    }

    /// Constructor hook for seeded disk-fault injection: identical to
    /// [`PlanStore::open`] but every subsequent data-file operation
    /// consults `fault` (see [`DiskFaultPlan`] for the op numbering).
    ///
    /// Recovery contract: duplicate keys resolve by highest generation
    /// (file order on ties), unreadable lines are counted in `skipped`
    /// and dropped, a torn tail is truncated, and the full outcome lands
    /// in [`PlanStore::recovery`]. Anything beyond `capacity` is evicted
    /// oldest-first from the in-memory index only — the file keeps every
    /// live record, so a second process with a larger capacity loses
    /// nothing. When damage was found the file is rewritten clean; if
    /// that rewrite fails (read-only disk) the store still opens, marked
    /// degraded.
    pub fn open_with(
        path: &Path,
        capacity: usize,
        fault: Option<Arc<DiskFaultPlan>>,
    ) -> Result<PlanStore> {
        let mut store = PlanStore::in_memory(capacity);
        store.path = Some(path.to_path_buf());
        store.fault = fault;
        if path.exists() {
            let _lock = StoreLock::acquire(path)?;
            store.recovery.orphan_snapshots = sweep_orphan_snapshots(path, true);
            let data = store.io_read(path).map_err(|source| StoreError::Io {
                op: "read",
                path: path.to_path_buf(),
                source,
            })?;
            let scan = scan_bytes(&data);
            store.recovery.total_lines = scan.report.total_lines;
            store.recovery.verified = scan.report.verified;
            store.recovery.legacy = scan.report.legacy;
            store.recovery.corrupt = scan.report.corrupt;
            store.recovery.torn_tail = scan.report.torn_tail;
            store.recovery.torn_bytes = scan.report.torn_bytes;
            store.next_generation = scan.max_generation + 1;
            let mut report = store.recovery.clone();
            let winners = fold_records(scan.records, &mut report);
            store.recovery = report;
            store.skipped =
                (store.recovery.corrupt + usize::from(store.recovery.torn_tail)) as u64;
            store.disk_lines = store.recovery.total_lines;
            store.disk_keys = store.recovery.live;
            for sr in winners {
                store.index(sr.rec);
            }
            // Reclaim the file when the load found damage or duplicates
            // (NOT when records merely exceeded our capacity — those
            // stay on disk for other readers). A failed rewrite (e.g.
            // read-only disk) degrades instead of failing the open: the
            // loaded records are already correct in memory.
            //
            // A VALID final line missing its newline (truncation that
            // stopped exactly at the line's last content byte) also
            // forces the rewrite: the record is served, but a blind
            // append would concatenate onto the unterminated line and
            // corrupt both records.
            let unterminated = !data.is_empty() && data.last() != Some(&b'\n');
            if !store.recovery.is_clean() || unterminated {
                match store.compact_locked() {
                    Ok(()) => store.recovery.repaired = true,
                    Err(e) => {
                        store.write_errors += 1;
                        store.degraded = true;
                        eprintln!("disco store: recovery rewrite failed ({e}); continuing degraded");
                    }
                }
            }
        }
        Ok(store)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Read the whole data file through the fault shim (one logical op).
    fn io_read(&self, path: &Path) -> std::io::Result<Vec<u8>> {
        use std::io::Read;
        let fault = self.fault.as_ref().and_then(|p| p.begin_op());
        let seed = self.fault.as_ref().map_or(0, |p| p.seed);
        let f = std::fs::File::open(path)?;
        let mut shim = FaultFile::new(f, fault, seed);
        let mut data = Vec::new();
        shim.read_to_end(&mut data)?;
        Ok(data)
    }

    /// Append one framed line through the fault shim (one logical op).
    fn io_append(&self, path: &Path, line: &str) -> std::io::Result<()> {
        let fault = self.fault.as_ref().and_then(|p| p.begin_op());
        let seed = self.fault.as_ref().map_or(0, |p| p.seed);
        let f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        let mut shim = FaultFile::new(f, fault, seed);
        shim.write_all(line.as_bytes())?;
        shim.write_all(b"\n")?;
        shim.flush()
    }

    /// Write a whole snapshot file through the fault shim (one logical op).
    fn io_write_snapshot(&self, path: &Path, contents: &str) -> std::io::Result<()> {
        let fault = self.fault.as_ref().and_then(|p| p.begin_op());
        let seed = self.fault.as_ref().map_or(0, |p| p.seed);
        let f = std::fs::File::create(path)?;
        let mut shim = FaultFile::new(f, fault, seed);
        shim.write_all(contents.as_bytes())?;
        shim.flush()
    }

    /// Rename through the fault shim (one logical op; `err`/`slow` only —
    /// a rename has no partial state to tear).
    fn io_rename(&self, from: &Path, to: &Path) -> std::io::Result<()> {
        match self.fault.as_ref().and_then(|p| p.begin_op()) {
            Some(DiskFault::Err { .. }) => return Err(super::io_fault::injected_error()),
            Some(DiskFault::Slow { ms, .. }) => {
                std::thread::sleep(std::time::Duration::from_millis(ms))
            }
            _ => {}
        }
        std::fs::rename(from, to)
    }

    fn touch(&mut self, key: &str) {
        self.clock += 1;
        self.recency.insert(key.to_string(), self.clock);
    }

    /// Insert into the index (no disk IO), evicting LRU overflow.
    fn index(&mut self, rec: PlanRecord) {
        let key = rec.key.clone();
        self.map.insert(key.clone(), rec);
        self.touch(&key);
        while self.map.len() > self.capacity {
            let oldest = self
                .recency
                .iter()
                .min_by_key(|&(_, &stamp)| stamp)
                .map(|(k, _)| k.clone());
            match oldest {
                Some(k) => {
                    self.map.remove(&k);
                    self.recency.remove(&k);
                    self.evictions += 1;
                }
                None => break,
            }
        }
    }

    /// Cache lookup; bumps LRU recency and the hit/miss counters.
    pub fn get(&mut self, key: &str) -> Option<&PlanRecord> {
        if self.map.contains_key(key) {
            self.hits += 1;
            self.touch(key);
            self.map.get(key)
        } else {
            self.misses += 1;
            None
        }
    }

    /// Lookup without touching recency or counters.
    pub fn peek(&self, key: &str) -> Option<&PlanRecord> {
        self.map.get(key)
    }

    /// Insert (or overwrite) a record and persist it. The append and any
    /// resulting compaction happen under the cross-process file lock.
    ///
    /// Disk failure does NOT fail the request: the record stays indexed
    /// in memory, `write_errors`/`degraded` are set and a warning is
    /// logged — a read-only disk turns the store into a cache, not an
    /// outage (DESIGN.md §14).
    pub fn put(&mut self, rec: PlanRecord) -> Result<()> {
        let generation = self.next_generation;
        self.next_generation += 1;
        let line = frame_line(generation, &rec.to_json().to_string());
        self.index(rec);
        if self.path.is_some() {
            if let Err(e) = self.put_disk(&line) {
                self.write_errors += 1;
                self.degraded = true;
                eprintln!("disco store: append failed ({e}); record kept memory-only");
            }
        }
        Ok(())
    }

    fn put_disk(&mut self, line: &str) -> Result<(), StoreError> {
        let path = self.path.clone().expect("put_disk without path");
        let _lock = StoreLock::acquire(&path)?;
        self.io_append(&path, line).map_err(|source| StoreError::Io {
            op: "append",
            path: path.clone(),
            source,
        })?;
        self.disk_lines += 1;
        // disk_keys is only ever set from an exact disk scan (open /
        // compaction), never guessed at put time: a guess based on
        // the capacity-bounded map over-counts once eviction starts
        // (every re-put of an evicted key would look new), inflating
        // the threshold until compaction never fires. A stale-LOW
        // disk_keys merely compacts a little early — the safe
        // direction, and it amortizes geometrically either way.
        if self.disk_lines > COMPACT_FACTOR * self.disk_keys.max(4) {
            self.compact_locked()?;
        }
        Ok(())
    }

    /// Compact the backing file under the cross-process lock. Unlike
    /// `put`, this surfaces disk failures to the caller (typed
    /// [`StoreError`] behind the anyhow wrapper) — an explicit compaction
    /// is an administrative action whose failure must be visible.
    pub fn compact(&mut self) -> Result<()> {
        let Some(path) = self.path.clone() else { return Ok(()) };
        let _lock = StoreLock::acquire(&path)?;
        self.compact_locked()?;
        Ok(())
    }

    /// Rewrite the backing file with exactly the live on-disk record set
    /// (one framed line per key, highest generation wins, corrupt lines
    /// dropped). The caller must hold the store lock. Compaction
    /// deliberately merges from *disk*, not from this process's
    /// in-memory index: a second process sharing the path may have
    /// appended records this index has never seen (or has evicted), and
    /// rewriting from memory would silently delete them. Every record
    /// this process has put is on disk already (`put` appends before
    /// compacting), so the disk set is a superset of this index.
    ///
    /// Crash-atomicity: the new contents land in `<store>.snap.<pid>`
    /// first and are renamed over the store. A crash before the rename
    /// leaves the old file intact plus an orphan snapshot (swept at next
    /// open); the rename itself is atomic. Every step's failure is a
    /// typed [`StoreError`] naming the step — nothing is swallowed.
    fn compact_locked(&mut self) -> Result<(), StoreError> {
        let Some(path) = self.path.clone() else { return Ok(()) };
        let data = match self.io_read(&path) {
            Ok(d) => d,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(source) => return Err(StoreError::Io { op: "read", path, source }),
        };
        let scan = scan_bytes(&data);
        self.next_generation = self.next_generation.max(scan.max_generation + 1);
        let mut report = RecoveryReport::default();
        let winners = fold_records(scan.records, &mut report);
        let mut out = String::new();
        for sr in &winners {
            out.push_str(&frame_line(sr.generation, &sr.payload));
            out.push('\n');
        }
        // Write-then-rename: the shared file is every process's source
        // of truth, so it must never be observable (or left, on a
        // crash) in a truncated in-place-rewrite state.
        let tmp = {
            let mut os = path.as_os_str().to_os_string();
            os.push(format!(".snap.{}", std::process::id()));
            PathBuf::from(os)
        };
        if let Err(source) = self.io_write_snapshot(&tmp, &out) {
            let _ = std::fs::remove_file(&tmp);
            return Err(StoreError::Io { op: "snapshot", path: tmp, source });
        }
        if let Err(source) = self.io_rename(&tmp, &path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(StoreError::Io { op: "rename", path, source });
        }
        self.disk_lines = winners.len();
        self.disk_keys = winners.len();
        Ok(())
    }

    /// All records for one canonical graph fingerprint (any environment),
    /// in deterministic key order — warm-start seed candidates.
    pub fn by_graph_fp(&self, graph_fp: &str) -> Vec<&PlanRecord> {
        let mut out: Vec<&PlanRecord> =
            self.map.values().filter(|r| r.graph_fp == graph_fp).collect();
        out.sort_by(|a, b| a.key.cmp(&b.key));
        out
    }

    /// The record whose sketch is nearest to `sketch` (ties broken by
    /// key for determinism), excluding `exclude_key`, within
    /// `max_distance`.
    pub fn nearest(
        &self,
        sketch: &GraphSketch,
        exclude_key: &str,
        max_distance: f64,
    ) -> Option<&PlanRecord> {
        self.map
            .values()
            .filter(|r| r.key != exclude_key)
            .map(|r| (r.sketch.distance(sketch), r))
            .filter(|(d, _)| *d <= max_distance)
            .min_by(|(da, a), (db, b)| {
                da.partial_cmp(db)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then_with(|| a.key.cmp(&b.key))
            })
            .map(|(_, r)| r)
    }
}

/// Verify a store file and print-ready report; `repair` rewrites the
/// file clean (and sweeps orphan snapshots) when damage is found. Runs
/// under the cross-process lock; a missing file is a clean empty store.
/// The scan is the same one `open` runs — fsck IS the recovery path,
/// minus the in-memory indexing.
pub fn fsck(path: &Path, repair: bool) -> Result<RecoveryReport> {
    if !path.exists() {
        return Ok(RecoveryReport::default());
    }
    let _lock = StoreLock::acquire(path)?;
    let mut report = RecoveryReport {
        orphan_snapshots: sweep_orphan_snapshots(path, repair),
        ..RecoveryReport::default()
    };
    let data = std::fs::read(path).map_err(|source| StoreError::Io {
        op: "read",
        path: path.to_path_buf(),
        source,
    })?;
    let scan = scan_bytes(&data);
    report.total_lines = scan.report.total_lines;
    report.verified = scan.report.verified;
    report.legacy = scan.report.legacy;
    report.corrupt = scan.report.corrupt;
    report.torn_tail = scan.report.torn_tail;
    report.torn_bytes = scan.report.torn_bytes;
    let winners = fold_records(scan.records, &mut report);
    if repair && !report.is_clean() {
        let mut out = String::new();
        for sr in &winners {
            out.push_str(&frame_line(sr.generation, &sr.payload));
            out.push('\n');
        }
        let tmp = {
            let mut os = path.as_os_str().to_os_string();
            os.push(format!(".snap.{}", std::process::id()));
            PathBuf::from(os)
        };
        std::fs::write(&tmp, out).map_err(|source| StoreError::Io {
            op: "snapshot",
            path: tmp.clone(),
            source,
        })?;
        std::fs::rename(&tmp, path).map_err(|source| StoreError::Io {
            op: "rename",
            path: path.to_path_buf(),
            source,
        })?;
        report.repaired = true;
    }
    Ok(report)
}

/// Convenience for CLI/config plumbing: `None`/`"none"` → memory-only.
pub fn open_store(path: Option<&str>, capacity: usize) -> Result<PlanStore> {
    match path {
        None => Ok(PlanStore::in_memory(capacity)),
        Some("none") => Ok(PlanStore::in_memory(capacity)),
        Some(p) if p.is_empty() => Err(anyhow!("empty plan-store path")),
        Some(p) => PlanStore::open(Path::new(p), capacity),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(key: &str, gfp: &str, cost: f64) -> PlanRecord {
        PlanRecord {
            key: key.to_string(),
            graph_fp: gfp.to_string(),
            arena_fp: 0xABCD,
            model: "m".into(),
            sketch: GraphSketch {
                kind_counts: vec![1, 2, 0],
                live: 3,
                allreduces: 1,
                num_workers: 4,
                total_flops: cost * 10.0,
                grad_bytes: 64.0,
            },
            muts: vec![
                Mutation::FuseOps { pred: 1, succ: 2, kind: FusionKind::NonDuplicate },
                Mutation::FuseAllReduce { a: 4, b: 5 },
            ],
            best_cost_ms: cost,
            initial_cost_ms: cost * 2.0,
            evals: 10,
            steps: 5,
            elapsed_ms: 1.5,
        }
    }

    #[test]
    fn record_json_roundtrip() {
        let r = record("k1", "g1", 3.25);
        let j = r.to_json().to_string();
        let r2 = PlanRecord::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(r, r2);
    }

    #[test]
    fn version_mismatch_is_skipped() {
        let mut j = record("k1", "g1", 1.0).to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("v".into(), Json::Num((RECORD_VERSION + 1) as f64));
        }
        assert!(PlanRecord::from_json(&j).is_none());
    }

    #[test]
    fn v1_and_v2_records_still_load() {
        // Pre-durability records (v1 fusion-only, v2 chunked, v3 framed)
        // must parse under the bumped version and keep their plans
        // intact — replaying a v1 record produces exactly the unchunked,
        // unsharded strategy it stored.
        for old in [1.0, 2.0, 3.0] {
            let mut j = record("k1", "g1", 1.0).to_json();
            if let Json::Obj(m) = &mut j {
                m.insert("v".into(), Json::Num(old));
            }
            let r = PlanRecord::from_json(&j).unwrap_or_else(|| panic!("v{old} record rejected"));
            assert_eq!(r.muts, record("k1", "g1", 1.0).muts);
            assert!(!r.muts.iter().any(|m| matches!(m, Mutation::SetChunks { .. })));
            assert!(!r.muts.iter().any(|m| matches!(m, Mutation::SetSharding { .. })));
        }
    }

    #[test]
    fn chunk_mutation_roundtrips() {
        let mut r = record("k2", "g1", 2.0);
        r.muts.push(Mutation::SetChunks { ar: 7, count: 8 });
        let j = r.to_json().to_string();
        let r2 = PlanRecord::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(r, r2);
        assert!(j.contains("\"ck\""));
    }

    #[test]
    fn shard_mutation_roundtrips() {
        let mut r = record("k3", "g1", 2.0);
        r.muts.push(Mutation::SetSharding {
            ar: 5,
            kind: CollectiveKind::ReduceScatterAllGather,
        });
        r.muts.push(Mutation::SetSharding { ar: 5, kind: CollectiveKind::AllReduce });
        let j = r.to_json().to_string();
        let r2 = PlanRecord::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(r, r2);
        assert!(j.contains("\"sh\""));
        // An unknown kind index is a malformed record, not a panic.
        let bad = j.replace("\"k\":1", "\"k\":9");
        assert!(PlanRecord::from_json(&Json::parse(&bad).unwrap()).is_none());
    }

    #[test]
    fn frame_line_verifies_and_detects_flips() {
        let payload = record("k1", "g1", 1.0).to_json().to_string();
        let line = frame_line(7, &payload);
        assert!(line.starts_with("v3:7:"));
        let mut legacy = false;
        assert!(matches!(
            verify_line(line.as_bytes(), 0, &mut legacy),
            LineVerdict::Valid(ScannedRecord { generation: 7, .. })
        ));
        // Any single-byte corruption of the payload must be rejected.
        let mut bad = line.clone().into_bytes();
        let last = bad.len() - 1;
        bad[last] ^= 0x20;
        assert!(matches!(verify_line(&bad, 0, &mut legacy), LineVerdict::Invalid));
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut s = PlanStore::in_memory(2);
        s.put(record("a", "g", 1.0)).unwrap();
        s.put(record("b", "g", 2.0)).unwrap();
        assert!(s.get("a").is_some()); // bump a → b is now LRU
        s.put(record("c", "g", 3.0)).unwrap();
        assert_eq!(s.len(), 2);
        assert!(s.peek("b").is_none(), "b should have been evicted");
        assert!(s.peek("a").is_some() && s.peek("c").is_some());
        assert_eq!(s.evictions, 1);
        assert_eq!((s.hits, s.misses), (1, 0));
        assert!(s.get("zz").is_none());
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn nearest_picks_minimal_distance_deterministically() {
        let mut s = PlanStore::in_memory(8);
        let mut far = record("far", "g1", 1.0);
        far.sketch.total_flops = 1e12;
        far.sketch.allreduces = 9;
        s.put(far).unwrap();
        s.put(record("near", "g2", 1.0)).unwrap();
        let probe = record("probe", "g3", 1.0).sketch;
        let n = s.nearest(&probe, "none", f64::INFINITY).unwrap();
        assert_eq!(n.key, "near");
        // Excluding the winner falls back to the next one.
        let n2 = s.nearest(&probe, "near", f64::INFINITY).unwrap();
        assert_eq!(n2.key, "far");
        // A tight radius excludes everything.
        assert!(s.nearest(&probe, "none", -1.0).is_none());
    }

    #[test]
    fn by_graph_fp_sorted() {
        let mut s = PlanStore::in_memory(8);
        s.put(record("b", "g1", 1.0)).unwrap();
        s.put(record("a", "g1", 1.0)).unwrap();
        s.put(record("c", "g2", 1.0)).unwrap();
        let got: Vec<&str> = s.by_graph_fp("g1").iter().map(|r| r.key.as_str()).collect();
        assert_eq!(got, vec!["a", "b"]);
    }

    #[test]
    fn persistence_last_write_wins_and_corrupt_lines_skipped() {
        let dir = std::env::temp_dir().join(format!("disco-store-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plans.jsonl");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = PlanStore::open(&path, 8).unwrap();
            s.put(record("a", "g", 1.0)).unwrap();
            s.put(record("b", "g", 2.0)).unwrap();
            s.put(record("a", "g", 9.0)).unwrap(); // overwrite
        }
        // Corrupt trailing line (newline-terminated → corrupt, not torn)
        // must not poison the load.
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            writeln!(f, "{{ not json").unwrap();
        }
        let s = PlanStore::open(&path, 8).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.peek("a").unwrap().best_cost_ms, 9.0);
        assert_eq!(s.skipped, 1);
        assert_eq!(s.recovery.corrupt, 1);
        assert!(!s.recovery.torn_tail);
        assert!(s.recovery.repaired);
        // Load compacted away the duplicate and the corrupt line.
        let reread = std::fs::read_to_string(&path).unwrap();
        assert_eq!(reread.lines().count(), 2);
        assert!(reread.lines().all(|l| l.starts_with("v3:")));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn generation_wins_over_file_order() {
        // A higher-generation line EARLIER in the file beats a
        // lower-generation duplicate appended after it (e.g. a stale
        // writer re-appending an old record after a compaction).
        let dir = std::env::temp_dir().join(format!("disco-store-gen-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plans.jsonl");
        let newer = frame_line(9, &record("k", "g", 5.0).to_json().to_string());
        let stale = frame_line(3, &record("k", "g", 1.0).to_json().to_string());
        std::fs::write(&path, format!("{newer}\n{stale}\n")).unwrap();
        let s = PlanStore::open(&path, 8).unwrap();
        assert_eq!(s.peek("k").unwrap().best_cost_ms, 5.0);
        assert_eq!(s.recovery.duplicates, 1);
        // A fresh put must supersede generation 9, even though the
        // stale line was the last one read.
        drop(s);
        let mut s = PlanStore::open(&path, 8).unwrap();
        s.put(record("k", "g", 7.0)).unwrap();
        let s = PlanStore::open(&path, 8).unwrap();
        assert_eq!(s.peek("k").unwrap().best_cost_ms, 7.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_tail_is_truncated_and_put_roundtrips() {
        let dir = std::env::temp_dir().join(format!("disco-store-torn-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plans.jsonl");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = PlanStore::open(&path, 8).unwrap();
            s.put(record("a", "g", 1.0)).unwrap();
        }
        // Simulate a crash mid-append: half a framed line, no newline.
        let half = frame_line(99, &record("b", "g", 2.0).to_json().to_string());
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{}", &half[..half.len() / 2]).unwrap();
        }
        {
            let mut s = PlanStore::open(&path, 8).unwrap();
            assert_eq!(s.len(), 1);
            assert!(s.recovery.torn_tail);
            assert!(s.recovery.repaired);
            s.put(record("c", "g", 3.0)).unwrap();
        }
        let s = PlanStore::open(&path, 8).unwrap();
        assert!(s.recovery.is_clean());
        assert_eq!(s.len(), 2);
        assert!(s.peek("a").is_some() && s.peek("c").is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn reopen_respects_capacity() {
        let dir = std::env::temp_dir().join(format!("disco-store-cap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plans.jsonl");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = PlanStore::open(&path, 8).unwrap();
            for i in 0..6 {
                s.put(record(&format!("k{i}"), "g", i as f64)).unwrap();
            }
        }
        let s = PlanStore::open(&path, 3).unwrap();
        assert_eq!(s.len(), 3);
        // Oldest-first eviction: the newest three survive.
        assert!(s.peek("k5").is_some() && s.peek("k4").is_some() && s.peek("k3").is_some());
        let _ = std::fs::remove_file(&path);
    }
}
