//! Strategy service: content-addressed plan caching, warm-started search
//! and the `disco serve`/`disco plan` front-end (DESIGN.md §11).
//!
//! DisCo's backtracking search is expensive and its output is a pure
//! function of (training graph, cluster/device, estimator, search
//! hyper-parameters). This layer exploits that purity the way auto-tuning
//! systems exploit tuning records: every search result is persisted under
//! a canonical content fingerprint, identical requests are served back by
//! *replaying* the recorded mutation sequence (zero simulator
//! invocations), and similar requests warm-start the search from cached
//! plans instead of rediscovering their rewrites.
//!
//! * [`fingerprint`] — relabeling-invariant graph hashing + environment
//!   keys (estimator name *and* content) + similarity sketches;
//! * [`store`] — the persistent JSONL plan store with checksummed v3
//!   framing, crash recovery and a bounded LRU index;
//! * [`io_fault`] — seeded disk-fault injection behind the store's
//!   constructor hook (the §14 durability proofs);
//! * [`warm`] — hit → warm → cold plan resolution;
//! * [`server`] — the threaded TCP front-end with per-fingerprint request
//!   coalescing and cold-search admission control.

pub mod fingerprint;
pub mod io_fault;
pub mod server;
pub mod store;
pub mod warm;

pub use fingerprint::{
    arena_fingerprint, env_fingerprint, graph_fingerprint, plan_key, EstimatorFp, Fingerprint,
    GraphSketch,
};
pub use io_fault::{DiskFault, DiskFaultPlan, FaultFile};
pub use server::{request, Server, ServeOptions};
pub use store::{
    fsck, open_store, PlanRecord, PlanStore, RecoveryReport, StoreError, RECORD_VERSION,
};
pub use warm::{plan_with_store, try_replay_hit, PlanOutcome, PlanSource, WarmOptions};

/// Config-file `service` section (`disco serve --config svc.json`): store
/// location, LRU capacity and warm-start policy.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub addr: String,
    /// JSONL store path; `None` (config string `"none"`) = memory-only.
    pub store_path: Option<String>,
    pub capacity: usize,
    pub warm_start: bool,
    /// Allow seeding from the nearest-sketch plan of a different graph.
    pub nearest: bool,
    /// Connection limit before the server sheds load.
    pub max_conns: usize,
    /// Default cold-search deadline budget in ms (0 = unlimited);
    /// requests override with `budget_ms`.
    pub cold_budget_ms: f64,
    /// Concurrent cold-search cap (separate from `max_conns`; 0 admits
    /// none — a replay-only server).
    pub max_cold: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            addr: "127.0.0.1:7077".to_string(),
            store_path: Some("plans.jsonl".to_string()),
            capacity: 512,
            warm_start: true,
            nearest: true,
            max_conns: 256,
            cold_budget_ms: 0.0,
            max_cold: 8,
        }
    }
}

impl ServiceConfig {
    /// Lower into the server's runtime options.
    pub fn serve_options(&self) -> ServeOptions {
        ServeOptions {
            addr: self.addr.clone(),
            store_path: self.store_path.clone(),
            capacity: self.capacity,
            warm: WarmOptions {
                enabled: self.warm_start,
                nearest: self.nearest,
                ..WarmOptions::default()
            },
            max_conns: self.max_conns,
            cold_budget_ms: self.cold_budget_ms,
            max_cold: self.max_cold,
        }
    }
}
