//! Content-addressed identity for (training graph, environment) pairs —
//! the key of the strategy service's plan store (DESIGN.md §11).
//!
//! A plan is reusable exactly when *everything that determines the search
//! result* is identical, so the key has two halves:
//!
//! * [`graph_fingerprint`] — a canonical hash of the live graph
//!   structure. It is **relabeling-invariant** (isomorphic graphs that
//!   differ only in arena numbering or node/graph names hash equal) and
//!   **semantics-sensitive** (any change to an op kind, role, dtype,
//!   shape, FLOPs, byte traffic, wiring — including duplicate operand
//!   edges like `x·x` — a fused group's contents, or the worker count
//!   produces a different hash). Node hashes are computed bottom-up in
//!   topological order, so a node's hash depends only on its own features
//!   and its operands' hashes, never on arena indices; the graph hash is
//!   the sorted multiset of live node hashes.
//! * [`env_fingerprint`] — the cluster, device, estimator and the
//!   result-relevant search hyper-parameters. Engine toggles that are
//!   property-tested to never change results (`eval_threads`,
//!   `delta_candidates`, `reuse_workspaces`, `parallel_min_nodes`,
//!   `cost_table`, `delta_sim`, `ckpt_every`, `track_best_path`) are
//!   deliberately excluded; `incremental_candidates` *is* included
//!   because it legitimately steers the random trajectory.
//!
//! Hashes use an explicit FNV-1a so fingerprints are stable across
//! platforms, Rust versions and process runs — they live on disk.
//! Both halves are 128-bit (two independently-seeded 64-bit lanes), so
//! accidental collisions are out of the picture at plan-store scale.
//!
//! [`GraphSketch`] is the companion *similarity* summary used by
//! warm-starting: a coarse feature vector (op-kind histogram, FLOPs,
//! gradient bytes, worker count) with an L1-style distance, for picking
//! the nearest cached plan when no exact fingerprint match exists.

use crate::device::DeviceModel;
use crate::graph::{FusedGroup, GraphError, OpKind, OrigOp, TrainingGraph};
use crate::network::Cluster;
use crate::search::SearchConfig;
use crate::util::json::Json;

/// Streaming FNV-1a 64-bit hasher with an explicit seed. Stable by
/// construction (unlike `DefaultHasher`, whose algorithm is not
/// guaranteed across Rust releases — fine for in-process memo keys,
/// wrong for on-disk identities).
#[derive(Debug, Clone)]
pub struct Fnv64(u64);

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

impl Fnv64 {
    pub fn new(seed: u64) -> Fnv64 {
        let mut h = Fnv64(FNV_OFFSET);
        h.u64(seed);
        h
    }

    #[inline]
    pub fn byte(&mut self, b: u8) {
        self.0 = (self.0 ^ b as u64).wrapping_mul(FNV_PRIME);
    }

    #[inline]
    pub fn u64(&mut self, x: u64) {
        for b in x.to_le_bytes() {
            self.byte(b);
        }
    }

    #[inline]
    pub fn usize(&mut self, x: usize) {
        self.u64(x as u64);
    }

    /// Hashes the bit pattern: -0.0 ≠ 0.0 and every NaN payload is
    /// distinct, which is exactly right for "did the input change".
    #[inline]
    pub fn f64(&mut self, x: f64) {
        self.u64(x.to_bits());
    }

    pub fn str(&mut self, s: &str) {
        self.usize(s.len());
        for &b in s.as_bytes() {
            self.byte(b);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// A 128-bit content fingerprint (two independently-seeded FNV lanes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint {
    pub hi: u64,
    pub lo: u64,
}

impl Fingerprint {
    /// 32-char lowercase hex form — the plan store's record key.
    pub fn hex(&self) -> String {
        format!("{:016x}{:016x}", self.hi, self.lo)
    }

    /// Parse [`Fingerprint::hex`] output.
    pub fn parse(s: &str) -> Option<Fingerprint> {
        if s.len() != 32 {
            return None;
        }
        let hi = u64::from_str_radix(&s[..16], 16).ok()?;
        let lo = u64::from_str_radix(&s[16..], 16).ok()?;
        Some(Fingerprint { hi, lo })
    }

    /// Combine two fingerprints (order-sensitive) into one — used to fuse
    /// the graph and environment halves into the plan key.
    pub fn combine(a: Fingerprint, b: Fingerprint) -> Fingerprint {
        let lane = |seed: u64| {
            let mut f = Fnv64::new(seed);
            f.u64(a.hi);
            f.u64(a.lo);
            f.u64(b.hi);
            f.u64(b.lo);
            f.finish()
        };
        Fingerprint { hi: lane(0xC0FF_EE01), lo: lane(0xC0FF_EE02) }
    }
}

impl std::fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}{:016x}", self.hi, self.lo)
    }
}

/// Content key of one fused-group member — everything cost-relevant,
/// nothing arena-relevant (`orig_id` is an arena index and `time_ms` is a
/// profiler annotation, so both are excluded).
fn orig_op_key(o: &OrigOp, seed: u64) -> u64 {
    let mut f = Fnv64::new(seed);
    f.str(o.kind.name());
    f.f64(o.flops);
    f.f64(o.bytes_in);
    f.f64(o.bytes_out);
    f.byte(o.duplicated as u8);
    f.finish()
}

/// Canonical hash of a fused group: sorted multisets of member keys and
/// of (producer key, consumer key) edges — invariant under member
/// reordering and arena relabeling, sensitive to any member or wiring
/// change.
fn group_hash(g: &FusedGroup, seed: u64) -> u64 {
    let keys: Vec<u64> = g.ops.iter().map(|o| orig_op_key(o, seed)).collect();
    let mut sorted = keys.clone();
    sorted.sort_unstable();
    let mut edges: Vec<(u64, u64)> =
        g.edges.iter().map(|&(a, b)| (keys[a], keys[b])).collect();
    edges.sort_unstable();
    let mut f = Fnv64::new(seed ^ 0xF05E_D0A7);
    f.usize(sorted.len());
    for k in sorted {
        f.u64(k);
    }
    f.usize(edges.len());
    for (a, b) in edges {
        f.u64(a);
        f.u64(b);
    }
    f.finish()
}

/// One lane of the canonical graph hash: bottom-up node hashes over a
/// topological order, combined as a sorted multiset.
fn graph_lane(g: &TrainingGraph, seed: u64) -> Result<u64, GraphError> {
    let order = g.topo_order()?;
    let mut node_hash = vec![0u64; g.nodes.len()];
    for &id in &order {
        let n = &g.nodes[id];
        let mut f = Fnv64::new(seed);
        f.str(n.kind.name());
        f.str(n.role.name());
        f.str(n.dtype.name());
        f.usize(n.shape.dims.len());
        for &d in &n.shape.dims {
            f.usize(d);
        }
        f.f64(n.flops);
        f.f64(n.bytes_in);
        f.f64(n.bytes_out);
        // Operand order and multiplicity preserved: `mul(x, x)` hashes
        // differently from `mul(x, y)` even when x and y hash equal as
        // subtrees do not, and a dropped duplicate edge changes the hash.
        f.usize(n.inputs.len());
        for &i in &n.inputs {
            f.u64(node_hash[i]);
        }
        match &n.fused {
            Some(grp) => f.u64(group_hash(grp, seed)),
            None => f.u64(0),
        }
        // Constituent *identities* are arena ids (relabeling-sensitive)
        // and carry no cost information beyond their count — byte totals
        // already live in `bytes_out`.
        f.usize(n.ar_constituents.len());
        // Folded only when active: an unsharded graph (including the
        // canonical `ShardSpec` of kind AllReduce) hashes exactly as it
        // did before the sharding vocabulary existed, so every pre-shard
        // plan record keeps its key.
        if n.is_sharded_collective() {
            f.byte(2);
        }
        node_hash[id] = f.finish();
    }
    let mut live: Vec<u64> = order.iter().map(|&id| node_hash[id]).collect();
    live.sort_unstable();
    let mut f = Fnv64::new(seed ^ 0x6AFF_1E55);
    f.usize(g.num_workers);
    f.usize(live.len());
    for h in live {
        f.u64(h);
    }
    Ok(f.finish())
}

/// Canonical, relabeling-invariant fingerprint of a live training graph.
/// Graph and node *names* are excluded by design — identity is structure,
/// not labels. Errors only on a cyclic graph (which `validate` rejects
/// everywhere else too).
pub fn graph_fingerprint(g: &TrainingGraph) -> Result<Fingerprint, GraphError> {
    Ok(Fingerprint { hi: graph_lane(g, 0x5EED_0001)?, lo: graph_lane(g, 0x5EED_0002)? })
}

/// Stable, id-*sensitive* arena fingerprint — the exact-replay
/// precondition persisted in plan records: a cached mutation sequence
/// may only be blind-replayed onto a graph whose arena numbering matches
/// the one it was recorded against. Hashes the same structural fields as
/// [`TrainingGraph::fingerprint`] (ids, kinds, wiring, fused groups, AR
/// constituents) but over the explicit FNV basis, because
/// `TrainingGraph::fingerprint` is built on `DefaultHasher`, whose
/// output is not guaranteed stable across Rust releases — fine for
/// in-process candidate dedup, wrong for on-disk identities.
pub fn arena_fingerprint(g: &TrainingGraph) -> u64 {
    let mut f = Fnv64::new(0xA12E_A0F1);
    for n in g.live() {
        f.usize(n.id);
        f.str(n.kind.name());
        f.usize(n.inputs.len());
        for &i in &n.inputs {
            f.usize(i);
        }
        match &n.fused {
            Some(grp) => f.u64(group_hash(grp, 0xA12E_A0F2)),
            None => f.u64(0),
        }
        f.usize(n.ar_constituents.len());
        for &a in &n.ar_constituents {
            f.usize(a);
        }
        // Active shard specs are replay-relevant (a SetSharding mutation
        // recorded against a sharded arena must not blind-replay onto an
        // unsharded one); folded only when active so pre-shard records
        // keep their arena hashes.
        if n.is_sharded_collective() {
            f.byte(2);
        }
    }
    f.finish()
}

/// Identity of the cost estimator as a cache-key component: its name
/// plus a hash of its *content* (trained-parameter artifact or
/// calibration state). Name alone is not enough — retraining the GNN
/// estimator changes every cost it predicts, so cached plans searched
/// under the old parameters are stale even though the name `"gnn"` is
/// unchanged (the ROADMAP-named invalidation bug). `content == 0` means
/// "content-free" (analytical / oracle estimators, or a named estimator
/// whose artifact is absent) and hashes exactly as the pre-content
/// format did, so those keys stay warm across the upgrade.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EstimatorFp {
    pub name: String,
    pub content: u64,
}

impl EstimatorFp {
    /// A content-free estimator identity (analytical, oracle).
    pub fn named(name: &str) -> EstimatorFp {
        EstimatorFp { name: name.to_string(), content: 0 }
    }

    /// Identity of a parameterised estimator: the serialized parameter
    /// bytes are hashed so any retrain flips the fingerprint and a
    /// byte-identical reload does not.
    pub fn with_params(name: &str, params: &[u8]) -> EstimatorFp {
        let mut f = Fnv64::new(0xE57A_7E01);
        f.usize(params.len());
        for &b in params {
            f.byte(b);
        }
        EstimatorFp { name: name.to_string(), content: f.finish() }
    }

    /// Resolve the identity for a request: `requested` is the client's
    /// estimator string, `serving` the backend actually used. A `"gnn"`
    /// request folds the trained-parameter artifact
    /// (`<artifacts>/gnn_trained.f32`, written by the training
    /// pipeline) into the key when present — the artifact state is part
    /// of the environment, so retraining invalidates cached plans.
    /// Absent artifact (or any other estimator) is content-free.
    pub fn resolve(requested: &str, serving: &str, artifacts: &std::path::Path) -> EstimatorFp {
        if requested == "gnn" {
            if let Ok(bytes) = std::fs::read(artifacts.join("gnn_trained.f32")) {
                return EstimatorFp::with_params(serving, &bytes);
            }
        }
        EstimatorFp::named(serving)
    }
}

/// Fingerprint of everything outside the graph that determines a search
/// result: cluster, device, estimator identity (name *and* content —
/// see [`EstimatorFp`]), simulation knobs and the trajectory-relevant
/// search hyper-parameters.
pub fn env_fingerprint(
    cluster: &Cluster,
    device: &DeviceModel,
    estimator: &EstimatorFp,
    cfg: &SearchConfig,
) -> Fingerprint {
    let lane = |seed: u64| {
        let mut f = Fnv64::new(seed);
        f.str(&cluster.name);
        f.usize(cluster.machines);
        f.usize(cluster.gpus_per_machine);
        f.f64(cluster.nic_bw);
        f.f64(cluster.overhead_ms);
        f.f64(cluster.noise_sigma);
        let d = &device.spec;
        f.str(&d.name);
        f.f64(d.peak_flops);
        f.f64(d.mem_bw);
        f.f64(d.launch_overhead_ms);
        f.f64(d.onchip_bytes);
        f.f64(d.noise_sigma);
        f.str(&estimator.name);
        // Folded only when nonzero: content-free estimators hash exactly
        // as the name-only format did, so analytical/oracle plan keys
        // stay warm across the content-hash upgrade.
        if estimator.content != 0 {
            f.byte(1);
            f.u64(estimator.content);
        }
        f.f64(cfg.alpha);
        f.usize(cfg.beta);
        f.usize(cfg.unchanged_limit);
        f.usize(cfg.max_queue);
        f.f64(cfg.max_seconds);
        f.u64(cfg.seed);
        f.byte(cfg.methods.nondup_fusion as u8);
        f.byte(cfg.methods.dup_fusion as u8);
        f.byte(cfg.methods.ar_fusion as u8);
        // Folded only when enabled: a chunking-off config hashes exactly
        // as it did before the chunking vocabulary existed, so every
        // pre-chunk plan record keeps its key (v1 cache stays warm).
        if cfg.methods.chunking {
            f.byte(1);
            f.usize(cfg.max_chunks as usize);
        }
        // Same stay-warm rule for the sharding extension; the tag byte is
        // distinct from chunking's so the two opt-ins can never alias.
        if cfg.methods.sharding {
            f.byte(2);
        }
        f.byte(cfg.incremental_candidates as u8);
        f.f64(cfg.sim.straggler_ms);
        f.byte(cfg.sim.ignore_comm as u8);
        f.finish()
    };
    Fingerprint { hi: lane(0xE4B0_0001), lo: lane(0xE4B0_0002) }
}

/// The plan store's record key: graph identity ⊕ environment identity.
pub fn plan_key(graph_fp: Fingerprint, env_fp: Fingerprint) -> Fingerprint {
    Fingerprint::combine(graph_fp, env_fp)
}

/// Coarse similarity summary of a graph, for nearest-plan warm-starting.
/// Cheap to compute, cheap to store, and deterministic.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphSketch {
    /// Live-node counts per op kind, indexed by
    /// [`OpKind::feature_index`]; the final slot aggregates kinds outside
    /// the feature vocabulary (`Fused`, control flow).
    pub kind_counts: Vec<u32>,
    pub live: u32,
    pub allreduces: u32,
    pub num_workers: u32,
    pub total_flops: f64,
    pub grad_bytes: f64,
}

impl GraphSketch {
    pub fn of(g: &TrainingGraph) -> GraphSketch {
        let mut kind_counts = vec![0u32; OpKind::ALL.len() + 1];
        for n in g.live() {
            kind_counts[n.kind.feature_index()] += 1;
        }
        GraphSketch {
            kind_counts,
            live: g.live_count() as u32,
            allreduces: g.allreduces().len() as u32,
            num_workers: g.num_workers as u32,
            total_flops: g.total_flops(),
            grad_bytes: g.total_gradient_bytes(),
        }
    }

    /// Symmetric distance: 0 for identical sketches, growing with
    /// histogram, scale and topology-class differences. Log-ratio terms
    /// keep FLOPs/bytes comparable across magnitudes.
    ///
    /// Sketches persisted before an op-kind vocabulary growth carry
    /// shorter `kind_counts`; missing slots count as zero. (The old
    /// `zip`-based histogram silently truncated to the shorter vector and
    /// then charged a flat length-difference penalty — dropping every
    /// count the longer sketch held in its tail slots.)
    pub fn distance(&self, other: &GraphSketch) -> f64 {
        let len = self.kind_counts.len().max(other.kind_counts.len());
        let hist: f64 = (0..len)
            .map(|i| {
                let a = *self.kind_counts.get(i).unwrap_or(&0) as f64;
                let b = *other.kind_counts.get(i).unwrap_or(&0) as f64;
                (a - b).abs()
            })
            .sum();
        let log_ratio = |a: f64, b: f64| (a.max(1.0) / b.max(1.0)).log2().abs();
        hist + 8.0 * log_ratio(self.total_flops, other.total_flops)
            + 2.0 * log_ratio(self.grad_bytes, other.grad_bytes)
            + 4.0 * (self.allreduces as f64 - other.allreduces as f64).abs()
            + 16.0 * f64::from(self.num_workers != other.num_workers)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kinds", Json::Arr(self.kind_counts.iter().map(|&c| Json::Num(c as f64)).collect())),
            ("live", Json::Num(self.live as f64)),
            ("ars", Json::Num(self.allreduces as f64)),
            ("workers", Json::Num(self.num_workers as f64)),
            ("flops", Json::Num(self.total_flops)),
            ("grad_bytes", Json::Num(self.grad_bytes)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<GraphSketch> {
        let mut kind_counts = j
            .get("kinds")
            .as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as u32))
            .collect::<Option<Vec<u32>>>()?;
        // Sketches recorded under an older, smaller op-kind vocabulary:
        // pad with zeros so every in-memory sketch has today's width and
        // indexing by `OpKind::feature_index` stays in bounds.
        if kind_counts.len() < OpKind::ALL.len() + 1 {
            kind_counts.resize(OpKind::ALL.len() + 1, 0);
        }
        Some(GraphSketch {
            kind_counts,
            live: j.get("live").as_usize()? as u32,
            allreduces: j.get("ars").as_usize()? as u32,
            num_workers: j.get("workers").as_usize()? as u32,
            total_flops: j.get("flops").as_f64()?,
            grad_bytes: j.get("grad_bytes").as_f64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::Role;

    fn tiny() -> TrainingGraph {
        let mut b = GraphBuilder::new("fp-tiny", 4);
        let p = b.param("w", &[64, 64]);
        let m = b.compute(OpKind::MatMul, "mm", &[p, p], &[64, 64], Role::Forward);
        let r = b.compute(OpKind::Relu, "relu", &[m], &[64, 64], Role::Forward);
        let gr = b.compute(OpKind::MatMul, "grad", &[r], &[64, 64], Role::Backward);
        let ar = b.allreduce("ar", gr, &[64, 64]);
        b.optimizer_update("apply", &[ar, p]);
        b.finish()
    }

    #[test]
    fn hex_roundtrip() {
        let fp = Fingerprint { hi: 0xDEAD_BEEF_0123_4567, lo: 0x89AB_CDEF_0000_0001 };
        assert_eq!(Fingerprint::parse(&fp.hex()), Some(fp));
        assert_eq!(Fingerprint::parse("xyz"), None);
        assert_eq!(Fingerprint::parse(&"0".repeat(31)), None);
    }

    #[test]
    fn fingerprint_deterministic_and_name_blind() {
        let a = tiny();
        let mut b = tiny();
        b.name = "renamed".into();
        for n in b.nodes.iter_mut() {
            n.name = format!("n{}", n.id);
        }
        b.invalidate_adjacency();
        assert_eq!(graph_fingerprint(&a).unwrap(), graph_fingerprint(&b).unwrap());
    }

    #[test]
    fn fingerprint_sensitive_to_shape_kind_flops_and_workers() {
        let base = graph_fingerprint(&tiny()).unwrap();
        let mut s = tiny();
        s.nodes[2].shape.dims[0] = 32;
        assert_ne!(graph_fingerprint(&s).unwrap(), base);
        let mut k = tiny();
        k.nodes[2].kind = OpKind::Gelu;
        assert_ne!(graph_fingerprint(&k).unwrap(), base);
        let mut f = tiny();
        f.nodes[1].flops *= 2.0;
        assert_ne!(graph_fingerprint(&f).unwrap(), base);
        let mut w = tiny();
        w.num_workers = 8;
        assert_ne!(graph_fingerprint(&w).unwrap(), base);
    }

    #[test]
    fn fingerprint_sensitive_to_duplicate_operand_edges() {
        // mul(x, x) vs mul(x, y) with y structurally identical to x: the
        // duplicate edge itself must be visible.
        let mut b1 = GraphBuilder::new("dup", 2);
        let x = b1.constant("x", &[16]);
        b1.compute(OpKind::Mul, "m", &[x, x], &[16], Role::Forward);
        let g1 = b1.finish();
        let mut b2 = GraphBuilder::new("dup", 2);
        let x = b2.constant("x", &[16]);
        let y = b2.constant("y", &[16]);
        b2.compute(OpKind::Mul, "m", &[x, y], &[16], Role::Forward);
        let g2 = b2.finish();
        assert_ne!(
            graph_fingerprint(&g1).unwrap(),
            graph_fingerprint(&g2).unwrap()
        );
    }

    #[test]
    fn env_fingerprint_sensitive_to_cluster_and_params() {
        let cfg = SearchConfig::default();
        let d = DeviceModel::gtx1080ti();
        let analytical = EstimatorFp::named("analytical");
        let a = env_fingerprint(&Cluster::cluster_a(), &d, &analytical, &cfg);
        let b = env_fingerprint(&Cluster::cluster_b(), &d, &analytical, &cfg);
        assert_ne!(a, b);
        let oracle = env_fingerprint(&Cluster::cluster_a(), &d, &EstimatorFp::named("oracle"), &cfg);
        assert_ne!(a, oracle);
        let seeded = env_fingerprint(
            &Cluster::cluster_a(),
            &d,
            &analytical,
            &SearchConfig { seed: 1, ..SearchConfig::default() },
        );
        assert_ne!(a, seeded);
        // Engine toggles that never change results do not change the key.
        let toggled = env_fingerprint(
            &Cluster::cluster_a(),
            &d,
            &analytical,
            &SearchConfig { eval_threads: 1, delta_sim: false, ..SearchConfig::default() },
        );
        assert_eq!(a, toggled);
    }

    #[test]
    fn estimator_content_flips_env_fingerprint() {
        let cfg = SearchConfig::default();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let named = env_fingerprint(&c, &d, &EstimatorFp::named("gnn"), &cfg);
        let trained_a =
            env_fingerprint(&c, &d, &EstimatorFp::with_params("gnn", &[1, 2, 3]), &cfg);
        let trained_a2 =
            env_fingerprint(&c, &d, &EstimatorFp::with_params("gnn", &[1, 2, 3]), &cfg);
        let trained_b =
            env_fingerprint(&c, &d, &EstimatorFp::with_params("gnn", &[1, 2, 4]), &cfg);
        // Retraining (different parameter bytes) invalidates; a
        // byte-identical reload of the same artifact does not.
        assert_ne!(named, trained_a, "parameter content must enter the key");
        assert_eq!(trained_a, trained_a2, "same-content reload must keep the key");
        assert_ne!(trained_a, trained_b, "retraining must flip the key");
    }

    #[test]
    fn estimator_resolve_tracks_artifact_state() {
        let dir = std::env::temp_dir().join(format!("disco-estfp-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let artifact = dir.join("gnn_trained.f32");
        let _ = std::fs::remove_file(&artifact);
        // Absent artifact → content-free (keys stay warm across upgrade);
        // non-gnn estimators never read the artifact at all.
        assert_eq!(EstimatorFp::resolve("gnn", "oracle", &dir), EstimatorFp::named("oracle"));
        assert_eq!(
            EstimatorFp::resolve("analytical", "analytical", &dir),
            EstimatorFp::named("analytical")
        );
        std::fs::write(&artifact, [0u8, 1, 2, 3]).unwrap();
        let first = EstimatorFp::resolve("gnn", "oracle", &dir);
        assert_ne!(first.content, 0);
        // Same-name, same-bytes reload: key unchanged.
        std::fs::write(&artifact, [0u8, 1, 2, 3]).unwrap();
        assert_eq!(EstimatorFp::resolve("gnn", "oracle", &dir), first);
        // Retrain: key flips.
        std::fs::write(&artifact, [9u8, 9, 9, 9]).unwrap();
        assert_ne!(EstimatorFp::resolve("gnn", "oracle", &dir), first);
        let _ = std::fs::remove_file(&artifact);
    }

    #[test]
    fn sketch_distance_zero_iff_same_shape_of_workload() {
        let a = GraphSketch::of(&tiny());
        let b = GraphSketch::of(&tiny());
        assert_eq!(a.distance(&b), 0.0);
        let mut g = tiny();
        g.nodes[2].deleted = true;
        g.invalidate_adjacency();
        let c = GraphSketch::of(&g);
        assert!(a.distance(&c) > 0.0);
        assert_eq!(a.distance(&c), c.distance(&a));
    }

    #[test]
    fn sketch_json_roundtrip() {
        let s = GraphSketch::of(&tiny());
        let j = s.to_json().to_string();
        let s2 = GraphSketch::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(s, s2);
    }

    #[test]
    fn sketch_distance_counts_tail_slots_across_vocabulary_growth() {
        // A sketch persisted under an older, shorter op-kind vocabulary
        // must compare against a modern one slot-by-slot with missing
        // slots zero — not be zip-truncated.
        let modern = GraphSketch::of(&tiny());
        let mut old = modern.clone();
        old.kind_counts.truncate(1);
        // Everything the modern sketch holds past slot 0 must be charged.
        // tiny() has six live nodes over five distinct op kinds, so at
        // most one kind's count can sit in slot 0 — the tail is nonempty
        // regardless of the feature-index assignment.
        let tail: f64 =
            modern.kind_counts[1..].iter().map(|&c| c as f64).sum();
        assert!(tail > 0.0, "test graph has no counts past slot 0");
        assert_eq!(modern.distance(&old), tail);
        assert_eq!(old.distance(&modern), tail, "distance must stay symmetric");
        // Zero-padded tails are genuinely identical sketches.
        let mut padded = old.clone();
        padded.kind_counts.resize(modern.kind_counts.len(), 0);
        assert_eq!(old.distance(&padded), 0.0);
    }

    #[test]
    fn sketch_from_json_pads_short_vocabulary() {
        let s = GraphSketch::of(&tiny());
        let mut j = s.to_json().to_string();
        // Simulate an old record: keep only the first three histogram
        // slots.
        let kinds: Vec<String> =
            s.kind_counts[..3].iter().map(|c| c.to_string()).collect();
        let old_kinds = format!("[{}]", kinds.join(","));
        let start = j.find("[").unwrap();
        let end = j.find("]").unwrap();
        j.replace_range(start..=end, &old_kinds);
        let parsed = GraphSketch::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(parsed.kind_counts.len(), OpKind::ALL.len() + 1);
        assert_eq!(&parsed.kind_counts[..3], &s.kind_counts[..3]);
        assert!(parsed.kind_counts[3..].iter().all(|&c| c == 0));
    }

    #[test]
    fn sharded_collective_flips_graph_and_arena_fingerprints() {
        use crate::graph::{CollectiveKind, ShardSpec};
        let base = tiny();
        let base_fp = graph_fingerprint(&base).unwrap();
        let base_arena = arena_fingerprint(&base);
        let ar = base.allreduces()[0];
        // The canonical AllReduce-kind spec is identical to no spec.
        let mut canon = tiny();
        canon.nodes[ar].shard = Some(ShardSpec::new(CollectiveKind::AllReduce));
        assert_eq!(graph_fingerprint(&canon).unwrap(), base_fp);
        assert_eq!(arena_fingerprint(&canon), base_arena);
        // An active reduce-scatter spec changes both identities.
        let mut sharded = tiny();
        sharded.nodes[ar].shard =
            Some(ShardSpec::new(CollectiveKind::ReduceScatterAllGather));
        assert_ne!(graph_fingerprint(&sharded).unwrap(), base_fp);
        assert_ne!(arena_fingerprint(&sharded), base_arena);
    }

    #[test]
    fn env_fingerprint_sharding_knob_folds_only_when_enabled() {
        use crate::search::MethodSet;
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let analytical = EstimatorFp::named("analytical");
        let off = env_fingerprint(&c, &d, &analytical, &SearchConfig::default());
        let on = env_fingerprint(
            &c,
            &d,
            &analytical,
            &SearchConfig { methods: MethodSet::all_with_sharding(), ..SearchConfig::default() },
        );
        assert_ne!(off, on, "enabling sharding must flip the env key");
        // Sharding-on and chunking-on configs must never alias.
        let chunked = env_fingerprint(
            &c,
            &d,
            &analytical,
            &SearchConfig { methods: MethodSet::all_with_chunking(), ..SearchConfig::default() },
        );
        assert_ne!(on, chunked);
    }
}
