//! In-tree HLO interpreter — the default offline [`super::Runtime`]
//! backend (DESIGN.md §9).
//!
//! Executes the ENTRY computation of the HLO *text* modules parsed by
//! [`crate::graph::hlo_import`]: F32/I32 literals, the elementwise op
//! families, `broadcast`/`reshape`/`transpose`/`slice`/`concatenate`,
//! general `dot` (batch + multiple contracting dimensions), `reduce` with
//! its nested to_apply computation (fast paths for add/max/min/mul
//! bodies, a generic recursive path otherwise), `iota`, `compare`,
//! `select`, `convert`, `parameter`/`constant`/`tuple`.
//!
//! This is an *executor*, not a compiler: values are dense host vectors,
//! every instruction materializes its result, and there is no layout or
//! fusion cleverness. That is exactly enough to run the AOT artifacts the
//! GNN estimator and the distributed-training example need — DistIR
//! (arXiv 2111.05426) makes the same trade to ground a strategy search in
//! real executions. Precision: f32 storage with f64 accumulation in `dot`
//! and `reduce`.

use crate::graph::hlo_import::{parse_module, HloComputation, HloInstr, HloModule};
use crate::graph::DType;
use crate::xla_stub::{Elements, Literal};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

/// A runtime value: a dense host tensor or a tuple.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
    Tuple(Vec<Value>),
}

impl Value {
    pub fn scalar_f32(v: f32) -> Value {
        Value::F32 { dims: vec![], data: vec![v] }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Value::F32 { dims, .. } | Value::I32 { dims, .. } => dims,
            Value::Tuple(_) => &[],
        }
    }

    pub fn elems(&self) -> usize {
        self.dims().iter().product()
    }

    fn f32s(&self) -> Result<(&[usize], &[f32])> {
        match self {
            Value::F32 { dims, data } => Ok((dims, data)),
            _ => bail!("expected f32 tensor, got {self:?}"),
        }
    }

    fn i32s(&self) -> Result<(&[usize], &[i32])> {
        match self {
            Value::I32 { dims, data } => Ok((dims, data)),
            _ => bail!("expected i32 tensor, got {self:?}"),
        }
    }

    /// Convert from the runtime's host literal type.
    pub fn from_literal(lit: &Literal) -> Value {
        let dims: Vec<usize> = lit.dims.iter().map(|&d| d as usize).collect();
        match &lit.elements {
            Elements::F32(v) => Value::F32 { dims, data: v.clone() },
            Elements::I32(v) => Value::I32 { dims, data: v.clone() },
        }
    }

    /// Convert back to the runtime's host literal type (arrays only —
    /// tuples are flattened by the caller).
    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.dims().iter().map(|&d| d as i64).collect();
        match self {
            Value::F32 { data, .. } => {
                Ok(Literal { elements: Elements::F32(data.clone()), dims })
            }
            Value::I32 { data, .. } => {
                Ok(Literal { elements: Elements::I32(data.clone()), dims })
            }
            Value::Tuple(_) => bail!("cannot convert tuple to a single literal"),
        }
    }
}

/// Row-major strides for a dim list.
fn strides(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

/// Decompose `lin` into a multi-index over `dims` (row-major).
fn unravel(mut lin: usize, dims: &[usize], out: &mut Vec<usize>) {
    out.clear();
    out.resize(dims.len(), 0);
    for i in (0..dims.len()).rev() {
        let d = dims[i].max(1);
        out[i] = lin % d;
        lin /= d;
    }
}

/// A loaded, executable HLO module.
pub struct Interp {
    module: HloModule,
}

impl Interp {
    /// Parse an HLO text module into an executable form.
    pub fn from_text(text: &str) -> Result<Interp> {
        let module = parse_module(text)?;
        module.entry()?; // validate early: an ENTRY must exist
        Ok(Interp { module })
    }

    pub fn module_name(&self) -> &str {
        &self.module.name
    }

    /// Number of parameters the ENTRY computation takes.
    pub fn num_params(&self) -> usize {
        self.module
            .entry()
            .map(|e| e.instrs.iter().filter(|i| i.opcode == "parameter").count())
            .unwrap_or(0)
    }

    /// Execute the ENTRY computation. Returns the root value with tuples
    /// flattened one level — matching PJRT's tupled-output convention.
    pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let args: Vec<Value> = inputs.iter().map(Value::from_literal).collect();
        let root = self.eval_computation(self.module.entry()?, &args)?;
        match root {
            Value::Tuple(vs) => vs.iter().map(Value::to_literal).collect(),
            v => Ok(vec![v.to_literal()?]),
        }
    }

    /// Evaluate one computation with the given arguments.
    fn eval_computation(&self, comp: &HloComputation, args: &[Value]) -> Result<Value> {
        let mut env: HashMap<&str, Value> = HashMap::with_capacity(comp.instrs.len());
        let mut root_name: Option<&str> = None;
        for instr in &comp.instrs {
            let v = self
                .eval_instr(instr, args, &env)
                .with_context(|| format!("evaluating {} = {}(..)", instr.name, instr.opcode))?;
            if instr.is_root {
                root_name = Some(&instr.name);
            }
            env.insert(&instr.name, v);
        }
        let root = root_name
            .or_else(|| comp.instrs.last().map(|i| i.name.as_str()))
            .ok_or_else(|| anyhow!("computation {} is empty", comp.name))?;
        env.remove(root).ok_or_else(|| anyhow!("root {root} not evaluated"))
    }

    fn operand<'e>(
        &self,
        instr: &HloInstr,
        idx: usize,
        env: &'e HashMap<&str, Value>,
    ) -> Result<&'e Value> {
        let name = instr
            .operands
            .get(idx)
            .ok_or_else(|| anyhow!("{} missing operand {idx}", instr.name))?;
        env.get(name.as_str())
            .ok_or_else(|| anyhow!("{}: operand '{name}' not defined", instr.name))
    }

    fn eval_instr(
        &self,
        instr: &HloInstr,
        args: &[Value],
        env: &HashMap<&str, Value>,
    ) -> Result<Value> {
        let (out_dtype, out_dims) = match instr.shape.first_array() {
            Some((dt, s)) => (dt, s.dims),
            None => (DType::F32, vec![]),
        };
        match instr.opcode.as_str() {
            "parameter" => {
                let idx: usize = instr
                    .payload
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("bad parameter index '{}'", instr.payload))?;
                args.get(idx)
                    .cloned()
                    .ok_or_else(|| anyhow!("parameter({idx}) but only {} inputs", args.len()))
            }
            "constant" => constant(&instr.payload, out_dtype, &out_dims),
            "iota" => {
                let d: usize = instr
                    .attr("iota_dimension")
                    .unwrap_or("0")
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("bad iota_dimension"))?;
                iota(out_dtype, &out_dims, d)
            }
            "broadcast" => broadcast(self.operand(instr, 0, env)?, &out_dims, &instr.dims_attr("dimensions")),
            "reshape" | "bitcast" | "copy" => {
                reshaped(self.operand(instr, 0, env)?, &out_dims)
            }
            "convert" | "bitcast-convert" => convert(self.operand(instr, 0, env)?, out_dtype),
            "transpose" => transpose(self.operand(instr, 0, env)?, &instr.dims_attr("dimensions")),
            "slice" => slice(
                self.operand(instr, 0, env)?,
                instr.attr("slice").unwrap_or(""),
                &out_dims,
            ),
            "concatenate" => {
                let parts: Result<Vec<&Value>> =
                    (0..instr.operands.len()).map(|i| self.operand(instr, i, env)).collect();
                concatenate(&parts?, *instr.dims_attr("dimensions").first().unwrap_or(&0), &out_dims)
            }
            "dot" => dot(
                self.operand(instr, 0, env)?,
                self.operand(instr, 1, env)?,
                &instr.dims_attr("lhs_batch_dims"),
                &instr.dims_attr("lhs_contracting_dims"),
                &instr.dims_attr("rhs_batch_dims"),
                &instr.dims_attr("rhs_contracting_dims"),
            ),
            "reduce" => {
                let body_name = instr
                    .attr("to_apply")
                    .ok_or_else(|| anyhow!("reduce without to_apply"))?;
                let body = self
                    .module
                    .computation(body_name)
                    .ok_or_else(|| anyhow!("unknown computation '{body_name}'"))?;
                self.reduce(
                    self.operand(instr, 0, env)?,
                    self.operand(instr, 1, env)?,
                    &instr.dims_attr("dimensions"),
                    body,
                )
            }
            "compare" => compare(
                self.operand(instr, 0, env)?,
                self.operand(instr, 1, env)?,
                instr.attr("direction").unwrap_or("EQ"),
            ),
            "select" => select(
                self.operand(instr, 0, env)?,
                self.operand(instr, 1, env)?,
                self.operand(instr, 2, env)?,
            ),
            "tuple" => {
                let parts: Result<Vec<Value>> = (0..instr.operands.len())
                    .map(|i| self.operand(instr, i, env).cloned())
                    .collect();
                Ok(Value::Tuple(parts?))
            }
            "get-tuple-element" => {
                let idx: usize = instr
                    .attr("index")
                    .unwrap_or("0")
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("bad tuple index"))?;
                match self.operand(instr, 0, env)? {
                    Value::Tuple(vs) => vs
                        .get(idx)
                        .cloned()
                        .ok_or_else(|| anyhow!("tuple index {idx} out of range")),
                    _ => bail!("get-tuple-element of non-tuple"),
                }
            }
            // Binary elementwise.
            "add" | "subtract" | "multiply" | "divide" | "maximum" | "minimum" | "power"
            | "remainder" | "and" | "or" | "xor" => binary(
                &instr.opcode,
                self.operand(instr, 0, env)?,
                self.operand(instr, 1, env)?,
            ),
            // Unary elementwise.
            "negate" | "exponential" | "exponential-minus-one" | "log" | "log-plus-one"
            | "sqrt" | "rsqrt" | "tanh" | "logistic" | "abs" | "sign" | "floor" | "ceil"
            | "cosine" | "sine" | "not" => unary(&instr.opcode, self.operand(instr, 0, env)?),
            other => bail!("unsupported HLO opcode '{other}' (in-tree interpreter, DESIGN.md §9)"),
        }
    }

    /// `reduce` with fast paths for the common scalar bodies and a generic
    /// recursive path for anything else.
    fn reduce(
        &self,
        data: &Value,
        init: &Value,
        dims: &[usize],
        body: &HloComputation,
    ) -> Result<Value> {
        let in_dims = data.dims().to_vec();
        for &d in dims {
            if d >= in_dims.len() {
                bail!("reduce dimension {d} out of range for rank {}", in_dims.len());
            }
        }
        let keep: Vec<usize> =
            (0..in_dims.len()).filter(|d| !dims.contains(d)).collect();
        let out_dims: Vec<usize> = keep.iter().map(|&d| in_dims[d]).collect();
        let out_strides = strides(&out_dims);

        // Recognize `(a, b) -> op(a, b)` bodies for the fold fast path:
        // exactly two parameters AND the root consuming both of them raw
        // (a body like `add(a, multiply(b, b))` must take the generic
        // path, not be misfolded into a plain sum).
        let fast = body.root().and_then(|r| {
            let params: Vec<&str> = body
                .instrs
                .iter()
                .filter(|i| i.opcode == "parameter")
                .map(|i| i.name.as_str())
                .collect();
            let root_takes_params = r.operands.len() == 2
                && params.len() == 2
                && r.operands.iter().all(|o| params.contains(&o.as_str()));
            match (root_takes_params, r.opcode.as_str()) {
                (true, "add") | (true, "maximum") | (true, "minimum") | (true, "multiply") => {
                    Some(r.opcode.clone())
                }
                _ => None,
            }
        });

        let mut idx = Vec::new();
        match data {
            Value::F32 { data: xs, .. } => {
                let (_, init_v) = init.f32s()?;
                let init_v = *init_v.first().ok_or_else(|| anyhow!("empty reduce init"))?;
                // f64 accumulators for the additive fast path.
                let mut acc = vec![init_v as f64; out_dims.iter().product::<usize>().max(1)];
                for (lin, &x) in xs.iter().enumerate() {
                    unravel(lin, &in_dims, &mut idx);
                    let o: usize =
                        keep.iter().enumerate().map(|(i, &d)| idx[d] * out_strides[i]).sum();
                    match fast.as_deref() {
                        Some("add") => acc[o] += x as f64,
                        Some("maximum") => acc[o] = acc[o].max(x as f64),
                        Some("minimum") => acc[o] = acc[o].min(x as f64),
                        Some("multiply") => acc[o] *= x as f64,
                        _ => {
                            let r = self.eval_computation(
                                body,
                                &[Value::scalar_f32(acc[o] as f32), Value::scalar_f32(x)],
                            )?;
                            let (_, rv) = r.f32s()?;
                            acc[o] = rv[0] as f64;
                        }
                    }
                }
                Ok(Value::F32 {
                    dims: out_dims,
                    data: acc.into_iter().map(|v| v as f32).collect(),
                })
            }
            Value::I32 { data: xs, .. } => {
                let (_, init_v) = init.i32s()?;
                let init_v = *init_v.first().ok_or_else(|| anyhow!("empty reduce init"))?;
                let mut acc = vec![init_v; out_dims.iter().product::<usize>().max(1)];
                for (lin, &x) in xs.iter().enumerate() {
                    unravel(lin, &in_dims, &mut idx);
                    let o: usize =
                        keep.iter().enumerate().map(|(i, &d)| idx[d] * out_strides[i]).sum();
                    match fast.as_deref() {
                        Some("add") => acc[o] = acc[o].wrapping_add(x),
                        Some("maximum") => acc[o] = acc[o].max(x),
                        Some("minimum") => acc[o] = acc[o].min(x),
                        Some("multiply") => acc[o] = acc[o].wrapping_mul(x),
                        _ => bail!("generic reduce bodies support f32 only"),
                    }
                }
                Ok(Value::I32 { dims: out_dims, data: acc })
            }
            Value::Tuple(_) => bail!("reduce over tuple"),
        }
    }
}

// ---------------------------------------------------------------------------
// Op implementations (free functions; no interpreter state needed).
// ---------------------------------------------------------------------------

fn constant(payload: &str, dtype: DType, dims: &[usize]) -> Result<Value> {
    let elems: usize = dims.iter().product();
    let toks: Vec<&str> = payload
        .split(|c: char| c == ',' || c == '{' || c == '}' || c.is_whitespace())
        .filter(|t| !t.is_empty())
        .collect();
    match dtype {
        DType::I32 => {
            let mut vals = Vec::with_capacity(toks.len());
            for t in &toks {
                vals.push(match *t {
                    "true" => 1,
                    "false" => 0,
                    _ => t
                        .parse::<i64>()
                        .map_err(|_| anyhow!("bad i32 literal '{t}'"))? as i32,
                });
            }
            let data = splat_or_exact(vals, elems)?;
            Ok(Value::I32 { dims: dims.to_vec(), data })
        }
        _ => {
            let mut vals = Vec::with_capacity(toks.len());
            for t in &toks {
                vals.push(match *t {
                    "inf" => f32::INFINITY,
                    "-inf" => f32::NEG_INFINITY,
                    "nan" => f32::NAN,
                    _ => t.parse::<f32>().map_err(|_| anyhow!("bad f32 literal '{t}'"))?,
                });
            }
            let data = splat_or_exact(vals, elems)?;
            Ok(Value::F32 { dims: dims.to_vec(), data })
        }
    }
}

/// Exactly `elems` values, or a single value splatted to `elems`.
fn splat_or_exact<T: Copy>(vals: Vec<T>, elems: usize) -> Result<Vec<T>> {
    if vals.len() == elems {
        Ok(vals)
    } else if vals.len() == 1 {
        Ok(vec![vals[0]; elems])
    } else {
        bail!("literal has {} values for {} elements", vals.len(), elems)
    }
}

fn iota(dtype: DType, dims: &[usize], d: usize) -> Result<Value> {
    if d >= dims.len() {
        bail!("iota_dimension {d} out of range for rank {}", dims.len());
    }
    let elems: usize = dims.iter().product();
    let st = strides(dims);
    let extent = dims[d];
    let vals = (0..elems).map(|lin| (lin / st[d]) % extent);
    match dtype {
        DType::I32 => Ok(Value::I32 { dims: dims.to_vec(), data: vals.map(|v| v as i32).collect() }),
        _ => Ok(Value::F32 { dims: dims.to_vec(), data: vals.map(|v| v as f32).collect() }),
    }
}

fn reshaped(v: &Value, out_dims: &[usize]) -> Result<Value> {
    let n: usize = out_dims.iter().product();
    if n != v.elems() {
        bail!("reshape: {} elems into {:?}", v.elems(), out_dims);
    }
    Ok(match v {
        Value::F32 { data, .. } => Value::F32 { dims: out_dims.to_vec(), data: data.clone() },
        Value::I32 { data, .. } => Value::I32 { dims: out_dims.to_vec(), data: data.clone() },
        Value::Tuple(_) => bail!("reshape of tuple"),
    })
}

fn convert(v: &Value, target: DType) -> Result<Value> {
    Ok(match (v, target) {
        (Value::F32 { dims, data }, DType::I32) => Value::I32 {
            dims: dims.clone(),
            // XLA converts float→int by truncation toward zero.
            data: data.iter().map(|&x| x as i32).collect(),
        },
        (Value::I32 { dims, data }, DType::I32) => {
            Value::I32 { dims: dims.clone(), data: data.clone() }
        }
        (Value::I32 { dims, data }, _) => Value::F32 {
            dims: dims.clone(),
            data: data.iter().map(|&x| x as f32).collect(),
        },
        (Value::F32 { dims, data }, _) => {
            Value::F32 { dims: dims.clone(), data: data.clone() }
        }
        (Value::Tuple(_), _) => bail!("convert of tuple"),
    })
}

fn broadcast(v: &Value, out_dims: &[usize], mapping: &[usize]) -> Result<Value> {
    let in_dims = v.dims().to_vec();
    if mapping.len() != in_dims.len() {
        bail!(
            "broadcast dimensions {:?} don't match operand rank {}",
            mapping,
            in_dims.len()
        );
    }
    for (k, &m) in mapping.iter().enumerate() {
        if m >= out_dims.len() || out_dims[m] != in_dims[k] {
            bail!("broadcast dim {k}→{m} mismatch: {:?} into {:?}", in_dims, out_dims);
        }
    }
    let out_elems: usize = out_dims.iter().product();
    let in_strides = strides(&in_dims);
    let mut idx = Vec::new();
    let gather = |lin: usize, idx: &mut Vec<usize>| -> usize {
        unravel(lin, out_dims, idx);
        mapping.iter().enumerate().map(|(k, &m)| idx[m] * in_strides[k]).sum()
    };
    Ok(match v {
        Value::F32 { data, .. } => Value::F32 {
            dims: out_dims.to_vec(),
            data: (0..out_elems).map(|l| data[gather(l, &mut idx)]).collect(),
        },
        Value::I32 { data, .. } => Value::I32 {
            dims: out_dims.to_vec(),
            data: (0..out_elems).map(|l| data[gather(l, &mut idx)]).collect(),
        },
        Value::Tuple(_) => bail!("broadcast of tuple"),
    })
}

fn transpose(v: &Value, perm: &[usize]) -> Result<Value> {
    let in_dims = v.dims().to_vec();
    if perm.len() != in_dims.len() {
        bail!("transpose permutation {:?} vs rank {}", perm, in_dims.len());
    }
    let out_dims: Vec<usize> = perm.iter().map(|&p| in_dims[p]).collect();
    let out_elems: usize = out_dims.iter().product();
    let in_strides = strides(&in_dims);
    let mut idx = Vec::new();
    let gather = |lin: usize, idx: &mut Vec<usize>| -> usize {
        unravel(lin, &out_dims, idx);
        perm.iter().enumerate().map(|(i, &p)| idx[i] * in_strides[p]).sum()
    };
    Ok(match v {
        Value::F32 { data, .. } => Value::F32 {
            dims: out_dims.clone(),
            data: (0..out_elems).map(|l| data[gather(l, &mut idx)]).collect(),
        },
        Value::I32 { data, .. } => Value::I32 {
            dims: out_dims.clone(),
            data: (0..out_elems).map(|l| data[gather(l, &mut idx)]).collect(),
        },
        Value::Tuple(_) => bail!("transpose of tuple"),
    })
}

/// Parse `{[0:5], [2:4:1]}` into per-dimension (start, stride).
fn parse_slice_attr(attr: &str, rank: usize) -> Result<Vec<(usize, usize)>> {
    let inner = attr.trim().trim_start_matches('{').trim_end_matches('}');
    let mut out = Vec::new();
    for part in inner.split(',') {
        let part = part.trim().trim_start_matches('[').trim_end_matches(']');
        if part.is_empty() {
            continue;
        }
        let fields: Vec<&str> = part.split(':').collect();
        let start: usize =
            fields.first().unwrap_or(&"0").trim().parse().unwrap_or(0);
        let stride: usize = fields.get(2).map(|s| s.trim().parse().unwrap_or(1)).unwrap_or(1);
        out.push((start, stride.max(1)));
    }
    if out.len() != rank {
        bail!("slice attr '{attr}' has {} dims, operand rank {rank}", out.len());
    }
    Ok(out)
}

fn slice(v: &Value, attr: &str, out_dims: &[usize]) -> Result<Value> {
    let in_dims = v.dims().to_vec();
    let spec = parse_slice_attr(attr, in_dims.len())?;
    let out_elems: usize = out_dims.iter().product();
    let in_strides = strides(&in_dims);
    let mut idx = Vec::new();
    let gather = |lin: usize, idx: &mut Vec<usize>| -> Result<usize> {
        unravel(lin, out_dims, idx);
        let mut o = 0usize;
        for (d, &(start, stride)) in spec.iter().enumerate() {
            let i = start + idx[d] * stride;
            if i >= in_dims[d] {
                bail!("slice index {i} out of bounds for dim {d} (extent {})", in_dims[d]);
            }
            o += i * in_strides[d];
        }
        Ok(o)
    };
    match v {
        Value::F32 { data, .. } => {
            let mut out = Vec::with_capacity(out_elems);
            for l in 0..out_elems {
                out.push(data[gather(l, &mut idx)?]);
            }
            Ok(Value::F32 { dims: out_dims.to_vec(), data: out })
        }
        Value::I32 { data, .. } => {
            let mut out = Vec::with_capacity(out_elems);
            for l in 0..out_elems {
                out.push(data[gather(l, &mut idx)?]);
            }
            Ok(Value::I32 { dims: out_dims.to_vec(), data: out })
        }
        Value::Tuple(_) => bail!("slice of tuple"),
    }
}

fn concatenate(parts: &[&Value], dim: usize, out_dims: &[usize]) -> Result<Value> {
    if parts.is_empty() {
        bail!("concatenate with no operands");
    }
    if dim >= out_dims.len() {
        bail!("concatenate dim {dim} out of range for rank {}", out_dims.len());
    }
    // Validate operand shapes against the declared result before writing:
    // every non-concat extent must match, and the concat extents must sum
    // to the declared one (a mismatch would otherwise index out of bounds
    // or leave silent zeros).
    let mut total = 0usize;
    for part in parts {
        let pd = part.dims();
        if pd.len() != out_dims.len() {
            bail!("concatenate rank mismatch: {:?} vs {:?}", pd, out_dims);
        }
        for (d, (&pe, &oe)) in pd.iter().zip(out_dims).enumerate() {
            if d != dim && pe != oe {
                bail!("concatenate extent mismatch at dim {d}: {:?} vs {:?}", pd, out_dims);
            }
        }
        total += pd[dim];
    }
    if total != out_dims[dim] {
        bail!(
            "concatenate extents sum to {total} but result declares {} along dim {dim}",
            out_dims[dim]
        );
    }
    let out_elems: usize = out_dims.iter().product();
    let out_strides = strides(out_dims);
    let is_f32 = matches!(parts[0], Value::F32 { .. });
    let mut out_f = vec![0.0f32; if is_f32 { out_elems } else { 0 }];
    let mut out_i = vec![0i32; if is_f32 { 0 } else { out_elems }];
    let mut offset = 0usize;
    let mut idx = Vec::new();
    for part in parts {
        if matches!(part, Value::F32 { .. }) != is_f32 {
            bail!("concatenate: mixed element types");
        }
        let in_dims = part.dims().to_vec();
        if dim >= in_dims.len() {
            bail!("concatenate dim {dim} out of range");
        }
        let n = part.elems();
        for lin in 0..n {
            unravel(lin, &in_dims, &mut idx);
            idx[dim] += offset;
            let o: usize = idx.iter().zip(&out_strides).map(|(&i, &s)| i * s).sum();
            match part {
                Value::F32 { data, .. } => out_f[o] = data[lin],
                Value::I32 { data, .. } => out_i[o] = data[lin],
                Value::Tuple(_) => bail!("concatenate of tuple"),
            }
        }
        offset += in_dims[dim];
    }
    Ok(if is_f32 {
        Value::F32 { dims: out_dims.to_vec(), data: out_f }
    } else {
        Value::I32 { dims: out_dims.to_vec(), data: out_i }
    })
}

/// General dot: batch dims + any number of contracting dims per side.
/// Output dims are `[batch (lhs order), lhs free, rhs free]` — XLA's
/// DotGeneral convention. f32 with f64 accumulation.
fn dot(
    lhs: &Value,
    rhs: &Value,
    lb: &[usize],
    lc: &[usize],
    rb: &[usize],
    rc: &[usize],
) -> Result<Value> {
    let (ldims, ldata) = lhs.f32s()?;
    let (rdims, rdata) = rhs.f32s()?;
    if lb.len() != rb.len() || lc.len() != rc.len() {
        bail!("dot: batch/contracting dim count mismatch");
    }
    for (&a, &b) in lb.iter().zip(rb) {
        if ldims[a] != rdims[b] {
            bail!("dot: batch extent mismatch {} vs {}", ldims[a], rdims[b]);
        }
    }
    for (&a, &b) in lc.iter().zip(rc) {
        if ldims[a] != rdims[b] {
            bail!("dot: contraction extent mismatch {} vs {}", ldims[a], rdims[b]);
        }
    }
    let lfree: Vec<usize> =
        (0..ldims.len()).filter(|d| !lb.contains(d) && !lc.contains(d)).collect();
    let rfree: Vec<usize> =
        (0..rdims.len()).filter(|d| !rb.contains(d) && !rc.contains(d)).collect();
    let mut out_dims: Vec<usize> = lb.iter().map(|&d| ldims[d]).collect();
    out_dims.extend(lfree.iter().map(|&d| ldims[d]));
    out_dims.extend(rfree.iter().map(|&d| rdims[d]));
    let out_elems: usize = out_dims.iter().product::<usize>().max(1);

    let lstr = strides(ldims);
    let rstr = strides(rdims);
    // Precompute (lhs offset, rhs offset) for every contraction index.
    let csizes: Vec<usize> = lc.iter().map(|&d| ldims[d]).collect();
    let celems: usize = csizes.iter().product::<usize>().max(1);
    let mut coffs = Vec::with_capacity(celems);
    let mut cidx = Vec::new();
    for clin in 0..celems {
        unravel(clin, &csizes, &mut cidx);
        let lo: usize = cidx.iter().zip(lc).map(|(&i, &d)| i * lstr[d]).sum();
        let ro: usize = cidx.iter().zip(rc).map(|(&i, &d)| i * rstr[d]).sum();
        coffs.push((lo, ro));
    }

    let mut out = Vec::with_capacity(out_elems);
    let mut oidx = Vec::new();
    for olin in 0..out_elems {
        unravel(olin, &out_dims, &mut oidx);
        let nb = lb.len();
        let nlf = lfree.len();
        let mut lbase = 0usize;
        let mut rbase = 0usize;
        for (i, &d) in lb.iter().enumerate() {
            lbase += oidx[i] * lstr[d];
        }
        for (i, &d) in rb.iter().enumerate() {
            rbase += oidx[i] * rstr[d];
        }
        for (i, &d) in lfree.iter().enumerate() {
            lbase += oidx[nb + i] * lstr[d];
        }
        for (i, &d) in rfree.iter().enumerate() {
            rbase += oidx[nb + nlf + i] * rstr[d];
        }
        let mut acc = 0.0f64;
        for &(lo, ro) in &coffs {
            acc += ldata[lbase + lo] as f64 * rdata[rbase + ro] as f64;
        }
        out.push(acc as f32);
    }
    Ok(Value::F32 { dims: out_dims, data: out })
}

fn binary(op: &str, a: &Value, b: &Value) -> Result<Value> {
    if a.dims() != b.dims() {
        bail!("{op}: shape mismatch {:?} vs {:?}", a.dims(), b.dims());
    }
    match (a, b) {
        (Value::F32 { dims, data: xa }, Value::F32 { data: xb, .. }) => {
            let f: fn(f32, f32) -> f32 = match op {
                "add" => |x, y| x + y,
                "subtract" => |x, y| x - y,
                "multiply" => |x, y| x * y,
                "divide" => |x, y| x / y,
                "maximum" => f32::max,
                "minimum" => f32::min,
                "power" => f32::powf,
                "remainder" => |x, y| x % y,
                _ => bail!("{op} unsupported on f32"),
            };
            Ok(Value::F32 {
                dims: dims.clone(),
                data: xa.iter().zip(xb).map(|(&x, &y)| f(x, y)).collect(),
            })
        }
        (Value::I32 { dims, data: xa }, Value::I32 { data: xb, .. }) => {
            let f: fn(i32, i32) -> i32 = match op {
                "add" => i32::wrapping_add,
                "subtract" => i32::wrapping_sub,
                "multiply" => i32::wrapping_mul,
                "divide" => |x, y| if y == 0 { 0 } else { x.wrapping_div(y) },
                "maximum" => i32::max,
                "minimum" => i32::min,
                "remainder" => |x, y| if y == 0 { 0 } else { x.wrapping_rem(y) },
                "and" => |x, y| x & y,
                "or" => |x, y| x | y,
                "xor" => |x, y| x ^ y,
                _ => bail!("{op} unsupported on i32"),
            };
            Ok(Value::I32 {
                dims: dims.clone(),
                data: xa.iter().zip(xb).map(|(&x, &y)| f(x, y)).collect(),
            })
        }
        _ => bail!("{op}: mixed or tuple operand types"),
    }
}

fn unary(op: &str, a: &Value) -> Result<Value> {
    match a {
        Value::F32 { dims, data } => {
            let f: fn(f32) -> f32 = match op {
                "negate" => |x| -x,
                "exponential" => f32::exp,
                "exponential-minus-one" => f32::exp_m1,
                "log" => f32::ln,
                "log-plus-one" => f32::ln_1p,
                "sqrt" => f32::sqrt,
                "rsqrt" => |x| 1.0 / x.sqrt(),
                "tanh" => f32::tanh,
                "logistic" => |x| 1.0 / (1.0 + (-x).exp()),
                "abs" => f32::abs,
                "sign" => f32::signum,
                "floor" => f32::floor,
                "ceil" => f32::ceil,
                "cosine" => f32::cos,
                "sine" => f32::sin,
                _ => bail!("{op} unsupported on f32"),
            };
            Ok(Value::F32 { dims: dims.clone(), data: data.iter().map(|&x| f(x)).collect() })
        }
        Value::I32 { dims, data } => {
            let f: fn(i32) -> i32 = match op {
                "negate" => |x| x.wrapping_neg(),
                "abs" => i32::wrapping_abs,
                "sign" => i32::signum,
                "not" => |x| if x == 0 { 1 } else { 0 }, // pred semantics
                _ => bail!("{op} unsupported on i32"),
            };
            Ok(Value::I32 { dims: dims.clone(), data: data.iter().map(|&x| f(x)).collect() })
        }
        Value::Tuple(_) => bail!("{op} of tuple"),
    }
}

fn compare(a: &Value, b: &Value, direction: &str) -> Result<Value> {
    if a.dims() != b.dims() {
        bail!("compare: shape mismatch {:?} vs {:?}", a.dims(), b.dims());
    }
    let cmp = |ord: std::cmp::Ordering| -> bool {
        match direction {
            "EQ" => ord.is_eq(),
            "NE" => ord.is_ne(),
            "LT" => ord.is_lt(),
            "LE" => ord.is_le(),
            "GT" => ord.is_gt(),
            "GE" => ord.is_ge(),
            _ => false,
        }
    };
    let data: Vec<i32> = match (a, b) {
        (Value::F32 { data: xa, .. }, Value::F32 { data: xb, .. }) => xa
            .iter()
            .zip(xb)
            // XLA totalorder-free comparison semantics: any comparison
            // involving NaN is false, except NE which is true.
            .map(|(&x, &y)| match x.partial_cmp(&y) {
                Some(ord) => cmp(ord) as i32,
                None => (direction == "NE") as i32,
            })
            .collect(),
        (Value::I32 { data: xa, .. }, Value::I32 { data: xb, .. }) => {
            xa.iter().zip(xb).map(|(&x, &y)| cmp(x.cmp(&y)) as i32).collect()
        }
        _ => bail!("compare: mixed operand types"),
    };
    Ok(Value::I32 { dims: a.dims().to_vec(), data })
}

fn select(pred: &Value, on_true: &Value, on_false: &Value) -> Result<Value> {
    let (_, p) = pred.i32s()?;
    if pred.dims() != on_true.dims() || on_true.dims() != on_false.dims() {
        bail!("select: shape mismatch");
    }
    Ok(match (on_true, on_false) {
        (Value::F32 { dims, data: xt }, Value::F32 { data: xf, .. }) => Value::F32 {
            dims: dims.clone(),
            data: p
                .iter()
                .zip(xt.iter().zip(xf))
                .map(|(&c, (&t, &f))| if c != 0 { t } else { f })
                .collect(),
        },
        (Value::I32 { dims, data: xt }, Value::I32 { data: xf, .. }) => Value::I32 {
            dims: dims.clone(),
            data: p
                .iter()
                .zip(xt.iter().zip(xf))
                .map(|(&c, (&t, &f))| if c != 0 { t } else { f })
                .collect(),
        },
        _ => bail!("select: mixed or tuple operand types"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run1(text: &str, inputs: &[Literal]) -> Vec<Literal> {
        Interp::from_text(text).unwrap().run(inputs).unwrap()
    }

    fn f32lit(data: &[f32], dims: &[i64]) -> Literal {
        Literal::vec1(data).reshape(dims).unwrap()
    }

    #[test]
    fn parameter_roundtrip_through_tuple() {
        let text = "HloModule t\nENTRY main {\n  p = f32[2,2]{1,0} parameter(0)\n  ROOT r = (f32[2,2]) tuple(p)\n}\n";
        let out = run1(text, &[f32lit(&[1.0, 2.0, 3.0, 4.0], &[2, 2])]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(out[0].dims, vec![2, 2]);
    }

    #[test]
    fn dot_matches_hand_computed_matmul() {
        // [2,3] x [3,2]: classic matmul.
        let text = "HloModule t\nENTRY main {\n  a = f32[2,3]{1,0} parameter(0)\n  b = f32[3,2]{1,0} parameter(1)\n  ROOT d = f32[2,2]{1,0} dot(a, b), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n}\n";
        let a = f32lit(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let b = f32lit(&[7.0, 8.0, 9.0, 10.0, 11.0, 12.0], &[3, 2]);
        let out = run1(text, &[a, b]);
        // Row 0: [1,2,3]·[7,9,11]=58, [1,2,3]·[8,10,12]=64
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn batched_dot_with_batch_dims() {
        // [2,2,2] x [2,2,2] batch over dim 0.
        let text = "HloModule t\nENTRY main {\n  a = f32[2,2,2]{2,1,0} parameter(0)\n  b = f32[2,2,2]{2,1,0} parameter(1)\n  ROOT d = f32[2,2,2]{2,1,0} dot(a, b), lhs_batch_dims={0}, lhs_contracting_dims={2}, rhs_batch_dims={0}, rhs_contracting_dims={1}\n}\n";
        let a = f32lit(&[1.0, 0.0, 0.0, 1.0, 2.0, 0.0, 0.0, 2.0], &[2, 2, 2]); // [I, 2I]
        let b = f32lit(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0], &[2, 2, 2]);
        let out = run1(text, &[a, b]);
        assert_eq!(
            out[0].to_vec::<f32>().unwrap(),
            vec![1.0, 2.0, 3.0, 4.0, 10.0, 12.0, 14.0, 16.0]
        );
    }

    #[test]
    fn reduce_sum_and_max_with_nested_bodies() {
        let text = "HloModule t\nadd_body {\n  x = f32[] parameter(0)\n  y = f32[] parameter(1)\n  ROOT s = f32[] add(x, y)\n}\nmax_body {\n  x = f32[] parameter(0)\n  y = f32[] parameter(1)\n  ROOT m = f32[] maximum(x, y)\n}\nENTRY main {\n  p = f32[2,3]{1,0} parameter(0)\n  zero = f32[] constant(0)\n  ninf = f32[] constant(-inf)\n  s = f32[2]{0} reduce(p, zero), dimensions={1}, to_apply=add_body\n  m = f32[3]{0} reduce(p, ninf), dimensions={0}, to_apply=max_body\n  ROOT r = (f32[2], f32[3]) tuple(s, m)\n}\n";
        let p = f32lit(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let out = run1(text, &[p]);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![6.0, 15.0]);
        assert_eq!(out[1].to_vec::<f32>().unwrap(), vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn broadcast_transpose_slice_concat() {
        let text = "HloModule t\nENTRY main {\n  v = f32[2]{0} parameter(0)\n  b = f32[2,3]{1,0} broadcast(v), dimensions={0}\n  t = f32[3,2]{1,0} transpose(b), dimensions={1,0}\n  s = f32[2,2]{1,0} slice(t), slice={[1:3], [0:2]}\n  ROOT c = f32[4,2]{1,0} concatenate(s, s), dimensions={0}\n}\n";
        let out = run1(text, &[f32lit(&[5.0, 9.0], &[2])]);
        // b rows: [5,5,5],[9,9,9]; t: [[5,9],[5,9],[5,9]]; s: rows 1..3 → [[5,9],[5,9]]
        assert_eq!(out[0].dims, vec![4, 2]);
        assert_eq!(
            out[0].to_vec::<f32>().unwrap(),
            vec![5.0, 9.0, 5.0, 9.0, 5.0, 9.0, 5.0, 9.0]
        );
    }

    #[test]
    fn iota_compare_convert_one_hot() {
        // One-hot encode i32 indices into f32 rows — the LM embedding trick.
        let text = "HloModule t\nENTRY main {\n  ix = s32[2]{0} parameter(0)\n  io = s32[2,4]{1,0} iota(), iota_dimension=1\n  bx = s32[2,4]{1,0} broadcast(ix), dimensions={0}\n  eq = pred[2,4]{1,0} compare(io, bx), direction=EQ\n  ROOT oh = f32[2,4]{1,0} convert(eq)\n}\n";
        let ix = Literal::vec1(&[2i32, 0]).reshape(&[2]).unwrap();
        let out = run1(text, &[ix]);
        assert_eq!(
            out[0].to_vec::<f32>().unwrap(),
            vec![0.0, 0.0, 1.0, 0.0, 1.0, 0.0, 0.0, 0.0]
        );
    }

    #[test]
    fn elementwise_and_scalar_constants() {
        let text = "HloModule t\nENTRY main {\n  p = f32[3]{0} parameter(0)\n  c = f32[] constant(2)\n  cb = f32[3]{0} broadcast(c), dimensions={}\n  m = f32[3]{0} multiply(p, cb)\n  e = f32[3]{0} exponential(m)\n  ROOT l = f32[3]{0} log(e)\n}\n";
        let out = run1(text, &[f32lit(&[0.5, 1.0, -1.0], &[3])]);
        let got = out[0].to_vec::<f32>().unwrap();
        for (g, want) in got.iter().zip([1.0f32, 2.0, -2.0]) {
            assert!((g - want).abs() < 1e-5, "{got:?}");
        }
    }

    #[test]
    fn unsupported_opcode_errors_cleanly() {
        let text = "HloModule t\nENTRY main {\n  p = f32[2]{0} parameter(0)\n  ROOT s = f32[2]{0} sort(p)\n}\n";
        let interp = Interp::from_text(text).unwrap();
        let err = interp.run(&[f32lit(&[2.0, 1.0], &[2])]).unwrap_err();
        assert!(format!("{err:#}").contains("unsupported HLO opcode"));
    }
}
