//! In-tree HLO interpreter — the default offline [`super::Runtime`]
//! backend (DESIGN.md §9).
//!
//! Executes the ENTRY computation of the HLO *text* modules parsed by
//! [`crate::graph::hlo_import`]. Coverage (the op table lives in
//! DESIGN.md §9): the elementwise families, `broadcast`/`reshape`/
//! `transpose`/`slice`/`concatenate`/`reverse`/`pad`/`clamp`, general
//! `dot` (batch + multiple contracting dimensions), `reduce` with its
//! nested to_apply computation, `gather`/`scatter` in the general
//! dimension-numbers form, `dynamic-slice`/`dynamic-update-slice`,
//! control flow (`while`, `conditional` in both predicated and indexed
//! forms, `call`) executing their nested computation bodies through a
//! real call frame, `iota`/`compare`/`select`/`convert`, tuples, and an
//! f16/bf16/s32/pred storage layer ([`super::value`]).
//!
//! This is an *executor*, not a compiler: values are dense host vectors,
//! every instruction materializes its result, and there is no layout or
//! fusion cleverness. That is exactly enough to run JAX-lowered training
//! artifacts in-tree — DistIR (arXiv 2111.05426) makes the same trade to
//! ground a strategy search in real executions. Precision contract:
//! ops compute in f32 and round once into the declared storage type;
//! `dot` and `reduce` accumulate in f64 regardless of storage type.
//! Semantics are pinned by the golden conformance corpus in
//! `rust/tests/hlo_corpus/` (authoring workflow: `disco run-hlo`).

use crate::graph::hlo_import::{parse_module, HloComputation, HloInstr, HloModule, Prim};
use crate::runtime::value::VType;
pub use crate::runtime::value::Value;
use crate::xla_stub::Literal;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

/// Hard cap on `while` trip counts — loops in real artifacts run for
/// thousands of iterations, not millions; past this the condition is
/// almost certainly never turning false.
const WHILE_ITER_CAP: usize = 1_000_000;

/// Row-major strides for a dim list.
fn strides(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

/// Decompose `lin` into a multi-index over `dims` (row-major).
fn unravel(mut lin: usize, dims: &[usize], out: &mut Vec<usize>) {
    out.clear();
    out.resize(dims.len(), 0);
    for i in (0..dims.len()).rev() {
        let d = dims[i].max(1);
        out[i] = lin % d;
        lin /= d;
    }
}

/// A loaded, executable HLO module.
pub struct Interp {
    module: HloModule,
}

impl Interp {
    /// Parse an HLO text module into an executable form.
    pub fn from_text(text: &str) -> Result<Interp> {
        let module = parse_module(text)?;
        module.entry()?; // validate early: an ENTRY must exist
        Ok(Interp { module })
    }

    pub fn module_name(&self) -> &str {
        &self.module.name
    }

    /// Number of parameters the ENTRY computation takes.
    pub fn num_params(&self) -> usize {
        self.module
            .entry()
            .map(|e| e.instrs.iter().filter(|i| i.opcode == "parameter").count())
            .unwrap_or(0)
    }

    /// Declared (prim, dims) of each ENTRY parameter, in parameter order.
    pub fn param_shapes(&self) -> Vec<(Prim, Vec<usize>)> {
        let Ok(entry) = self.module.entry() else { return Vec::new() };
        let mut out: Vec<(usize, (Prim, Vec<usize>))> = entry
            .instrs
            .iter()
            .filter(|i| i.opcode == "parameter")
            .filter_map(|i| {
                let idx: usize = i.payload.trim().parse().ok()?;
                let (p, s) = i.shape.first_prim()?;
                Some((idx, (p, s.dims)))
            })
            .collect();
        out.sort_by_key(|(idx, _)| *idx);
        out.into_iter().map(|(_, ps)| ps).collect()
    }

    /// Declared (prim, dims) of each ENTRY output, with the root tuple
    /// flattened one level (mirroring [`Interp::run`]).
    pub fn output_shapes(&self) -> Vec<(Prim, Vec<usize>)> {
        use crate::graph::hlo_import::HloShape;
        let Ok(entry) = self.module.entry() else { return Vec::new() };
        let Some(root) = entry.root() else { return Vec::new() };
        match &root.shape {
            HloShape::Tuple(elems) => elems
                .iter()
                .filter_map(|e| e.first_prim())
                .map(|(p, s)| (p, s.dims))
                .collect(),
            arr => arr.first_prim().map(|(p, s)| vec![(p, s.dims)]).unwrap_or_default(),
        }
    }

    /// Execute the ENTRY computation. Returns the root value with tuples
    /// flattened one level — matching PJRT's tupled-output convention.
    pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let args: Vec<Value> = inputs.iter().map(Value::from_literal).collect();
        let root = self.run_values(&args)?;
        match root {
            Value::Tuple(vs) => vs.iter().map(Value::to_literal).collect(),
            v => Ok(vec![v.to_literal()?]),
        }
    }

    /// Execute the ENTRY computation on already-typed values, returning
    /// the raw root value (tuples not flattened) — the corpus runner's
    /// entry point.
    pub fn run_values(&self, args: &[Value]) -> Result<Value> {
        self.eval_computation(self.module.entry()?, args)
    }

    /// Evaluate one computation with the given arguments — one call
    /// frame. Nested bodies (reduce/scatter combiners, while condition
    /// and body, conditional branches, call targets) recurse through
    /// here with their own environments.
    fn eval_computation(&self, comp: &HloComputation, args: &[Value]) -> Result<Value> {
        let mut env: HashMap<&str, Value> = HashMap::with_capacity(comp.instrs.len());
        let mut root_name: Option<&str> = None;
        for instr in &comp.instrs {
            let v = self
                .eval_instr(instr, args, &env)
                .with_context(|| format!("evaluating {} = {}(..)", instr.name, instr.opcode))?;
            if instr.is_root {
                root_name = Some(&instr.name);
            }
            env.insert(&instr.name, v);
        }
        let root = root_name
            .or_else(|| comp.instrs.last().map(|i| i.name.as_str()))
            .ok_or_else(|| anyhow!("computation {} is empty", comp.name))?;
        env.remove(root).ok_or_else(|| anyhow!("root {root} not evaluated"))
    }

    fn operand<'e>(
        &self,
        instr: &HloInstr,
        idx: usize,
        env: &'e HashMap<&str, Value>,
    ) -> Result<&'e Value> {
        let name = instr
            .operands
            .get(idx)
            .ok_or_else(|| anyhow!("{} missing operand {idx}", instr.name))?;
        env.get(name.as_str())
            .ok_or_else(|| anyhow!("{}: operand '{name}' not defined", instr.name))
    }

    /// Nested computation cited by an attribute (`to_apply=`,
    /// `condition=`, `body=`, …).
    fn body(&self, instr: &HloInstr, key: &str) -> Result<&HloComputation> {
        let name = instr
            .attr(key)
            .ok_or_else(|| anyhow!("{} without {key}= attribute", instr.opcode))?;
        self.module
            .computation(name)
            .ok_or_else(|| anyhow!("unknown computation '{name}'"))
    }

    fn eval_instr(
        &self,
        instr: &HloInstr,
        args: &[Value],
        env: &HashMap<&str, Value>,
    ) -> Result<Value> {
        let (out_vt, out_dims) = match instr.shape.first_prim() {
            Some((p, s)) => (VType::of(p), s.dims),
            None => (VType::F32, vec![]),
        };
        match instr.opcode.as_str() {
            "parameter" => {
                let idx: usize = instr
                    .payload
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("bad parameter index '{}'", instr.payload))?;
                let v = args
                    .get(idx)
                    .ok_or_else(|| anyhow!("parameter({idx}) but only {} inputs", args.len()))?;
                // Array parameters adopt their declared storage type —
                // f32 interchange literals narrow into f16/bf16 here.
                // Tuple-typed parameters (while/conditional frames) pass
                // through untouched.
                match (v, v.vtype()) {
                    (Value::Tuple(_), _) => Ok(v.clone()),
                    (_, Some(vt)) if vt == out_vt => Ok(v.clone()),
                    _ => v.cast(out_vt),
                }
            }
            "constant" => constant(&instr.payload, out_vt, &out_dims),
            "iota" => {
                let d: usize = instr
                    .attr("iota_dimension")
                    .unwrap_or("0")
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("bad iota_dimension"))?;
                iota(out_vt, &out_dims, d)
            }
            "broadcast" => broadcast(self.operand(instr, 0, env)?, &out_dims, &instr.dims_attr("dimensions")),
            "reshape" | "bitcast" | "copy" => {
                reshaped(self.operand(instr, 0, env)?, &out_dims)
            }
            "convert" | "bitcast-convert" => self.operand(instr, 0, env)?.cast(out_vt),
            "transpose" => transpose(self.operand(instr, 0, env)?, &instr.dims_attr("dimensions")),
            "slice" => slice(
                self.operand(instr, 0, env)?,
                instr.attr("slice").unwrap_or(""),
                &out_dims,
            ),
            "reverse" => reverse(self.operand(instr, 0, env)?, &instr.dims_attr("dimensions")),
            "pad" => pad(
                self.operand(instr, 0, env)?,
                self.operand(instr, 1, env)?,
                instr.attr("padding").unwrap_or(""),
                &out_dims,
            ),
            "concatenate" => {
                let parts: Result<Vec<&Value>> =
                    (0..instr.operands.len()).map(|i| self.operand(instr, i, env)).collect();
                concatenate(&parts?, *instr.dims_attr("dimensions").first().unwrap_or(&0), &out_dims)
            }
            "dynamic-slice" => self.dynamic_slice(instr, env, &out_dims),
            "dynamic-update-slice" => self.dynamic_update_slice(instr, env, out_vt),
            "gather" => self.gather(instr, env, &out_dims),
            "scatter" => self.scatter(instr, env, out_vt),
            "dot" => dot(
                self.operand(instr, 0, env)?,
                self.operand(instr, 1, env)?,
                &instr.dims_attr("lhs_batch_dims"),
                &instr.dims_attr("lhs_contracting_dims"),
                &instr.dims_attr("rhs_batch_dims"),
                &instr.dims_attr("rhs_contracting_dims"),
                out_vt,
            ),
            "reduce" => self.reduce(
                self.operand(instr, 0, env)?,
                self.operand(instr, 1, env)?,
                &instr.dims_attr("dimensions"),
                self.body(instr, "to_apply")?,
                out_vt,
            ),
            "compare" => compare(
                self.operand(instr, 0, env)?,
                self.operand(instr, 1, env)?,
                instr.attr("direction").unwrap_or("EQ"),
            ),
            "select" => select(
                self.operand(instr, 0, env)?,
                self.operand(instr, 1, env)?,
                self.operand(instr, 2, env)?,
                out_vt,
            ),
            "clamp" => clamp(
                self.operand(instr, 0, env)?,
                self.operand(instr, 1, env)?,
                self.operand(instr, 2, env)?,
                out_vt,
            ),
            "tuple" => {
                let parts: Result<Vec<Value>> = (0..instr.operands.len())
                    .map(|i| self.operand(instr, i, env).cloned())
                    .collect();
                Ok(Value::Tuple(parts?))
            }
            "get-tuple-element" => {
                let idx: usize = instr
                    .attr("index")
                    .unwrap_or("0")
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("bad tuple index"))?;
                match self.operand(instr, 0, env)? {
                    Value::Tuple(vs) => vs
                        .get(idx)
                        .cloned()
                        .ok_or_else(|| anyhow!("tuple index {idx} out of range")),
                    _ => bail!("get-tuple-element of non-tuple"),
                }
            }
            "while" => {
                let cond = self.body(instr, "condition")?;
                let body = self.body(instr, "body")?;
                let mut carried = self.operand(instr, 0, env)?.clone();
                for it in 0usize.. {
                    if it > WHILE_ITER_CAP {
                        bail!("while exceeded {WHILE_ITER_CAP} iterations (runaway condition?)");
                    }
                    let c = self
                        .eval_computation(cond, std::slice::from_ref(&carried))
                        .context("while condition")?;
                    if c.scalar()? == 0.0 {
                        break;
                    }
                    carried = self
                        .eval_computation(body, std::slice::from_ref(&carried))
                        .context("while body")?;
                }
                Ok(carried)
            }
            "conditional" => self.conditional(instr, env),
            // NOTE: `map` is deliberately NOT routed here — it applies
            // its body per element, not once, and mis-executing it as a
            // call would be silently wrong. It stays unsupported.
            "call" if instr.attr("to_apply").is_some() => {
                let comp = self.body(instr, "to_apply")?;
                let call_args: Result<Vec<Value>> = (0..instr.operands.len())
                    .map(|i| self.operand(instr, i, env).cloned())
                    .collect();
                self.eval_computation(comp, &call_args?)
            }
            // Binary elementwise.
            "add" | "subtract" | "multiply" | "divide" | "maximum" | "minimum" | "power"
            | "remainder" | "and" | "or" | "xor" | "atan2" => binary(
                &instr.opcode,
                self.operand(instr, 0, env)?,
                self.operand(instr, 1, env)?,
                out_vt,
            ),
            // Unary elementwise.
            "negate" | "exponential" | "exponential-minus-one" | "log" | "log-plus-one"
            | "sqrt" | "rsqrt" | "cbrt" | "tanh" | "logistic" | "abs" | "sign" | "floor"
            | "ceil" | "round-nearest-afz" | "round-nearest-even" | "cosine" | "sine"
            | "not" | "is-finite" => {
                unary(&instr.opcode, self.operand(instr, 0, env)?, out_vt)
            }
            other => bail!("unsupported HLO opcode '{other}' (in-tree interpreter, DESIGN.md §9)"),
        }
    }

    // -- control flow -------------------------------------------------------

    /// `conditional` in both HLO forms: predicated
    /// (`true_computation=`/`false_computation=`) and N-way indexed
    /// (`branch_computations={%b0, %b1, …}`, out-of-range selectors
    /// clamp to the last branch, per the XLA spec).
    fn conditional(&self, instr: &HloInstr, env: &HashMap<&str, Value>) -> Result<Value> {
        let sel = self.operand(instr, 0, env)?;
        if let Some(list) = instr.attr("branch_computations") {
            let names: Vec<&str> = list
                .trim()
                .trim_start_matches('{')
                .trim_end_matches('}')
                .split(',')
                .map(|s| s.trim().trim_start_matches('%'))
                .filter(|s| !s.is_empty())
                .collect();
            if names.is_empty() {
                bail!("conditional with empty branch_computations");
            }
            let raw = sel.scalar()? as i64;
            let idx = if raw < 0 || raw as usize >= names.len() {
                names.len() - 1
            } else {
                raw as usize
            };
            let comp = self
                .module
                .computation(names[idx])
                .ok_or_else(|| anyhow!("unknown computation '{}'", names[idx]))?;
            let arg = self.operand(instr, idx + 1, env)?.clone();
            self.eval_computation(comp, &[arg])
        } else {
            let taken = sel.scalar()? != 0.0;
            let comp = self.body(
                instr,
                if taken { "true_computation" } else { "false_computation" },
            )?;
            let arg = self.operand(instr, if taken { 1 } else { 2 }, env)?.clone();
            self.eval_computation(comp, &[arg])
        }
    }

    // -- dynamic slicing ----------------------------------------------------

    /// Start indices for dynamic-slice/dynamic-update-slice: one scalar
    /// operand per dimension starting at `first`, or (legacy form) a
    /// single rank-1 vector operand.
    fn dynamic_starts(
        &self,
        instr: &HloInstr,
        env: &HashMap<&str, Value>,
        first: usize,
        rank: usize,
    ) -> Result<Vec<i64>> {
        if instr.operands.len() < first {
            bail!("{}: missing start-index operands", instr.name);
        }
        let given = instr.operands.len() - first;
        if given == 1 && rank != 1 {
            let (dims, xs) = self.operand(instr, first, env)?.ints()?;
            if dims.len() == 1 && xs.len() == rank {
                return Ok(xs.iter().map(|&x| x as i64).collect());
            }
        }
        if given != rank {
            bail!("{}: {} start indices for rank {rank}", instr.name, given);
        }
        (0..rank)
            .map(|d| Ok(self.operand(instr, first + d, env)?.scalar()? as i64))
            .collect()
    }

    fn dynamic_slice(
        &self,
        instr: &HloInstr,
        env: &HashMap<&str, Value>,
        out_dims: &[usize],
    ) -> Result<Value> {
        let v = self.operand(instr, 0, env)?;
        let in_dims = v.dims().to_vec();
        let sizes = instr.dims_attr("dynamic_slice_sizes");
        let sizes = if sizes.len() == in_dims.len() { sizes } else { out_dims.to_vec() };
        if sizes.len() != in_dims.len() {
            bail!("dynamic-slice sizes {:?} vs rank {}", sizes, in_dims.len());
        }
        for (d, (&sz, &n)) in sizes.iter().zip(&in_dims).enumerate() {
            if sz > n {
                bail!("dynamic-slice size {sz} exceeds operand extent {n} in dim {d}");
            }
        }
        let starts = self.dynamic_starts(instr, env, 1, in_dims.len())?;
        // XLA clamps each start into [0, dim - size].
        let starts: Vec<usize> = starts
            .iter()
            .zip(&in_dims)
            .zip(&sizes)
            .map(|((&s, &d), &sz)| s.clamp(0, d.saturating_sub(sz) as i64) as usize)
            .collect();
        let in_strides = strides(&in_dims);
        let sz = sizes.clone();
        let mut idx = Vec::new();
        v.remap(
            sizes,
            |lin| {
                unravel(lin, &sz, &mut idx);
                Ok(Some(
                    idx.iter()
                        .zip(&starts)
                        .zip(&in_strides)
                        .map(|((&i, &s), &st)| (s + i) * st)
                        .sum(),
                ))
            },
            None,
        )
    }

    fn dynamic_update_slice(
        &self,
        instr: &HloInstr,
        env: &HashMap<&str, Value>,
        out_vt: VType,
    ) -> Result<Value> {
        let v = self.operand(instr, 0, env)?;
        let u = self.operand(instr, 1, env)?;
        let in_dims = v.dims().to_vec();
        let u_dims = u.dims().to_vec();
        if u_dims.len() != in_dims.len() {
            bail!("dynamic-update-slice rank mismatch: {:?} vs {:?}", u_dims, in_dims);
        }
        for (d, (&sz, &n)) in u_dims.iter().zip(&in_dims).enumerate() {
            if sz > n {
                bail!("dynamic-update-slice update extent {sz} exceeds operand extent {n} in dim {d}");
            }
        }
        let starts = self.dynamic_starts(instr, env, 2, in_dims.len())?;
        let starts: Vec<usize> = starts
            .iter()
            .zip(&in_dims)
            .zip(&u_dims)
            .map(|((&s, &d), &sz)| s.clamp(0, d.saturating_sub(sz) as i64) as usize)
            .collect();
        let in_strides = strides(&in_dims);
        let mut idx = Vec::new();
        if v.is_int() {
            let (_, base) = v.ints()?;
            let (_, upd) = u.ints()?;
            let mut out = base.to_vec();
            for (lin, &x) in upd.iter().enumerate() {
                unravel(lin, &u_dims, &mut idx);
                let o: usize = idx
                    .iter()
                    .zip(&starts)
                    .zip(&in_strides)
                    .map(|((&i, &s), &st)| (s + i) * st)
                    .sum();
                out[o] = x;
            }
            Value::from_i32s(out_vt, in_dims, out)
        } else {
            let (_, base) = v.floats()?;
            let (_, upd) = u.floats()?;
            let mut out = base.into_owned();
            for (lin, &x) in upd.iter().enumerate() {
                unravel(lin, &u_dims, &mut idx);
                let o: usize = idx
                    .iter()
                    .zip(&starts)
                    .zip(&in_strides)
                    .map(|((&i, &s), &st)| (s + i) * st)
                    .sum();
                out[o] = x;
            }
            Value::from_f32s(out_vt, in_dims, out)
        }
    }

    // -- gather / scatter ---------------------------------------------------

    /// General-dimension-numbers `gather` (XLA semantics: start indices
    /// clamp into bounds so every output element is defined).
    fn gather(
        &self,
        instr: &HloInstr,
        env: &HashMap<&str, Value>,
        out_dims: &[usize],
    ) -> Result<Value> {
        let operand = self.operand(instr, 0, env)?;
        let (idx_dims, idx_data) = {
            let (d, x) = self.operand(instr, 1, env)?.ints()?;
            (d.to_vec(), x.to_vec())
        };
        let odims = operand.dims().to_vec();
        let offset_dims = instr.dims_attr("offset_dims");
        let collapsed = instr.dims_attr("collapsed_slice_dims");
        let start_map = instr.dims_attr("start_index_map");
        let slice_sizes = instr.dims_attr("slice_sizes");
        let ivd: usize = instr
            .attr("index_vector_dim")
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or(idx_dims.len());
        if slice_sizes.len() != odims.len() {
            bail!("gather slice_sizes {:?} vs operand rank {}", slice_sizes, odims.len());
        }
        for (&s, &d) in slice_sizes.iter().zip(&odims) {
            if s > d {
                bail!("gather slice size {s} exceeds operand extent {d}");
            }
        }
        for &c in &collapsed {
            if slice_sizes.get(c) != Some(&1) {
                bail!("gather collapsed dim {c} must have slice size 1");
            }
        }
        // Range-check the dimension numbers up front so a malformed
        // module reports a named error instead of panicking mid-walk.
        if let Some(&d) = offset_dims.iter().find(|&&d| d >= out_dims.len()) {
            bail!("gather offset dim {d} out of range for output rank {}", out_dims.len());
        }
        if let Some(&d) = start_map.iter().find(|&&d| d >= odims.len()) {
            bail!("gather start_index_map entry {d} out of range for operand rank {}", odims.len());
        }
        if ivd > idx_dims.len() {
            bail!("gather index_vector_dim {ivd} out of range for indices rank {}", idx_dims.len());
        }
        // Output positions not in offset_dims are batch positions; their
        // coordinates walk the index tensor's batch dims in order.
        let batch_pos: Vec<usize> =
            (0..out_dims.len()).filter(|d| !offset_dims.contains(d)).collect();
        let idx_batch: Vec<usize> = (0..idx_dims.len()).filter(|&d| d != ivd).collect();
        if batch_pos.len() != idx_batch.len() {
            bail!(
                "gather: {} output batch dims vs {} index batch dims",
                batch_pos.len(),
                idx_batch.len()
            );
        }
        // offset_dims (in order) map onto the non-collapsed operand dims
        // (in order).
        let offset_operand_dims: Vec<usize> =
            (0..odims.len()).filter(|d| !collapsed.contains(d)).collect();
        if offset_operand_dims.len() != offset_dims.len() {
            bail!(
                "gather: {} offset dims vs {} uncollapsed operand dims",
                offset_dims.len(),
                offset_operand_dims.len()
            );
        }
        let ostrides = strides(&odims);
        let istrides = strides(&idx_dims);
        let out_elems: usize = out_dims.iter().product();
        let mut oidx = Vec::new();
        let fetch_start = |oidx: &[usize], k: usize| -> Result<i64> {
            let mut lin = 0usize;
            let mut b = 0usize;
            for (d, &st) in istrides.iter().enumerate() {
                let coord = if d == ivd {
                    k
                } else {
                    let c = oidx[batch_pos[b]];
                    b += 1;
                    c
                };
                lin += coord * st;
            }
            idx_data
                .get(lin)
                .map(|&v| v as i64)
                .ok_or_else(|| anyhow!("gather index read out of bounds"))
        };
        let mut out_src = Vec::with_capacity(out_elems);
        for lin in 0..out_elems {
            unravel(lin, out_dims, &mut oidx);
            // Clamped start vector in operand space.
            let mut start = vec![0i64; odims.len()];
            for (k, &d) in start_map.iter().enumerate() {
                let raw = fetch_start(&oidx, k)?;
                start[d] = raw.clamp(0, (odims[d] - slice_sizes[d]) as i64);
            }
            let mut src = 0usize;
            for (w, &d) in offset_operand_dims.iter().enumerate() {
                src += (start[d] as usize + oidx[offset_dims[w]]) * ostrides[d];
            }
            for &d in &collapsed {
                src += start[d] as usize * ostrides[d];
            }
            out_src.push(src);
        }
        let mut it = out_src.into_iter();
        operand.remap(out_dims.to_vec(), |_| Ok(Some(it.next().unwrap())), None)
    }

    /// General-dimension-numbers `scatter` (XLA semantics: updates whose
    /// window falls out of bounds are dropped). The combiner is the
    /// `to_apply` computation; add/max/min/multiply bodies and the
    /// overwrite body (`ROOT = parameter(1)`) run as fast paths,
    /// anything else evaluates the body per update element.
    fn scatter(
        &self,
        instr: &HloInstr,
        env: &HashMap<&str, Value>,
        out_vt: VType,
    ) -> Result<Value> {
        let operand = self.operand(instr, 0, env)?;
        let (idx_dims, idx_data) = {
            let (d, x) = self.operand(instr, 1, env)?.ints()?;
            (d.to_vec(), x.to_vec())
        };
        let updates = self.operand(instr, 2, env)?;
        let body = self.body(instr, "to_apply")?;
        let odims = operand.dims().to_vec();
        let udims = updates.dims().to_vec();
        let window_dims = instr.dims_attr("update_window_dims");
        let inserted = instr.dims_attr("inserted_window_dims");
        let scatter_map = instr.dims_attr("scatter_dims_to_operand_dims");
        let ivd: usize = instr
            .attr("index_vector_dim")
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or(idx_dims.len());
        if let Some(&d) = window_dims.iter().find(|&&d| d >= udims.len()) {
            bail!("scatter update_window_dim {d} out of range for updates rank {}", udims.len());
        }
        if let Some(&d) = scatter_map.iter().find(|&&d| d >= odims.len()) {
            bail!(
                "scatter scatter_dims_to_operand_dims entry {d} out of range for operand rank {}",
                odims.len()
            );
        }
        if let Some(&d) = inserted.iter().find(|&&d| d >= odims.len()) {
            bail!("scatter inserted_window_dim {d} out of range for operand rank {}", odims.len());
        }
        if ivd > idx_dims.len() {
            bail!("scatter index_vector_dim {ivd} out of range for indices rank {}", idx_dims.len());
        }
        let batch_pos: Vec<usize> =
            (0..udims.len()).filter(|d| !window_dims.contains(d)).collect();
        let idx_batch: Vec<usize> = (0..idx_dims.len()).filter(|&d| d != ivd).collect();
        if batch_pos.len() != idx_batch.len() {
            bail!(
                "scatter: {} update batch dims vs {} index batch dims",
                batch_pos.len(),
                idx_batch.len()
            );
        }
        let window_operand_dims: Vec<usize> =
            (0..odims.len()).filter(|d| !inserted.contains(d)).collect();
        if window_operand_dims.len() != window_dims.len() {
            bail!(
                "scatter: {} window dims vs {} uninserted operand dims",
                window_dims.len(),
                window_operand_dims.len()
            );
        }
        let ostrides = strides(&odims);
        let istrides = strides(&idx_dims);
        let u_elems: usize = udims.iter().product();
        let mut uidx = Vec::new();
        let fetch_start = |uidx: &[usize], k: usize| -> Result<i64> {
            let mut lin = 0usize;
            let mut b = 0usize;
            for (d, &st) in istrides.iter().enumerate() {
                let coord = if d == ivd {
                    k
                } else {
                    let c = uidx[batch_pos[b]];
                    b += 1;
                    c
                };
                lin += coord * st;
            }
            idx_data
                .get(lin)
                .map(|&v| v as i64)
                .ok_or_else(|| anyhow!("scatter index read out of bounds"))
        };
        // Destination linear index for one update element, or None when
        // out of bounds (dropped).
        let dest = |uidx: &[usize]| -> Result<Option<usize>> {
            let mut start = vec![0i64; odims.len()];
            for (k, &d) in scatter_map.iter().enumerate() {
                start[d] = fetch_start(uidx, k)?;
            }
            let mut lin = 0usize;
            for (w, &d) in window_operand_dims.iter().enumerate() {
                let i = start[d] + uidx[window_dims[w]] as i64;
                if i < 0 || i as usize >= odims[d] {
                    return Ok(None);
                }
                lin += i as usize * ostrides[d];
            }
            for &d in &inserted {
                let i = start[d];
                if i < 0 || i as usize >= odims[d] {
                    return Ok(None);
                }
                lin += i as usize * ostrides[d];
            }
            Ok(Some(lin))
        };
        let combiner = scalar_body_op(body);
        if operand.is_int() {
            let (_, base) = operand.ints()?;
            let (_, upd) = updates.ints()?;
            let mut out = base.to_vec();
            for (lin, &x) in upd.iter().enumerate() {
                unravel(lin, &udims, &mut uidx);
                let Some(o) = dest(&uidx)? else { continue };
                out[o] = match combiner.as_deref() {
                    Some("add") => out[o].wrapping_add(x),
                    Some("maximum") => out[o].max(x),
                    Some("minimum") => out[o].min(x),
                    Some("multiply") => out[o].wrapping_mul(x),
                    Some("overwrite") => x,
                    _ => bail!("generic scatter combiners support float operands only"),
                };
            }
            Value::from_i32s(out_vt, odims, out)
        } else {
            let (_, base) = operand.floats()?;
            let upd = updates.floats()?.1.into_owned();
            let mut out = base.into_owned();
            for (lin, &x) in upd.iter().enumerate() {
                unravel(lin, &udims, &mut uidx);
                let Some(o) = dest(&uidx)? else { continue };
                out[o] = match combiner.as_deref() {
                    Some("add") => out[o] + x,
                    Some("maximum") => out[o].max(x),
                    Some("minimum") => out[o].min(x),
                    Some("multiply") => out[o] * x,
                    Some("overwrite") => x,
                    _ => {
                        let r = self.eval_computation(
                            body,
                            &[Value::scalar_f32(out[o]), Value::scalar_f32(x)],
                        )?;
                        r.scalar()? as f32
                    }
                };
            }
            Value::from_f32s(out_vt, odims, out)
        }
    }

    /// `reduce` with fast paths for the common scalar bodies and a generic
    /// recursive path for anything else. Accumulation is f64 regardless
    /// of storage type; the result rounds once into `out_vt`.
    fn reduce(
        &self,
        data: &Value,
        init: &Value,
        dims: &[usize],
        body: &HloComputation,
        out_vt: VType,
    ) -> Result<Value> {
        let in_dims = data.dims().to_vec();
        for &d in dims {
            if d >= in_dims.len() {
                bail!("reduce dimension {d} out of range for rank {}", in_dims.len());
            }
        }
        let keep: Vec<usize> =
            (0..in_dims.len()).filter(|d| !dims.contains(d)).collect();
        let out_dims: Vec<usize> = keep.iter().map(|&d| in_dims[d]).collect();
        let out_strides = strides(&out_dims);
        let fast = scalar_body_op(body).filter(|op| op.as_str() != "overwrite");

        let mut idx = Vec::new();
        if data.is_int() {
            let (_, xs) = data.ints()?;
            let (_, init_v) = init.ints()?;
            let init_v = *init_v.first().ok_or_else(|| anyhow!("empty reduce init"))?;
            let mut acc = vec![init_v; out_dims.iter().product::<usize>().max(1)];
            for (lin, &x) in xs.iter().enumerate() {
                unravel(lin, &in_dims, &mut idx);
                let o: usize =
                    keep.iter().enumerate().map(|(i, &d)| idx[d] * out_strides[i]).sum();
                match fast.as_deref() {
                    Some("add") => acc[o] = acc[o].wrapping_add(x),
                    Some("maximum") => acc[o] = acc[o].max(x),
                    Some("minimum") => acc[o] = acc[o].min(x),
                    Some("multiply") => acc[o] = acc[o].wrapping_mul(x),
                    Some("and") => acc[o] &= x,
                    Some("or") => acc[o] |= x,
                    _ => bail!("generic reduce bodies support float operands only"),
                }
            }
            Value::from_i32s(out_vt, out_dims, acc)
        } else {
            let (_, xs) = data.floats()?;
            let (_, init_v) = init.floats()?;
            let init_v = *init_v.first().ok_or_else(|| anyhow!("empty reduce init"))?;
            let mut acc = vec![init_v as f64; out_dims.iter().product::<usize>().max(1)];
            for (lin, &x) in xs.iter().enumerate() {
                unravel(lin, &in_dims, &mut idx);
                let o: usize =
                    keep.iter().enumerate().map(|(i, &d)| idx[d] * out_strides[i]).sum();
                match fast.as_deref() {
                    Some("add") => acc[o] += x as f64,
                    Some("maximum") => acc[o] = acc[o].max(x as f64),
                    Some("minimum") => acc[o] = acc[o].min(x as f64),
                    Some("multiply") => acc[o] *= x as f64,
                    _ => {
                        let r = self.eval_computation(
                            body,
                            &[Value::scalar_f32(acc[o] as f32), Value::scalar_f32(x)],
                        )?;
                        acc[o] = r.scalar()?;
                    }
                }
            }
            Value::from_f32s(out_vt, out_dims, acc.into_iter().map(|v| v as f32).collect())
        }
    }
}

/// Recognize a `(a, b) -> op(a, b)` scalar combiner body: exactly two
/// parameters AND the root consuming both of them raw (a body like
/// `add(a, multiply(b, b))` must take the generic path). A body whose
/// root *is* the second parameter is the overwrite combiner.
fn scalar_body_op(body: &HloComputation) -> Option<String> {
    let r = body.root()?;
    let params: Vec<&str> = body
        .instrs
        .iter()
        .filter(|i| i.opcode == "parameter")
        .map(|i| i.name.as_str())
        .collect();
    if r.opcode == "parameter" && r.payload.trim() == "1" {
        return Some("overwrite".to_string());
    }
    let root_takes_params = r.operands.len() == 2
        && params.len() == 2
        && r.operands.iter().all(|o| params.contains(&o.as_str()));
    match (root_takes_params, r.opcode.as_str()) {
        (true, "add") | (true, "maximum") | (true, "minimum") | (true, "multiply")
        | (true, "and") | (true, "or") => Some(r.opcode.clone()),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Op implementations (free functions; no interpreter state needed).
// ---------------------------------------------------------------------------

fn constant(payload: &str, vt: VType, dims: &[usize]) -> Result<Value> {
    let elems: usize = dims.iter().product();
    let toks: Vec<&str> = payload
        .split(|c: char| c == ',' || c == '{' || c == '}' || c.is_whitespace())
        .filter(|t| !t.is_empty())
        .collect();
    if vt.is_float() {
        let mut vals = Vec::with_capacity(toks.len());
        for t in &toks {
            vals.push(match *t {
                "inf" => f32::INFINITY,
                "-inf" => f32::NEG_INFINITY,
                "nan" => f32::NAN,
                _ => t.parse::<f32>().map_err(|_| anyhow!("bad float literal '{t}'"))?,
            });
        }
        Value::from_f32s(vt, dims.to_vec(), splat_or_exact(vals, elems)?)
    } else {
        let mut vals = Vec::with_capacity(toks.len());
        for t in &toks {
            vals.push(match *t {
                "true" => 1,
                "false" => 0,
                _ => t
                    .parse::<i64>()
                    .map_err(|_| anyhow!("bad integer literal '{t}'"))? as i32,
            });
        }
        Value::from_i32s(vt, dims.to_vec(), splat_or_exact(vals, elems)?)
    }
}

/// Exactly `elems` values, or a single value splatted to `elems`.
fn splat_or_exact<T: Copy>(vals: Vec<T>, elems: usize) -> Result<Vec<T>> {
    if vals.len() == elems {
        Ok(vals)
    } else if vals.len() == 1 {
        Ok(vec![vals[0]; elems])
    } else {
        bail!("literal has {} values for {} elements", vals.len(), elems)
    }
}

fn iota(vt: VType, dims: &[usize], d: usize) -> Result<Value> {
    if d >= dims.len() {
        bail!("iota_dimension {d} out of range for rank {}", dims.len());
    }
    let elems: usize = dims.iter().product();
    let st = strides(dims);
    let extent = dims[d];
    let vals = (0..elems).map(|lin| (lin / st[d]) % extent);
    if vt.is_float() {
        Value::from_f32s(vt, dims.to_vec(), vals.map(|v| v as f32).collect())
    } else {
        Value::from_i32s(vt, dims.to_vec(), vals.map(|v| v as i32).collect())
    }
}

fn reshaped(v: &Value, out_dims: &[usize]) -> Result<Value> {
    let n: usize = out_dims.iter().product();
    if n != v.elems() {
        bail!("reshape: {} elems into {:?}", v.elems(), out_dims);
    }
    v.remap(out_dims.to_vec(), |lin| Ok(Some(lin)), None)
}

fn broadcast(v: &Value, out_dims: &[usize], mapping: &[usize]) -> Result<Value> {
    let in_dims = v.dims().to_vec();
    if mapping.len() != in_dims.len() {
        bail!(
            "broadcast dimensions {:?} don't match operand rank {}",
            mapping,
            in_dims.len()
        );
    }
    for (k, &m) in mapping.iter().enumerate() {
        if m >= out_dims.len() || out_dims[m] != in_dims[k] {
            bail!("broadcast dim {k}→{m} mismatch: {:?} into {:?}", in_dims, out_dims);
        }
    }
    let in_strides = strides(&in_dims);
    let mut idx = Vec::new();
    v.remap(
        out_dims.to_vec(),
        |lin| {
            unravel(lin, out_dims, &mut idx);
            Ok(Some(mapping.iter().enumerate().map(|(k, &m)| idx[m] * in_strides[k]).sum()))
        },
        None,
    )
}

fn transpose(v: &Value, perm: &[usize]) -> Result<Value> {
    let in_dims = v.dims().to_vec();
    if perm.len() != in_dims.len() {
        bail!("transpose permutation {:?} vs rank {}", perm, in_dims.len());
    }
    let out_dims: Vec<usize> = perm.iter().map(|&p| in_dims[p]).collect();
    let in_strides = strides(&in_dims);
    let od = out_dims.clone();
    let mut idx = Vec::new();
    v.remap(
        out_dims,
        |lin| {
            unravel(lin, &od, &mut idx);
            Ok(Some(perm.iter().enumerate().map(|(i, &p)| idx[i] * in_strides[p]).sum()))
        },
        None,
    )
}

/// Parse `{[0:5], [2:4:1]}` into per-dimension (start, stride).
fn parse_slice_attr(attr: &str, rank: usize) -> Result<Vec<(usize, usize)>> {
    let inner = attr.trim().trim_start_matches('{').trim_end_matches('}');
    let mut out = Vec::new();
    for part in inner.split(',') {
        let part = part.trim().trim_start_matches('[').trim_end_matches(']');
        if part.is_empty() {
            continue;
        }
        let fields: Vec<&str> = part.split(':').collect();
        let start: usize =
            fields.first().unwrap_or(&"0").trim().parse().unwrap_or(0);
        let stride: usize = fields.get(2).map(|s| s.trim().parse().unwrap_or(1)).unwrap_or(1);
        out.push((start, stride.max(1)));
    }
    if out.len() != rank {
        bail!("slice attr '{attr}' has {} dims, operand rank {rank}", out.len());
    }
    Ok(out)
}

fn slice(v: &Value, attr: &str, out_dims: &[usize]) -> Result<Value> {
    let in_dims = v.dims().to_vec();
    let spec = parse_slice_attr(attr, in_dims.len())?;
    let in_strides = strides(&in_dims);
    let mut idx = Vec::new();
    v.remap(
        out_dims.to_vec(),
        |lin| {
            unravel(lin, out_dims, &mut idx);
            let mut o = 0usize;
            for (d, &(start, stride)) in spec.iter().enumerate() {
                let i = start + idx[d] * stride;
                if i >= in_dims[d] {
                    bail!("slice index {i} out of bounds for dim {d} (extent {})", in_dims[d]);
                }
                o += i * in_strides[d];
            }
            Ok(Some(o))
        },
        None,
    )
}

fn reverse(v: &Value, dims: &[usize]) -> Result<Value> {
    let in_dims = v.dims().to_vec();
    for &d in dims {
        if d >= in_dims.len() {
            bail!("reverse dimension {d} out of range for rank {}", in_dims.len());
        }
    }
    let in_strides = strides(&in_dims);
    let od = in_dims.clone();
    let mut idx = Vec::new();
    v.remap(
        in_dims.clone(),
        |lin| {
            unravel(lin, &od, &mut idx);
            let mut o = 0usize;
            for (d, &i) in idx.iter().enumerate() {
                let i = if dims.contains(&d) { od[d] - 1 - i } else { i };
                o += i * in_strides[d];
            }
            Ok(Some(o))
        },
        None,
    )
}

/// Parse `1_2_0x0_3` (lo_hi[_interior] per dimension, `x`-separated)
/// into (lo, hi, interior) triples. Negative lo/hi trim edges.
fn parse_pad_attr(attr: &str, rank: usize) -> Result<Vec<(i64, i64, usize)>> {
    let mut out = Vec::new();
    for part in attr.trim().split('x') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let f: Vec<&str> = part.split('_').collect();
        if f.len() < 2 || f.len() > 3 {
            bail!("bad padding spec '{part}' (expected lo_hi[_interior])");
        }
        let lo: i64 = f[0].trim().parse().map_err(|_| anyhow!("bad pad lo '{}'", f[0]))?;
        let hi: i64 = f[1].trim().parse().map_err(|_| anyhow!("bad pad hi '{}'", f[1]))?;
        let interior: usize =
            f.get(2).map(|s| s.trim().parse().unwrap_or(0)).unwrap_or(0);
        out.push((lo, hi, interior));
    }
    if out.len() != rank {
        bail!("padding '{attr}' has {} dims, operand rank {rank}", out.len());
    }
    Ok(out)
}

fn pad(v: &Value, pad_value: &Value, attr: &str, out_dims: &[usize]) -> Result<Value> {
    let in_dims = v.dims().to_vec();
    let spec = parse_pad_attr(attr, in_dims.len())?;
    // Validate declared output against the spec.
    for (d, &(lo, hi, interior)) in spec.iter().enumerate() {
        let n = in_dims[d] as i64;
        let expect = lo + hi + n + (n - 1).max(0) * interior as i64;
        if expect != out_dims[d] as i64 {
            bail!(
                "pad dim {d}: spec {lo}_{hi}_{interior} over extent {n} gives {expect}, \
                 result declares {}",
                out_dims[d]
            );
        }
    }
    let in_strides = strides(&in_dims);
    let mut idx = Vec::new();
    v.remap(
        out_dims.to_vec(),
        |lin| {
            unravel(lin, out_dims, &mut idx);
            let mut o = 0usize;
            for (d, &(lo, _, interior)) in spec.iter().enumerate() {
                let pos = idx[d] as i64 - lo;
                let step = interior as i64 + 1;
                if pos < 0 || pos % step != 0 {
                    return Ok(None);
                }
                let i = (pos / step) as usize;
                if i >= in_dims[d] {
                    return Ok(None);
                }
                o += i * in_strides[d];
            }
            Ok(Some(o))
        },
        Some(pad_value),
    )
}

fn concatenate(parts: &[&Value], dim: usize, out_dims: &[usize]) -> Result<Value> {
    if parts.is_empty() {
        bail!("concatenate with no operands");
    }
    if dim >= out_dims.len() {
        bail!("concatenate dim {dim} out of range for rank {}", out_dims.len());
    }
    // Validate operand shapes against the declared result before writing:
    // every non-concat extent must match, and the concat extents must sum
    // to the declared one (a mismatch would otherwise index out of bounds
    // or leave silent zeros).
    let mut total = 0usize;
    for part in parts {
        let pd = part.dims();
        if pd.len() != out_dims.len() {
            bail!("concatenate rank mismatch: {:?} vs {:?}", pd, out_dims);
        }
        for (d, (&pe, &oe)) in pd.iter().zip(out_dims).enumerate() {
            if d != dim && pe != oe {
                bail!("concatenate extent mismatch at dim {d}: {:?} vs {:?}", pd, out_dims);
            }
        }
        total += pd[dim];
    }
    if total != out_dims[dim] {
        bail!(
            "concatenate extents sum to {total} but result declares {} along dim {dim}",
            out_dims[dim]
        );
    }
    // Per concat-coordinate lookup: coordinate along `dim` → (part,
    // local coordinate). Robust to zero-extent parts.
    let mut which: Vec<(usize, usize)> = Vec::with_capacity(out_dims[dim]);
    for (p, part) in parts.iter().enumerate() {
        for local in 0..part.dims()[dim] {
            which.push((p, local));
        }
    }
    let out_elems: usize = out_dims.iter().product();
    let first = parts[0];
    let same_storage = parts.iter().all(|p| p.vtype() == first.vtype());
    if !same_storage {
        bail!("concatenate: mixed element types");
    }
    let mut oidx = Vec::new();
    let part_dims: Vec<Vec<usize>> = parts.iter().map(|p| p.dims().to_vec()).collect();
    let part_strides: Vec<Vec<usize>> = part_dims.iter().map(|d| strides(d)).collect();
    // (part index, source linear) for every output element.
    let mut sources = Vec::with_capacity(out_elems);
    for lin in 0..out_elems {
        unravel(lin, out_dims, &mut oidx);
        let (p, local) = which[oidx[dim]];
        let mut src = 0usize;
        for (d, &i) in oidx.iter().enumerate() {
            let i = if d == dim { local } else { i };
            src += i * part_strides[p][d];
        }
        sources.push((p, src));
    }
    macro_rules! assemble {
        ($variant:ident) => {{
            let bufs: Vec<&[_]> = parts
                .iter()
                .map(|p| match p {
                    Value::$variant { data, .. } => Ok(data.as_slice()),
                    _ => Err(anyhow!("concatenate: mixed element types")),
                })
                .collect::<Result<_>>()?;
            let data = sources.iter().map(|&(p, s)| bufs[p][s]).collect();
            Ok(Value::$variant { dims: out_dims.to_vec(), data })
        }};
    }
    match first {
        Value::F32 { .. } => assemble!(F32),
        Value::F16 { .. } => assemble!(F16),
        Value::BF16 { .. } => assemble!(BF16),
        Value::I32 { .. } => assemble!(I32),
        Value::Tuple(_) => bail!("concatenate of tuple"),
    }
}

/// General dot: batch dims + any number of contracting dims per side.
/// Output dims are `[batch (lhs order), lhs free, rhs free]` — XLA's
/// DotGeneral convention. f64 accumulation, one rounding into `out_vt`.
fn dot(
    lhs: &Value,
    rhs: &Value,
    lb: &[usize],
    lc: &[usize],
    rb: &[usize],
    rc: &[usize],
    out_vt: VType,
) -> Result<Value> {
    let (ldims, ldata) = lhs.floats()?;
    let (rdims, rdata) = rhs.floats()?;
    let ldims = ldims.to_vec();
    let rdims = rdims.to_vec();
    if lb.len() != rb.len() || lc.len() != rc.len() {
        bail!("dot: batch/contracting dim count mismatch");
    }
    for (&a, &b) in lb.iter().zip(rb) {
        if ldims[a] != rdims[b] {
            bail!("dot: batch extent mismatch {} vs {}", ldims[a], rdims[b]);
        }
    }
    for (&a, &b) in lc.iter().zip(rc) {
        if ldims[a] != rdims[b] {
            bail!("dot: contraction extent mismatch {} vs {}", ldims[a], rdims[b]);
        }
    }
    let lfree: Vec<usize> =
        (0..ldims.len()).filter(|d| !lb.contains(d) && !lc.contains(d)).collect();
    let rfree: Vec<usize> =
        (0..rdims.len()).filter(|d| !rb.contains(d) && !rc.contains(d)).collect();
    let mut out_dims: Vec<usize> = lb.iter().map(|&d| ldims[d]).collect();
    out_dims.extend(lfree.iter().map(|&d| ldims[d]));
    out_dims.extend(rfree.iter().map(|&d| rdims[d]));
    let out_elems: usize = out_dims.iter().product::<usize>().max(1);

    let lstr = strides(&ldims);
    let rstr = strides(&rdims);
    // Precompute (lhs offset, rhs offset) for every contraction index.
    let csizes: Vec<usize> = lc.iter().map(|&d| ldims[d]).collect();
    let celems: usize = csizes.iter().product::<usize>().max(1);
    let mut coffs = Vec::with_capacity(celems);
    let mut cidx = Vec::new();
    for clin in 0..celems {
        unravel(clin, &csizes, &mut cidx);
        let lo: usize = cidx.iter().zip(lc).map(|(&i, &d)| i * lstr[d]).sum();
        let ro: usize = cidx.iter().zip(rc).map(|(&i, &d)| i * rstr[d]).sum();
        coffs.push((lo, ro));
    }

    let mut out = Vec::with_capacity(out_elems);
    let mut oidx = Vec::new();
    for olin in 0..out_elems {
        unravel(olin, &out_dims, &mut oidx);
        let nb = lb.len();
        let nlf = lfree.len();
        let mut lbase = 0usize;
        let mut rbase = 0usize;
        for (i, &d) in lb.iter().enumerate() {
            lbase += oidx[i] * lstr[d];
        }
        for (i, &d) in rb.iter().enumerate() {
            rbase += oidx[i] * rstr[d];
        }
        for (i, &d) in lfree.iter().enumerate() {
            lbase += oidx[nb + i] * lstr[d];
        }
        for (i, &d) in rfree.iter().enumerate() {
            rbase += oidx[nb + nlf + i] * rstr[d];
        }
        let mut acc = 0.0f64;
        for &(lo, ro) in &coffs {
            acc += ldata[lbase + lo] as f64 * rdata[rbase + ro] as f64;
        }
        out.push(acc as f32);
    }
    Value::from_f32s(out_vt, out_dims, out)
}

fn binary(op: &str, a: &Value, b: &Value, out_vt: VType) -> Result<Value> {
    if a.dims() != b.dims() {
        bail!("{op}: shape mismatch {:?} vs {:?}", a.dims(), b.dims());
    }
    if a.is_int() && b.is_int() {
        let (dims, xa) = a.ints()?;
        let (_, xb) = b.ints()?;
        let f: fn(i32, i32) -> i32 = match op {
            "add" => i32::wrapping_add,
            "subtract" => i32::wrapping_sub,
            "multiply" => i32::wrapping_mul,
            "divide" => |x, y| if y == 0 { 0 } else { x.wrapping_div(y) },
            "maximum" => i32::max,
            "minimum" => i32::min,
            "remainder" => |x, y| if y == 0 { 0 } else { x.wrapping_rem(y) },
            "and" => |x, y| x & y,
            "or" => |x, y| x | y,
            "xor" => |x, y| x ^ y,
            // XLA integer pow: negative exponents give 0 except for
            // base ±1; positive exponents wrap like the other int ops.
            "power" => |x: i32, y: i32| {
                if y < 0 {
                    return match x {
                        1 => 1,
                        -1 => {
                            if y % 2 == 0 {
                                1
                            } else {
                                -1
                            }
                        }
                        _ => 0,
                    };
                }
                let (mut base, mut exp, mut acc) = (x, y as u32, 1i32);
                while exp > 0 {
                    if exp & 1 == 1 {
                        acc = acc.wrapping_mul(base);
                    }
                    base = base.wrapping_mul(base);
                    exp >>= 1;
                }
                acc
            },
            _ => bail!("{op} unsupported on integers"),
        };
        Value::from_i32s(
            out_vt,
            dims.to_vec(),
            xa.iter().zip(xb).map(|(&x, &y)| f(x, y)).collect(),
        )
    } else if a.is_float() && b.is_float() {
        let (dims, xa) = a.floats()?;
        let (_, xb) = b.floats()?;
        let f: fn(f32, f32) -> f32 = match op {
            "add" => |x, y| x + y,
            "subtract" => |x, y| x - y,
            "multiply" => |x, y| x * y,
            "divide" => |x, y| x / y,
            "maximum" => f32::max,
            "minimum" => f32::min,
            "power" => f32::powf,
            "remainder" => |x, y| x % y,
            "atan2" => f32::atan2,
            _ => bail!("{op} unsupported on floats"),
        };
        Value::from_f32s(
            out_vt,
            dims.to_vec(),
            xa.iter().zip(xb.iter()).map(|(&x, &y)| f(x, y)).collect(),
        )
    } else {
        bail!("{op}: mixed or tuple operand types")
    }
}

fn unary(op: &str, a: &Value, out_vt: VType) -> Result<Value> {
    if a.is_int() {
        let (dims, data) = a.ints()?;
        let f: fn(i32) -> i32 = match op {
            "negate" => |x| x.wrapping_neg(),
            "abs" => i32::wrapping_abs,
            "sign" => i32::signum,
            // `not` is logical on pred, bitwise complement on s32 — the
            // declared result type says which one this instruction is.
            "not" => {
                if out_vt == VType::Pred {
                    |x| (x == 0) as i32
                } else {
                    |x: i32| !x
                }
            }
            "is-finite" => |_| 1,
            _ => bail!("{op} unsupported on integers"),
        };
        Value::from_i32s(out_vt, dims.to_vec(), data.iter().map(|&x| f(x)).collect())
    } else if a.is_float() {
        let (dims, data) = a.floats()?;
        if op == "is-finite" {
            return Value::from_i32s(
                out_vt,
                dims.to_vec(),
                data.iter().map(|&x| x.is_finite() as i32).collect(),
            );
        }
        let f: fn(f32) -> f32 = match op {
            "negate" => |x| -x,
            "exponential" => f32::exp,
            "exponential-minus-one" => f32::exp_m1,
            "log" => f32::ln,
            "log-plus-one" => f32::ln_1p,
            "sqrt" => f32::sqrt,
            "rsqrt" => |x| 1.0 / x.sqrt(),
            "cbrt" => f32::cbrt,
            "tanh" => f32::tanh,
            "logistic" => |x| 1.0 / (1.0 + (-x).exp()),
            "abs" => f32::abs,
            "sign" => f32::signum,
            "floor" => f32::floor,
            "ceil" => f32::ceil,
            "round-nearest-afz" => f32::round,
            "round-nearest-even" => round_ties_even_f32,
            "cosine" => f32::cos,
            "sine" => f32::sin,
            _ => bail!("{op} unsupported on floats"),
        };
        Value::from_f32s(out_vt, dims.to_vec(), data.iter().map(|&x| f(x)).collect())
    } else {
        bail!("{op} of tuple")
    }
}

/// Round half to even (MSRV-safe stand-in for `f32::round_ties_even`).
fn round_ties_even_f32(x: f32) -> f32 {
    let r = x.round();
    if (x - x.trunc()).abs() == 0.5 && r % 2.0 != 0.0 {
        r - x.signum()
    } else {
        r
    }
}

fn compare(a: &Value, b: &Value, direction: &str) -> Result<Value> {
    if a.dims() != b.dims() {
        bail!("compare: shape mismatch {:?} vs {:?}", a.dims(), b.dims());
    }
    if !matches!(direction, "EQ" | "NE" | "LT" | "LE" | "GT" | "GE") {
        bail!("unsupported compare direction '{direction}' (in-tree interpreter, DESIGN.md §9)");
    }
    let cmp = |ord: std::cmp::Ordering| -> bool {
        match direction {
            "EQ" => ord.is_eq(),
            "NE" => ord.is_ne(),
            "LT" => ord.is_lt(),
            "LE" => ord.is_le(),
            "GT" => ord.is_gt(),
            "GE" => ord.is_ge(),
            _ => unreachable!(),
        }
    };
    let data: Vec<i32> = if a.is_int() && b.is_int() {
        let (_, xa) = a.ints()?;
        let (_, xb) = b.ints()?;
        xa.iter().zip(xb).map(|(&x, &y)| cmp(x.cmp(&y)) as i32).collect()
    } else if a.is_float() && b.is_float() {
        let (_, xa) = a.floats()?;
        let (_, xb) = b.floats()?;
        xa.iter()
            .zip(xb.iter())
            // XLA totalorder-free comparison semantics: any comparison
            // involving NaN is false, except NE which is true.
            .map(|(&x, &y)| match x.partial_cmp(&y) {
                Some(ord) => cmp(ord) as i32,
                None => (direction == "NE") as i32,
            })
            .collect()
    } else {
        bail!("compare: mixed operand types");
    };
    Ok(Value::I32 { dims: a.dims().to_vec(), data })
}

fn select(pred: &Value, on_true: &Value, on_false: &Value, out_vt: VType) -> Result<Value> {
    let (_, p) = pred.ints()?;
    if on_true.dims() != on_false.dims() {
        bail!("select: branch shape mismatch");
    }
    // Scalar predicates broadcast; otherwise shapes must match.
    let scalar_pred = p.len() == 1 && pred.dims().is_empty();
    if !scalar_pred && pred.dims() != on_true.dims() {
        bail!("select: predicate shape mismatch");
    }
    let pick = |i: usize| -> bool {
        if scalar_pred {
            p[0] != 0
        } else {
            p[i] != 0
        }
    };
    if on_true.is_int() && on_false.is_int() {
        let (dims, xt) = on_true.ints()?;
        let (_, xf) = on_false.ints()?;
        Value::from_i32s(
            out_vt,
            dims.to_vec(),
            (0..xt.len()).map(|i| if pick(i) { xt[i] } else { xf[i] }).collect(),
        )
    } else if on_true.is_float() && on_false.is_float() {
        let (dims, xt) = on_true.floats()?;
        let (_, xf) = on_false.floats()?;
        Value::from_f32s(
            out_vt,
            dims.to_vec(),
            (0..xt.len()).map(|i| if pick(i) { xt[i] } else { xf[i] }).collect(),
        )
    } else {
        bail!("select: mixed or tuple operand types")
    }
}

/// `clamp(min, x, max)`: min/max either scalar or the operand's shape.
fn clamp(lo: &Value, x: &Value, hi: &Value, out_vt: VType) -> Result<Value> {
    let bound_ok = |b: &Value| b.elems() == 1 || b.dims() == x.dims();
    if !bound_ok(lo) || !bound_ok(hi) {
        bail!(
            "clamp: bounds must be scalar or match the operand shape {:?}",
            x.dims()
        );
    }
    if x.is_int() {
        let (dims, xs) = x.ints()?;
        let (_, ls) = lo.ints()?;
        let (_, hs) = hi.ints()?;
        let at = |s: &[i32], i: usize| if s.len() == 1 { s[0] } else { s[i] };
        Value::from_i32s(
            out_vt,
            dims.to_vec(),
            (0..xs.len())
                .map(|i| xs[i].clamp(at(ls, i).min(at(hs, i)), at(hs, i).max(at(ls, i))))
                .collect(),
        )
    } else {
        let (dims, xs) = x.floats()?;
        let (_, ls) = lo.floats()?;
        let (_, hs) = hi.floats()?;
        let at = |s: &[f32], i: usize| if s.len() == 1 { s[0] } else { s[i] };
        // XLA clamp = max(min, min(x, max)) elementwise.
        Value::from_f32s(
            out_vt,
            dims.to_vec(),
            (0..xs.len())
                .map(|i| xs[i].min(at(&hs, i)).max(at(&ls, i)))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run1(text: &str, inputs: &[Literal]) -> Vec<Literal> {
        Interp::from_text(text).unwrap().run(inputs).unwrap()
    }

    fn f32lit(data: &[f32], dims: &[i64]) -> Literal {
        Literal::vec1(data).reshape(dims).unwrap()
    }

    #[test]
    fn parameter_roundtrip_through_tuple() {
        let text = "HloModule t\nENTRY main {\n  p = f32[2,2]{1,0} parameter(0)\n  ROOT r = (f32[2,2]) tuple(p)\n}\n";
        let out = run1(text, &[f32lit(&[1.0, 2.0, 3.0, 4.0], &[2, 2])]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(out[0].dims, vec![2, 2]);
    }

    #[test]
    fn dot_matches_hand_computed_matmul() {
        // [2,3] x [3,2]: classic matmul.
        let text = "HloModule t\nENTRY main {\n  a = f32[2,3]{1,0} parameter(0)\n  b = f32[3,2]{1,0} parameter(1)\n  ROOT d = f32[2,2]{1,0} dot(a, b), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n}\n";
        let a = f32lit(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let b = f32lit(&[7.0, 8.0, 9.0, 10.0, 11.0, 12.0], &[3, 2]);
        let out = run1(text, &[a, b]);
        // Row 0: [1,2,3]·[7,9,11]=58, [1,2,3]·[8,10,12]=64
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn batched_dot_with_batch_dims() {
        // [2,2,2] x [2,2,2] batch over dim 0.
        let text = "HloModule t\nENTRY main {\n  a = f32[2,2,2]{2,1,0} parameter(0)\n  b = f32[2,2,2]{2,1,0} parameter(1)\n  ROOT d = f32[2,2,2]{2,1,0} dot(a, b), lhs_batch_dims={0}, lhs_contracting_dims={2}, rhs_batch_dims={0}, rhs_contracting_dims={1}\n}\n";
        let a = f32lit(&[1.0, 0.0, 0.0, 1.0, 2.0, 0.0, 0.0, 2.0], &[2, 2, 2]); // [I, 2I]
        let b = f32lit(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0], &[2, 2, 2]);
        let out = run1(text, &[a, b]);
        assert_eq!(
            out[0].to_vec::<f32>().unwrap(),
            vec![1.0, 2.0, 3.0, 4.0, 10.0, 12.0, 14.0, 16.0]
        );
    }

    #[test]
    fn reduce_sum_and_max_with_nested_bodies() {
        let text = "HloModule t\nadd_body {\n  x = f32[] parameter(0)\n  y = f32[] parameter(1)\n  ROOT s = f32[] add(x, y)\n}\nmax_body {\n  x = f32[] parameter(0)\n  y = f32[] parameter(1)\n  ROOT m = f32[] maximum(x, y)\n}\nENTRY main {\n  p = f32[2,3]{1,0} parameter(0)\n  zero = f32[] constant(0)\n  ninf = f32[] constant(-inf)\n  s = f32[2]{0} reduce(p, zero), dimensions={1}, to_apply=add_body\n  m = f32[3]{0} reduce(p, ninf), dimensions={0}, to_apply=max_body\n  ROOT r = (f32[2], f32[3]) tuple(s, m)\n}\n";
        let p = f32lit(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let out = run1(text, &[p]);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![6.0, 15.0]);
        assert_eq!(out[1].to_vec::<f32>().unwrap(), vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn broadcast_transpose_slice_concat() {
        let text = "HloModule t\nENTRY main {\n  v = f32[2]{0} parameter(0)\n  b = f32[2,3]{1,0} broadcast(v), dimensions={0}\n  t = f32[3,2]{1,0} transpose(b), dimensions={1,0}\n  s = f32[2,2]{1,0} slice(t), slice={[1:3], [0:2]}\n  ROOT c = f32[4,2]{1,0} concatenate(s, s), dimensions={0}\n}\n";
        let out = run1(text, &[f32lit(&[5.0, 9.0], &[2])]);
        // b rows: [5,5,5],[9,9,9]; t: [[5,9],[5,9],[5,9]]; s: rows 1..3 → [[5,9],[5,9]]
        assert_eq!(out[0].dims, vec![4, 2]);
        assert_eq!(
            out[0].to_vec::<f32>().unwrap(),
            vec![5.0, 9.0, 5.0, 9.0, 5.0, 9.0, 5.0, 9.0]
        );
    }

    #[test]
    fn iota_compare_convert_one_hot() {
        // One-hot encode i32 indices into f32 rows — the LM embedding trick.
        let text = "HloModule t\nENTRY main {\n  ix = s32[2]{0} parameter(0)\n  io = s32[2,4]{1,0} iota(), iota_dimension=1\n  bx = s32[2,4]{1,0} broadcast(ix), dimensions={0}\n  eq = pred[2,4]{1,0} compare(io, bx), direction=EQ\n  ROOT oh = f32[2,4]{1,0} convert(eq)\n}\n";
        let ix = Literal::vec1(&[2i32, 0]).reshape(&[2]).unwrap();
        let out = run1(text, &[ix]);
        assert_eq!(
            out[0].to_vec::<f32>().unwrap(),
            vec![0.0, 0.0, 1.0, 0.0, 1.0, 0.0, 0.0, 0.0]
        );
    }

    #[test]
    fn elementwise_and_scalar_constants() {
        let text = "HloModule t\nENTRY main {\n  p = f32[3]{0} parameter(0)\n  c = f32[] constant(2)\n  cb = f32[3]{0} broadcast(c), dimensions={}\n  m = f32[3]{0} multiply(p, cb)\n  e = f32[3]{0} exponential(m)\n  ROOT l = f32[3]{0} log(e)\n}\n";
        let out = run1(text, &[f32lit(&[0.5, 1.0, -1.0], &[3])]);
        let got = out[0].to_vec::<f32>().unwrap();
        for (g, want) in got.iter().zip([1.0f32, 2.0, -2.0]) {
            assert!((g - want).abs() < 1e-5, "{got:?}");
        }
    }

    #[test]
    fn unsupported_opcode_errors_cleanly() {
        let text = "HloModule t\nENTRY main {\n  p = f32[2]{0} parameter(0)\n  ROOT s = f32[2]{0} sort(p)\n}\n";
        let interp = Interp::from_text(text).unwrap();
        let err = interp.run(&[f32lit(&[2.0, 1.0], &[2])]).unwrap_err();
        assert!(format!("{err:#}").contains("unsupported HLO opcode"));
    }

    #[test]
    fn gather_rows_from_embedding_table() {
        // The embedding-lookup shape: [V,D] table, [B,1] indices.
        let text = "HloModule t\nENTRY main {\n  e = f32[4,2]{1,0} parameter(0)\n  ix = s32[3,1]{1,0} parameter(1)\n  ROOT g = f32[3,2]{1,0} gather(e, ix), offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, index_vector_dim=1, slice_sizes={1,2}\n}\n";
        let e = f32lit(&[0.0, 0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5], &[4, 2]);
        let ix = Literal::vec1(&[2i32, 0, 3]).reshape(&[3, 1]).unwrap();
        let out = run1(text, &[e, ix]);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![2.0, 2.5, 0.0, 0.5, 3.0, 3.5]);
    }

    #[test]
    fn gather_clamps_out_of_bounds_starts() {
        let text = "HloModule t\nENTRY main {\n  e = f32[4]{0} parameter(0)\n  ix = s32[2,1]{1,0} parameter(1)\n  ROOT g = f32[2]{0} gather(e, ix), offset_dims={}, collapsed_slice_dims={0}, start_index_map={0}, index_vector_dim=1, slice_sizes={1}\n}\n";
        let e = f32lit(&[10.0, 11.0, 12.0, 13.0], &[4]);
        let ix = Literal::vec1(&[-5i32, 99]).reshape(&[2, 1]).unwrap();
        let out = run1(text, &[e, ix]);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![10.0, 13.0]);
    }

    #[test]
    fn scatter_add_accumulates_duplicate_indices() {
        let text = "HloModule t\nadd_f {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT r = f32[] add(a, b)\n}\nENTRY main {\n  z = f32[4]{0} parameter(0)\n  ix = s32[3,1]{1,0} parameter(1)\n  u = f32[3]{0} parameter(2)\n  ROOT s = f32[4]{0} scatter(z, ix, u), update_window_dims={}, inserted_window_dims={0}, scatter_dims_to_operand_dims={0}, index_vector_dim=1, to_apply=add_f\n}\n";
        let z = f32lit(&[0.0; 4], &[4]);
        let ix = Literal::vec1(&[1i32, 1, 3]).reshape(&[3, 1]).unwrap();
        let u = f32lit(&[5.0, 7.0, 2.0], &[3]);
        let out = run1(text, &[z, ix, u]);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![0.0, 12.0, 0.0, 2.0]);
    }

    #[test]
    fn scatter_drops_out_of_bounds_updates() {
        let text = "HloModule t\nadd_f {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT r = f32[] add(a, b)\n}\nENTRY main {\n  z = f32[3]{0} parameter(0)\n  ix = s32[2,1]{1,0} parameter(1)\n  u = f32[2]{0} parameter(2)\n  ROOT s = f32[3]{0} scatter(z, ix, u), update_window_dims={}, inserted_window_dims={0}, scatter_dims_to_operand_dims={0}, index_vector_dim=1, to_apply=add_f\n}\n";
        let z = f32lit(&[1.0, 1.0, 1.0], &[3]);
        let ix = Literal::vec1(&[7i32, 0]).reshape(&[2, 1]).unwrap();
        let u = f32lit(&[100.0, 5.0], &[2]);
        let out = run1(text, &[z, ix, u]);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![6.0, 1.0, 1.0]);
    }

    #[test]
    fn while_loop_counts_and_accumulates() {
        // (i, acc) → (i+1, acc+i) while i < 5: acc = 0+1+2+3+4 = 10.
        let text = "HloModule t\ncond {\n  t = (s32[], s32[]) parameter(0)\n  i = s32[] get-tuple-element(t), index=0\n  five = s32[] constant(5)\n  ROOT lt = pred[] compare(i, five), direction=LT\n}\nbody {\n  t = (s32[], s32[]) parameter(0)\n  i = s32[] get-tuple-element(t), index=0\n  acc = s32[] get-tuple-element(t), index=1\n  one = s32[] constant(1)\n  i2 = s32[] add(i, one)\n  acc2 = s32[] add(acc, i)\n  ROOT r = (s32[], s32[]) tuple(i2, acc2)\n}\nENTRY main {\n  zero = s32[] constant(0)\n  init = (s32[], s32[]) tuple(zero, zero)\n  w = (s32[], s32[]) while(init), condition=cond, body=body\n  ROOT acc = s32[] get-tuple-element(w), index=1\n}\n";
        let out = run1(text, &[]);
        assert_eq!(out[0].to_vec::<i32>().unwrap(), vec![10]);
    }

    #[test]
    fn conditional_predicated_and_indexed() {
        let text = "HloModule t\ndouble {\n  x = f32[] parameter(0)\n  two = f32[] constant(2)\n  ROOT r = f32[] multiply(x, two)\n}\nnegate_c {\n  x = f32[] parameter(0)\n  ROOT r = f32[] negate(x)\n}\nENTRY main {\n  p = pred[] parameter(0)\n  a = f32[] parameter(1)\n  c = f32[] conditional(p, a, a), true_computation=double, false_computation=negate_c\n  ix = s32[] parameter(2)\n  d = f32[] conditional(ix, a, a), branch_computations={double, negate_c}\n  ROOT r = (f32[], f32[]) tuple(c, d)\n}\n";
        let interp = Interp::from_text(text).unwrap();
        let run = |p: i32, ix: i32| -> (f32, f32) {
            let out = interp
                .run(&[
                    Literal::vec1(&[p]).reshape(&[]).unwrap(),
                    f32lit(&[3.0], &[]),
                    Literal::vec1(&[ix]).reshape(&[]).unwrap(),
                ])
                .unwrap();
            (out[0].to_vec::<f32>().unwrap()[0], out[1].to_vec::<f32>().unwrap()[0])
        };
        assert_eq!(run(1, 0), (6.0, 6.0));
        assert_eq!(run(0, 1), (-3.0, -3.0));
        // Out-of-range branch index clamps to the last branch.
        assert_eq!(run(0, 99).1, -3.0);
    }

    #[test]
    fn dynamic_slice_and_update_clamp_starts() {
        let text = "HloModule t\nENTRY main {\n  v = f32[4]{0} parameter(0)\n  i = s32[] parameter(1)\n  ds = f32[2]{0} dynamic-slice(v, i), dynamic_slice_sizes={2}\n  u = f32[2]{0} parameter(2)\n  dus = f32[4]{0} dynamic-update-slice(v, u, i)\n  ROOT r = (f32[2], f32[4]) tuple(ds, dus)\n}\n";
        let interp = Interp::from_text(text).unwrap();
        let v = f32lit(&[1.0, 2.0, 3.0, 4.0], &[4]);
        let u = f32lit(&[8.0, 9.0], &[2]);
        let i = Literal::vec1(&[3i32]).reshape(&[]).unwrap(); // clamps to 2
        let out = interp.run(&[v, i, u]).unwrap();
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![3.0, 4.0]);
        assert_eq!(out[1].to_vec::<f32>().unwrap(), vec![1.0, 2.0, 8.0, 9.0]);
    }

    #[test]
    fn pad_reverse_clamp() {
        let text = "HloModule t\nENTRY main {\n  v = f32[3]{0} parameter(0)\n  z = f32[] constant(-1)\n  p = f32[7]{0} pad(v, z), padding=1_1_1\n  r = f32[3]{0} reverse(v), dimensions={0}\n  lo = f32[] constant(0)\n  hi = f32[] constant(2)\n  c = f32[3]{0} clamp(lo, v, hi)\n  ROOT t = (f32[7], f32[3], f32[3]) tuple(p, r, c)\n}\n";
        let out = run1(text, &[f32lit(&[1.0, 2.0, 3.0], &[3])]);
        assert_eq!(
            out[0].to_vec::<f32>().unwrap(),
            vec![-1.0, 1.0, -1.0, 2.0, -1.0, 3.0, -1.0]
        );
        assert_eq!(out[1].to_vec::<f32>().unwrap(), vec![3.0, 2.0, 1.0]);
        assert_eq!(out[2].to_vec::<f32>().unwrap(), vec![1.0, 2.0, 2.0]);
    }

    #[test]
    fn f16_parameters_round_storage() {
        // 1 + 2⁻¹² is not representable in f16; storage rounds it away.
        let text = "HloModule t\nENTRY main {\n  p = f16[2]{0} parameter(0)\n  ROOT r = f32[2]{0} convert(p)\n}\n";
        let x = 1.0 + 2.0f32.powi(-12);
        let out = run1(text, &[f32lit(&[x, 2.5], &[2])]);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![1.0, 2.5]);
    }

    #[test]
    fn f16_reduce_accumulates_in_f64_then_rounds_once() {
        // Each element is 1 + 2⁻¹⁰ (exactly one f16 ULP above 1.0). The
        // wide accumulator keeps the exact sum 8 + 2⁻⁷, which is exactly
        // one f16 ULP above 8.0 — a sequential f16 accumulation would
        // have rounded the increments away midway.
        let text = "HloModule t\nsum {\n  a = f16[] parameter(0)\n  b = f16[] parameter(1)\n  ROOT r = f16[] add(a, b)\n}\nENTRY main {\n  p = f16[8]{0} parameter(0)\n  z = f16[] constant(0)\n  s = f16[] reduce(p, z), dimensions={0}, to_apply=sum\n  ROOT r = f32[] convert(s)\n}\n";
        let tiny = 2.0f32.powi(-10);
        let input = vec![1.0 + tiny; 8];
        let out = run1(text, &[f32lit(&input, &[8])]);
        let got = out[0].to_vec::<f32>().unwrap()[0];
        assert!((got - 8.0078125).abs() < 1e-6, "got {got}");
    }

    #[test]
    fn call_executes_nested_computation() {
        let text = "HloModule t\nsq {\n  x = f32[2]{0} parameter(0)\n  ROOT r = f32[2]{0} multiply(x, x)\n}\nENTRY main {\n  p = f32[2]{0} parameter(0)\n  ROOT c = f32[2]{0} call(p), to_apply=sq\n}\n";
        let out = run1(text, &[f32lit(&[3.0, -4.0], &[2])]);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![9.0, 16.0]);
    }
}
