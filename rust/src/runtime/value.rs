//! Typed runtime values for the in-tree HLO interpreter (DESIGN.md §9).
//!
//! A [`Value`] is a dense host tensor whose buffer is **storage-typed**:
//! `f16`/`bf16` tensors hold 16-bit patterns, not widened floats, so the
//! interpreter reproduces reduced-precision rounding the way a real
//! backend does. Arithmetic follows the usual software-emulation
//! contract: every op widens its operands to `f32` (f64 accumulation for
//! `dot`/`reduce`), computes, and rounds the result back to the
//! instruction's declared storage type — one rounding per op, the same
//! observable semantics as XLA's CPU float-normalization pass.
//!
//! `pred`/`s32`/`u32`/`s64` all store as `i32` (pred as 0/1); the
//! [`VType`] of the declared result distinguishes pred narrowing
//! (non-zero → 1) from integer truncation.
//!
//! The `f16`/`bf16` bit conversions (round-to-nearest-even, subnormals,
//! inf/NaN) and the ULP distance used by the conformance tests live here
//! too, so tests and the corpus runner share one definition.

use crate::graph::hlo_import::Prim;
use crate::xla_stub::{Elements, Literal};
use anyhow::{anyhow, bail, Result};
use std::borrow::Cow;

/// Storage type of one interpreter value — the executable refinement of
/// the byte-accounting [`crate::graph::DType`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VType {
    F32,
    F16,
    BF16,
    /// s32/u32/s64 storage.
    I32,
    /// Boolean storage (i32 0/1); narrowing maps non-zero → 1.
    Pred,
}

impl VType {
    /// Storage type of a parsed HLO primitive type.
    pub fn of(prim: Prim) -> VType {
        match prim {
            Prim::F32 => VType::F32,
            Prim::F16 => VType::F16,
            Prim::BF16 => VType::BF16,
            Prim::S32 => VType::I32,
            Prim::Pred => VType::Pred,
        }
    }

    pub fn is_float(self) -> bool {
        matches!(self, VType::F32 | VType::F16 | VType::BF16)
    }

    pub fn name(self) -> &'static str {
        match self {
            VType::F32 => "f32",
            VType::F16 => "f16",
            VType::BF16 => "bf16",
            VType::I32 => "s32",
            VType::Pred => "pred",
        }
    }
}

/// A runtime value: a dense host tensor or a tuple.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    /// IEEE binary16 bit patterns.
    F16 { dims: Vec<usize>, data: Vec<u16> },
    /// bfloat16 bit patterns.
    BF16 { dims: Vec<usize>, data: Vec<u16> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
    Tuple(Vec<Value>),
}

impl Value {
    pub fn scalar_f32(v: f32) -> Value {
        Value::F32 { dims: vec![], data: vec![v] }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Value::F32 { dims, .. }
            | Value::F16 { dims, .. }
            | Value::BF16 { dims, .. }
            | Value::I32 { dims, .. } => dims,
            Value::Tuple(_) => &[],
        }
    }

    pub fn elems(&self) -> usize {
        match self {
            Value::Tuple(_) => 0,
            _ => self.dims().iter().product(),
        }
    }

    /// Storage type (tuples have none).
    pub fn vtype(&self) -> Option<VType> {
        match self {
            Value::F32 { .. } => Some(VType::F32),
            Value::F16 { .. } => Some(VType::F16),
            Value::BF16 { .. } => Some(VType::BF16),
            Value::I32 { .. } => Some(VType::I32),
            Value::Tuple(_) => None,
        }
    }

    pub fn is_float(&self) -> bool {
        matches!(self, Value::F32 { .. } | Value::F16 { .. } | Value::BF16 { .. })
    }

    pub fn is_int(&self) -> bool {
        matches!(self, Value::I32 { .. })
    }

    /// Widen to f32 (borrowed for f32 storage, owned otherwise).
    pub fn floats(&self) -> Result<(&[usize], Cow<'_, [f32]>)> {
        match self {
            Value::F32 { dims, data } => Ok((dims, Cow::Borrowed(data))),
            Value::F16 { dims, data } => {
                Ok((dims, Cow::Owned(data.iter().map(|&b| f16_bits_to_f32(b)).collect())))
            }
            Value::BF16 { dims, data } => {
                Ok((dims, Cow::Owned(data.iter().map(|&b| bf16_bits_to_f32(b)).collect())))
            }
            _ => bail!("expected a float tensor, got {}", self.type_str()),
        }
    }

    pub fn ints(&self) -> Result<(&[usize], &[i32])> {
        match self {
            Value::I32 { dims, data } => Ok((dims, data)),
            _ => bail!("expected an integer/pred tensor, got {}", self.type_str()),
        }
    }

    fn type_str(&self) -> String {
        match self.vtype() {
            Some(vt) => format!("{}{:?}", vt.name(), self.dims()),
            None => "tuple".to_string(),
        }
    }

    /// Build a float-family value by narrowing f32 data into `vt` storage.
    /// `vt` must be a float type.
    pub fn from_f32s(vt: VType, dims: Vec<usize>, data: Vec<f32>) -> Result<Value> {
        Ok(match vt {
            VType::F32 => Value::F32 { dims, data },
            VType::F16 => {
                Value::F16 { dims, data: data.into_iter().map(f32_to_f16_bits).collect() }
            }
            VType::BF16 => {
                Value::BF16 { dims, data: data.into_iter().map(f32_to_bf16_bits).collect() }
            }
            VType::I32 => Value::I32 {
                dims,
                // XLA float→int conversion truncates toward zero.
                data: data.into_iter().map(|x| x as i32).collect(),
            },
            VType::Pred => {
                Value::I32 { dims, data: data.into_iter().map(|x| (x != 0.0) as i32).collect() }
            }
        })
    }

    /// Build an int-family value (or convert to a float type) from i32s.
    pub fn from_i32s(vt: VType, dims: Vec<usize>, data: Vec<i32>) -> Result<Value> {
        Ok(match vt {
            VType::I32 => Value::I32 { dims, data },
            VType::Pred => {
                Value::I32 { dims, data: data.into_iter().map(|x| (x != 0) as i32).collect() }
            }
            _ => Value::from_f32s(vt, dims, data.into_iter().map(|x| x as f32).collect())?,
        })
    }

    /// `convert`-style cast into `vt` storage (identity when already
    /// there).
    pub fn cast(&self, vt: VType) -> Result<Value> {
        if self.vtype() == Some(vt) {
            return Ok(self.clone());
        }
        if let Value::Tuple(_) = self {
            bail!("cannot convert a tuple");
        }
        let dims = self.dims().to_vec();
        if self.is_int() {
            let (_, xs) = self.ints()?;
            Value::from_i32s(vt, dims, xs.to_vec())
        } else {
            let (_, xs) = self.floats()?;
            Value::from_f32s(vt, dims, xs.into_owned())
        }
    }

    /// Convert from the runtime's host literal type (f32/i32 interchange).
    pub fn from_literal(lit: &Literal) -> Value {
        let dims: Vec<usize> = lit.dims.iter().map(|&d| d as usize).collect();
        match &lit.elements {
            Elements::F32(v) => Value::F32 { dims, data: v.clone() },
            Elements::I32(v) => Value::I32 { dims, data: v.clone() },
        }
    }

    /// Convert back to the runtime's host literal type (arrays only —
    /// tuples are flattened by the caller). Reduced-precision floats
    /// widen to f32: the `Literal` interchange type carries f32/i32 only,
    /// and f16/bf16 → f32 is exact.
    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.dims().iter().map(|&d| d as i64).collect();
        match self {
            Value::F32 { data, .. } => {
                Ok(Literal { elements: Elements::F32(data.clone()), dims })
            }
            Value::F16 { .. } | Value::BF16 { .. } => {
                let (_, xs) = self.floats()?;
                Ok(Literal { elements: Elements::F32(xs.into_owned()), dims })
            }
            Value::I32 { data, .. } => {
                Ok(Literal { elements: Elements::I32(data.clone()), dims })
            }
            Value::Tuple(_) => bail!("cannot convert tuple to a single literal"),
        }
    }

    /// The single scalar as f64 (any non-tuple storage; pred/i32 widen).
    pub fn scalar(&self) -> Result<f64> {
        if self.elems() != 1 {
            bail!("expected a scalar, got {}", self.type_str());
        }
        if self.is_int() {
            Ok(self.ints()?.1[0] as f64)
        } else {
            Ok(self.floats()?.1[0] as f64)
        }
    }

    /// Pure data movement into the same storage type: out[i] =
    /// self[src(i)], or the `fill` scalar where `src` returns `None`
    /// (pad). `fill` must share the storage type when provided.
    pub fn remap(
        &self,
        out_dims: Vec<usize>,
        mut src: impl FnMut(usize) -> Result<Option<usize>>,
        fill: Option<&Value>,
    ) -> Result<Value> {
        let out_elems: usize = out_dims.iter().product();
        macro_rules! arm {
            ($variant:ident, $data:expr, $zero:expr) => {{
                let fill_v = match fill {
                    Some(Value::$variant { data: fd, .. }) => {
                        *fd.first().ok_or_else(|| anyhow!("empty pad value"))?
                    }
                    Some(other) => bail!(
                        "pad value storage mismatch: {} vs {}",
                        other.type_str(),
                        self.type_str()
                    ),
                    None => $zero,
                };
                let mut out = Vec::with_capacity(out_elems);
                for lin in 0..out_elems {
                    out.push(match src(lin)? {
                        Some(i) => $data[i],
                        None => fill_v,
                    });
                }
                Ok(Value::$variant { dims: out_dims, data: out })
            }};
        }
        match self {
            Value::F32 { data, .. } => arm!(F32, data, 0.0),
            Value::F16 { data, .. } => arm!(F16, data, 0),
            Value::BF16 { data, .. } => arm!(BF16, data, 0),
            Value::I32 { data, .. } => arm!(I32, data, 0),
            Value::Tuple(_) => bail!("cannot remap a tuple"),
        }
    }
}

// ---------------------------------------------------------------------------
// f16 / bf16 bit conversions (round-to-nearest-even).
// ---------------------------------------------------------------------------

/// f32 → IEEE binary16 bits, round-to-nearest-even, with subnormals,
/// overflow→inf, and NaN→canonical quiet NaN.
pub fn f32_to_f16_bits(value: f32) -> u16 {
    let x = value.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let exp = ((x >> 23) & 0xff) as i32;
    let mut man = x & 0x007f_ffff;
    if exp == 255 {
        // Inf stays inf; NaN becomes the canonical quiet NaN.
        return if man != 0 { sign | 0x7e00 } else { sign | 0x7c00 };
    }
    let e16 = exp - 112; // re-bias: f32 bias 127 → f16 bias 15
    if e16 >= 31 {
        return sign | 0x7c00; // overflow → inf
    }
    if e16 <= 0 {
        if e16 < -10 {
            return sign; // below half the smallest subnormal → ±0
        }
        // Subnormal: shift the 24-bit significand into the 10-bit field.
        man |= 0x0080_0000;
        let shift = (14 - e16) as u32;
        let kept = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let rounded =
            kept + (rem > half) as u32 + ((rem == half) as u32 & (kept & 1));
        return sign | rounded as u16;
    }
    let kept = ((e16 as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    // Rounding may carry through the exponent (up to inf) — that carry is
    // exactly the correct result, so no masking.
    let rounded = kept + (rem > 0x1000) as u32 + ((rem == 0x1000) as u32 & (kept & 1));
    sign | rounded as u16
}

/// IEEE binary16 bits → f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign
        } else {
            // Subnormal: value = man · 2⁻²⁴; normalize into f32.
            let k = 31 - man.leading_zeros(); // 0..=9
            let e = (k + 103) << 23;
            let m = ((man & !(1u32 << k)) << (23 - k)) & 0x007f_ffff;
            sign | e | m
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (man << 13)
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// f32 → bfloat16 bits, round-to-nearest-even (NaN → quiet, sign kept).
pub fn f32_to_bf16_bits(value: f32) -> u16 {
    let x = value.to_bits();
    if value.is_nan() {
        return ((x >> 16) as u16) | 0x0040;
    }
    let bias = 0x7fff + ((x >> 16) & 1);
    ((x.wrapping_add(bias)) >> 16) as u16
}

/// bfloat16 bits → f32 (exact).
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// ULP distance between two 16-bit float patterns of the same format
/// (f16 or bf16): both bit patterns are mapped onto a monotone integer
/// line, so the distance is format-agnostic. ±0 compare equal; any NaN
/// involvement returns `u32::MAX` unless both are NaN.
pub fn ulp_diff_16(a: u16, b: u16, is_bf16: bool) -> u32 {
    let is_nan = |v: u16| {
        if is_bf16 {
            (v & 0x7fff) > 0x7f80
        } else {
            (v & 0x7fff) > 0x7c00
        }
    };
    match (is_nan(a), is_nan(b)) {
        (true, true) => return 0,
        (true, false) | (false, true) => return u32::MAX,
        _ => {}
    }
    let order = |v: u16| -> i32 {
        let m = (v & 0x7fff) as i32;
        if v & 0x8000 != 0 {
            -m
        } else {
            m
        }
    };
    (order(a) - order(b)).unsigned_abs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_known_constants() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // max finite
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00); // → inf
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // Smallest subnormal 2⁻²⁴ and smallest normal 2⁻¹⁴.
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-24)), 0x0001);
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-14)), 0x0400);
        // Below half the smallest subnormal rounds to zero.
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-26)), 0x0000);
    }

    #[test]
    fn f16_round_to_nearest_even() {
        // 1 + 2⁻¹¹ is exactly halfway between 1.0 and the next f16; ties
        // go to even (1.0). 1 + 3·2⁻¹¹ is halfway and rounds up to even.
        assert_eq!(f32_to_f16_bits(1.0 + 2.0f32.powi(-11)), 0x3c00);
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * 2.0f32.powi(-11)), 0x3c02);
        // Just above halfway rounds up.
        assert_eq!(f32_to_f16_bits(1.0 + 2.0f32.powi(-11) + 2.0f32.powi(-20)), 0x3c01);
    }

    #[test]
    fn f16_roundtrip_is_identity_on_representables() {
        for bits in (0u16..=0xffff).step_by(7) {
            let f = f16_bits_to_f32(bits);
            if f.is_nan() {
                continue;
            }
            assert_eq!(f32_to_f16_bits(f), bits, "bits {bits:#06x} → {f}");
        }
    }

    #[test]
    fn bf16_conversions() {
        assert_eq!(f32_to_bf16_bits(1.0), 0x3f80);
        assert_eq!(bf16_bits_to_f32(0x3f80), 1.0);
        assert_eq!(f32_to_bf16_bits(-0.5), 0xbf00);
        // Round-to-nearest-even at the 16-bit boundary.
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3f80_8000)), 0x3f80); // tie→even
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3f81_8000)), 0x3f82); // tie→even
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3f80_8001)), 0x3f81);
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
        for x in [0.0f32, 3.5, -1.25e10, 7.8e-20] {
            let b = f32_to_bf16_bits(x);
            let back = bf16_bits_to_f32(b);
            assert!((back - x).abs() <= x.abs() * 0.01, "{x} → {back}");
        }
    }

    #[test]
    fn ulp_distance() {
        assert_eq!(ulp_diff_16(0x3c00, 0x3c00, false), 0);
        assert_eq!(ulp_diff_16(0x3c00, 0x3c01, false), 1);
        assert_eq!(ulp_diff_16(0x0000, 0x8000, false), 0); // ±0
        assert_eq!(ulp_diff_16(0x0001, 0x8001, false), 2); // straddles zero
        assert_eq!(ulp_diff_16(0x7e00, 0x7e00, false), 0); // NaN == NaN here
        assert_eq!(ulp_diff_16(0x7e00, 0x3c00, false), u32::MAX);
    }

    #[test]
    fn value_cast_and_narrowing() {
        let v = Value::F32 { dims: vec![3], data: vec![1.0, 2.5, -3.7] };
        let h = v.cast(VType::F16).unwrap();
        let (_, back) = h.floats().unwrap();
        assert_eq!(back.as_ref(), &[1.0, 2.5, -3.7]); // exactly representable
        let i = v.cast(VType::I32).unwrap();
        assert_eq!(i.ints().unwrap().1, &[1, 2, -3]); // trunc toward zero
        let p = v.cast(VType::Pred).unwrap();
        assert_eq!(p.ints().unwrap().1, &[1, 1, 1]);
        let z = Value::F32 { dims: vec![2], data: vec![0.0, 0.5] };
        assert_eq!(z.cast(VType::Pred).unwrap().ints().unwrap().1, &[0, 1]);
    }

    #[test]
    fn remap_with_fill() {
        let v = Value::I32 { dims: vec![2], data: vec![7, 9] };
        let fill = Value::I32 { dims: vec![], data: vec![-1] };
        let out = v
            .remap(
                vec![4],
                |i| Ok(if i < 2 { Some(i) } else { None }),
                Some(&fill),
            )
            .unwrap();
        assert_eq!(out.ints().unwrap().1, &[7, 9, -1, -1]);
    }
}
