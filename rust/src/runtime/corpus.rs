//! Golden-conformance-corpus format and runner (DESIGN.md §9).
//!
//! A corpus case is an ordinary HLO text file whose leading comment
//! lines carry the test vector, so every case stays a valid module that
//! any HLO tool can read:
//!
//! ```text
//! // case: gather picks rows of an embedding table
//! // input: f32[4,2] = 0 0.5 1 1.5 2 2.5 3 3.5
//! // input: s32[3,1] = 2 0 3
//! // expect: f32[3,2] = 2 2.5 0 0.5 3 3.5
//! // tol: 1e-5
//! // ulp: 1
//! HloModule gather_rows
//! ENTRY main { … }
//! ```
//!
//! Inputs become interchange literals (floats → f32, integers/pred →
//! i32; the module's declared parameter types narrow storage on entry).
//! Expected values compare against the flattened root tuple in order:
//! integer/pred outputs must match **exactly**; f32 within `tol`
//! (absolute + relative, default 1e-5); f16/bf16 within `ulp` ULPs
//! (default 1) after narrowing the expected decimals into the storage
//! format — narrowing the widened interpreter output is lossless, so
//! the comparison happens on storage bit patterns.
//!
//! `disco run-hlo <file>` runs one case and prints the actual outputs
//! as ready-to-paste `// expect:` lines — the corpus authoring loop.
//! The table-driven test over `rust/tests/hlo_corpus/` lives in
//! `tests/interp.rs` and lists every failing file by name.

use crate::graph::hlo_import::{HloShape, Prim};
use crate::runtime::interp::Interp;
use crate::runtime::value::{f32_to_bf16_bits, f32_to_f16_bits, ulp_diff_16, VType};
use crate::xla_stub::Literal;
use anyhow::{anyhow, bail, Context, Result};

/// One `// expect:` directive.
#[derive(Debug, Clone)]
pub struct Expected {
    pub prim: Prim,
    pub dims: Vec<usize>,
    pub vals: Vec<f64>,
}

/// A parsed corpus case: module text plus its test vector.
#[derive(Debug, Clone)]
pub struct CorpusCase {
    pub name: String,
    pub text: String,
    pub inputs: Vec<Literal>,
    pub expects: Vec<Expected>,
    /// Absolute+relative tolerance for f32 outputs.
    pub tol: f64,
    /// Max ULP distance for f16/bf16 outputs.
    pub ulp: u32,
}

fn parse_typed_values(spec: &str) -> Result<(Prim, Vec<usize>, Vec<f64>)> {
    let (ty, vals) = spec
        .split_once('=')
        .ok_or_else(|| anyhow!("directive needs 'type = values', got '{spec}'"))?;
    let shape = HloShape::parse(ty.trim())
        .ok_or_else(|| anyhow!("bad type '{}' in directive", ty.trim()))?;
    let (prim, s) = shape
        .first_prim()
        .ok_or_else(|| anyhow!("tuple types are not valid in directives"))?;
    let dims = s.dims;
    let elems: usize = dims.iter().product();
    let mut out = Vec::new();
    for tok in vals.split_whitespace() {
        out.push(match tok {
            "inf" => f64::INFINITY,
            "-inf" => f64::NEG_INFINITY,
            "nan" => f64::NAN,
            "true" => 1.0,
            "false" => 0.0,
            _ => tok
                .parse::<f64>()
                .map_err(|_| anyhow!("bad value '{tok}' in directive"))?,
        });
    }
    if out.len() == 1 && elems != 1 {
        out = vec![out[0]; elems];
    }
    if out.len() != elems {
        bail!("directive '{}' has {} values for {} elements", ty.trim(), out.len(), elems);
    }
    Ok((prim, dims, out))
}

/// Parse one corpus file's directives; the whole text stays the module
/// source (the HLO parser skips comment lines).
pub fn parse_case(name: &str, text: &str) -> Result<CorpusCase> {
    let mut inputs = Vec::new();
    let mut expects = Vec::new();
    let mut tol = 1e-5f64;
    let mut ulp = 1u32;
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        let Some(rest) = line.strip_prefix("//") else { continue };
        let rest = rest.trim();
        let at = |e: anyhow::Error| e.context(format!("{name}:{}", ln + 1));
        if let Some(spec) = rest.strip_prefix("input:") {
            let (prim, dims, vals) = parse_typed_values(spec).map_err(at)?;
            let ldims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let lit = if VType::of(prim).is_float() {
                let data: Vec<f32> = vals.iter().map(|&v| v as f32).collect();
                Literal::vec1(&data).reshape(&ldims)
            } else {
                let data: Vec<i32> = vals.iter().map(|&v| v as i32).collect();
                Literal::vec1(&data).reshape(&ldims)
            }
            .map_err(|e| anyhow!("{name}:{}: {e:?}", ln + 1))?;
            inputs.push(lit);
        } else if let Some(spec) = rest.strip_prefix("expect:") {
            let (prim, dims, vals) = parse_typed_values(spec).map_err(at)?;
            expects.push(Expected { prim, dims, vals });
        } else if let Some(v) = rest.strip_prefix("tol:") {
            tol = v.trim().parse().map_err(|_| anyhow!("{name}:{}: bad tol", ln + 1))?;
        } else if let Some(v) = rest.strip_prefix("ulp:") {
            ulp = v.trim().parse().map_err(|_| anyhow!("{name}:{}: bad ulp", ln + 1))?;
        }
    }
    Ok(CorpusCase {
        name: name.to_string(),
        text: text.to_string(),
        inputs,
        expects,
        tol,
        ulp,
    })
}

/// Compare one output against its `// expect:` directive.
fn check_output(
    case: &CorpusCase,
    idx: usize,
    exp: &Expected,
    got: &Literal,
) -> Result<()> {
    let got_dims: Vec<usize> = got.dims.iter().map(|&d| d as usize).collect();
    if got_dims != exp.dims {
        bail!(
            "{}: output {idx} shape {:?}, expected {:?}",
            case.name,
            got_dims,
            exp.dims
        );
    }
    match VType::of(exp.prim) {
        VType::I32 | VType::Pred => {
            let xs = got
                .to_vec::<i32>()
                .map_err(|_| anyhow!("{}: output {idx} is not integer-typed", case.name))?;
            for (i, (&g, &w)) in xs.iter().zip(&exp.vals).enumerate() {
                if g as f64 != w {
                    bail!(
                        "{}: output {idx} [{i}] = {g}, expected {w} (exact integer match)",
                        case.name
                    );
                }
            }
        }
        VType::F32 => {
            let xs = got
                .to_vec::<f32>()
                .map_err(|_| anyhow!("{}: output {idx} is not float-typed", case.name))?;
            for (i, (&g, &w)) in xs.iter().zip(&exp.vals).enumerate() {
                let ok = if w.is_nan() {
                    (g as f64).is_nan()
                } else if w.is_infinite() {
                    g as f64 == w
                } else {
                    (g as f64 - w).abs() <= case.tol * (1.0 + w.abs())
                };
                if !ok {
                    bail!(
                        "{}: output {idx} [{i}] = {g}, expected {w} (tol {})",
                        case.name,
                        case.tol
                    );
                }
            }
        }
        vt @ (VType::F16 | VType::BF16) => {
            // The interpreter widens f16/bf16 outputs to f32 losslessly;
            // narrowing both sides back recovers the storage bits.
            let xs = got
                .to_vec::<f32>()
                .map_err(|_| anyhow!("{}: output {idx} is not float-typed", case.name))?;
            let is_bf = vt == VType::BF16;
            let narrow = |x: f32| if is_bf { f32_to_bf16_bits(x) } else { f32_to_f16_bits(x) };
            for (i, (&g, &w)) in xs.iter().zip(&exp.vals).enumerate() {
                let d = ulp_diff_16(narrow(g), narrow(w as f32), is_bf);
                if d > case.ulp {
                    bail!(
                        "{}: output {idx} [{i}] = {g}, expected {w} ({d} ULPs apart, \
                         allowed {})",
                        case.name,
                        case.ulp
                    );
                }
            }
        }
    }
    Ok(())
}

/// Execute one case end-to-end: parse the module, run the inputs,
/// compare every output. Returns the actual outputs so callers (the
/// `run-hlo` CLI) can print them.
pub fn run_case(case: &CorpusCase) -> Result<Vec<Literal>> {
    let interp = Interp::from_text(&case.text)
        .with_context(|| format!("{}: parsing module", case.name))?;
    if interp.num_params() != case.inputs.len() {
        bail!(
            "{}: module takes {} parameters, {} input directives given",
            case.name,
            interp.num_params(),
            case.inputs.len()
        );
    }
    let out = interp
        .run(&case.inputs)
        .with_context(|| format!("{}: executing", case.name))?;
    if !case.expects.is_empty() {
        if out.len() != case.expects.len() {
            bail!(
                "{}: module produced {} outputs, {} expect directives given",
                case.name,
                out.len(),
                case.expects.len()
            );
        }
        for (idx, (exp, got)) in case.expects.iter().zip(&out).enumerate() {
            check_output(case, idx, exp, got)?;
        }
    }
    Ok(out)
}

/// Load + run one corpus file from disk.
pub fn run_file(path: &std::path::Path) -> Result<Vec<Literal>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let name = path
        .file_name()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| path.display().to_string());
    let case = parse_case(&name, &text)?;
    run_case(&case)
}

/// Render actual outputs as ready-to-paste `// expect:` directives,
/// using the module's declared output types.
pub fn render_expects(text: &str, outputs: &[Literal]) -> Vec<String> {
    let shapes = Interp::from_text(text).map(|i| i.output_shapes()).unwrap_or_default();
    outputs
        .iter()
        .enumerate()
        .map(|(i, lit)| {
            let (prim, dims) = shapes
                .get(i)
                .cloned()
                .unwrap_or((Prim::F32, lit.dims.iter().map(|&d| d as usize).collect()));
            let ty = match prim {
                Prim::F32 => "f32",
                Prim::F16 => "f16",
                Prim::BF16 => "bf16",
                Prim::S32 => "s32",
                Prim::Pred => "pred",
            };
            let dims_s: Vec<String> = dims.iter().map(|d| d.to_string()).collect();
            let vals = match lit.to_vec::<f32>() {
                Ok(xs) => xs.iter().map(|x| format!("{x}")).collect::<Vec<_>>().join(" "),
                Err(_) => lit
                    .to_vec::<i32>()
                    .map(|xs| xs.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(" "))
                    .unwrap_or_default(),
            };
            format!("// expect: {ty}[{}] = {vals}", dims_s.join(","))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const CASE: &str = "\
// case: add two vectors
// input: f32[3] = 1 2 3
// input: f32[3] = 10 20 30
// expect: f32[3] = 11 22 33
HloModule add_vec
ENTRY main {
  a = f32[3] parameter(0)
  b = f32[3] parameter(1)
  ROOT r = f32[3] add(a, b)
}
";

    #[test]
    fn case_parses_runs_and_verifies() {
        let case = parse_case("add_vec.hlo", CASE).unwrap();
        assert_eq!(case.inputs.len(), 2);
        assert_eq!(case.expects.len(), 1);
        let out = run_case(&case).unwrap();
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![11.0, 22.0, 33.0]);
    }

    #[test]
    fn mismatch_reports_case_name_and_index() {
        let bad = CASE.replace("11 22 33", "11 22 34");
        let case = parse_case("add_vec.hlo", &bad).unwrap();
        let err = format!("{:#}", run_case(&case).unwrap_err());
        assert!(err.contains("add_vec.hlo"), "{err}");
        assert!(err.contains("[2]"), "{err}");
    }

    #[test]
    fn integer_outputs_require_exact_match() {
        let text = "\
// input: s32[2] = 3 4
// expect: s32[2] = 4 5
HloModule inc
ENTRY main {
  a = s32[2] parameter(0)
  c = s32[] constant(1)
  cb = s32[2] broadcast(c), dimensions={}
  ROOT r = s32[2] add(a, cb)
}
";
        let case = parse_case("inc.hlo", text).unwrap();
        run_case(&case).unwrap();
        let off = text.replace("= 4 5", "= 4 6");
        let case = parse_case("inc.hlo", &off).unwrap();
        assert!(run_case(&case).is_err());
    }

    #[test]
    fn f16_outputs_compare_in_ulps() {
        let text = "\
// input: f32[2] = 1.0 2.0
// expect: f16[2] = 1.0 2.0
HloModule cvt
ENTRY main {
  a = f32[2] parameter(0)
  ROOT r = f16[2] convert(a)
}
";
        let case = parse_case("cvt.hlo", text).unwrap();
        run_case(&case).unwrap();
        // One f16 ULP off (1.0009765625) passes at ulp:1, fails at ulp:0.
        let near = text.replace("expect: f16[2] = 1.0 2.0", "expect: f16[2] = 1.001 2.0");
        let case = parse_case("cvt.hlo", &near).unwrap();
        run_case(&case).unwrap();
        let strict = near.replace("// input", "// ulp: 0\n// input");
        let case = parse_case("cvt.hlo", &strict).unwrap();
        assert!(run_case(&case).is_err());
    }

    #[test]
    fn render_expects_roundtrips() {
        let case = parse_case("add_vec.hlo", CASE).unwrap();
        let out = run_case(&case).unwrap();
        let lines = render_expects(CASE, &out);
        assert_eq!(lines, vec!["// expect: f32[3] = 11 22 33"]);
    }
}
