//! Offline artifact generator (DESIGN.md §9).
//!
//! `python/compile/aot.py` lowers the L2 JAX models to HLO text with a
//! real XLA — but that toolchain isn't available in the airgapped build.
//! This module emits an equivalent set of artifacts *from Rust*: HLO text
//! modules (forward passes, hand-derived backward passes, and fused Adam
//! updates), initial parameter files, and `manifest.json`, all executable
//! by the in-tree interpreter ([`crate::runtime::interp`]).
//!
//! The generated models are smaller, documented variants of aot.py's
//! (the manifest carries every shape, so the Rust side adapts
//! automatically — see the feature contract in [`crate::runtime::gnn`]):
//!
//! * **GNN estimator** — one mean-aggregation graph-conv layer with a
//!   tanh residual + a 2-layer regression MLP over the masked-sum
//!   embedding (aot.py: 6 GAT layers). Same inputs `(flat, feats, adj,
//!   mask)`, same log-space MSE objective, same flat-vector Adam step.
//! * **Transformer LM → bigram LM** — next-token logits from a single
//!   `[vocab, vocab]` table via one-hot matmul. The distributed-training
//!   example still exercises the full loop: per-worker gradients, real
//!   ring AllReduce, fused Adam, held-out eval.
//!
//! Backward passes are hand-derived chain rules over dot/reduce/
//! elementwise ops; `tests/interp.rs` verifies them against finite
//! differences through the interpreter.

use crate::graph::DType;
use crate::runtime::gnn::{FEAT_DIM, MAX_NODES};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::path::Path;

/// Hidden width of the offline GNN variant.
pub const GNN_HIDDEN: usize = 16;
/// MLP hidden width of the offline GNN variant.
pub const GNN_MLP_HIDDEN: usize = 16;
/// Static batch of the GNN artifacts (queries arrive in small bursts).
pub const GNN_BATCH: usize = 8;
/// Adam learning rate baked into `gnn_train.hlo.txt`. Higher than
/// aot.py's 2e-3: the offline variant is trained for few steps in tests
/// and examples, and Adam's per-step movement is ≈ lr.
pub const GNN_LR: f64 = 2e-2;

/// Vocabulary of the mixed-precision embedding probe model
/// (`embed_grads.hlo.txt`).
pub const EMBED_VOCAB: usize = 16;
/// Embedding width of the probe model.
pub const EMBED_DIM: usize = 8;
/// Batch of the probe model.
pub const EMBED_BATCH: usize = 2;
/// Sequence length of the probe model (the `while` trip count).
pub const EMBED_SEQ: usize = 4;

/// Flat parameter length of the embedding probe model (the table).
pub fn embed_flat_len() -> usize {
    EMBED_VOCAB * EMBED_DIM
}

/// Bigram-LM vocabulary (the synthetic corpus is ASCII, < 128).
pub const LM_VOCAB: usize = 128;
/// Token window length per example.
pub const LM_SEQ: usize = 32;
/// Per-worker batch size.
pub const LM_BATCH: usize = 4;
/// Adam learning rate baked into `lm_adam.hlo.txt`.
pub const LM_LR: f64 = 2e-2;

/// Flat parameter-vector length of the GNN estimator:
/// `[W_in, b_in, W1, b1, Wm1, bm1, Wm2, bm2]` concatenated.
pub fn gnn_flat_len() -> usize {
    let (f, h, m) = (FEAT_DIM, GNN_HIDDEN, GNN_MLP_HIDDEN);
    f * h + h + h * h + h + h * m + m + m + 1
}

/// Flat parameter length of the bigram LM (the logit table).
pub fn lm_flat_len() -> usize {
    LM_VOCAB * LM_VOCAB
}

// ---------------------------------------------------------------------------
// Tiny HLO text emitter.
// ---------------------------------------------------------------------------

/// Instruction handle within an [`Emit`] builder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Id(usize);

/// Builds the ENTRY computation of an HLO text module, tracking the
/// shape of every emitted instruction so op helpers can compute result
/// types exactly the way the interpreter does.
struct Emit {
    lines: Vec<String>,
    shapes: Vec<(DType, Vec<usize>)>,
    need_sum: bool,
    need_max: bool,
}

fn dimlist(dims: &[usize]) -> String {
    let parts: Vec<String> = dims.iter().map(|d| d.to_string()).collect();
    format!("{{{}}}", parts.join(","))
}

impl Emit {
    fn new() -> Emit {
        Emit { lines: Vec::new(), shapes: Vec::new(), need_sum: false, need_max: false }
    }

    fn ty(dt: DType, dims: &[usize]) -> String {
        let base = match dt {
            DType::I32 => "s32",
            _ => "f32",
        };
        let parts: Vec<String> = dims.iter().map(|d| d.to_string()).collect();
        format!("{base}[{}]", parts.join(","))
    }

    fn nm(&self, id: Id) -> String {
        format!("v{}", id.0)
    }

    fn dims(&self, id: Id) -> &[usize] {
        &self.shapes[id.0].1
    }

    fn push_ty(&mut self, dt: DType, dims: Vec<usize>, tystr: String, expr: String) -> Id {
        let id = Id(self.shapes.len());
        self.lines.push(format!("  v{} = {tystr} {expr}", id.0));
        self.shapes.push((dt, dims));
        id
    }

    fn push(&mut self, dt: DType, dims: Vec<usize>, expr: String) -> Id {
        let ty = Self::ty(dt, &dims);
        self.push_ty(dt, dims, ty, expr)
    }

    fn param(&mut self, idx: usize, dt: DType, dims: &[usize]) -> Id {
        self.push(dt, dims.to_vec(), format!("parameter({idx})"))
    }

    /// Scalar f32 constant.
    fn cf(&mut self, v: f64) -> Id {
        self.push(DType::F32, vec![], format!("constant({:?})", v as f32))
    }

    /// Scalar constant broadcast to `dims`.
    fn splat(&mut self, v: f64, dims: &[usize]) -> Id {
        let c = self.cf(v);
        self.bcast(c, dims, &[])
    }

    /// Broadcast with an explicit operand→output dimension mapping.
    fn bcast(&mut self, x: Id, out_dims: &[usize], mapping: &[usize]) -> Id {
        let (dt, in_dims) = self.shapes[x.0].clone();
        assert_eq!(in_dims.len(), mapping.len(), "bcast mapping rank");
        for (k, &m) in mapping.iter().enumerate() {
            assert_eq!(out_dims[m], in_dims[k], "bcast extent");
        }
        let expr = format!("broadcast({}), dimensions={}", self.nm(x), dimlist(mapping));
        self.push(dt, out_dims.to_vec(), expr)
    }

    fn bin(&mut self, op: &str, a: Id, b: Id) -> Id {
        assert_eq!(self.dims(a), self.dims(b), "{op} operand shapes");
        let (dt, dims) = self.shapes[a.0].clone();
        let expr = format!("{op}({}, {})", self.nm(a), self.nm(b));
        self.push(dt, dims, expr)
    }

    fn un(&mut self, op: &str, a: Id) -> Id {
        let (dt, dims) = self.shapes[a.0].clone();
        let expr = format!("{op}({})", self.nm(a));
        self.push(dt, dims, expr)
    }

    /// General dot; result dims are `[batch (lhs order), lhs free, rhs
    /// free]` — must mirror the interpreter exactly.
    fn dot(&mut self, a: Id, b: Id, lb: &[usize], lc: &[usize], rb: &[usize], rc: &[usize]) -> Id {
        let ldims = self.dims(a).to_vec();
        let rdims = self.dims(b).to_vec();
        for (&x, &y) in lb.iter().zip(rb) {
            assert_eq!(ldims[x], rdims[y], "dot batch extent");
        }
        for (&x, &y) in lc.iter().zip(rc) {
            assert_eq!(ldims[x], rdims[y], "dot contraction extent");
        }
        let mut out: Vec<usize> = lb.iter().map(|&d| ldims[d]).collect();
        out.extend((0..ldims.len()).filter(|d| !lb.contains(d) && !lc.contains(d)).map(|d| ldims[d]));
        out.extend((0..rdims.len()).filter(|d| !rb.contains(d) && !rc.contains(d)).map(|d| rdims[d]));
        let expr = format!(
            "dot({}, {}), lhs_batch_dims={}, lhs_contracting_dims={}, rhs_batch_dims={}, rhs_contracting_dims={}",
            self.nm(a),
            self.nm(b),
            dimlist(lb),
            dimlist(lc),
            dimlist(rb),
            dimlist(rc)
        );
        self.push(DType::F32, out, expr)
    }

    fn reduce_sum(&mut self, a: Id, rdims: &[usize]) -> Id {
        self.need_sum = true;
        let init = self.cf(0.0);
        self.reduce(a, init, rdims, "sum_f32")
    }

    fn reduce_max(&mut self, a: Id, rdims: &[usize]) -> Id {
        self.need_max = true;
        let init = self.push(DType::F32, vec![], "constant(-inf)".to_string());
        self.reduce(a, init, rdims, "max_f32")
    }

    fn reduce(&mut self, a: Id, init: Id, rdims: &[usize], body: &str) -> Id {
        let in_dims = self.dims(a).to_vec();
        let out: Vec<usize> = (0..in_dims.len())
            .filter(|d| !rdims.contains(d))
            .map(|d| in_dims[d])
            .collect();
        let expr = format!(
            "reduce({}, {}), dimensions={}, to_apply={body}",
            self.nm(a),
            self.nm(init),
            dimlist(rdims)
        );
        self.push(DType::F32, out, expr)
    }

    fn reshape(&mut self, a: Id, dims: &[usize]) -> Id {
        let (dt, in_dims) = self.shapes[a.0].clone();
        assert_eq!(
            in_dims.iter().product::<usize>(),
            dims.iter().product::<usize>(),
            "reshape elems"
        );
        let expr = format!("reshape({})", self.nm(a));
        self.push(dt, dims.to_vec(), expr)
    }

    fn transpose(&mut self, a: Id, perm: &[usize]) -> Id {
        let (dt, in_dims) = self.shapes[a.0].clone();
        let dims: Vec<usize> = perm.iter().map(|&p| in_dims[p]).collect();
        let expr = format!("transpose({}), dimensions={}", self.nm(a), dimlist(perm));
        self.push(dt, dims, expr)
    }

    /// 1-D slice `[start:end]`.
    fn slice1(&mut self, a: Id, start: usize, end: usize) -> Id {
        let (dt, _) = self.shapes[a.0];
        let expr = format!("slice({}), slice={{[{start}:{end}]}}", self.nm(a));
        self.push(dt, vec![end - start], expr)
    }

    /// 2-D slice `[r0:r1, c0:c1]`.
    fn slice2(&mut self, a: Id, r: (usize, usize), c: (usize, usize)) -> Id {
        let (dt, _) = self.shapes[a.0];
        let expr = format!(
            "slice({}), slice={{[{}:{}], [{}:{}]}}",
            self.nm(a),
            r.0,
            r.1,
            c.0,
            c.1
        );
        self.push(dt, vec![r.1 - r.0, c.1 - c.0], expr)
    }

    fn concat1(&mut self, parts: &[Id], total: usize) -> Id {
        let names: Vec<String> = parts.iter().map(|&p| self.nm(p)).collect();
        let expr = format!("concatenate({}), dimensions={{0}}", names.join(", "));
        self.push(DType::F32, vec![total], expr)
    }

    /// Elementwise compare producing a `pred` tensor (stored as i32).
    fn cmp(&mut self, a: Id, b: Id, direction: &str) -> Id {
        assert_eq!(self.dims(a), self.dims(b), "compare shapes");
        let dims = self.dims(a).to_vec();
        let parts: Vec<String> = dims.iter().map(|d| d.to_string()).collect();
        let tystr = format!("pred[{}]", parts.join(","));
        let expr = format!("compare({}, {}), direction={direction}", self.nm(a), self.nm(b));
        self.push_ty(DType::I32, dims, tystr, expr)
    }

    fn convert_f32(&mut self, a: Id) -> Id {
        let dims = self.dims(a).to_vec();
        let expr = format!("convert({})", self.nm(a));
        self.push(DType::F32, dims, expr)
    }

    fn iota_i32(&mut self, dims: &[usize], d: usize) -> Id {
        self.push(DType::I32, dims.to_vec(), format!("iota(), iota_dimension={d}"))
    }

    /// Emit the ROOT tuple and assemble the final module text.
    fn finish(mut self, module_name: &str, outputs: &[Id]) -> String {
        let types: Vec<String> =
            outputs.iter().map(|&o| Self::ty(self.shapes[o.0].0, self.dims(o))).collect();
        let names: Vec<String> = outputs.iter().map(|&o| self.nm(o)).collect();
        let id = self.shapes.len();
        self.lines.push(format!(
            "  ROOT v{id} = ({}) tuple({})",
            types.join(", "),
            names.join(", ")
        ));
        let mut text = format!("HloModule {module_name}\n\n");
        if self.need_sum {
            text.push_str(
                "sum_f32 {\n  sa = f32[] parameter(0)\n  sb = f32[] parameter(1)\n  ROOT sr = f32[] add(sa, sb)\n}\n\n",
            );
        }
        if self.need_max {
            text.push_str(
                "max_f32 {\n  ma = f32[] parameter(0)\n  mb = f32[] parameter(1)\n  ROOT mr = f32[] maximum(ma, mb)\n}\n\n",
            );
        }
        text.push_str("ENTRY main {\n");
        for l in &self.lines {
            text.push_str(l);
            text.push('\n');
        }
        text.push_str("}\n");
        text
    }
}

// ---------------------------------------------------------------------------
// Shared building blocks.
// ---------------------------------------------------------------------------

/// Fused Adam update on a flat `[n]` vector; returns `(p', m', v')`.
/// `t` is the 1-based step count as an `f32[1]` input.
fn adam(e: &mut Emit, p: Id, g: Id, m: Id, v: Id, t: Id, lr: f64, n: usize) -> (Id, Id, Id) {
    let dims = [n];
    let ts = e.reshape(t, &[]);
    let b1 = e.cf(0.9);
    let b2 = e.cf(0.999);
    let b1t = e.bin("power", b1, ts);
    let b2t = e.bin("power", b2, ts);
    let one = e.cf(1.0);
    let mc = e.bin("subtract", one, b1t); // 1 - β1^t
    let vc = e.bin("subtract", one, b2t);

    let c_b1 = e.splat(0.9, &dims);
    let c_1mb1 = e.splat(0.1, &dims);
    let c_b2 = e.splat(0.999, &dims);
    let c_1mb2 = e.splat(0.001, &dims);
    let m_scaled = e.bin("multiply", c_b1, m);
    let g_scaled = e.bin("multiply", c_1mb1, g);
    let m2 = e.bin("add", m_scaled, g_scaled);
    let gg = e.bin("multiply", g, g);
    let v_scaled = e.bin("multiply", c_b2, v);
    let gg_scaled = e.bin("multiply", c_1mb2, gg);
    let v2 = e.bin("add", v_scaled, gg_scaled);

    let mcb = e.bcast(mc, &dims, &[]);
    let vcb = e.bcast(vc, &dims, &[]);
    let mhat = e.bin("divide", m2, mcb);
    let vhat = e.bin("divide", v2, vcb);
    let sv = e.un("sqrt", vhat);
    let eps = e.splat(1e-8, &dims);
    let denom = e.bin("add", sv, eps);
    let upd = e.bin("divide", mhat, denom);
    let lrb = e.splat(lr, &dims);
    let step = e.bin("multiply", lrb, upd);
    let p2 = e.bin("subtract", p, step);
    (p2, m2, v2)
}

/// Intermediate values of the GNN forward pass needed by the backward.
struct GnnFwd {
    w1: Id,
    wm1: Id,
    wm2: Id,
    t0: Id,
    t1: Id,
    agg: Id,
    g: Id,
    u1: Id,
    r1: Id,
    mask3: Id,
    /// Prediction in log space, `[B]`.
    yv: Id,
}

/// Emit the GNN forward pass: `yv = ln t̂` for each batched subgraph.
fn gnn_forward(e: &mut Emit, flat: Id, feats: Id, adj: Id, mask: Id) -> GnnFwd {
    let (f, h, m, b, n) = (FEAT_DIM, GNN_HIDDEN, GNN_MLP_HIDDEN, GNN_BATCH, MAX_NODES);
    // Unpack the flat parameter vector.
    let mut off = 0usize;
    let mut take = |e: &mut Emit, len: usize| -> Id {
        let s = e.slice1(flat, off, off + len);
        off += len;
        s
    };
    let w_in_flat = take(e, f * h);
    let w_in = e.reshape(w_in_flat, &[f, h]);
    let b_in = take(e, h);
    let w1_flat = take(e, h * h);
    let w1 = e.reshape(w1_flat, &[h, h]);
    let b1 = take(e, h);
    let wm1_flat = take(e, h * m);
    let wm1 = e.reshape(wm1_flat, &[h, m]);
    let bm1 = take(e, m);
    let wm2_flat = take(e, m);
    let wm2 = e.reshape(wm2_flat, &[m, 1]);
    let bm2 = take(e, 1);
    debug_assert_eq!(off, gnn_flat_len());

    let bnh = [b, n, h];
    // h0 = tanh(feats·W_in + b_in) * mask
    let z0a = e.dot(feats, w_in, &[], &[2], &[], &[0]);
    let b_in3 = e.bcast(b_in, &bnh, &[2]);
    let z0 = e.bin("add", z0a, b_in3);
    let t0 = e.un("tanh", z0);
    let mask3 = e.bcast(mask, &bnh, &[0, 1]);
    let h0 = e.bin("multiply", t0, mask3);
    // One graph-conv layer: agg = adj·h0 (message passing over data deps).
    let agg = e.dot(adj, h0, &[0], &[2], &[0], &[1]);
    let z1a = e.dot(agg, w1, &[], &[2], &[], &[0]);
    let b13 = e.bcast(b1, &bnh, &[2]);
    let z1 = e.bin("add", z1a, b13);
    let t1 = e.un("tanh", z1);
    // Residual + re-mask, then the masked-sum fused-op embedding (eq. (2)).
    let hs = e.bin("add", h0, t1);
    let hm = e.bin("multiply", hs, mask3);
    let g = e.reduce_sum(hm, &[1]); // [B, H]
    // Regression MLP: relu hidden + linear output in log space.
    let u1a = e.dot(g, wm1, &[], &[1], &[], &[0]);
    let bm1b = e.bcast(bm1, &[b, m], &[1]);
    let u1 = e.bin("add", u1a, bm1b);
    let zero_bm = e.splat(0.0, &[b, m]);
    let r1 = e.bin("maximum", u1, zero_bm);
    let ya = e.dot(r1, wm2, &[], &[1], &[], &[0]);
    let bm2b = e.bcast(bm2, &[b, 1], &[1]);
    let y2 = e.bin("add", ya, bm2b);
    let yv = e.reshape(y2, &[b]);
    GnnFwd { w1, wm1, wm2, t0, t1, agg, g, u1, r1, mask3, yv }
}

/// `gnn_infer.hlo.txt`: `(flat, feats, adj, mask) -> (t̂_ms[B],)`.
pub fn gnn_infer_hlo() -> String {
    let (f, b, n) = (FEAT_DIM, GNN_BATCH, MAX_NODES);
    let mut e = Emit::new();
    let flat = e.param(0, DType::F32, &[gnn_flat_len()]);
    let feats = e.param(1, DType::F32, &[b, n, f]);
    let adj = e.param(2, DType::F32, &[b, n, n]);
    let mask = e.param(3, DType::F32, &[b, n]);
    let fwd = gnn_forward(&mut e, flat, feats, adj, mask);
    let pred = e.un("exponential", fwd.yv);
    e.finish("gnn_infer_offline", &[pred])
}

/// `gnn_train.hlo.txt`: one fused forward+backward+Adam step.
/// `(flat, m, v, t, feats, adj, mask, target_ms) -> (loss, flat', m', v')`.
pub fn gnn_train_hlo() -> String {
    let (f, h, m_dim, b, n) = (FEAT_DIM, GNN_HIDDEN, GNN_MLP_HIDDEN, GNN_BATCH, MAX_NODES);
    let flat_len = gnn_flat_len();
    let mut e = Emit::new();
    let flat = e.param(0, DType::F32, &[flat_len]);
    let m_in = e.param(1, DType::F32, &[flat_len]);
    let v_in = e.param(2, DType::F32, &[flat_len]);
    let t_in = e.param(3, DType::F32, &[1]);
    let feats = e.param(4, DType::F32, &[b, n, f]);
    let adj = e.param(5, DType::F32, &[b, n, n]);
    let mask = e.param(6, DType::F32, &[b, n]);
    let targets = e.param(7, DType::F32, &[b]);

    let fwd = gnn_forward(&mut e, flat, feats, adj, mask);
    let bnh = [b, n, h];

    // loss = mean((yv - ln(max(target, 1e-5)))²) — MSE in log space, so
    // |Δln t| IS the relative error (the paper's metric).
    let floor = e.splat(1e-5, &[b]);
    let tmax = e.bin("maximum", targets, floor);
    let lt = e.un("log", tmax);
    let d = e.bin("subtract", fwd.yv, lt);
    let dd = e.bin("multiply", d, d);
    let loss_sum = e.reduce_sum(dd, &[0]);
    let inv_b = e.cf(1.0 / b as f64);
    let loss = e.bin("multiply", loss_sum, inv_b);

    // ---- hand-derived backward ------------------------------------------
    let two_over_b = e.splat(2.0 / b as f64, &[b]);
    let dyv = e.bin("multiply", d, two_over_b);
    let dy2 = e.reshape(dyv, &[b, 1]);
    let dbm2 = e.reduce_sum(dy2, &[0]); // [1]
    let dwm2 = e.dot(fwd.r1, dy2, &[], &[0], &[], &[0]); // [M,1]
    let dr1 = e.dot(dy2, fwd.wm2, &[], &[1], &[], &[1]); // [B,M]
    let zero_bm = e.splat(0.0, &[b, m_dim]);
    let pos = e.cmp(fwd.u1, zero_bm, "GT");
    let posf = e.convert_f32(pos);
    let du1 = e.bin("multiply", dr1, posf);
    let dbm1 = e.reduce_sum(du1, &[0]); // [M]
    let dwm1 = e.dot(fwd.g, du1, &[], &[0], &[], &[0]); // [H,M]
    let dg = e.dot(du1, fwd.wm1, &[], &[1], &[], &[1]); // [B,H]

    // g = Σ_nodes h: every node inherits dg; gradients flow through the
    // residual (h0 + t1) and both tanh gates.
    let dh = e.bcast(dg, &bnh, &[0, 2]);
    let dpre = e.bin("multiply", dh, fwd.mask3);
    let ones = e.splat(1.0, &bnh);
    let t1sq = e.bin("multiply", fwd.t1, fwd.t1);
    let gate1 = e.bin("subtract", ones, t1sq);
    let dz1 = e.bin("multiply", dpre, gate1);
    let db1 = e.reduce_sum(dz1, &[0, 1]); // [H]
    let dw1 = e.dot(fwd.agg, dz1, &[], &[0, 1], &[], &[0, 1]); // [H,H]
    let dagg = e.dot(dz1, fwd.w1, &[], &[2], &[], &[1]); // [B,N,H]
    let adj_t = e.transpose(adj, &[0, 2, 1]);
    let dh0_agg = e.dot(adj_t, dagg, &[0], &[2], &[0], &[1]); // [B,N,H]
    let dh0 = e.bin("add", dpre, dh0_agg);
    let dt0 = e.bin("multiply", dh0, fwd.mask3);
    let t0sq = e.bin("multiply", fwd.t0, fwd.t0);
    let gate0 = e.bin("subtract", ones, t0sq);
    let dz0 = e.bin("multiply", dt0, gate0);
    let db_in = e.reduce_sum(dz0, &[0, 1]); // [H]
    let dw_in = e.dot(feats, dz0, &[], &[0, 1], &[], &[0, 1]); // [F,H]

    let dw_in_f = e.reshape(dw_in, &[f * h]);
    let dw1_f = e.reshape(dw1, &[h * h]);
    let dwm1_f = e.reshape(dwm1, &[h * m_dim]);
    let dwm2_f = e.reshape(dwm2, &[m_dim]);
    let grad = e.concat1(
        &[dw_in_f, db_in, dw1_f, db1, dwm1_f, dbm1, dwm2_f, dbm2],
        flat_len,
    );

    let (p2, m2, v2) = adam(&mut e, flat, grad, m_in, v_in, t_in, GNN_LR, flat_len);
    e.finish("gnn_train_offline", &[loss, p2, m2, v2])
}

/// Shared bigram-LM forward: `(loss, X, softmax, T)` given flat + tokens.
struct LmFwd {
    loss: Id,
    x: Id,
    sm: Id,
    t_onehot: Id,
}

fn lm_forward(e: &mut Emit, flat: Id, tokens: Id) -> LmFwd {
    let (v, s, b) = (LM_VOCAB, LM_SEQ, LM_BATCH);
    let bsv = [b, s, v];
    let table = e.reshape(flat, &[v, v]);
    let inp = e.slice2(tokens, (0, b), (0, s)); // [B,S] i32
    let tgt = e.slice2(tokens, (0, b), (1, s + 1));
    // One-hot encode via iota/compare/convert (no gather needed).
    let io = e.iota_i32(&bsv, 2);
    let inp_b = e.bcast(inp, &bsv, &[0, 1]);
    let x_pred = e.cmp(io, inp_b, "EQ");
    let x = e.convert_f32(x_pred);
    let tgt_b = e.bcast(tgt, &bsv, &[0, 1]);
    let t_pred = e.cmp(io, tgt_b, "EQ");
    let t_onehot = e.convert_f32(t_pred);
    // logits = X·E, then a numerically stable log-softmax.
    let logits = e.dot(x, table, &[], &[2], &[], &[0]); // [B,S,V]
    let mx = e.reduce_max(logits, &[2]); // [B,S]
    let mxb = e.bcast(mx, &bsv, &[0, 1]);
    let ls = e.bin("subtract", logits, mxb);
    let ex = e.un("exponential", ls);
    let z = e.reduce_sum(ex, &[2]); // [B,S]
    let lz = e.un("log", z);
    let lzb = e.bcast(lz, &bsv, &[0, 1]);
    let lp = e.bin("subtract", ls, lzb);
    let picked = e.bin("multiply", t_onehot, lp);
    let ll = e.reduce_sum(picked, &[2]); // [B,S]
    let ll_sum = e.reduce_sum(ll, &[0, 1]); // []
    let neg_inv = e.cf(-1.0 / (b * s) as f64);
    let loss = e.bin("multiply", ll_sum, neg_inv);
    let zb = e.bcast(z, &bsv, &[0, 1]);
    let sm = e.bin("divide", ex, zb);
    LmFwd { loss, x, sm, t_onehot }
}

/// `lm_grads.hlo.txt`: `(flat, tokens[B,S+1] i32) -> (loss, grad[L])`.
pub fn lm_grads_hlo() -> String {
    let (v, s, b) = (LM_VOCAB, LM_SEQ, LM_BATCH);
    let mut e = Emit::new();
    let flat = e.param(0, DType::F32, &[lm_flat_len()]);
    let tokens = e.param(1, DType::I32, &[b, s + 1]);
    let fwd = lm_forward(&mut e, flat, tokens);
    // dlogits = (softmax − onehot(target)) / (B·S); dE = Xᵀ·dlogits.
    let diff = e.bin("subtract", fwd.sm, fwd.t_onehot);
    let scale = e.splat(1.0 / (b * s) as f64, &[b, s, v]);
    let dlogits = e.bin("multiply", diff, scale);
    let de = e.dot(fwd.x, dlogits, &[], &[0, 1], &[], &[0, 1]); // [V,V]
    let grad = e.reshape(de, &[lm_flat_len()]);
    e.finish("lm_grads_offline", &[fwd.loss, grad])
}

/// `lm_eval.hlo.txt`: `(flat, tokens) -> (loss,)`.
pub fn lm_eval_hlo() -> String {
    let (s, b) = (LM_SEQ, LM_BATCH);
    let mut e = Emit::new();
    let flat = e.param(0, DType::F32, &[lm_flat_len()]);
    let tokens = e.param(1, DType::I32, &[b, s + 1]);
    let fwd = lm_forward(&mut e, flat, tokens);
    e.finish("lm_eval_offline", &[fwd.loss])
}

/// `lm_adam.hlo.txt`: `(flat, grad, m, v, t) -> (flat', m', v')`.
pub fn lm_adam_hlo() -> String {
    let l = lm_flat_len();
    let mut e = Emit::new();
    let p = e.param(0, DType::F32, &[l]);
    let g = e.param(1, DType::F32, &[l]);
    let m = e.param(2, DType::F32, &[l]);
    let v = e.param(3, DType::F32, &[l]);
    let t = e.param(4, DType::F32, &[1]);
    let (p2, m2, v2) = adam(&mut e, p, g, m, v, t, LM_LR, l);
    e.finish("lm_adam_offline", &[p2, m2, v2])
}

// ---------------------------------------------------------------------------
// Interpreter-coverage artifacts: the new op families (gather/scatter,
// while/conditional, dynamic slicing, pad/reverse/clamp, f16/bf16) each
// appear in at least one generated module, so the artifact set itself
// pins the interpreter's coverage — not just the test corpus. These two
// modules need nested while/conditional bodies, which the ENTRY-only
// `Emit` builder doesn't model, so they are written as documented
// templates instead.
// ---------------------------------------------------------------------------

/// `embed_grads.hlo.txt` — a representative JAX-lowered-style training
/// step: `(flat[V·D], tokens[B,S] s32, targets[B]) -> (loss, grad[V·D])`.
///
/// Forward: the flat table reshapes to `E[V,D]`, passes through a
/// mixed-precision f16 cast pair (master weights stay f32), embeds the
/// tokens via general-dimension-numbers `gather`, pools over the
/// sequence with a real `while` loop (`dynamic-slice` per step), and
/// predicts `Σ_d pooled[b,d]` clamped into ±8. Loss is `½ Σ_b (pred −
/// target)²`.
///
/// Backward (hand-derived): `dpred = (pred − target) · clamp-gate`,
/// broadcast back over the pooled sum and the sequence, and accumulated
/// into the table with a scatter-add — the gradient of gather. Finite
/// differences validate it end-to-end in `tests/interp.rs`, including
/// through the while-loop call-frame path.
pub fn embed_grads_hlo() -> String {
    let (v, d, b, s) = (EMBED_VOCAB, EMBED_DIM, EMBED_BATCH, EMBED_SEQ);
    let l = embed_flat_len();
    let carried = format!("(s32[], f32[{b},{d}], f32[{b},{s},{d}])");
    format!(
        r#"HloModule embed_grads_offline

sum_f32 {{
  sa = f32[] parameter(0)
  sb = f32[] parameter(1)
  ROOT sr = f32[] add(sa, sb)
}}

pool_cond {{
  pct = {carried} parameter(0)
  pci = s32[] get-tuple-element(pct), index=0
  pcs = s32[] constant({s})
  ROOT pclt = pred[] compare(pci, pcs), direction=LT
}}

pool_body {{
  pbt = {carried} parameter(0)
  pbi = s32[] get-tuple-element(pbt), index=0
  pbacc = f32[{b},{d}] get-tuple-element(pbt), index=1
  pbemb = f32[{b},{s},{d}] get-tuple-element(pbt), index=2
  pbz = s32[] constant(0)
  pbsl = f32[{b},1,{d}] dynamic-slice(pbemb, pbz, pbi, pbz), dynamic_slice_sizes={{{b},1,{d}}}
  pbslr = f32[{b},{d}] reshape(pbsl)
  pbacc2 = f32[{b},{d}] add(pbacc, pbslr)
  pbone = s32[] constant(1)
  pbi2 = s32[] add(pbi, pbone)
  ROOT pbr = {carried} tuple(pbi2, pbacc2, pbemb)
}}

ENTRY main {{
  flat = f32[{l}] parameter(0)
  tokens = s32[{b},{s}] parameter(1)
  targets = f32[{b}] parameter(2)
  e = f32[{v},{d}] reshape(flat)
  eh = f16[{v},{d}] convert(e)
  ef = f32[{v},{d}] convert(eh)
  ixr = s32[{b},{s},1] reshape(tokens)
  emb = f32[{b},{s},{d}] gather(ef, ixr), offset_dims={{2}}, collapsed_slice_dims={{0}}, start_index_map={{0}}, index_vector_dim=2, slice_sizes={{1,{d}}}
  zero_i = s32[] constant(0)
  zero_f = f32[] constant(0)
  zacc = f32[{b},{d}] broadcast(zero_f), dimensions={{}}
  init = {carried} tuple(zero_i, zacc, emb)
  w = {carried} while(init), condition=pool_cond, body=pool_body
  pooled = f32[{b},{d}] get-tuple-element(w), index=1
  pred_raw = f32[{b}] reduce(pooled, zero_f), dimensions={{1}}, to_apply=sum_f32
  lo = f32[] constant(-8)
  hi = f32[] constant(8)
  predc = f32[{b}] clamp(lo, pred_raw, hi)
  diff = f32[{b}] subtract(predc, targets)
  dd = f32[{b}] multiply(diff, diff)
  loss_sum = f32[] reduce(dd, zero_f), dimensions={{0}}, to_apply=sum_f32
  half = f32[] constant(0.5)
  loss = f32[] multiply(loss_sum, half)
  lob = f32[{b}] broadcast(lo), dimensions={{}}
  hib = f32[{b}] broadcast(hi), dimensions={{}}
  in_lo = pred[{b}] compare(pred_raw, lob), direction=GT
  in_hi = pred[{b}] compare(pred_raw, hib), direction=LT
  in_band = pred[{b}] and(in_lo, in_hi)
  gate = f32[{b}] convert(in_band)
  dpred = f32[{b}] multiply(diff, gate)
  dpool = f32[{b},{d}] broadcast(dpred), dimensions={{0}}
  demb = f32[{b},{s},{d}] broadcast(dpool), dimensions={{0,2}}
  ztab = f32[{v},{d}] broadcast(zero_f), dimensions={{}}
  dtab = f32[{v},{d}] scatter(ztab, ixr, demb), update_window_dims={{2}}, inserted_window_dims={{0}}, scatter_dims_to_operand_dims={{0}}, index_vector_dim=2, to_apply=sum_f32
  grad = f32[{l}] reshape(dtab)
  ROOT out = (f32[], f32[{l}]) tuple(loss, grad)
}}
"#
    )
}

/// `probe_ops.hlo.txt` — one artifact touching the remaining new
/// families with deterministic arithmetic: `pad` (with interior),
/// `reverse`, predicated `conditional` with nested branch bodies,
/// `dynamic-update-slice`, and a bf16 storage round-trip.
/// `(v[4], sel pred) -> (pad[10], cond[4], dus[4], bf16_roundtrip[4])`.
pub fn probe_ops_hlo() -> String {
    r#"HloModule probe_ops_offline

neg_branch {
  nx = f32[4] parameter(0)
  ROOT nr = f32[4] negate(nx)
}

half_branch {
  hx = f32[4] parameter(0)
  hc = f32[] constant(0.5)
  hb = f32[4] broadcast(hc), dimensions={}
  ROOT hr = f32[4] multiply(hx, hb)
}

ENTRY main {
  v = f32[4] parameter(0)
  sel = pred[] parameter(1)
  z = f32[] constant(0)
  p = f32[10] pad(v, z), padding=1_2_1
  rv = f32[4] reverse(v), dimensions={0}
  c = f32[4] conditional(sel, v, rv), true_computation=neg_branch, false_computation=half_branch
  u = f32[2] slice(v), slice={[0:2]}
  two = s32[] constant(2)
  du = f32[4] dynamic-update-slice(rv, u, two)
  bh = bf16[4] convert(v)
  bf = f32[4] convert(bh)
  ROOT t = (f32[10], f32[4], f32[4], f32[4]) tuple(p, c, du, bf)
}
"#
    .to_string()
}

// ---------------------------------------------------------------------------
// Parameter initialization + manifest.
// ---------------------------------------------------------------------------

/// Deterministic initial GNN parameters (scaled-normal weights, zero
/// biases) in the flat layout `gnn_train.hlo.txt` slices.
pub fn gnn_init_params() -> Vec<f32> {
    let (f, h, m) = (FEAT_DIM, GNN_HIDDEN, GNN_MLP_HIDDEN);
    let mut rng = Rng::new(0x6E51_17);
    let mut out = Vec::with_capacity(gnn_flat_len());
    let mut matrix = |rng: &mut Rng, out: &mut Vec<f32>, rows: usize, cols: usize| {
        let scale = 1.0 / (rows as f64).sqrt();
        for _ in 0..rows * cols {
            out.push((rng.gen_normal() * scale) as f32);
        }
    };
    matrix(&mut rng, &mut out, f, h); // W_in
    out.resize(out.len() + h, 0.0); // b_in
    matrix(&mut rng, &mut out, h, h); // W1
    out.resize(out.len() + h, 0.0); // b1
    matrix(&mut rng, &mut out, h, m); // Wm1
    out.resize(out.len() + m, 0.0); // bm1
    matrix(&mut rng, &mut out, m, 1); // Wm2
    out.push(0.0); // bm2
    debug_assert_eq!(out.len(), gnn_flat_len());
    out
}

/// Initial LM parameters: a zero logit table (uniform predictions).
pub fn lm_init_params() -> Vec<f32> {
    vec![0.0; lm_flat_len()]
}

fn spec(shape: &[usize], dtype: &str) -> Json {
    Json::obj(vec![
        ("shape", Json::arr_usize(shape)),
        ("dtype", Json::Str(dtype.to_string())),
    ])
}

fn artifact(file: &str, inputs: Vec<Json>, outputs: Vec<Json>) -> Json {
    Json::obj(vec![
        ("file", Json::Str(file.to_string())),
        ("inputs", Json::Arr(inputs)),
        ("outputs", Json::Arr(outputs)),
    ])
}

/// The manifest describing every generated artifact — the same schema
/// `python/compile/aot.py` writes.
pub fn manifest_json() -> Json {
    let (f, h_b, n) = (FEAT_DIM, GNN_BATCH, MAX_NODES);
    let gp = gnn_flat_len();
    let lp = lm_flat_len();
    let (lv, ls, lb) = (LM_VOCAB, LM_SEQ, LM_BATCH);
    let artifacts = Json::obj(vec![
        (
            "gnn_infer",
            artifact(
                "gnn_infer.hlo.txt",
                vec![
                    spec(&[gp], "float32"),
                    spec(&[h_b, n, f], "float32"),
                    spec(&[h_b, n, n], "float32"),
                    spec(&[h_b, n], "float32"),
                ],
                vec![spec(&[h_b], "float32")],
            ),
        ),
        (
            "gnn_train",
            artifact(
                "gnn_train.hlo.txt",
                vec![
                    spec(&[gp], "float32"),
                    spec(&[gp], "float32"),
                    spec(&[gp], "float32"),
                    spec(&[1], "float32"),
                    spec(&[h_b, n, f], "float32"),
                    spec(&[h_b, n, n], "float32"),
                    spec(&[h_b, n], "float32"),
                    spec(&[h_b], "float32"),
                ],
                vec![
                    spec(&[], "float32"),
                    spec(&[gp], "float32"),
                    spec(&[gp], "float32"),
                    spec(&[gp], "float32"),
                ],
            ),
        ),
        (
            "lm_grads",
            artifact(
                "lm_grads.hlo.txt",
                vec![spec(&[lp], "float32"), spec(&[lb, ls + 1], "int32")],
                vec![spec(&[], "float32"), spec(&[lp], "float32")],
            ),
        ),
        (
            "lm_adam",
            artifact(
                "lm_adam.hlo.txt",
                vec![
                    spec(&[lp], "float32"),
                    spec(&[lp], "float32"),
                    spec(&[lp], "float32"),
                    spec(&[lp], "float32"),
                    spec(&[1], "float32"),
                ],
                vec![
                    spec(&[lp], "float32"),
                    spec(&[lp], "float32"),
                    spec(&[lp], "float32"),
                ],
            ),
        ),
        (
            "lm_eval",
            artifact(
                "lm_eval.hlo.txt",
                vec![spec(&[lp], "float32"), spec(&[lb, ls + 1], "int32")],
                vec![spec(&[], "float32")],
            ),
        ),
        (
            "embed_grads",
            artifact(
                "embed_grads.hlo.txt",
                vec![
                    spec(&[embed_flat_len()], "float32"),
                    spec(&[EMBED_BATCH, EMBED_SEQ], "int32"),
                    spec(&[EMBED_BATCH], "float32"),
                ],
                vec![spec(&[], "float32"), spec(&[embed_flat_len()], "float32")],
            ),
        ),
        (
            "probe_ops",
            artifact(
                "probe_ops.hlo.txt",
                vec![spec(&[4], "float32"), spec(&[], "pred")],
                vec![
                    spec(&[10], "float32"),
                    spec(&[4], "float32"),
                    spec(&[4], "float32"),
                    spec(&[4], "float32"),
                ],
            ),
        ),
    ]);
    Json::obj(vec![
        ("artifacts", artifacts),
        (
            "gnn",
            Json::obj(vec![
                ("params", Json::Str("gnn_params.f32".to_string())),
                ("flat_len", Json::Num(gp as f64)),
                ("batch", Json::Num(h_b as f64)),
                ("max_nodes", Json::Num(n as f64)),
                ("feat_dim", Json::Num(f as f64)),
                ("n_op_kinds", Json::Num(crate::runtime::gnn::N_OP_KINDS as f64)),
                ("lr", Json::Num(GNN_LR)),
            ]),
        ),
        (
            "lm",
            Json::obj(vec![
                ("params", Json::Str("lm_params.f32".to_string())),
                ("flat_len", Json::Num(lp as f64)),
                ("param_count", Json::Num(lp as f64)),
                ("vocab", Json::Num(lv as f64)),
                ("seq", Json::Num(ls as f64)),
                ("batch", Json::Num(lb as f64)),
                ("lr", Json::Num(LM_LR)),
            ]),
        ),
        ("generator", Json::Str("rust-offline (runtime::gen, DESIGN.md §9)".to_string())),
    ])
}

fn write_f32(path: &Path, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
}

/// Write the full artifact set into `dir` (HLO modules, params,
/// manifest). The manifest is written last — it is the sentinel
/// [`ensure_artifacts`] checks.
pub fn write_artifacts(dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating artifact dir {}", dir.display()))?;
    std::fs::write(dir.join("gnn_infer.hlo.txt"), gnn_infer_hlo())?;
    std::fs::write(dir.join("gnn_train.hlo.txt"), gnn_train_hlo())?;
    std::fs::write(dir.join("lm_grads.hlo.txt"), lm_grads_hlo())?;
    std::fs::write(dir.join("lm_eval.hlo.txt"), lm_eval_hlo())?;
    std::fs::write(dir.join("lm_adam.hlo.txt"), lm_adam_hlo())?;
    std::fs::write(dir.join("embed_grads.hlo.txt"), embed_grads_hlo())?;
    std::fs::write(dir.join("probe_ops.hlo.txt"), probe_ops_hlo())?;
    write_f32(&dir.join("gnn_params.f32"), &gnn_init_params())?;
    write_f32(&dir.join("lm_params.f32"), &lm_init_params())?;
    std::fs::write(dir.join("manifest.json"), manifest_json().to_string())?;
    Ok(())
}

/// Generate artifacts into `dir` unless a manifest already exists there
/// (a prebuilt set from `python/compile/aot.py` is never overwritten).
pub fn ensure_artifacts(dir: &Path) -> Result<()> {
    if dir.join("manifest.json").exists() {
        return Ok(());
    }
    write_artifacts(dir)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_layout_lengths() {
        // F=49, H=16, M=16: 784+16+256+16+256+16+16+1.
        assert_eq!(gnn_flat_len(), 1361);
        assert_eq!(gnn_init_params().len(), gnn_flat_len());
        assert_eq!(lm_init_params().len(), LM_VOCAB * LM_VOCAB);
    }

    #[test]
    fn generated_modules_parse() {
        for (name, text) in [
            ("gnn_infer", gnn_infer_hlo()),
            ("gnn_train", gnn_train_hlo()),
            ("lm_grads", lm_grads_hlo()),
            ("lm_eval", lm_eval_hlo()),
            ("lm_adam", lm_adam_hlo()),
            ("embed_grads", embed_grads_hlo()),
            ("probe_ops", probe_ops_hlo()),
        ] {
            let m = crate::graph::hlo_import::parse_module(&text)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(m.entry().is_ok(), "{name} has no ENTRY");
        }
    }

    #[test]
    fn manifest_schema_matches_runtime_expectations() {
        let m = manifest_json();
        assert_eq!(
            m.get("artifacts").get("gnn_train").get("file").as_str(),
            Some("gnn_train.hlo.txt")
        );
        assert_eq!(m.get("gnn").get("flat_len").as_usize(), Some(gnn_flat_len()));
        assert_eq!(m.get("lm").get("batch").as_usize(), Some(LM_BATCH));
    }
}
