//! The GNN Fused-Op Estimator, executed as an AOT-compiled HLO artifact.
//!
//! This is the paper's §4.3 cost model running on the Rust side of the
//! stack: [`GnnPredictor`] encodes fused-op subgraphs into the feature
//! tensors the estimator model expects (contract shared by
//! `python/compile/model.py` and `runtime::gen` — keep all three in
//! sync), executes `gnn_infer.hlo.txt` through the active runtime backend
//! (the in-tree interpreter by default, PJRT when a real binding exists —
//! DESIGN.md §9), and implements [`FusedOpEstimator`] so the search can
//! use it transparently. Training (`gnn_train.hlo.txt`) runs from Rust
//! too — see [`GnnTrainer`].

use super::{lit_f32, lit_scalar, lit_to_f64s, Executable, Runtime};
use crate::estimator::{AnalyticalFused, FusedOpEstimator};
use crate::graph::FusedGroup;
use crate::profiler::FusedSample;
use anyhow::{anyhow, Result};
use std::sync::Mutex;

/// Feature-encoding constants — the contract with python/compile/model.py.
pub const N_OP_KINDS: usize = 40;
pub const N_SCALAR_FEATS: usize = 9;
pub const FEAT_DIM: usize = N_OP_KINDS + N_SCALAR_FEATS;
pub const MAX_NODES: usize = 64;

/// Encode one fused group (plus the fused node's boundary traffic) into
/// (features, adjacency, mask) rows.
/// Returns false (and encodes nothing) when the group exceeds MAX_NODES.
pub fn encode_group(
    group: &FusedGroup,
    node_bytes_in: f64,
    node_bytes_out: f64,
    feats: &mut [f32], // [MAX_NODES * FEAT_DIM]
    adj: &mut [f32],   // [MAX_NODES * MAX_NODES]
    mask: &mut [f32],  // [MAX_NODES]
) -> bool {
    let n = group.ops.len();
    if n == 0 || n > MAX_NODES {
        return false;
    }
    let bin_feat = (0.2 * (node_bytes_in.max(0.0) / 1e6 + 1e-4).ln()) as f32;
    let bout_feat = (0.2 * (node_bytes_out.max(0.0) / 1e6 + 1e-4).ln()) as f32;
    let mut has_out = vec![false; n];
    let mut has_in = vec![false; n];
    for &(a, b) in &group.edges {
        has_out[a] = true;
        has_in[b] = true;
    }
    for (i, op) in group.ops.iter().enumerate() {
        let row = &mut feats[i * FEAT_DIM..(i + 1) * FEAT_DIM];
        let k = op.kind.feature_index().min(N_OP_KINDS - 1);
        row[k] = 1.0;
        // Scaled log-space features — contract with model.py.
        row[N_OP_KINDS] = (0.2 * (op.time_ms.max(0.0) + 1e-5).ln()) as f32;
        row[N_OP_KINDS + 1] = (0.2 * (op.bytes_in.max(0.0) / 1e6 + 1e-4).ln()) as f32;
        row[N_OP_KINDS + 2] = (0.2 * (op.bytes_out.max(0.0) / 1e6 + 1e-4).ln()) as f32;
        row[N_OP_KINDS + 3] = (0.2 * (op.flops.max(0.0) / 1e9 + 1e-5).ln()) as f32;
        row[N_OP_KINDS + 4] = if op.duplicated { 1.0 } else { 0.0 };
        row[N_OP_KINDS + 5] = bin_feat;
        row[N_OP_KINDS + 6] = bout_feat;
        row[N_OP_KINDS + 7] = if has_out[i] { 1.0 } else { 0.0 };
        row[N_OP_KINDS + 8] = if has_in[i] { 1.0 } else { 0.0 };
        mask[i] = 1.0;
        adj[i * MAX_NODES + i] = 1.0; // self loop
    }
    for &(a, b) in &group.edges {
        // Undirected message passing over the data dependencies.
        adj[a * MAX_NODES + b] = 1.0;
        adj[b * MAX_NODES + a] = 1.0;
    }
    true
}

/// Inference-side predictor implementing [`FusedOpEstimator`].
pub struct GnnPredictor {
    exec: Executable,
    batch: usize,
    params: Vec<f32>,
    /// Fallback for groups larger than MAX_NODES.
    fallback: AnalyticalFused,
    /// (queries, batched_calls) counters for §Perf. Mutex (not RefCell)
    /// so the predictor stays `Sync` — the search evaluates candidates on
    /// worker threads that share one estimator.
    stats: Mutex<(u64, u64)>,
}

impl GnnPredictor {
    /// Load the estimator with the initial (untrained) parameters from the
    /// manifest.
    pub fn load(rt: &Runtime, fallback: AnalyticalFused) -> Result<GnnPredictor> {
        let params_file = rt
            .manifest
            .raw
            .get("gnn")
            .get("params")
            .as_str()
            .ok_or_else(|| anyhow!("manifest missing gnn.params"))?
            .to_string();
        let params = rt.manifest.load_f32(&params_file)?;
        Self::with_params(rt, params, fallback)
    }

    /// Load with explicit (e.g. trained) flat parameters.
    pub fn with_params(
        rt: &Runtime,
        params: Vec<f32>,
        fallback: AnalyticalFused,
    ) -> Result<GnnPredictor> {
        let exec = rt.load("gnn_infer")?;
        let batch = exec.spec.inputs[1].shape[0];
        let expected = exec.spec.inputs[0].elems();
        if params.len() != expected {
            return Err(anyhow!("gnn params len {} != {}", params.len(), expected));
        }
        Ok(GnnPredictor { exec, batch, params, fallback, stats: Mutex::new((0, 0)) })
    }

    pub fn stats(&self) -> (u64, u64) {
        *self.stats.lock().unwrap()
    }

    /// Predict times (ms) for up to `batch` groups in one artifact call.
    /// Oversized groups get the analytical fallback.
    pub fn predict(&self, items: &[(FusedGroup, f64, f64)]) -> Result<Vec<f64>> {
        let mut out = vec![0.0f64; items.len()];
        let mut chunk_idx: Vec<usize> = Vec::new();
        let mut start = 0;
        while start < items.len() {
            let end = (start + self.batch).min(items.len());
            chunk_idx.clear();
            let mut feats = vec![0.0f32; self.batch * MAX_NODES * FEAT_DIM];
            let mut adj = vec![0.0f32; self.batch * MAX_NODES * MAX_NODES];
            let mut mask = vec![0.0f32; self.batch * MAX_NODES];
            for (slot, i) in (start..end).enumerate() {
                let (group, bin, bout) = &items[i];
                let ok = encode_group(
                    group,
                    *bin,
                    *bout,
                    &mut feats[slot * MAX_NODES * FEAT_DIM..(slot + 1) * MAX_NODES * FEAT_DIM],
                    &mut adj[slot * MAX_NODES * MAX_NODES..(slot + 1) * MAX_NODES * MAX_NODES],
                    &mut mask[slot * MAX_NODES..(slot + 1) * MAX_NODES],
                );
                if ok {
                    chunk_idx.push(i);
                } else {
                    out[i] = self.fallback.estimate_ms(group, *bin, *bout);
                }
            }
            if !chunk_idx.is_empty() {
                let res = self.exec.run(&[
                    lit_f32(&self.params, &[self.params.len()])?,
                    lit_f32(&feats, &[self.batch, MAX_NODES, FEAT_DIM])?,
                    lit_f32(&adj, &[self.batch, MAX_NODES, MAX_NODES])?,
                    lit_f32(&mask, &[self.batch, MAX_NODES])?,
                ])?;
                let preds = lit_to_f64s(&res[0])?;
                for (slot, i) in (start..end).enumerate() {
                    if chunk_idx.contains(&i) {
                        out[i] = preds[slot].max(1e-4);
                    }
                }
                let mut st = self.stats.lock().unwrap();
                st.1 += 1;
            }
            let mut st = self.stats.lock().unwrap();
            st.0 += (end - start) as u64;
            start = end;
        }
        Ok(out)
    }
}

impl FusedOpEstimator for GnnPredictor {
    fn estimate_ms(&self, group: &FusedGroup, bytes_in: f64, bytes_out: f64) -> f64 {
        self.predict(&[(group.clone(), bytes_in, bytes_out)])
            .map(|v| v[0])
            .unwrap_or_else(|_| self.fallback.estimate_ms(group, bytes_in, bytes_out))
    }

    fn estimate_batch(&self, items: &[(FusedGroup, f64, f64)]) -> Vec<f64> {
        self.predict(items).unwrap_or_else(|_| {
            items
                .iter()
                .map(|(g, bi, bo)| self.fallback.estimate_ms(g, *bi, *bo))
                .collect()
        })
    }

    fn name(&self) -> &'static str {
        "gnn"
    }
}

/// Training loop driver over the `gnn_train` artifact.
pub struct GnnTrainer {
    exec: Executable,
    pub batch: usize,
    pub params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    step: f32,
}

impl GnnTrainer {
    pub fn new(rt: &Runtime) -> Result<GnnTrainer> {
        let exec = rt.load("gnn_train")?;
        let batch = exec.spec.inputs[4].shape[0];
        let params_file = rt
            .manifest
            .raw
            .get("gnn")
            .get("params")
            .as_str()
            .ok_or_else(|| anyhow!("manifest missing gnn.params"))?
            .to_string();
        let params = rt.manifest.load_f32(&params_file)?;
        let n = params.len();
        Ok(GnnTrainer { exec, batch, params, m: vec![0.0; n], v: vec![0.0; n], step: 0.0 })
    }

    /// One SGD step over up to `batch` samples (padded with repeats).
    /// Returns the training loss.
    pub fn step(&mut self, samples: &[&FusedSample]) -> Result<f64> {
        assert!(!samples.is_empty());
        let mut feats = vec![0.0f32; self.batch * MAX_NODES * FEAT_DIM];
        let mut adj = vec![0.0f32; self.batch * MAX_NODES * MAX_NODES];
        let mut mask = vec![0.0f32; self.batch * MAX_NODES];
        let mut targets = vec![0.0f32; self.batch];
        for slot in 0..self.batch {
            let s = samples[slot % samples.len()];
            encode_group(
                &s.group,
                s.bytes_in,
                s.bytes_out,
                &mut feats[slot * MAX_NODES * FEAT_DIM..(slot + 1) * MAX_NODES * FEAT_DIM],
                &mut adj[slot * MAX_NODES * MAX_NODES..(slot + 1) * MAX_NODES * MAX_NODES],
                &mut mask[slot * MAX_NODES..(slot + 1) * MAX_NODES],
            );
            targets[slot] = s.label_ms as f32;
        }
        self.step += 1.0;
        let n = self.params.len();
        let res = self.exec.run(&[
            lit_f32(&self.params, &[n])?,
            lit_f32(&self.m, &[n])?,
            lit_f32(&self.v, &[n])?,
            lit_f32(&[self.step], &[1])?,
            lit_f32(&feats, &[self.batch, MAX_NODES, FEAT_DIM])?,
            lit_f32(&adj, &[self.batch, MAX_NODES, MAX_NODES])?,
            lit_f32(&mask, &[self.batch, MAX_NODES])?,
            lit_f32(&targets, &[self.batch])?,
        ])?;
        let loss = lit_scalar(&res[0])? as f64;
        self.params = super::lit_to_f32(&res[1])?;
        self.m = super::lit_to_f32(&res[2])?;
        self.v = super::lit_to_f32(&res[3])?;
        Ok(loss)
    }

    /// Train for `epochs` passes over `samples` with per-epoch shuffling
    /// (deterministic). Returns per-step losses.
    pub fn train(&mut self, samples: &[FusedSample], epochs: usize) -> Result<Vec<f64>> {
        let mut losses = Vec::new();
        let mut rng = crate::util::rng::Rng::new(0x6A77);
        let mut order: Vec<usize> = (0..samples.len()).collect();
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            let mut i = 0;
            while i < order.len() {
                let end = (i + self.batch).min(order.len());
                let batch: Vec<&FusedSample> =
                    order[i..end].iter().map(|&j| &samples[j]).collect();
                losses.push(self.step(&batch)?);
                i = end;
            }
        }
        Ok(losses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpKind, OrigOp};

    fn group(n: usize) -> FusedGroup {
        FusedGroup {
            ops: (0..n)
                .map(|i| OrigOp {
                    orig_id: i,
                    kind: OpKind::Mul,
                    flops: 100.0,
                    bytes_in: 64.0,
                    bytes_out: 64.0,
                    time_ms: 0.01,
                    duplicated: i % 2 == 1,
                })
                .collect(),
            edges: (1..n).map(|i| (i - 1, i)).collect(),
        }
    }

    #[test]
    fn encode_basic() {
        let g = group(3);
        let mut feats = vec![0.0; MAX_NODES * FEAT_DIM];
        let mut adj = vec![0.0; MAX_NODES * MAX_NODES];
        let mut mask = vec![0.0; MAX_NODES];
        assert!(encode_group(&g, 4e5, 4e5, &mut feats, &mut adj, &mut mask));
        // 3 live nodes.
        assert_eq!(mask.iter().filter(|&&m| m == 1.0).count(), 3);
        // One-hot set for Mul.
        let k = OpKind::Mul.feature_index();
        assert_eq!(feats[k], 1.0);
        // Self loops + undirected edges.
        assert_eq!(adj[0], 1.0);
        assert_eq!(adj[1], 1.0); // 0->1
        assert_eq!(adj[MAX_NODES], 1.0); // 1->0 (mirrored)
        // dup flag on second node.
        assert_eq!(feats[FEAT_DIM + N_OP_KINDS + 4], 1.0);
    }

    #[test]
    fn encode_rejects_oversize() {
        let g = group(MAX_NODES + 1);
        let mut feats = vec![0.0; MAX_NODES * FEAT_DIM];
        let mut adj = vec![0.0; MAX_NODES * MAX_NODES];
        let mut mask = vec![0.0; MAX_NODES];
        assert!(!encode_group(&g, 4e5, 4e5, &mut feats, &mut adj, &mut mask));
    }
}
