//! Distributed LM training driven from Rust — the enactment path the
//! end-to-end example exercises.
//!
//! Synchronous data parallelism over `world` worker threads:
//!
//! 1. each worker executes `lm_grads.hlo.txt` (loss + flat gradient) on
//!    its own executable — the in-tree HLO interpreter by default, a
//!    PJRT CPU client when a real binding is present (DESIGN.md §9) —
//!    over its own shard of the token stream;
//! 2. gradients are averaged with the **real** ring AllReduce
//!    ([`crate::collective`]) — reduce-scatter + all-gather over the
//!    worker ring, exactly the collective the paper's clusters run;
//! 3. every worker applies the fused-Adam artifact (`lm_adam.hlo.txt`)
//!    to the averaged gradient, keeping replicas bit-identical.
//!
//! Numerics are real (the loss curve in EXPERIMENTS.md comes from here);
//! *time* is modelled by the network/device substrates per DESIGN.md §2.

use super::{lit_f32, lit_i32, lit_scalar, lit_to_f32, Runtime};
use crate::collective::{make_ring, RingPeer};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::{Arc, Barrier, Mutex};

/// Training-run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub artifacts: PathBuf,
    pub world: usize,
    pub steps: usize,
    /// Evaluate held-out loss every `eval_every` steps (0 = never).
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifacts: super::Manifest::default_dir(),
            world: 4,
            steps: 100,
            eval_every: 25,
            seed: 0x7EA1,
        }
    }
}

/// Per-step record of the run.
#[derive(Debug, Clone)]
pub struct StepLog {
    pub step: usize,
    /// Mean training loss across workers.
    pub loss: f64,
    /// Held-out loss (only on eval steps).
    pub eval_loss: Option<f64>,
}

/// Result of a distributed training run.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub log: Vec<StepLog>,
    pub world: usize,
    pub param_count: usize,
    pub wall_seconds: f64,
}

/// Synthetic byte-level corpus: a mixture of short repeated "words"
/// separated by spaces — structured enough that the LM's loss falls well
/// below the uniform baseline, with per-position entropy from the word
/// choice. Deterministic per seed.
pub struct Corpus {
    data: Vec<i32>,
}

impl Corpus {
    pub fn synthetic(len: usize, seed: u64) -> Corpus {
        const WORDS: [&[u8]; 8] = [
            b"the", b"quick", b"brown", b"fox", b"jumps", b"over", b"lazy", b"dog",
        ];
        let mut rng = Rng::new(seed);
        let mut data = Vec::with_capacity(len + 16);
        while data.len() < len {
            let w = WORDS[rng.gen_range(WORDS.len())];
            for &b in w {
                data.push(b as i32);
            }
            data.push(b' ' as i32);
        }
        data.truncate(len);
        Corpus { data }
    }

    /// A [batch, seq+1] window for `worker` at `step` (disjoint shards).
    pub fn batch(&self, batch: usize, seq: usize, worker: usize, world: usize, step: usize) -> Vec<i32> {
        let win = seq + 1;
        let mut out = Vec::with_capacity(batch * win);
        let shard = self.data.len() / world.max(1);
        let base = worker * shard;
        for b in 0..batch {
            let off = base + ((step * batch + b) * 17) % shard.saturating_sub(win).max(1);
            for i in 0..win {
                out.push(self.data[(off + i) % self.data.len()]);
            }
        }
        out
    }
}

/// Run synchronous data-parallel training. Returns the loss log.
pub fn train_distributed(cfg: &TrainConfig) -> Result<TrainResult> {
    let start = std::time::Instant::now();
    // On the interpreter backend an empty artifact dir is bootstrapped
    // in-process (DESIGN.md §9) before the manifest is read.
    if super::BackendKind::from_env() == super::BackendKind::Interp {
        super::gen::ensure_artifacts(&cfg.artifacts)?;
    }
    // Read static config from the manifest once.
    let manifest = super::Manifest::load(&cfg.artifacts)?;
    let lm = manifest.raw.get("lm");
    let (batch, seq, flat_len) = (
        lm.get("batch").as_usize().ok_or_else(|| anyhow!("manifest lm.batch"))?,
        lm.get("seq").as_usize().ok_or_else(|| anyhow!("manifest lm.seq"))?,
        lm.get("flat_len").as_usize().ok_or_else(|| anyhow!("manifest lm.flat_len"))?,
    );
    let params0 = manifest.load_f32(
        lm.get("params").as_str().ok_or_else(|| anyhow!("manifest lm.params"))?,
    )?;
    let corpus = Arc::new(Corpus::synthetic(1 << 18, cfg.seed));
    let eval_tokens: Arc<Vec<i32>> = {
        // Held-out window from the tail of the stream.
        let held = Corpus::synthetic(batch * (seq + 1) * 2, cfg.seed ^ 0xE7A1);
        Arc::new(held.batch(batch, seq, 0, 1, 0))
    };

    let world = cfg.world.max(1);
    let peers = make_ring(world);
    let barrier = Arc::new(Barrier::new(world));
    let log = Arc::new(Mutex::new(Vec::<StepLog>::new()));
    let cfg = cfg.clone();

    let mut handles = Vec::new();
    for peer in peers {
        let corpus = corpus.clone();
        let eval_tokens = eval_tokens.clone();
        let barrier = barrier.clone();
        let log = log.clone();
        let params0 = params0.clone();
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || -> Result<()> {
            worker_loop(
                peer, &cfg, batch, seq, flat_len, params0, &corpus, &eval_tokens, &barrier, &log,
            )
        }));
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("worker panicked"))??;
    }

    let log = Arc::try_unwrap(log)
        .map_err(|_| anyhow!("log still shared"))?
        .into_inner()
        .unwrap();
    Ok(TrainResult {
        log,
        world,
        param_count: flat_len,
        wall_seconds: start.elapsed().as_secs_f64(),
    })
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    peer: RingPeer,
    cfg: &TrainConfig,
    batch: usize,
    seq: usize,
    flat_len: usize,
    params0: Vec<f32>,
    corpus: &Corpus,
    eval_tokens: &[i32],
    barrier: &Barrier,
    log: &Mutex<Vec<StepLog>>,
) -> Result<()> {
    // Each worker owns a PJRT client + executables (thread confinement).
    let rt = Runtime::new(&cfg.artifacts)?;
    let grads_exe = rt.load("lm_grads")?;
    let adam_exe = rt.load("lm_adam")?;
    let eval_exe = rt.load("lm_eval")?;

    let mut params = params0;
    let mut m = vec![0.0f32; flat_len];
    let mut v = vec![0.0f32; flat_len];

    for step in 1..=cfg.steps {
        let tokens = corpus.batch(batch, seq, peer.rank, peer.world, step);
        let out = grads_exe.run(&[
            lit_f32(&params, &[flat_len])?,
            lit_i32(&tokens, &[batch, seq + 1])?,
        ])?;
        let loss = lit_scalar(&out[0])? as f64;
        let mut grad = lit_to_f32(&out[1])?;

        // The real collective: average gradients across the ring.
        peer.allreduce_mean(&mut grad);
        // Mean loss across workers for logging (reuse the ring).
        let mut loss_buf = vec![loss as f32];
        peer.allreduce_mean(&mut loss_buf);

        let out = adam_exe.run(&[
            lit_f32(&params, &[flat_len])?,
            lit_f32(&grad, &[flat_len])?,
            lit_f32(&m, &[flat_len])?,
            lit_f32(&v, &[flat_len])?,
            lit_f32(&[step as f32], &[1])?,
        ])?;
        params = lit_to_f32(&out[0])?;
        m = lit_to_f32(&out[1])?;
        v = lit_to_f32(&out[2])?;

        let eval_loss = if cfg.eval_every > 0 && step % cfg.eval_every == 0 && peer.rank == 0 {
            let out = eval_exe.run(&[
                lit_f32(&params, &[flat_len])?,
                lit_i32(eval_tokens, &[batch, seq + 1])?,
            ])?;
            Some(lit_scalar(&out[0])? as f64)
        } else {
            None
        };

        if peer.rank == 0 {
            log.lock().unwrap().push(StepLog {
                step,
                loss: loss_buf[0] as f64,
                eval_loss,
            });
        }
        barrier.wait();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic_and_tokenish() {
        let a = Corpus::synthetic(1000, 1);
        let b = Corpus::synthetic(1000, 1);
        assert_eq!(a.data, b.data);
        assert!(a.data.iter().all(|&t| (0..256).contains(&t)));
        // Contains spaces (word separators).
        assert!(a.data.iter().any(|&t| t == b' ' as i32));
    }

    #[test]
    fn batches_disjoint_across_workers() {
        let c = Corpus::synthetic(10_000, 2);
        let b0 = c.batch(4, 16, 0, 4, 0);
        let b1 = c.batch(4, 16, 1, 4, 0);
        assert_eq!(b0.len(), 4 * 17);
        assert_ne!(b0, b1);
    }
}
