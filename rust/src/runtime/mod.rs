//! PJRT runtime: load the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and execute them from Rust — Python is never on
//! this path.
//!
//! The interchange format is HLO *text* (see aot.py's module docs for why
//! not serialized protos). `manifest.json` carries the static input/output
//! shapes of every artifact plus the initial flat parameter vectors.

pub mod gnn;
pub mod trainer;

use crate::util::json::Json;
// The real `xla` crate is unavailable offline; an API-compatible typed
// stub keeps this module compiling and makes the backend-missing failure
// mode explicit at `Runtime::new` (see rust/src/xla_stub.rs).
use crate::xla_stub as xla;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Shape+dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Option<TensorSpec> {
        Some(TensorSpec {
            shape: j
                .get("shape")
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Option<Vec<_>>>()?,
            dtype: j.get("dtype").as_str()?.to_string(),
        })
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact's metadata from the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub raw: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| {
                format!("reading {}/manifest.json (run `make artifacts`)", dir.display())
            })?;
        let raw = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        Ok(Manifest { dir: dir.to_path_buf(), raw })
    }

    /// Default artifacts directory: `$DISCO_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("DISCO_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn artifact(&self, name: &str) -> Result<ArtifactSpec> {
        let a = self.raw.get("artifacts").get(name);
        if *a == Json::Null {
            return Err(anyhow!("artifact '{name}' not in manifest"));
        }
        let parse = |key: &str| -> Result<Vec<TensorSpec>> {
            a.get(key)
                .as_arr()
                .ok_or_else(|| anyhow!("bad manifest"))?
                .iter()
                .map(|j| TensorSpec::from_json(j).ok_or_else(|| anyhow!("bad spec")))
                .collect()
        };
        Ok(ArtifactSpec {
            file: a
                .get("file")
                .as_str()
                .ok_or_else(|| anyhow!("bad manifest"))?
                .to_string(),
            inputs: parse("inputs")?,
            outputs: parse("outputs")?,
        })
    }

    /// Load a raw little-endian f32 parameter file referenced by the
    /// manifest (e.g. `lm_params.f32`).
    pub fn load_f32(&self, file: &str) -> Result<Vec<f32>> {
        let bytes =
            std::fs::read(self.dir.join(file)).with_context(|| format!("reading {file}"))?;
        if bytes.len() % 4 != 0 {
            return Err(anyhow!("{file}: length not a multiple of 4"));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// A compiled artifact ready to execute on the PJRT CPU client.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// Shared PJRT CPU client + manifest.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
}

impl Runtime {
    pub fn new(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, manifest: Manifest::load(dir)? })
    }

    /// Load + compile one artifact.
    pub fn load(&self, name: &str) -> Result<Executable> {
        let spec = self.manifest.artifact(name)?;
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        Ok(Executable { spec, exe })
    }
}

impl Executable {
    /// Execute with the given inputs; returns the flattened output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "artifact {} expects {} inputs, got {}",
                self.spec.file,
                self.spec.inputs.len(),
                inputs.len()
            ));
        }
        let out = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.spec.file))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True: the result is always a tuple.
        lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))
    }
}

/// Build an f32 literal of the given shape from a slice.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(anyhow!("lit_f32: {} elems for shape {:?}", data.len(), shape));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal of the given shape from a slice.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(anyhow!("lit_i32: {} elems for shape {:?}", data.len(), shape));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Extract an f32 vector from a literal.
pub fn lit_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
}

/// Extract an f64 vector from an f32 literal.
pub fn lit_to_f64s(lit: &xla::Literal) -> Result<Vec<f64>> {
    Ok(lit_to_f32(lit)?.into_iter().map(|x| x as f64).collect())
}

/// Extract the single f32 scalar of a literal.
pub fn lit_scalar(lit: &xla::Literal) -> Result<f32> {
    let v = lit_to_f32(lit)?;
    v.first().copied().ok_or_else(|| anyhow!("empty literal"))
}
