//! Runtime: load AOT-compiled HLO artifacts and execute them from Rust —
//! Python is never on this path (DESIGN.md §9).
//!
//! The interchange format is HLO *text* (see `python/compile/aot.py`'s
//! module docs for why not serialized protos). `manifest.json` carries the
//! static input/output shapes of every artifact plus the initial flat
//! parameter vectors.
//!
//! Two execution backends sit behind one [`Runtime`] API:
//!
//! * [`BackendKind::Interp`] (default) — the in-tree HLO interpreter
//!   ([`interp`]). Fully offline: when the artifact directory is empty it
//!   is bootstrapped by the generator ([`gen`]), so `Runtime::new`
//!   succeeds with zero setup and the GNN-estimator / distributed-training
//!   paths run for real.
//! * [`BackendKind::Pjrt`] — the PJRT client path. The real `xla` crate is
//!   unavailable offline, so this goes through the API-compatible typed
//!   stub in `rust/src/xla_stub.rs` and fails with a clear message at
//!   construction; when a real binding lands, only the stub changes.
//!
//! Select with `DISCO_BACKEND=interp|pjrt` (CLI: `--backend`).

pub mod corpus;
pub mod gen;
pub mod gnn;
pub mod interp;
pub mod trainer;
pub mod value;

use crate::util::json::Json;
use crate::xla_stub as xla;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Which execution engine backs [`Runtime`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// In-tree HLO interpreter (offline default).
    Interp,
    /// PJRT client (requires a real `xla` binding; stubbed offline).
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "interp" | "interpreter" => Some(BackendKind::Interp),
            "pjrt" | "xla" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Interp => "interp",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// Backend selected by `$DISCO_BACKEND` (default: the interpreter).
    /// A set-but-unrecognized value warns loudly instead of silently
    /// running a different backend than the one requested.
    pub fn from_env() -> BackendKind {
        match std::env::var("DISCO_BACKEND") {
            Ok(s) => BackendKind::parse(&s).unwrap_or_else(|| {
                eprintln!(
                    "warning: DISCO_BACKEND='{s}' not recognized (expected interp|pjrt); \
                     using the interpreter backend"
                );
                BackendKind::Interp
            }),
            Err(_) => BackendKind::Interp,
        }
    }
}

/// Shape+dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Option<TensorSpec> {
        Some(TensorSpec {
            shape: j
                .get("shape")
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Option<Vec<_>>>()?,
            dtype: j.get("dtype").as_str()?.to_string(),
        })
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact's metadata from the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub raw: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| {
                format!(
                    "reading {}/manifest.json (run `disco gen-artifacts` or `make artifacts`)",
                    dir.display()
                )
            })?;
        let raw = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        Ok(Manifest { dir: dir.to_path_buf(), raw })
    }

    /// Default artifacts directory: `$DISCO_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("DISCO_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn artifact(&self, name: &str) -> Result<ArtifactSpec> {
        let a = self.raw.get("artifacts").get(name);
        if *a == Json::Null {
            return Err(anyhow!("artifact '{name}' not in manifest"));
        }
        let parse = |key: &str| -> Result<Vec<TensorSpec>> {
            a.get(key)
                .as_arr()
                .ok_or_else(|| anyhow!("bad manifest"))?
                .iter()
                .map(|j| TensorSpec::from_json(j).ok_or_else(|| anyhow!("bad spec")))
                .collect()
        };
        Ok(ArtifactSpec {
            file: a
                .get("file")
                .as_str()
                .ok_or_else(|| anyhow!("bad manifest"))?
                .to_string(),
            inputs: parse("inputs")?,
            outputs: parse("outputs")?,
        })
    }

    /// Load a raw little-endian f32 parameter file referenced by the
    /// manifest (e.g. `lm_params.f32`).
    pub fn load_f32(&self, file: &str) -> Result<Vec<f32>> {
        let bytes =
            std::fs::read(self.dir.join(file)).with_context(|| format!("reading {file}"))?;
        if bytes.len() % 4 != 0 {
            return Err(anyhow!("{file}: length not a multiple of 4"));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// The engine behind one loaded artifact.
enum Engine {
    Interp(interp::Interp),
    Pjrt(xla::PjRtLoadedExecutable),
}

/// A compiled artifact ready to execute.
pub struct Executable {
    pub spec: ArtifactSpec,
    engine: Engine,
}

/// Artifact manifest + execution backend.
pub struct Runtime {
    pub manifest: Manifest,
    backend: BackendKind,
    /// Only constructed on the PJRT path.
    client: Option<xla::PjRtClient>,
}

impl Runtime {
    /// Open the artifact directory with the environment-selected backend
    /// (interpreter by default — succeeds offline; an empty directory is
    /// bootstrapped by [`gen::ensure_artifacts`]).
    pub fn new(dir: &Path) -> Result<Runtime> {
        Self::with_backend(dir, BackendKind::from_env())
    }

    pub fn with_backend(dir: &Path, backend: BackendKind) -> Result<Runtime> {
        let client = match backend {
            BackendKind::Interp => {
                gen::ensure_artifacts(dir)?;
                None
            }
            BackendKind::Pjrt => Some(
                xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?,
            ),
        };
        let manifest = Manifest::load(dir)?;
        // Prebuilt (aot.py / JAX-lowered) sets load through the
        // interpreter like generated ones: gather/scatter, dynamic
        // slicing, while/conditional and the f16/bf16/s32/pred storage
        // layer are all implemented in-tree (conformance corpus:
        // rust/tests/hlo_corpus/), so the stamp gate that used to force
        // `--backend pjrt` for such sets is gone. A module using a
        // genuinely unsupported opcode (e.g. a Pallas custom-call)
        // still fails with a clear "unsupported HLO opcode" error at
        // execution.
        Ok(Runtime { manifest, backend, client })
    }

    pub fn backend(&self) -> BackendKind {
        self.backend
    }

    /// Load + compile one artifact.
    pub fn load(&self, name: &str) -> Result<Executable> {
        let spec = self.manifest.artifact(name)?;
        let path = self.manifest.dir.join(&spec.file);
        let engine = match self.backend {
            BackendKind::Interp => {
                let text = std::fs::read_to_string(&path)
                    .with_context(|| format!("reading {}", path.display()))?;
                let it = interp::Interp::from_text(&text)
                    .with_context(|| format!("parsing {}", path.display()))?;
                if it.num_params() != spec.inputs.len() {
                    return Err(anyhow!(
                        "{name}: module takes {} parameters, manifest says {}",
                        it.num_params(),
                        spec.inputs.len()
                    ));
                }
                Engine::Interp(it)
            }
            BackendKind::Pjrt => {
                let client = self
                    .client
                    .as_ref()
                    .ok_or_else(|| anyhow!("PJRT client not initialized"))?;
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
                Engine::Pjrt(exe)
            }
        };
        Ok(Executable { spec, engine })
    }
}

impl Executable {
    /// Execute with the given inputs; returns the flattened output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "artifact {} expects {} inputs, got {}",
                self.spec.file,
                self.spec.inputs.len(),
                inputs.len()
            ));
        }
        for (i, (lit, spec)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            let n: i64 = lit.dims.iter().product();
            if n as usize != spec.elems() {
                return Err(anyhow!(
                    "artifact {} input {i}: {} elements for spec {:?}",
                    self.spec.file,
                    n,
                    spec.shape
                ));
            }
        }
        match &self.engine {
            Engine::Interp(it) => it
                .run(inputs)
                .with_context(|| format!("interpreting {}", self.spec.file)),
            Engine::Pjrt(exe) => {
                let out = exe
                    .execute::<xla::Literal>(inputs)
                    .map_err(|e| anyhow!("execute {}: {e:?}", self.spec.file))?;
                let lit = out[0][0]
                    .to_literal_sync()
                    .map_err(|e| anyhow!("to_literal: {e:?}"))?;
                // aot.py lowers with return_tuple=True: always a tuple.
                lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))
            }
        }
    }
}

/// Build an f32 literal of the given shape from a slice.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(anyhow!("lit_f32: {} elems for shape {:?}", data.len(), shape));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal of the given shape from a slice.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(anyhow!("lit_i32: {} elems for shape {:?}", data.len(), shape));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Extract an f32 vector from a literal.
pub fn lit_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
}

/// Extract an f64 vector from an f32 literal.
pub fn lit_to_f64s(lit: &xla::Literal) -> Result<Vec<f64>> {
    Ok(lit_to_f32(lit)?.into_iter().map(|x| x as f64).collect())
}

/// Extract the single f32 scalar of a literal.
pub fn lit_scalar(lit: &xla::Literal) -> Result<f32> {
    let v = lit_to_f32(lit)?;
    v.first().copied().ok_or_else(|| anyhow!("empty literal"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("disco-rt-test-{tag}-{}", std::process::id()))
    }

    #[test]
    fn interp_backend_bootstraps_and_loads_every_artifact() {
        let dir = tmp_dir("boot");
        let rt = Runtime::with_backend(&dir, BackendKind::Interp).unwrap();
        assert_eq!(rt.backend().name(), "interp");
        for name in [
            "gnn_infer",
            "gnn_train",
            "lm_grads",
            "lm_adam",
            "lm_eval",
            "embed_grads",
            "probe_ops",
        ] {
            let exe = rt.load(name).unwrap();
            assert!(!exe.spec.inputs.is_empty(), "{name}");
        }
        // Params round-trip through the manifest.
        let params = rt
            .manifest
            .load_f32(rt.manifest.raw.get("gnn").get("params").as_str().unwrap())
            .unwrap();
        assert_eq!(params.len(), gen::gnn_flat_len());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pjrt_backend_still_fails_cleanly_offline() {
        let dir = tmp_dir("pjrt");
        let err = Runtime::with_backend(&dir, BackendKind::Pjrt).unwrap_err();
        assert!(format!("{err:#}").contains("not available"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn backend_parse_and_env_default() {
        assert_eq!(BackendKind::parse("interp"), Some(BackendKind::Interp));
        assert_eq!(BackendKind::parse("pjrt"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::parse("zzz"), None);
    }

    #[test]
    fn run_rejects_wrong_input_arity_and_shape() {
        let dir = tmp_dir("arity");
        let rt = Runtime::with_backend(&dir, BackendKind::Interp).unwrap();
        let exe = rt.load("lm_adam").unwrap();
        assert!(exe.run(&[]).is_err());
        let l = gen::lm_flat_len();
        let bad = lit_f32(&[0.0; 7], &[7]).unwrap();
        let good = lit_f32(&vec![0.0; l], &[l]).unwrap();
        let t = lit_f32(&[1.0], &[1]).unwrap();
        let out = exe.run(&[bad, good.clone(), good.clone(), good.clone(), t.clone()]);
        assert!(out.is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
