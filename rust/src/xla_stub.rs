//! Typed stand-in for the `xla` (PJRT) crate, which is unavailable in the
//! airgapped build (same policy as the in-tree substitutes in [`crate::util`]
//! for rand/serde_json/clap). It mirrors exactly the API surface
//! [`crate::runtime`] uses, so that module compiles unchanged; every entry
//! point that would need a real XLA runtime returns an error instead.
//!
//! Since the in-tree HLO interpreter landed (DESIGN.md §9), this stub is
//! only reached when the PJRT backend is explicitly selected
//! (`DISCO_BACKEND=pjrt` / `--backend pjrt`):
//! [`Runtime::with_backend`](crate::runtime::Runtime::with_backend) then
//! fails with a clear message at construction. The default interpreter
//! backend executes artifacts for real, offline. [`Literal`] remains the
//! host-tensor interchange type for *both* backends, so its
//! construction/readback is implemented for real.

use std::fmt;
use std::path::Path;

/// Error type standing in for `xla::Error` (callers format it with `{:?}`).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT backend not available in this build (offline stub; \
         see rust/src/xla_stub.rs)"
    ))
}

/// Element types a [`Literal`] can hold (the subset the runtime uses).
#[derive(Debug, Clone, PartialEq)]
pub enum Elements {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Scalar types storable in a literal.
pub trait NativeType: Copy {
    fn wrap(data: &[Self]) -> Elements;
    fn unwrap(e: &Elements) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: &[Self]) -> Elements {
        Elements::F32(data.to_vec())
    }
    fn unwrap(e: &Elements) -> Option<Vec<Self>> {
        match e {
            Elements::F32(v) => Some(v.clone()),
            Elements::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: &[Self]) -> Elements {
        Elements::I32(data.to_vec())
    }
    fn unwrap(e: &Elements) -> Option<Vec<Self>> {
        match e {
            Elements::I32(v) => Some(v.clone()),
            Elements::F32(_) => None,
        }
    }
}

/// Host tensor literal. Construction and readback work for real; only
/// execution requires the missing backend.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    pub elements: Elements,
    pub dims: Vec<i64>,
}

impl Literal {
    fn len(&self) -> usize {
        match &self.elements {
            Elements::F32(v) => v.len(),
            Elements::I32(v) => v.len(),
        }
    }

    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { elements: T::wrap(data), dims: vec![data.len() as i64] }
    }

    /// Reshape; errors when the element count does not match.
    pub fn reshape(self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n as usize != self.len() {
            return Err(Error(format!("reshape: {} elems into {:?}", self.len(), dims)));
        }
        Ok(Literal { elements: self.elements, dims: dims.to_vec() })
    }

    /// Read elements back out (type-checked).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::unwrap(&self.elements).ok_or_else(|| Error("to_vec: element type mismatch".into()))
    }

    /// Flatten a tuple literal. The stub never produces tuples (execution
    /// is unavailable), so this is an error by construction.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module text (opaque here).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper (opaque here).
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident result buffer.
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Loaded executable handle.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Mirrors `xla-rs`: per-device, per-output buffers.
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.dims, vec![2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(Literal::vec1(&[1i32]).reshape(&[7]).is_err());
    }

    #[test]
    fn backend_entry_points_error() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("not available"));
    }
}
