//! Discrete-event simulation of one training iteration (paper §4.4).
//!
//! Two resources model the worker: a **compute stream** (the GPU executes
//! one kernel at a time) and a **communication channel** (one AllReduce in
//! flight at a time — NCCL's in-order collective channel). Computation and
//! communication overlap freely; the only coupling is data dependencies
//! (an AllReduce starts once its (fused) gradient tensor is produced; an
//! optimizer op starts once its aggregated gradient arrives).
//!
//! The same engine backs both
//! * the **cost model** `Cost(H)` used by the search (clean per-op times
//!   from a [`CostSource`], paper's Simulator), and
//! * the **high-fidelity "real execution"** ([`hifi`]) that substitutes for
//!   the paper's physical testbed: per-op noise, per-worker jitter and
//!   AllReduce straggler synchronization (see DESIGN.md §2).
//!
//! ## Incremental evaluation (search hot path, `rust/PERF.md` §5)
//!
//! Two layers make per-candidate evaluation cost proportional to the
//! *affected suffix* of the schedule instead of the whole graph:
//!
//! * [`CostTable`] — every live node's time resolved once per candidate
//!   into flat `Vec<f64>`s indexed by arena id, so the event loop performs
//!   zero dyn-dispatched cost calls, zero signature hashes and zero lock
//!   acquisitions per scheduled event ([`simulate_table_in`]).
//! * [`CheckpointLog`] / [`simulate_delta`] — a parent evaluation records
//!   periodic snapshots of the full scheduler state
//!   ([`simulate_ckpt_in`]); a child that differs by a few recorded
//!   mutations restores the latest checkpoint preceding the first event
//!   its mutation frontier can influence and replays only the suffix.
//!   Results are bit-identical to a full simulation (property-tested, no
//!   float tolerance).
//!
//! ## Chunked collectives (DESIGN.md §13)
//!
//! An AllReduce with an active [`crate::graph::ChunkSpec`] streams through
//! the channel as `k` equal chunks: the per-collective overhead is paid
//! once, then chunk `i` *lands* at `L_i = start + D + i·(T−D)/k`, and a
//! pipelinable consumer (optimizer update, fusible compute) may begin as
//! soon as its first chunk lands instead of waiting for the whole tensor.
//! Graphs with no active chunking take the pre-chunk [`event_loop`]
//! untouched — results and traces are bit-identical to the pre-chunk
//! simulator (`prop_chunked_sim_degenerates_to_whole_tensor`). Chunked
//! graphs run a **dual-track** loop ([`event_loop_extended`]): a
//! conservative track replays the whole-tensor arithmetic exactly (it owns
//! the heap keys, so the schedule *order* matches the unchunked run) and
//! an actual track carries the overlapped times, each clamped to its
//! conservative counterpart — which makes "chunking never loses under the
//! flat-network model" a per-event invariant, not a hope.
//!
//! ## Sharded collectives (ZeRO/FSDP, DESIGN.md §16)
//!
//! A collective with an active [`crate::graph::ShardSpec`] runs as
//! **reduce-scatter + all-gather** on the actual track of the same
//! dual-track loop. With ring cost `t_full = 2(W−1)·x/(bw·W) + D`, each
//! phase transfers `(W−1)·x/(bw·W)` and pays the negotiation overhead
//! once: `t_rs = t_ag = (t_full − D)/2 + D` — both derived *inside* the
//! event loop from the [`CostTable`]'s unsharded entry, so
//! [`CostTable::extend_in`]'s copy-surviving-entries contract holds
//! (`SetSharding` never changes `bytes_out`). The reduce-scatter releases
//! the optimizer step, which updates only the local 1/W parameter shard
//! (actual compute `t/W`); the all-gather of updated shards launches when
//! the collective's last consumer finishes and is schedulable *into the
//! next iteration's forward pass* — its tail beyond the forward-compute
//! window (`act_ag_tail − fwd_window`) is what extends the reported
//! makespan. The conservative track still replays the whole-tensor DDP
//! arithmetic (schedule order and snapshots stay those of the DDP run).
//! Unlike chunking, sharding carries **no never-worse clamp**: each
//! phase re-pays `D`, so `t_rs + t_ag = t_full + D` — the split wins via
//! the `/W` optimizer and the forward-overlapped all-gather, not by
//! construction; the search keeps a candidate only when it actually
//! wins.

pub mod hifi;
pub mod trace;

use crate::graph::{Node, NodeFlags, NodeId, OpKind, Role, TrainingGraph};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Observer of scheduling decisions (Chrome-trace export, debugging).
/// The no-op implementation compiles away in the search hot path.
pub trait Recorder {
    fn record(&mut self, _node: &Node, _start_ms: f64, _end_ms: f64, _comm: bool) {}
    /// One chunk of a chunked AllReduce: `idx` in `1..=count`, spanning
    /// `[start_ms, end_ms]` on the channel. `end_ms` is the chunk's land
    /// time (its `CommWait`); the whole collective's [`Recorder::record`]
    /// call still fires with the full channel span. Default: no-op.
    fn record_chunk(
        &mut self,
        _node: &Node,
        _idx: u32,
        _count: u32,
        _start_ms: f64,
        _end_ms: f64,
    ) {
    }
}

/// Default no-op recorder.
pub struct NoRecord;

impl Recorder for NoRecord {}

/// Where per-node times come from. The searcher's estimator implements
/// this; the hi-fi simulator implements it with the noisy device model.
pub trait CostSource {
    /// Execution time of a computation node, ms.
    fn compute_time_ms(&self, node: &Node) -> f64;
    /// AllReduce time for a (fused) gradient tensor of `bytes`, ms.
    fn comm_time_ms(&self, bytes: f64) -> f64;
    /// Hook called once per candidate graph before simulation — cost
    /// sources with batched backends (the GNN estimator) prefetch every
    /// fused-op prediction here. Default: no-op.
    fn prepare(&self, _graph: &TrainingGraph) {}
    /// Fixed per-collective negotiation/launch overhead, ms — paid once
    /// per AllReduce regardless of chunk count; the chunks of a chunked
    /// collective stream through the *remaining* channel occupancy.
    /// Sources with an affine comm model return their intercept. Default
    /// 0 (pure-bandwidth chunking).
    fn comm_overhead_ms(&self) -> f64 {
        0.0
    }
}

/// Simulation knobs.
#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    /// Extra delay added to every AllReduce start, modelling worker skew
    /// (0 in the cost model; >0 in hi-fi runs).
    pub straggler_ms: f64,
    /// If true, AllReduces are skipped entirely (single-device runs,
    /// Fig. 8).
    pub ignore_comm: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { straggler_ms: 0.0, ignore_comm: false }
    }
}

/// Result of simulating one iteration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimResult {
    /// End-to-end per-iteration time (makespan), ms.
    pub makespan_ms: f64,
    /// Total compute-stream busy time, ms (Fig. 7 "computation time").
    pub comp_busy_ms: f64,
    /// Total channel busy time, ms (Fig. 7 "communication time").
    pub comm_busy_ms: f64,
    /// Total compute-stream idle time spent waiting on dependencies: the
    /// sum over kernels of `start − device_free_before` (dependency
    /// stalls, not tail idle after the last kernel).
    pub comp_idle_ms: f64,
    /// Same for the communication channel: time the channel sat idle
    /// between collectives waiting for a gradient to be produced.
    pub comm_idle_ms: f64,
    /// Number of scheduled compute kernels.
    pub kernels: usize,
    /// Number of AllReduce operations executed.
    pub allreduces: usize,
    /// Peak device-memory footprint of live intermediate tensors, bytes
    /// (refcounted: an output is freed once its last consumer completes).
    /// One of op fusion's benefits the paper cites — fewer materialized
    /// intermediates — made measurable.
    pub peak_bytes: f64,
}

impl SimResult {
    /// The paper's overlap metric (§6.3): (comp + comm) / makespan.
    /// Values > 1 mean overlap; 1 means fully serialized.
    pub fn overlap_ratio(&self) -> f64 {
        if self.makespan_ms == 0.0 {
            1.0
        } else {
            (self.comp_busy_ms + self.comm_busy_ms) / self.makespan_ms
        }
    }
}

/// Fully-overlapped lower bound (the paper's "FO" line in Fig. 6):
/// computation and communication each run back-to-back with perfect
/// overlap and no dependency stalls.
pub fn fo_bound(graph: &TrainingGraph, costs: &dyn CostSource) -> f64 {
    let mut comp = 0.0;
    let mut comm = 0.0;
    for n in graph.live() {
        match n.kind {
            OpKind::AllReduce => comm += costs.comm_time_ms(n.bytes_out),
            OpKind::Parameter | OpKind::Constant => {}
            _ => comp += costs.compute_time_ms(n),
        }
    }
    comp.max(comm)
}

/// Flat per-node cost table: every live node's execution time resolved
/// once per candidate, indexed by arena id. The table-driven event loop
/// ([`simulate_table_in`]) reads these arrays instead of calling
/// [`CostSource`] per event — the dyn dispatch, fused-group signature
/// hash and estimator-memo lock all happen at *table-build* time, never
/// inside the scheduler.
///
/// Requires the cost source to be deterministic per node (the searcher's
/// estimators are — predictions are memoized by structural signature);
/// noisy sources like [`hifi`] must keep using the dyn path, because a
/// table resolves costs in arena order, not schedule order.
#[derive(Debug, Clone, Default)]
pub struct CostTable {
    compute: Vec<f64>,
    comm: Vec<f64>,
    /// Per-collective overhead ([`CostSource::comm_overhead_ms`]) — one
    /// scalar per source, resolved at build time like the per-node costs.
    overhead: f64,
}

impl CostTable {
    pub fn new() -> CostTable {
        CostTable::default()
    }

    /// Build the table for `graph`, reusing this table's capacity. Calls
    /// `costs.prepare` first so batched backends (the GNN estimator)
    /// resolve every fused-op prediction in one backend call.
    pub fn build_in(&mut self, graph: &TrainingGraph, costs: &dyn CostSource) {
        costs.prepare(graph);
        let n = graph.nodes.len();
        self.compute.clear();
        self.compute.resize(n, 0.0);
        self.comm.clear();
        self.comm.resize(n, 0.0);
        self.overhead = costs.comm_overhead_ms();
        self.fill(graph, costs, 0);
    }

    /// Fresh table for `graph` (convenience wrapper over [`build_in`]).
    ///
    /// [`build_in`]: CostTable::build_in
    pub fn build(graph: &TrainingGraph, costs: &dyn CostSource) -> CostTable {
        let mut t = CostTable::new();
        t.build_in(graph, costs);
        t
    }

    /// Derive a child candidate's table from its parent's: surviving ids
    /// keep the parent's entries (per-node costs depend only on the node,
    /// which rewrites never edit in place — fusion appends new nodes and
    /// tombstones old ones), so only the appended ids are resolved
    /// through `costs`. This is what makes per-candidate estimator work
    /// O(mutations), not O(graph).
    pub fn extend_in(
        &mut self,
        parent: &CostTable,
        graph: &TrainingGraph,
        costs: &dyn CostSource,
    ) {
        costs.prepare(graph);
        let n = graph.nodes.len();
        let base = parent.compute.len().min(n);
        self.compute.clear();
        self.compute.extend_from_slice(&parent.compute[..base]);
        self.compute.resize(n, 0.0);
        self.comm.clear();
        self.comm.extend_from_slice(&parent.comm[..base]);
        self.comm.resize(n, 0.0);
        self.overhead = costs.comm_overhead_ms();
        self.fill(graph, costs, base);
    }

    fn fill(&mut self, graph: &TrainingGraph, costs: &dyn CostSource, from: NodeId) {
        for node in graph.live() {
            if node.id < from {
                continue;
            }
            match node.kind {
                OpKind::AllReduce => self.comm[node.id] = costs.comm_time_ms(node.bytes_out),
                OpKind::Parameter | OpKind::Constant => {}
                _ => self.compute[node.id] = costs.compute_time_ms(node),
            }
        }
    }

    /// Resolved compute time of node `id` (0 for comm/param/const ids).
    #[inline]
    pub fn compute_ms(&self, id: NodeId) -> f64 {
        self.compute[id]
    }

    /// Resolved AllReduce time of node `id` (0 for non-comm ids).
    #[inline]
    pub fn comm_ms(&self, id: NodeId) -> f64 {
        self.comm[id]
    }

    /// Resolved per-collective overhead (ms).
    #[inline]
    pub fn overhead_ms(&self) -> f64 {
        self.overhead
    }

    /// Number of arena slots covered.
    pub fn len(&self) -> usize {
        self.compute.len()
    }

    pub fn is_empty(&self) -> bool {
        self.compute.is_empty()
    }
}

/// Reusable per-evaluation scratch state for [`simulate_in`]: the ready
/// heap, in-degrees, ready times, memory refcounts and the delta-sim
/// frontier flags. One workspace per simulating thread; reusing it across
/// evaluations makes a full search perform zero per-eval scratch
/// allocations once the vectors have grown to the largest graph seen
/// (see `rust/PERF.md`).
#[derive(Debug, Default)]
pub struct SimWorkspace {
    indeg: Vec<u32>,
    ready: Vec<f64>,
    /// Actual-track ready times of the chunked loop (the conservative
    /// track owns `ready` and the heap keys). Zero-filled and unread in
    /// unchunked runs.
    ready_act: Vec<f64>,
    consumers_left: Vec<u32>,
    heap: BinaryHeap<Reverse<(OrderedF64, u32, u32)>>,
    flags: NodeFlags,
}

impl SimWorkspace {
    pub fn new() -> SimWorkspace {
        SimWorkspace::default()
    }

    /// Reset for a graph of `n` arena slots. Keeps capacity.
    fn reset(&mut self, n: usize) {
        self.indeg.clear();
        self.indeg.resize(n, 0);
        self.ready.clear();
        self.ready.resize(n, 0.0);
        self.ready_act.clear();
        self.ready_act.resize(n, 0.0);
        self.consumers_left.clear();
        self.consumers_left.resize(n, 0);
        self.heap.clear();
    }
}

/// All mutable scalar state of one simulation — split out so a
/// [`CheckpointLog`] can snapshot and restore it wholesale. Accumulator
/// arithmetic happens in event order on these fields, so a restored
/// prefix is bit-identical to having replayed it.
#[derive(Debug, Clone, Copy, Default)]
struct SimState {
    seq: u32,
    device_free: f64,
    channel_free: f64,
    comp_busy: f64,
    comm_busy: f64,
    comp_idle: f64,
    comm_idle: f64,
    kernels: usize,
    allreduces: usize,
    makespan: f64,
    scheduled: usize,
    live_bytes: f64,
    peak_bytes: f64,
    // Actual-track counterparts used by the extended loop only; counts
    // and the memory accounting are schedule-order facts shared by both
    // tracks. All stay zero in plain (unchunked, unsharded) runs. For
    // chunked-only graphs the act busy accumulators receive the exact
    // same addends in the same order as their conservative counterparts,
    // so they end bitwise equal; sharded graphs diverge (reduce-scatter +
    // all-gather occupy the actual channel, the optimizer runs `t/W`).
    act_device_free: f64,
    act_channel_free: f64,
    act_comp_busy: f64,
    act_comm_busy: f64,
    act_comp_idle: f64,
    act_comm_idle: f64,
    act_makespan: f64,
    /// Latest all-gather completion on the actual channel (sharded
    /// collectives only); its tail beyond the next iteration's forward
    /// window extends the actual makespan after the loop.
    act_ag_tail: f64,
}

impl SimState {
    fn result(&self) -> SimResult {
        SimResult {
            makespan_ms: self.makespan,
            comp_busy_ms: self.comp_busy,
            comm_busy_ms: self.comm_busy,
            comp_idle_ms: self.comp_idle,
            comm_idle_ms: self.comm_idle,
            kernels: self.kernels,
            allreduces: self.allreduces,
            peak_bytes: self.peak_bytes,
        }
    }

    /// Result of an extended (chunked and/or sharded) run: the actual
    /// (overlapped) track. For chunked-only graphs the act busy fields
    /// are bitwise equal to the conservative ones (same addends, same
    /// order); sharded graphs report the split collective's real channel
    /// and device occupancy.
    fn result_act(&self) -> SimResult {
        SimResult {
            makespan_ms: self.act_makespan,
            comp_busy_ms: self.act_comp_busy,
            comm_busy_ms: self.act_comm_busy,
            comp_idle_ms: self.act_comp_idle,
            comm_idle_ms: self.act_comm_idle,
            kernels: self.kernels,
            allreduces: self.allreduces,
            peak_bytes: self.peak_bytes,
        }
    }
}

/// One snapshot of scheduler state, taken *before* event
/// `events_done` was popped: events `0..events_done` are already applied.
#[derive(Debug, Clone, Default)]
struct SimCheckpoint {
    events_done: usize,
    state: SimState,
    heap: BinaryHeap<Reverse<(OrderedF64, u32, u32)>>,
    indeg: Vec<u32>,
    ready: Vec<f64>,
    /// Actual-track ready times — populated only by chunked recordings
    /// (empty otherwise, so unchunked snapshots cost nothing extra).
    ready_act: Vec<f64>,
    consumers_left: Vec<u32>,
}

/// Periodic scheduler snapshots plus the scheduled-event order of one
/// parent evaluation ([`simulate_ckpt_in`]). Children sharing the parent
/// restore the latest snapshot that precedes their mutation frontier and
/// replay only the suffix ([`simulate_delta`]). Reused across steps —
/// snapshot buffers keep their capacity.
#[derive(Debug, Default)]
pub struct CheckpointLog {
    every: usize,
    sched_order: Vec<u32>,
    snaps: Vec<SimCheckpoint>,
    used: usize,
    /// Which event loop recorded this log: snapshots of an extended
    /// (chunked and/or sharded) run carry the actual track too, and
    /// [`simulate_delta`] restores (or synthesizes) it accordingly.
    extended: bool,
}

impl CheckpointLog {
    pub fn new() -> CheckpointLog {
        CheckpointLog::default()
    }

    /// Snapshot cadence: one every `every` events (`0` = auto, n/8
    /// clamped to ≥ 32 — a handful of snapshots per evaluation, so the
    /// recording overhead stays a small fraction of the event loop).
    fn reset(&mut self, every: usize, n: usize, extended: bool) {
        self.every = if every > 0 { every } else { (n / 8).max(32) };
        self.sched_order.clear();
        self.used = 0;
        self.extended = extended;
    }

    /// Events the recorded parent evaluation scheduled.
    pub fn events(&self) -> usize {
        self.sched_order.len()
    }

    /// Snapshots currently held.
    pub fn snapshots(&self) -> usize {
        self.used
    }

    fn snap(&mut self, events_done: usize, st: &SimState, ws: &SimWorkspace) {
        if self.used == self.snaps.len() {
            self.snaps.push(SimCheckpoint::default());
        }
        let s = &mut self.snaps[self.used];
        s.events_done = events_done;
        s.state = *st;
        s.heap.clone_from(&ws.heap);
        s.indeg.clone_from(&ws.indeg);
        s.ready.clone_from(&ws.ready);
        if self.extended {
            s.ready_act.clone_from(&ws.ready_act);
        } else {
            s.ready_act.clear();
        }
        s.consumers_left.clone_from(&ws.consumers_left);
        self.used += 1;
    }
}

/// Monomorphized per-node cost lookup for the event loop: the table
/// variant compiles to two array reads — no virtual call, no hash, no
/// lock per scheduled event.
trait NodeCosts {
    fn compute(&self, node: &Node) -> f64;
    fn comm(&self, node: &Node) -> f64;
    fn overhead(&self) -> f64;
}

struct DynCosts<'a>(&'a dyn CostSource);

impl NodeCosts for DynCosts<'_> {
    #[inline]
    fn compute(&self, node: &Node) -> f64 {
        self.0.compute_time_ms(node)
    }
    #[inline]
    fn comm(&self, node: &Node) -> f64 {
        self.0.comm_time_ms(node.bytes_out)
    }
    #[inline]
    fn overhead(&self) -> f64 {
        self.0.comm_overhead_ms()
    }
}

struct TableCosts<'a>(&'a CostTable);

impl NodeCosts for TableCosts<'_> {
    #[inline]
    fn compute(&self, node: &Node) -> f64 {
        self.0.compute[node.id]
    }
    #[inline]
    fn comm(&self, node: &Node) -> f64 {
        self.0.comm[node.id]
    }
    #[inline]
    fn overhead(&self) -> f64 {
        self.0.overhead
    }
}

/// Simulate one training iteration of `graph` under `costs`.
///
/// Scheduling discipline: per resource, earliest-ready-first (FIFO on
/// ready time, ties broken by enqueue sequence) — the paper's ready-queue
/// process, with AllReduces "executed in order of production of their
/// respective gradient tensors".
pub fn simulate(graph: &TrainingGraph, costs: &dyn CostSource, opts: SimOptions) -> SimResult {
    simulate_with(graph, costs, opts, &mut NoRecord)
}

/// [`simulate`] with a scheduling observer (Chrome-trace export etc.).
/// Thin wrapper allocating a fresh workspace; hot paths call
/// [`simulate_in`] with a reused one.
pub fn simulate_with<R: Recorder>(
    graph: &TrainingGraph,
    costs: &dyn CostSource,
    opts: SimOptions,
    rec: &mut R,
) -> SimResult {
    simulate_in(graph, costs, opts, rec, &mut SimWorkspace::new())
}

/// Core event loop: [`simulate_with`] threaded through a caller-owned
/// [`SimWorkspace`]. Bit-identical to a fresh-workspace run (property
/// test `prop_sim_workspace_reuse_identical`). This is the dyn-dispatch
/// path; the search hot path uses [`simulate_table_in`].
pub fn simulate_in<R: Recorder>(
    graph: &TrainingGraph,
    costs: &dyn CostSource,
    opts: SimOptions,
    rec: &mut R,
    ws: &mut SimWorkspace,
) -> SimResult {
    let mut st = SimState::default();
    init_state(graph, ws, &mut st);
    if graph.has_chunking() || graph.has_sharding() {
        event_loop_extended(graph, &DynCosts(costs), opts, rec, ws, &mut st, None);
        debug_assert_eq!(st.scheduled, graph.live_count(), "graph has a cycle?");
        return st.result_act();
    }
    event_loop(graph, &DynCosts(costs), opts, rec, ws, &mut st, None);
    debug_assert_eq!(st.scheduled, graph.live_count(), "graph has a cycle?");
    st.result()
}

/// [`simulate_in`] driven by a pre-resolved [`CostTable`]: the event loop
/// performs zero dyn-dispatched cost calls and zero lock acquisitions per
/// scheduled event. Bit-identical to the dyn path for deterministic cost
/// sources (property test `prop_cost_table_matches_dyn_lookup`).
pub fn simulate_table_in<R: Recorder>(
    graph: &TrainingGraph,
    table: &CostTable,
    opts: SimOptions,
    rec: &mut R,
    ws: &mut SimWorkspace,
) -> SimResult {
    let mut st = SimState::default();
    init_state(graph, ws, &mut st);
    if graph.has_chunking() || graph.has_sharding() {
        event_loop_extended(graph, &TableCosts(table), opts, rec, ws, &mut st, None);
        debug_assert_eq!(st.scheduled, graph.live_count(), "graph has a cycle?");
        return st.result_act();
    }
    event_loop(graph, &TableCosts(table), opts, rec, ws, &mut st, None);
    debug_assert_eq!(st.scheduled, graph.live_count(), "graph has a cycle?");
    st.result()
}

/// [`simulate_table_in`] that additionally records `log`: periodic
/// scheduler snapshots (every `every` events; 0 = auto) plus the
/// scheduled-event order, for subsequent [`simulate_delta`] calls against
/// children of this graph.
pub fn simulate_ckpt_in<R: Recorder>(
    graph: &TrainingGraph,
    table: &CostTable,
    opts: SimOptions,
    rec: &mut R,
    ws: &mut SimWorkspace,
    log: &mut CheckpointLog,
    every: usize,
) -> SimResult {
    let mut st = SimState::default();
    init_state(graph, ws, &mut st);
    let extended = graph.has_chunking() || graph.has_sharding();
    log.reset(every, graph.nodes.len(), extended);
    if extended {
        event_loop_extended(graph, &TableCosts(table), opts, rec, ws, &mut st, Some(log));
        debug_assert_eq!(st.scheduled, graph.live_count(), "graph has a cycle?");
        return st.result_act();
    }
    event_loop(graph, &TableCosts(table), opts, rec, ws, &mut st, Some(log));
    debug_assert_eq!(st.scheduled, graph.live_count(), "graph has a cycle?");
    st.result()
}

/// Simulate `child` — `parent` plus a recorded mutation sequence — by
/// restoring the latest checkpoint of the parent's evaluation that
/// precedes the first event the mutations can influence, then replaying
/// only the suffix. `frontier` is the union of nodes each rewrite
/// touched, as collected by [`crate::fusion::FusionEffects::extend_frontier`]
/// plus the mutation operands; `table` is the *child's* cost table
/// (see [`CostTable::extend_in`]).
///
/// Bit-identical to `simulate_table_in(child, …)` — no float tolerance
/// (property test `prop_delta_sim_matches_full`). The recorder only
/// observes the replayed suffix, so the search passes [`NoRecord`].
///
/// Correctness sketch: parent and child runs pop identical events with
/// identical state updates until the first event `u` whose processing
/// touches a differing slot — `u` itself differs, or it reads the
/// refcount of a differing input, or it decrements the indegree of a
/// differing successor. All three imply `u` is in the frontier's one-hop
/// closure over the *parent* adjacency, which is exactly the flag set
/// scanned below. Frontier slots themselves are untouched before that
/// event, so re-initializing them from the child graph after restoring
/// the snapshot reproduces the child's exact state at that point.
#[allow(clippy::too_many_arguments)]
pub fn simulate_delta<R: Recorder>(
    parent: &TrainingGraph,
    log: &CheckpointLog,
    child: &TrainingGraph,
    frontier: &[NodeId],
    table: &CostTable,
    opts: SimOptions,
    rec: &mut R,
    ws: &mut SimWorkspace,
) -> SimResult {
    let parent_len = parent.nodes.len();
    let child_len = child.nodes.len();
    debug_assert!(child_len >= parent_len, "child arenas only append");
    // Degenerate guard: an appended live node with no inputs would belong
    // in the *initial* ready heap, which no restored parent snapshot can
    // contain. Fusion rewrites never produce one (a fused kernel always
    // keeps at least one external operand), but arbitrary imported graphs
    // could — fall back to the full table simulation, which is
    // bit-identical by contract.
    if child.nodes[parent_len..]
        .iter()
        .any(|n| !n.deleted && n.inputs.is_empty())
    {
        return simulate_table_in(child, table, opts, rec, ws);
    }
    let csucc = child.succ_csr();

    // --- divergence bound: frontier ∪ parent-inputs ∪ parent-consumers --
    ws.flags.reset(parent_len);
    let psucc = parent.succ_csr();
    for &a in frontier {
        if a >= parent_len {
            continue; // node appended by an earlier mutation: not in the parent schedule
        }
        ws.flags.mark(a);
        for &i in &parent.nodes[a].inputs {
            ws.flags.mark(i);
        }
        for &c in psucc.row(a) {
            ws.flags.mark(c as NodeId);
        }
    }
    let d = log
        .sched_order
        .iter()
        .position(|&id| ws.flags.is_marked(id as NodeId))
        .unwrap_or(log.sched_order.len());

    // --- restore the latest snapshot with events_done <= d --------------
    let cp = log.snaps[..log.used]
        .iter()
        .rev()
        .find(|s| s.events_done <= d)
        .expect("checkpoint log missing the initial snapshot");
    let mut st = cp.state;
    ws.heap.clone_from(&cp.heap);
    ws.indeg.clone_from(&cp.indeg);
    ws.indeg.resize(child_len, 0);
    ws.ready.clone_from(&cp.ready);
    ws.ready.resize(child_len, 0.0);
    ws.consumers_left.clone_from(&cp.consumers_left);
    ws.consumers_left.resize(child_len, 0);
    let child_extended = child.has_chunking() || child.has_sharding();
    if child_extended {
        if log.extended {
            ws.ready_act.clone_from(&cp.ready_act);
        } else {
            // Plain (unchunked, unsharded) parent prefix: the actual
            // track is identical to the conservative one everywhere (no
            // chunked or sharded collective was ever processed), so
            // synthesize it from the conservative state. `act_ag_tail`
            // stays 0: no all-gather has run in such a prefix.
            ws.ready_act.clone_from(&cp.ready);
            st.act_device_free = st.device_free;
            st.act_channel_free = st.channel_free;
            st.act_comp_busy = st.comp_busy;
            st.act_comm_busy = st.comm_busy;
            st.act_comp_idle = st.comp_idle;
            st.act_comm_idle = st.comm_idle;
            st.act_makespan = st.makespan;
        }
        ws.ready_act.resize(child_len, 0.0);
    }

    // --- patch to child-initial values ----------------------------------
    // Appended nodes were never initialized by the parent run; frontier
    // nodes were initialized with parent wiring. Both sets are untouched
    // by the restored prefix (their first interaction is event >= d), so
    // child-initial values are exact. Appended fused nodes always have
    // inputs, so none belongs in the (restored) initial ready heap.
    for id in parent_len..child_len {
        let node = &child.nodes[id];
        if node.deleted {
            continue; // absorbed by a later mutation of the same candidate
        }
        ws.indeg[id] = node.inputs.len() as u32;
        ws.ready[id] = 0.0;
        if child_extended {
            ws.ready_act[id] = 0.0;
        }
        ws.consumers_left[id] = csucc.out_degree(id) as u32;
    }
    for &a in frontier {
        if a >= parent_len || child.nodes[a].deleted {
            continue; // deleted slots are never read by the child's event loop
        }
        ws.indeg[a] = child.nodes[a].inputs.len() as u32;
        ws.ready[a] = 0.0;
        if child_extended {
            ws.ready_act[a] = 0.0;
        }
        ws.consumers_left[a] = csucc.out_degree(a) as u32;
    }

    // --- replay the suffix ----------------------------------------------
    // A plain child replays through the pre-chunk loop even when the
    // parent log is extended: the conservative parts of an extended
    // snapshot are bitwise what a plain run of the stripped parent would
    // have recorded (the conservative track *is* that run), and the
    // plain loop reads nothing else.
    if child_extended {
        event_loop_extended(child, &TableCosts(table), opts, rec, ws, &mut st, None);
        debug_assert_eq!(st.scheduled, child.live_count(), "delta replay lost events");
        return st.result_act();
    }
    event_loop(child, &TableCosts(table), opts, rec, ws, &mut st, None);
    debug_assert_eq!(st.scheduled, child.live_count(), "delta replay lost events");
    st.result()
}

/// Seed workspace + state for a from-scratch run of `graph`.
fn init_state(graph: &TrainingGraph, ws: &mut SimWorkspace, st: &mut SimState) {
    let succ = graph.succ_csr();
    ws.reset(graph.nodes.len());
    for node in graph.live() {
        ws.indeg[node.id] = node.inputs.len() as u32;
        // Memory refcounting: an intermediate lives from its producer's
        // completion until its last consumer's completion. Parameters and
        // constants are persistent state, excluded from the peak.
        ws.consumers_left[node.id] = succ.out_degree(node.id) as u32;
        if node.inputs.is_empty() {
            ws.heap.push(Reverse((OrderedF64(0.0), st.seq, node.id as u32)));
            st.seq += 1;
        }
    }
}

/// The event loop shared by every entry point, generic over the cost
/// lookup (monomorphized — the table variant has no per-event dyn call)
/// and resumable from any [`SimState`] + workspace pair.
///
/// (ready_time, seq, id) min-heap over BOTH resources; popping in global
/// ready order keeps each resource's discipline consistent (a newly
/// enabled node is never ready earlier than the node that enabled it).
fn event_loop<C: NodeCosts, R: Recorder>(
    graph: &TrainingGraph,
    costs: &C,
    opts: SimOptions,
    rec: &mut R,
    ws: &mut SimWorkspace,
    st: &mut SimState,
    mut log: Option<&mut CheckpointLog>,
) {
    let succ = graph.succ_csr();
    let transient =
        |node: &Node| !matches!(node.kind, OpKind::Parameter | OpKind::Constant);

    loop {
        if let Some(l) = log.as_deref_mut() {
            if st.scheduled % l.every == 0 {
                l.snap(st.scheduled, st, ws);
            }
        }
        let Some(Reverse((OrderedF64(rt), _s, id))) = ws.heap.pop() else { break };
        if let Some(l) = log.as_deref_mut() {
            l.sched_order.push(id);
        }
        let id = id as NodeId;
        let node = &graph.nodes[id];
        let done = match node.kind {
            OpKind::AllReduce => {
                if opts.ignore_comm {
                    rt
                } else {
                    let start = (rt + opts.straggler_ms).max(st.channel_free);
                    st.comm_idle += start - st.channel_free;
                    let t = costs.comm(node);
                    st.channel_free = start + t;
                    st.comm_busy += t;
                    st.allreduces += 1;
                    rec.record(node, start, st.channel_free, true);
                    st.channel_free
                }
            }
            OpKind::Parameter | OpKind::Constant => rt,
            _ => {
                let t = costs.compute(node);
                let start = rt.max(st.device_free);
                st.comp_idle += start - st.device_free;
                st.device_free = start + t;
                st.comp_busy += t;
                st.kernels += 1;
                rec.record(node, start, st.device_free, false);
                st.device_free
            }
        };
        st.makespan = st.makespan.max(done);
        st.scheduled += 1;

        if transient(node) {
            st.live_bytes += node.bytes_out;
            st.peak_bytes = st.peak_bytes.max(st.live_bytes);
        }
        for &i in &node.inputs {
            ws.consumers_left[i] -= 1;
            if ws.consumers_left[i] == 0 && transient(&graph.nodes[i]) {
                st.live_bytes -= graph.nodes[i].bytes_out;
            }
        }

        for &v in succ.row(id) {
            let v = v as NodeId;
            ws.ready[v] = ws.ready[v].max(done);
            ws.indeg[v] -= 1;
            if ws.indeg[v] == 0 {
                ws.heap.push(Reverse((OrderedF64(ws.ready[v]), st.seq, v as u32)));
                st.seq += 1;
            }
        }
    }
}

/// Per-phase time of a sharded collective, derived from its unsharded
/// full-all-reduce time `t_full`: ring cost splits the transfer evenly
/// across the reduce-scatter and all-gather phases, and each phase
/// re-pays the negotiation overhead `D` (clamped into `[0, t_full]`).
#[inline]
fn shard_phase_ms(t_full: f64, overhead: f64) -> f64 {
    let d = overhead.min(t_full).max(0.0);
    (t_full - d) / 2.0 + d
}

/// Dual-track event loop for graphs with at least one chunked or sharded
/// collective.
///
/// * The **conservative track** replays [`event_loop`]'s arithmetic
///   bit-for-bit — it owns the heap keys, so events pop in exactly the
///   order a plain run of the chunk- and shard-stripped graph would
///   schedule them, and checkpoint snapshots stay compatible with plain
///   children. For sharded graphs the conservative track *is* the DDP
///   baseline schedule.
/// * The **actual track** (`ready_act`, `act_*` state) carries the
///   overlapped times. For chunking, every actual value is clamped so it
///   never exceeds its conservative counterpart — `max`/`+` are monotone
///   in f64, so `act_makespan <= makespan` holds *exactly*, by induction
///   per event, with no float tolerance (the monotonicity property
///   test). Sharding is **not** clamped (module docs): the split
///   collective's all-gather tail can legitimately exceed the DDP
///   makespan when the next iteration's forward window is too short to
///   hide it.
///
/// A chunked AllReduce occupies the channel for its full time `T`, but its
/// data lands incrementally: overhead `D` once, then `k` equal chunks of
/// `(T−D)/k`. A pipelinable consumer with compute cost `c` processes each
/// landed chunk in `c/k`, finishing at `max(L_1 + c, L_k + c/k)` — which
/// the whole-tensor scheduler reproduces by giving it the *effective*
/// ready time `r = max(L_1, L_k − (k−1)·c/k)`, clamped to `L_k` (the
/// whole-tensor arrival) against last-chunk rounding.
///
/// A sharded collective ([`crate::graph::ShardSpec`], never chunked — the
/// rewrites enforce exclusivity) occupies the actual channel for its
/// reduce-scatter phase only; its consumers (optimizer updates, by the
/// sharding legality rule) see the reduce-scatter completion and run at
/// `t/W` on the actual device (each rank updates its local shard). When
/// the collective's last consumer finishes, the all-gather of updated
/// parameter shards is laid onto the actual channel; the loop tracks the
/// latest all-gather completion and, after draining, extends the actual
/// makespan by whatever tail the next iteration's forward-compute window
/// (`Σ` forward costs) cannot hide.
fn event_loop_extended<C: NodeCosts, R: Recorder>(
    graph: &TrainingGraph,
    costs: &C,
    opts: SimOptions,
    rec: &mut R,
    ws: &mut SimWorkspace,
    st: &mut SimState,
    mut log: Option<&mut CheckpointLog>,
) {
    let succ = graph.succ_csr();
    let transient =
        |node: &Node| !matches!(node.kind, OpKind::Parameter | OpKind::Constant);
    let sharding = graph.has_sharding();
    // Forward-compute window the all-gathers overlap into (the next
    // iteration's forward pass). A pure function of graph + costs, so
    // recomputing it on a delta-sim suffix replay is deterministic.
    let fwd_window: f64 = if sharding {
        graph
            .live()
            .filter(|n| {
                n.role == Role::Forward
                    && !matches!(n.kind, OpKind::AllReduce | OpKind::Parameter | OpKind::Constant)
            })
            .map(|n| costs.compute(n))
            .sum()
    } else {
        0.0
    };
    let workers = graph.num_workers.max(1) as f64;

    loop {
        if let Some(l) = log.as_deref_mut() {
            if st.scheduled % l.every == 0 {
                l.snap(st.scheduled, st, ws);
            }
        }
        let Some(Reverse((OrderedF64(rt), _s, id))) = ws.heap.pop() else { break };
        if let Some(l) = log.as_deref_mut() {
            l.sched_order.push(id);
        }
        let id = id as NodeId;
        let node = &graph.nodes[id];
        let rt_act = ws.ready_act[id];
        let k = node.chunk_count();
        let chunked_ar = node.kind == OpKind::AllReduce && k >= 2 && !opts.ignore_comm;
        let (done, done_act) = match node.kind {
            OpKind::AllReduce => {
                if opts.ignore_comm {
                    (rt, rt_act)
                } else {
                    let start = (rt + opts.straggler_ms).max(st.channel_free);
                    st.comm_idle += start - st.channel_free;
                    let t = costs.comm(node);
                    st.channel_free = start + t;
                    st.comm_busy += t;
                    st.allreduces += 1;

                    // Actual channel occupancy: the reduce-scatter phase
                    // for a sharded collective, the full transfer
                    // otherwise (the all-gather is laid later, when the
                    // optimizer consumers finish).
                    let t_act =
                        if node.is_sharded_collective() { shard_phase_ms(t, costs.overhead()) } else { t };
                    let start_a = (rt_act + opts.straggler_ms).max(st.act_channel_free);
                    st.act_comm_idle += start_a - st.act_channel_free;
                    st.act_channel_free = start_a + t_act;
                    st.act_comm_busy += t_act;
                    let done_a = st.act_channel_free;
                    rec.record(node, start_a, done_a, true);
                    if node.is_sharded_collective() {
                        // Phase 1 of 2: the reduce-scatter span (the
                        // all-gather is recorded when it launches).
                        rec.record_chunk(node, 1, 2, start_a, done_a);
                    }
                    if k >= 2 {
                        let d_over = costs.overhead().min(t).max(0.0);
                        let per = (t - d_over) / k as f64;
                        let mut s = start_a + d_over;
                        let mut land1 = done_a;
                        for i in 1..=k {
                            let e = if i == k { done_a } else { s + per };
                            rec.record_chunk(node, i, k, s, e);
                            if i == 1 {
                                land1 = e;
                            }
                            s = e;
                        }
                        for &v in succ.row(id) {
                            let v = v as NodeId;
                            let vn = &graph.nodes[v];
                            let pipeline = vn.kind.is_fusible_compute()
                                || vn.kind == OpKind::Fused
                                || vn.role == Role::Optimizer;
                            let r = if pipeline {
                                let u = costs.compute(vn) / k as f64;
                                land1.max(done_a - (k - 1) as f64 * u).min(done_a)
                            } else {
                                done_a
                            };
                            ws.ready_act[v] = ws.ready_act[v].max(r);
                        }
                    }
                    (st.channel_free, done_a)
                }
            }
            OpKind::Parameter | OpKind::Constant => (rt, rt_act),
            _ => {
                let t = costs.compute(node);
                let start = rt.max(st.device_free);
                st.comp_idle += start - st.device_free;
                st.device_free = start + t;
                st.comp_busy += t;
                st.kernels += 1;

                // An optimizer update fed by a sharded collective touches
                // only the local 1/W parameter shard on the actual track
                // (ZeRO: optimizer state and step are sharded).
                let t_act = if sharding
                    && node.role == Role::Optimizer
                    && node.inputs.iter().any(|&i| graph.nodes[i].is_sharded_collective())
                {
                    t / workers
                } else {
                    t
                };
                let start_a = rt_act.max(st.act_device_free);
                st.act_comp_idle += start_a - st.act_device_free;
                st.act_device_free = start_a + t_act;
                st.act_comp_busy += t_act;
                rec.record(node, start_a, st.act_device_free, false);
                (st.device_free, st.act_device_free)
            }
        };
        st.makespan = st.makespan.max(done);
        st.act_makespan = st.act_makespan.max(done_act);
        st.scheduled += 1;

        if transient(node) {
            st.live_bytes += node.bytes_out;
            st.peak_bytes = st.peak_bytes.max(st.live_bytes);
        }
        for &i in &node.inputs {
            ws.consumers_left[i] -= 1;
            if ws.consumers_left[i] == 0 {
                let inp = &graph.nodes[i];
                if transient(inp) {
                    st.live_bytes -= inp.bytes_out;
                }
                // Last consumer of a sharded collective just finished:
                // every rank's shard of the updated parameter exists, so
                // the all-gather restoring replication goes on the actual
                // channel now. Its completion only matters as a tail
                // against the next iteration's forward window (below) —
                // within this iteration nothing consumes it, which is
                // exactly the prefetch freedom DeepCompile exploits.
                if sharding && inp.is_sharded_collective() && !opts.ignore_comm {
                    let t_ag = shard_phase_ms(costs.comm(inp), costs.overhead());
                    let start = done_act.max(st.act_channel_free);
                    st.act_comm_idle += start - st.act_channel_free;
                    st.act_channel_free = start + t_ag;
                    st.act_comm_busy += t_ag;
                    st.act_ag_tail = st.act_ag_tail.max(st.act_channel_free);
                    rec.record_chunk(inp, 2, 2, start, st.act_channel_free);
                }
            }
        }

        for &v in succ.row(id) {
            let v = v as NodeId;
            ws.ready[v] = ws.ready[v].max(done);
            // A chunked AR already relaxed its consumers' actual ready
            // times chunk-wise above; everything else propagates its
            // actual completion.
            if !chunked_ar {
                ws.ready_act[v] = ws.ready_act[v].max(done_act);
            }
            ws.indeg[v] -= 1;
            if ws.indeg[v] == 0 {
                ws.heap.push(Reverse((OrderedF64(ws.ready[v]), st.seq, v as u32)));
                st.seq += 1;
            }
        }
    }

    // All-gather tail: the updated-parameter all-gathers overlap the next
    // iteration's forward pass; only the portion the forward window
    // cannot hide extends the per-iteration time.
    if sharding && st.act_ag_tail > 0.0 {
        st.act_makespan = st.act_makespan.max(st.act_ag_tail - fwd_window);
    }
}

/// f64 wrapper with total order for the heap (times are never NaN).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OrderedF64(pub f64);

impl Eq for OrderedF64 {}

impl PartialOrd for OrderedF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrderedF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("NaN time")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::Role;

    /// Fixed-cost source: every compute op takes `comp` ms, every AllReduce
    /// `comm` ms.
    struct Fixed {
        comp: f64,
        comm: f64,
    }

    impl CostSource for Fixed {
        fn compute_time_ms(&self, _node: &Node) -> f64 {
            self.comp
        }
        fn comm_time_ms(&self, _bytes: f64) -> f64 {
            self.comm
        }
    }

    /// chain of `k` backward ops, each feeding an AllReduce + optimizer.
    fn bp_chain(k: usize) -> TrainingGraph {
        let mut b = GraphBuilder::new("chain", 4);
        let mut prev = b.constant("x", &[64]);
        for i in 0..k {
            let g = b.compute(OpKind::Mul, &format!("g{i}"), &[prev], &[64], Role::Backward);
            let p = b.param(&format!("w{i}"), &[64]);
            let ar = b.allreduce(&format!("ar{i}"), g, &[64]);
            b.optimizer_update(&format!("u{i}"), &[ar, p]);
            prev = g;
        }
        b.finish()
    }

    #[test]
    fn serial_chain_no_comm() {
        let g = bp_chain(5);
        let r = simulate(&g, &Fixed { comp: 1.0, comm: 0.0 }, SimOptions { ignore_comm: true, ..Default::default() });
        // 5 grads + 5 optimizer updates = 10 kernels of 1ms, serial device.
        assert_eq!(r.kernels, 10);
        assert_eq!(r.makespan_ms, 10.0);
        assert_eq!(r.comp_busy_ms, 10.0);
        assert_eq!(r.allreduces, 0);
        // Device never stalls: every kernel is ready by the time the
        // previous one finishes.
        assert_eq!(r.comp_idle_ms, 0.0);
    }

    #[test]
    fn workspace_reuse_identical_to_fresh() {
        let mut ws = SimWorkspace::new();
        let c = Fixed { comp: 0.7, comm: 1.3 };
        for k in [1usize, 6, 3] {
            let g = bp_chain(k);
            let fresh = simulate(&g, &c, SimOptions::default());
            let reused = simulate_in(&g, &c, SimOptions::default(), &mut NoRecord, &mut ws);
            assert_eq!(fresh, reused, "k={k}");
        }
    }

    #[test]
    fn comm_overlaps_compute() {
        let g = bp_chain(4);
        let r = simulate(&g, &Fixed { comp: 1.0, comm: 1.0 }, SimOptions::default());
        // 4 grads serial on device (t=1..4); AR_i starts at its grad's
        // completion, channel serializes; optimizer ops ride the device.
        assert_eq!(r.allreduces, 4);
        assert!(r.overlap_ratio() > 1.0, "overlap={}", r.overlap_ratio());
        // Makespan is far below full serialization.
        assert!(r.makespan_ms < r.comp_busy_ms + r.comm_busy_ms);
    }

    #[test]
    fn makespan_at_least_fo_bound() {
        let g = bp_chain(6);
        let c = Fixed { comp: 0.7, comm: 1.3 };
        let r = simulate(&g, &c, SimOptions::default());
        assert!(r.makespan_ms >= fo_bound(&g, &c) - 1e-9);
    }

    #[test]
    fn makespan_at_most_serial_sum() {
        let g = bp_chain(6);
        let c = Fixed { comp: 0.7, comm: 1.3 };
        let r = simulate(&g, &c, SimOptions::default());
        assert!(r.makespan_ms <= r.comp_busy_ms + r.comm_busy_ms + 1e-9);
    }

    #[test]
    fn channel_serializes_allreduces() {
        // One producer, two ARs on it: second waits for first.
        let mut b = GraphBuilder::new("two-ar", 2);
        let x = b.constant("x", &[64]);
        let gop = b.compute(OpKind::Mul, "g", &[x], &[64], Role::Backward);
        b.allreduce("ar1", gop, &[64]);
        b.allreduce("ar2", gop, &[64]);
        let g = b.finish();
        let r = simulate(&g, &Fixed { comp: 1.0, comm: 2.0 }, SimOptions::default());
        // grad done at 1; ar1 spans 1..3, ar2 3..5.
        assert_eq!(r.makespan_ms, 5.0);
        assert_eq!(r.comm_busy_ms, 4.0);
    }

    #[test]
    fn straggler_delays_comm() {
        let g = bp_chain(3);
        let base = simulate(&g, &Fixed { comp: 0.1, comm: 1.0 }, SimOptions::default());
        let slow = simulate(
            &g,
            &Fixed { comp: 0.1, comm: 1.0 },
            SimOptions { straggler_ms: 0.5, ignore_comm: false },
        );
        assert!(slow.makespan_ms > base.makespan_ms);
    }

    #[test]
    fn optimizer_waits_for_allreduce() {
        // comp=1, comm=10: the optimizer op for the first gradient cannot
        // start before its AR finishes at 1+10=11.
        let g = bp_chain(1);
        let r = simulate(&g, &Fixed { comp: 1.0, comm: 10.0 }, SimOptions::default());
        // grad 0..1, AR 1..11, optimizer 11..12.
        assert_eq!(r.makespan_ms, 12.0);
        // The device sat idle 1..11 waiting for the aggregated gradient;
        // the channel sat idle 0..1 waiting for the gradient.
        assert_eq!(r.comp_idle_ms, 10.0);
        assert_eq!(r.comm_idle_ms, 1.0);
    }

    #[test]
    fn cost_table_matches_dyn_path() {
        let g = bp_chain(6);
        let c = Fixed { comp: 0.7, comm: 1.3 };
        let table = CostTable::build(&g, &c);
        for n in g.live() {
            match n.kind {
                OpKind::AllReduce => {
                    assert_eq!(table.comm_ms(n.id), c.comm_time_ms(n.bytes_out))
                }
                OpKind::Parameter | OpKind::Constant => {
                    assert_eq!(table.compute_ms(n.id), 0.0)
                }
                _ => assert_eq!(table.compute_ms(n.id), c.compute_time_ms(n)),
            }
        }
        for opts in [
            SimOptions::default(),
            SimOptions { straggler_ms: 0.4, ignore_comm: false },
            SimOptions { straggler_ms: 0.0, ignore_comm: true },
        ] {
            let dynr = simulate(&g, &c, opts);
            let tabr =
                simulate_table_in(&g, &table, opts, &mut NoRecord, &mut SimWorkspace::new());
            assert_eq!(dynr, tabr);
        }
    }

    #[test]
    fn delta_replay_matches_full_after_fusion() {
        use crate::fusion::{fuse_ops_explain, FusionKind};
        let parent = bp_chain(8);
        let c = Fixed { comp: 0.7, comm: 1.3 };
        // Fuse two adjacent backward ops (late in the chain for a short
        // suffix; correctness must hold for any checkpoint cadence).
        let (p, s) = {
            let pairs = crate::fusion::op_fusion_candidates(&parent);
            *pairs.last().unwrap()
        };
        let mut child = parent.clone();
        let fx = fuse_ops_explain(&mut child, p, s, FusionKind::NonDuplicate).unwrap();
        let mut frontier = vec![p, s];
        fx.extend_frontier(&child, &mut frontier);

        for opts in [
            SimOptions::default(),
            SimOptions { straggler_ms: 0.3, ignore_comm: false },
            SimOptions { straggler_ms: 0.0, ignore_comm: true },
        ] {
            for every in [1usize, 3, 1000] {
                let mut ws = SimWorkspace::new();
                let parent_table = CostTable::build(&parent, &c);
                let mut log = CheckpointLog::new();
                let _ = simulate_ckpt_in(
                    &parent,
                    &parent_table,
                    opts,
                    &mut NoRecord,
                    &mut ws,
                    &mut log,
                    every,
                );
                assert_eq!(log.events(), parent.live_count());
                assert!(log.snapshots() >= 1);
                let mut child_table = CostTable::new();
                child_table.extend_in(&parent_table, &child, &c);
                let delta = simulate_delta(
                    &parent,
                    &log,
                    &child,
                    &frontier,
                    &child_table,
                    opts,
                    &mut NoRecord,
                    &mut ws,
                );
                let full = simulate_table_in(
                    &child,
                    &child_table,
                    opts,
                    &mut NoRecord,
                    &mut SimWorkspace::new(),
                );
                assert_eq!(delta, full, "every={every} opts={opts:?}");
            }
        }
    }

    #[test]
    fn delta_preserves_duplicate_operand_consumers() {
        use crate::fusion::{fuse_ops_explain, FusionKind};
        // sq consumes m twice (x·x style). An unrelated fusion must leave
        // sq's operand list — and hence its indegree and the delta replay —
        // untouched.
        let mut b = GraphBuilder::new("dup", 4);
        let x = b.constant("x", &[64]);
        let m = b.compute(OpKind::Mul, "m", &[x], &[64], Role::Forward);
        let sq = b.compute(OpKind::Mul, "sq", &[m, m], &[64], Role::Forward);
        let t1 = b.compute(OpKind::Tanh, "t1", &[sq], &[64], Role::Backward);
        let t2 = b.compute(OpKind::Sigmoid, "t2", &[t1], &[64], Role::Backward);
        b.allreduce("ar", t2, &[64]);
        let parent = b.finish();
        assert_eq!(parent.nodes[sq].inputs, vec![m, m]);

        let mut child = parent.clone();
        let fx = fuse_ops_explain(&mut child, t1, t2, FusionKind::NonDuplicate).unwrap();
        assert_eq!(child.nodes[sq].inputs, vec![m, m], "unrelated fusion edited sq");
        let mut frontier = vec![t1, t2];
        fx.extend_frontier(&child, &mut frontier);

        let c = Fixed { comp: 0.5, comm: 1.1 };
        let mut ws = SimWorkspace::new();
        let parent_table = CostTable::build(&parent, &c);
        let mut log = CheckpointLog::new();
        let _ = simulate_ckpt_in(
            &parent,
            &parent_table,
            SimOptions::default(),
            &mut NoRecord,
            &mut ws,
            &mut log,
            2,
        );
        let mut child_table = CostTable::new();
        child_table.extend_in(&parent_table, &child, &c);
        let delta = simulate_delta(
            &parent,
            &log,
            &child,
            &frontier,
            &child_table,
            SimOptions::default(),
            &mut NoRecord,
            &mut ws,
        );
        let full = simulate_table_in(
            &child,
            &child_table,
            SimOptions::default(),
            &mut NoRecord,
            &mut SimWorkspace::new(),
        );
        assert_eq!(delta, full);
    }

    #[test]
    fn extended_table_matches_fresh_build() {
        use crate::fusion::{fuse_ops, FusionKind};
        let parent = bp_chain(5);
        let c = Fixed { comp: 0.9, comm: 0.2 };
        let parent_table = CostTable::build(&parent, &c);
        let mut child = parent.clone();
        let (p, s) = *crate::fusion::op_fusion_candidates(&parent).first().unwrap();
        fuse_ops(&mut child, p, s, FusionKind::NonDuplicate).unwrap();
        let mut extended = CostTable::new();
        extended.extend_in(&parent_table, &child, &c);
        let fresh = CostTable::build(&child, &c);
        assert_eq!(extended.len(), fresh.len());
        for n in child.live() {
            assert_eq!(extended.compute_ms(n.id), fresh.compute_ms(n.id), "node {}", n.id);
            assert_eq!(extended.comm_ms(n.id), fresh.comm_ms(n.id), "node {}", n.id);
        }
    }

    #[test]
    fn fo_bound_is_max_of_totals() {
        let g = bp_chain(4);
        let c = Fixed { comp: 2.0, comm: 1.0 };
        // 8 compute ops * 2ms = 16; 4 ARs * 1ms = 4.
        assert_eq!(fo_bound(&g, &c), 16.0);
        let c2 = Fixed { comp: 0.1, comm: 5.0 };
        assert_eq!(fo_bound(&g, &c2), 20.0);
    }

    use crate::graph::ChunkSpec;

    /// Like [`Fixed`] but with a per-collective overhead.
    struct FixedOver {
        comp: f64,
        comm: f64,
        over: f64,
    }

    impl CostSource for FixedOver {
        fn compute_time_ms(&self, _node: &Node) -> f64 {
            self.comp
        }
        fn comm_time_ms(&self, _bytes: f64) -> f64 {
            self.comm
        }
        fn comm_overhead_ms(&self) -> f64 {
            self.over
        }
    }

    #[test]
    fn chunking_overlaps_allreduce_with_optimizer() {
        // comp=1, comm=10, unchunked: grad 0..1, AR 1..11, opt 11..12.
        // Chunked k=2 (no overhead): chunks land at 6 and 11; the opt
        // processes each landed half in 0.5ms, so its effective ready time
        // is max(L1, L2 − 0.5) = 10.5 and it finishes at 11.5.
        let mut g = bp_chain(1);
        let ar = g.allreduces()[0];
        let c = Fixed { comp: 1.0, comm: 10.0 };
        assert_eq!(simulate(&g, &c, SimOptions::default()).makespan_ms, 12.0);
        g.nodes[ar].chunk = Some(ChunkSpec::new(2));
        let r2 = simulate(&g, &c, SimOptions::default());
        assert_eq!(r2.makespan_ms, 11.5);
        // Busy totals are schedule facts, identical to the unchunked run.
        assert_eq!(r2.comp_busy_ms, 2.0);
        assert_eq!(r2.comm_busy_ms, 10.0);
        assert_eq!(r2.allreduces, 1);
        // k=4: L1 = 3.5, ready = max(3.5, 11 − 3·0.25) = 10.25 → 11.25.
        g.nodes[ar].chunk = Some(ChunkSpec::new(4));
        assert_eq!(simulate(&g, &c, SimOptions::default()).makespan_ms, 11.25);
    }

    #[test]
    fn chunk_overhead_delays_first_land() {
        // Compute-heavy consumer (comp=16 ≫ per-chunk stream): the first
        // land time governs. k=4, comm=10: grad 0..16, AR 16..26.
        // D=2: L1 = 16+2+2 = 20 → opt 20..36. D=0: L1 = 18.5 → 34.5.
        let mut g = bp_chain(1);
        let ar = g.allreduces()[0];
        g.nodes[ar].chunk = Some(ChunkSpec::new(4));
        let with_over = FixedOver { comp: 16.0, comm: 10.0, over: 2.0 };
        let no_over = FixedOver { comp: 16.0, comm: 10.0, over: 0.0 };
        assert_eq!(simulate(&g, &with_over, SimOptions::default()).makespan_ms, 36.0);
        assert_eq!(simulate(&g, &no_over, SimOptions::default()).makespan_ms, 34.5);
    }

    #[test]
    fn chunk_count_one_is_bit_identical_to_unchunked() {
        // count <= 1 is canonically unchunked: the gate routes through the
        // pre-chunk event loop, so results are the same bits.
        let c = Fixed { comp: 0.7, comm: 1.3 };
        for k in [1usize, 4, 7] {
            let g = bp_chain(k);
            let base = simulate(&g, &c, SimOptions::default());
            let mut g1 = g.clone();
            for ar in g1.allreduces() {
                g1.nodes[ar].chunk = Some(ChunkSpec::new(1));
            }
            assert!(!g1.has_chunking());
            assert_eq!(simulate(&g1, &c, SimOptions::default()), base);
        }
    }

    #[test]
    fn chunking_never_worse_flat_network() {
        // Exact (no tolerance): every actual value is clamped to its
        // conservative counterpart per event.
        for n in [1usize, 3, 6] {
            for count in [2u32, 3, 5, 8] {
                for (comp, comm, over) in
                    [(1.0, 10.0, 0.0), (0.3, 2.7, 0.4), (5.0, 1.0, 0.1), (1.0, 1.0, 1.0)]
                {
                    let base = bp_chain(n);
                    let mut g = base.clone();
                    for ar in g.allreduces() {
                        g.nodes[ar].chunk = Some(ChunkSpec::new(count));
                    }
                    let c = FixedOver { comp, comm, over };
                    let whole = simulate(&base, &c, SimOptions::default());
                    let chunked = simulate(&g, &c, SimOptions::default());
                    assert!(
                        chunked.makespan_ms <= whole.makespan_ms,
                        "n={n} count={count} comp={comp} comm={comm} over={over}: \
                         {} > {}",
                        chunked.makespan_ms,
                        whole.makespan_ms
                    );
                    assert_eq!(chunked.comp_busy_ms, whole.comp_busy_ms);
                    assert_eq!(chunked.comm_busy_ms, whole.comm_busy_ms);
                    assert_eq!(chunked.peak_bytes, whole.peak_bytes);
                }
            }
        }
    }

    /// [`bp_chain`] with tensors wide enough for legal vocabulary
    /// chunkings (16 KiB gradients).
    fn bp_chain_wide(k: usize) -> TrainingGraph {
        let mut b = GraphBuilder::new("chainw", 4);
        let mut prev = b.constant("x", &[1 << 12]);
        for i in 0..k {
            let g = b.compute(OpKind::Mul, &format!("g{i}"), &[prev], &[1 << 12], Role::Backward);
            let p = b.param(&format!("w{i}"), &[1 << 12]);
            let ar = b.allreduce(&format!("ar{i}"), g, &[1 << 12]);
            b.optimizer_update(&format!("u{i}"), &[ar, p]);
            prev = g;
        }
        b.finish()
    }

    #[test]
    fn chunked_delta_matches_full_all_mode_combos() {
        use crate::fusion::set_chunks_explain;
        let c = FixedOver { comp: 0.7, comm: 1.3, over: 0.2 };
        // (parent chunked?, child chunked?) — chunk mutations drive all
        // three reachable combinations; (false, false) is the pre-chunk
        // path covered by the existing delta tests.
        for (parent_chunked, child_mutation_count) in
            [(false, 8u32), (true, 8u32), (true, 1u32)]
        {
            let mut parent = bp_chain_wide(6);
            if parent_chunked {
                let ar0 = parent.allreduces()[0];
                set_chunks_explain(&mut parent, ar0, 4).unwrap();
            }
            // Mutate: re-chunk (or un-chunk) an AR. For the un-chunk case
            // target the same AR so the child ends fully unchunked.
            let target = if child_mutation_count == 1 {
                parent.allreduces()[0]
            } else {
                *parent.allreduces().last().unwrap()
            };
            let mut child = parent.clone();
            let fx = set_chunks_explain(&mut child, target, child_mutation_count).unwrap();
            let mut frontier = vec![target];
            fx.extend_frontier(&child, &mut frontier);
            if child_mutation_count == 1 {
                assert!(!child.has_chunking());
            } else {
                assert!(child.has_chunking());
            }

            for opts in [
                SimOptions::default(),
                SimOptions { straggler_ms: 0.3, ignore_comm: false },
            ] {
                for every in [1usize, 3, 1000] {
                    let mut ws = SimWorkspace::new();
                    let parent_table = CostTable::build(&parent, &c);
                    let mut log = CheckpointLog::new();
                    let _ = simulate_ckpt_in(
                        &parent,
                        &parent_table,
                        opts,
                        &mut NoRecord,
                        &mut ws,
                        &mut log,
                        every,
                    );
                    assert_eq!(log.extended, parent.has_chunking());
                    let mut child_table = CostTable::new();
                    child_table.extend_in(&parent_table, &child, &c);
                    let delta = simulate_delta(
                        &parent,
                        &log,
                        &child,
                        &frontier,
                        &child_table,
                        opts,
                        &mut NoRecord,
                        &mut ws,
                    );
                    let full = simulate_table_in(
                        &child,
                        &child_table,
                        opts,
                        &mut NoRecord,
                        &mut SimWorkspace::new(),
                    );
                    assert_eq!(
                        delta, full,
                        "parent_chunked={parent_chunked} count={child_mutation_count} \
                         every={every} opts={opts:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn sharding_splits_collective_and_overlaps_allgather() {
        use crate::fusion::set_sharding_explain;
        use crate::graph::CollectiveKind;
        // comp=1, comm=10, W=4, no overhead. DDP: grad 0..1, AR 1..11,
        // opt 11..12 → 12. Sharded: RS 1..6 (t/2), opt on the local
        // shard 6..6.25 (t/W), AG 6.25..11.25; no forward window in a
        // bp chain, so the AG tail is fully exposed → 11.25.
        let mut g = bp_chain(1);
        let ar = g.allreduces()[0];
        let c = Fixed { comp: 1.0, comm: 10.0 };
        assert_eq!(simulate(&g, &c, SimOptions::default()).makespan_ms, 12.0);
        set_sharding_explain(&mut g, ar, CollectiveKind::ReduceScatterAllGather).unwrap();
        assert!(g.has_sharding());
        let r = simulate(&g, &c, SimOptions::default());
        assert_eq!(r.makespan_ms, 11.25);
        // Actual occupancy: RS + AG on the channel, grad + sharded opt
        // on the device.
        assert_eq!(r.comm_busy_ms, 10.0);
        assert_eq!(r.comp_busy_ms, 1.25);
        assert_eq!(r.allreduces, 1);
    }

    #[test]
    fn sharding_pays_overhead_twice_no_clamp() {
        use crate::fusion::set_sharding_explain;
        use crate::graph::CollectiveKind;
        // With per-phase overhead D=2: t_rs = t_ag = (10−2)/2 + 2 = 6.
        // RS 1..7, opt 7..7.25, AG 7.25..13.25 — worse than the 12ms DDP
        // run. Sharding has no never-worse clamp; the search must reject
        // this candidate on merit.
        let mut g = bp_chain(1);
        let ar = g.allreduces()[0];
        let c = FixedOver { comp: 1.0, comm: 10.0, over: 2.0 };
        assert_eq!(simulate(&g, &c, SimOptions::default()).makespan_ms, 12.0);
        set_sharding_explain(&mut g, ar, CollectiveKind::ReduceScatterAllGather).unwrap();
        let r = simulate(&g, &c, SimOptions::default());
        assert_eq!(r.makespan_ms, 13.25);
    }

    #[test]
    fn sharded_allgather_hides_behind_forward_window() {
        use crate::fusion::set_sharding_explain;
        use crate::graph::CollectiveKind;
        // One forward op extends the overlap window: the AG tail counts
        // only past Σ(forward compute).
        let mut b = GraphBuilder::new("fwd", 4);
        let x = b.constant("x", &[64]);
        let f = b.compute(OpKind::Mul, "f", &[x], &[64], Role::Forward);
        let gr = b.compute(OpKind::Mul, "g", &[f], &[64], Role::Backward);
        let p = b.param("w", &[64]);
        let ar = b.allreduce("ar", gr, &[64]);
        b.optimizer_update("u", &[ar, p]);
        let mut g = b.finish();
        let c = Fixed { comp: 1.0, comm: 10.0 };
        // DDP: f 0..1, g 1..2, AR 2..12, opt 12..13.
        assert_eq!(simulate(&g, &c, SimOptions::default()).makespan_ms, 13.0);
        set_sharding_explain(&mut g, ar, CollectiveKind::ReduceScatterAllGather).unwrap();
        // RS 2..7, opt 7..7.25, AG 7.25..12.25; fwd window = 1 hides 1ms
        // of the tail: max(7.25, 12.25 − 1) = 11.25.
        let r = simulate(&g, &c, SimOptions::default());
        assert_eq!(r.makespan_ms, 11.25);
    }

    #[test]
    fn sharded_ignore_comm_skips_both_phases() {
        use crate::fusion::set_sharding_explain;
        use crate::graph::CollectiveKind;
        // ignore_comm drops RS and AG entirely; the sharded optimizer
        // still runs t/W on the actual device (its sharding is a compute
        // fact, not a communication one). bp_chain(1): grad 0..1,
        // opt 1..1.25.
        let mut g = bp_chain(1);
        let ar = g.allreduces()[0];
        set_sharding_explain(&mut g, ar, CollectiveKind::ReduceScatterAllGather).unwrap();
        let c = Fixed { comp: 1.0, comm: 10.0 };
        let r = simulate(&g, &c, SimOptions { ignore_comm: true, ..Default::default() });
        assert_eq!(r.makespan_ms, 1.25);
        assert_eq!(r.comm_busy_ms, 0.0);
        assert_eq!(r.allreduces, 0);
    }

    #[test]
    fn sharded_delta_matches_full_all_mode_combos() {
        use crate::fusion::{set_chunks_explain, set_sharding_explain};
        use crate::graph::CollectiveKind;
        let c = FixedOver { comp: 0.7, comm: 1.3, over: 0.2 };
        // (parent sharded?, parent chunked?, child unshards?) — covers
        // plain→sharded, sharded→more-sharded, sharded→plain, and the
        // mixed chunk+shard graph, each against a full re-simulation.
        for (parent_sharded, parent_chunked, child_unshards) in [
            (false, false, false),
            (true, false, false),
            (true, false, true),
            (false, true, false),
        ] {
            let mut parent = bp_chain_wide(6);
            if parent_sharded {
                let ar0 = parent.allreduces()[0];
                set_sharding_explain(&mut parent, ar0, CollectiveKind::ReduceScatterAllGather)
                    .unwrap();
            }
            if parent_chunked {
                let ar0 = parent.allreduces()[0];
                set_chunks_explain(&mut parent, ar0, 4).unwrap();
            }
            let (target, kind) = if child_unshards {
                (parent.allreduces()[0], CollectiveKind::AllReduce)
            } else {
                (*parent.allreduces().last().unwrap(), CollectiveKind::ReduceScatterAllGather)
            };
            let mut child = parent.clone();
            let fx = set_sharding_explain(&mut child, target, kind).unwrap();
            let mut frontier = vec![target];
            fx.extend_frontier(&child, &mut frontier);
            assert_eq!(child.has_sharding(), !child_unshards);

            for opts in [
                SimOptions::default(),
                SimOptions { straggler_ms: 0.3, ignore_comm: false },
            ] {
                for every in [1usize, 3, 1000] {
                    let mut ws = SimWorkspace::new();
                    let parent_table = CostTable::build(&parent, &c);
                    let mut log = CheckpointLog::new();
                    let _ = simulate_ckpt_in(
                        &parent,
                        &parent_table,
                        opts,
                        &mut NoRecord,
                        &mut ws,
                        &mut log,
                        every,
                    );
                    assert_eq!(
                        log.extended,
                        parent.has_chunking() || parent.has_sharding()
                    );
                    let mut child_table = CostTable::new();
                    child_table.extend_in(&parent_table, &child, &c);
                    let delta = simulate_delta(
                        &parent,
                        &log,
                        &child,
                        &frontier,
                        &child_table,
                        opts,
                        &mut NoRecord,
                        &mut ws,
                    );
                    let full = simulate_table_in(
                        &child,
                        &child_table,
                        opts,
                        &mut NoRecord,
                        &mut SimWorkspace::new(),
                    );
                    assert_eq!(
                        delta, full,
                        "sharded={parent_sharded} chunked={parent_chunked} \
                         unshards={child_unshards} every={every} opts={opts:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn chunked_only_graph_unaffected_by_sharding_machinery() {
        // The extended loop's sharding paths are all gated on
        // `has_sharding()`; a chunked-only graph must keep its exact
        // pre-sharding arithmetic (bit-identical busy totals to the
        // conservative track).
        let mut g = bp_chain(3);
        let ar = g.allreduces()[1];
        g.nodes[ar].chunk = Some(ChunkSpec::new(4));
        let c = FixedOver { comp: 1.0, comm: 5.0, over: 0.5 };
        let r = simulate(&g, &c, SimOptions::default());
        let mut stripped = g.clone();
        stripped.nodes[ar].chunk = None;
        let base = simulate(&stripped, &c, SimOptions::default());
        assert_eq!(r.comp_busy_ms, base.comp_busy_ms);
        assert_eq!(r.comm_busy_ms, base.comm_busy_ms);
        assert!(r.makespan_ms <= base.makespan_ms);
    }
}
