//! Discrete-event simulation of one training iteration (paper §4.4).
//!
//! Two resources model the worker: a **compute stream** (the GPU executes
//! one kernel at a time) and a **communication channel** (one AllReduce in
//! flight at a time — NCCL's in-order collective channel). Computation and
//! communication overlap freely; the only coupling is data dependencies
//! (an AllReduce starts once its (fused) gradient tensor is produced; an
//! optimizer op starts once its aggregated gradient arrives).
//!
//! The same engine backs both
//! * the **cost model** `Cost(H)` used by the search (clean per-op times
//!   from a [`CostSource`], paper's Simulator), and
//! * the **high-fidelity "real execution"** ([`hifi`]) that substitutes for
//!   the paper's physical testbed: per-op noise, per-worker jitter and
//!   AllReduce straggler synchronization (see DESIGN.md §2).

pub mod hifi;
pub mod trace;

use crate::graph::{Node, NodeId, OpKind, TrainingGraph};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Observer of scheduling decisions (Chrome-trace export, debugging).
/// The no-op implementation compiles away in the search hot path.
pub trait Recorder {
    fn record(&mut self, _node: &Node, _start_ms: f64, _end_ms: f64, _comm: bool) {}
}

/// Default no-op recorder.
pub struct NoRecord;

impl Recorder for NoRecord {}

/// Where per-node times come from. The searcher's estimator implements
/// this; the hi-fi simulator implements it with the noisy device model.
pub trait CostSource {
    /// Execution time of a computation node, ms.
    fn compute_time_ms(&self, node: &Node) -> f64;
    /// AllReduce time for a (fused) gradient tensor of `bytes`, ms.
    fn comm_time_ms(&self, bytes: f64) -> f64;
    /// Hook called once per candidate graph before simulation — cost
    /// sources with batched backends (the GNN estimator) prefetch every
    /// fused-op prediction here. Default: no-op.
    fn prepare(&self, _graph: &TrainingGraph) {}
}

/// Simulation knobs.
#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    /// Extra delay added to every AllReduce start, modelling worker skew
    /// (0 in the cost model; >0 in hi-fi runs).
    pub straggler_ms: f64,
    /// If true, AllReduces are skipped entirely (single-device runs,
    /// Fig. 8).
    pub ignore_comm: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { straggler_ms: 0.0, ignore_comm: false }
    }
}

/// Result of simulating one iteration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimResult {
    /// End-to-end per-iteration time (makespan), ms.
    pub makespan_ms: f64,
    /// Total compute-stream busy time, ms (Fig. 7 "computation time").
    pub comp_busy_ms: f64,
    /// Total channel busy time, ms (Fig. 7 "communication time").
    pub comm_busy_ms: f64,
    /// Total compute-stream idle time spent waiting on dependencies: the
    /// sum over kernels of `start − device_free_before` (dependency
    /// stalls, not tail idle after the last kernel).
    pub comp_idle_ms: f64,
    /// Same for the communication channel: time the channel sat idle
    /// between collectives waiting for a gradient to be produced.
    pub comm_idle_ms: f64,
    /// Number of scheduled compute kernels.
    pub kernels: usize,
    /// Number of AllReduce operations executed.
    pub allreduces: usize,
    /// Peak device-memory footprint of live intermediate tensors, bytes
    /// (refcounted: an output is freed once its last consumer completes).
    /// One of op fusion's benefits the paper cites — fewer materialized
    /// intermediates — made measurable.
    pub peak_bytes: f64,
}

impl SimResult {
    /// The paper's overlap metric (§6.3): (comp + comm) / makespan.
    /// Values > 1 mean overlap; 1 means fully serialized.
    pub fn overlap_ratio(&self) -> f64 {
        if self.makespan_ms == 0.0 {
            1.0
        } else {
            (self.comp_busy_ms + self.comm_busy_ms) / self.makespan_ms
        }
    }
}

/// Fully-overlapped lower bound (the paper's "FO" line in Fig. 6):
/// computation and communication each run back-to-back with perfect
/// overlap and no dependency stalls.
pub fn fo_bound(graph: &TrainingGraph, costs: &dyn CostSource) -> f64 {
    let mut comp = 0.0;
    let mut comm = 0.0;
    for n in graph.live() {
        match n.kind {
            OpKind::AllReduce => comm += costs.comm_time_ms(n.bytes_out),
            OpKind::Parameter | OpKind::Constant => {}
            _ => comp += costs.compute_time_ms(n),
        }
    }
    comp.max(comm)
}

/// Reusable per-evaluation scratch state for [`simulate_in`]: the ready
/// heap, in-degrees, ready times and memory refcounts. One workspace per
/// simulating thread; reusing it across evaluations makes a full search
/// perform zero per-eval scratch allocations once the vectors have grown
/// to the largest graph seen (see `rust/PERF.md`).
#[derive(Debug, Default)]
pub struct SimWorkspace {
    indeg: Vec<u32>,
    ready: Vec<f64>,
    consumers_left: Vec<u32>,
    heap: BinaryHeap<Reverse<(OrderedF64, u32, u32)>>,
}

impl SimWorkspace {
    pub fn new() -> SimWorkspace {
        SimWorkspace::default()
    }

    /// Reset for a graph of `n` arena slots. Keeps capacity.
    fn reset(&mut self, n: usize) {
        self.indeg.clear();
        self.indeg.resize(n, 0);
        self.ready.clear();
        self.ready.resize(n, 0.0);
        self.consumers_left.clear();
        self.consumers_left.resize(n, 0);
        self.heap.clear();
    }
}

/// Simulate one training iteration of `graph` under `costs`.
///
/// Scheduling discipline: per resource, earliest-ready-first (FIFO on
/// ready time, ties broken by enqueue sequence) — the paper's ready-queue
/// process, with AllReduces "executed in order of production of their
/// respective gradient tensors".
pub fn simulate(graph: &TrainingGraph, costs: &dyn CostSource, opts: SimOptions) -> SimResult {
    simulate_with(graph, costs, opts, &mut NoRecord)
}

/// [`simulate`] with a scheduling observer (Chrome-trace export etc.).
/// Thin wrapper allocating a fresh workspace; hot paths call
/// [`simulate_in`] with a reused one.
pub fn simulate_with<R: Recorder>(
    graph: &TrainingGraph,
    costs: &dyn CostSource,
    opts: SimOptions,
    rec: &mut R,
) -> SimResult {
    simulate_in(graph, costs, opts, rec, &mut SimWorkspace::new())
}

/// Core event loop: [`simulate_with`] threaded through a caller-owned
/// [`SimWorkspace`]. Bit-identical to a fresh-workspace run (property
/// test `prop_sim_workspace_reuse_identical`).
pub fn simulate_in<R: Recorder>(
    graph: &TrainingGraph,
    costs: &dyn CostSource,
    opts: SimOptions,
    rec: &mut R,
    ws: &mut SimWorkspace,
) -> SimResult {
    let n = graph.nodes.len();
    let succ = graph.succ_csr();
    ws.reset(n);

    // (ready_time, seq, id) min-heap over BOTH resources; popping in global
    // ready order keeps each resource's discipline consistent (a newly
    // enabled node is never ready earlier than the node that enabled it).
    let mut seq = 0u32;

    for node in graph.live() {
        ws.indeg[node.id] = node.inputs.len() as u32;
        // Memory refcounting: an intermediate lives from its producer's
        // completion until its last consumer's completion. Parameters and
        // constants are persistent state, excluded from the peak.
        ws.consumers_left[node.id] = succ.out_degree(node.id) as u32;
        if node.inputs.is_empty() {
            ws.heap.push(Reverse((OrderedF64(0.0), seq, node.id as u32)));
            seq += 1;
        }
    }

    let mut device_free = 0.0f64;
    let mut channel_free = 0.0f64;
    let mut comp_busy = 0.0f64;
    let mut comm_busy = 0.0f64;
    let mut comp_idle = 0.0f64;
    let mut comm_idle = 0.0f64;
    let mut kernels = 0usize;
    let mut allreduces = 0usize;
    let mut makespan = 0.0f64;
    let mut scheduled = 0usize;

    let mut live_bytes = 0.0f64;
    let mut peak_bytes = 0.0f64;
    let transient =
        |node: &Node| !matches!(node.kind, OpKind::Parameter | OpKind::Constant);

    while let Some(Reverse((OrderedF64(rt), _s, id))) = ws.heap.pop() {
        let id = id as NodeId;
        let node = &graph.nodes[id];
        let done = match node.kind {
            OpKind::AllReduce => {
                if opts.ignore_comm {
                    rt
                } else {
                    let start = (rt + opts.straggler_ms).max(channel_free);
                    comm_idle += start - channel_free;
                    let t = costs.comm_time_ms(node.bytes_out);
                    channel_free = start + t;
                    comm_busy += t;
                    allreduces += 1;
                    rec.record(node, start, channel_free, true);
                    channel_free
                }
            }
            OpKind::Parameter | OpKind::Constant => rt,
            _ => {
                let t = costs.compute_time_ms(node);
                let start = rt.max(device_free);
                comp_idle += start - device_free;
                device_free = start + t;
                comp_busy += t;
                kernels += 1;
                rec.record(node, start, device_free, false);
                device_free
            }
        };
        makespan = makespan.max(done);
        scheduled += 1;

        if transient(node) {
            live_bytes += node.bytes_out;
            peak_bytes = peak_bytes.max(live_bytes);
        }
        for &i in &node.inputs {
            ws.consumers_left[i] -= 1;
            if ws.consumers_left[i] == 0 && transient(&graph.nodes[i]) {
                live_bytes -= graph.nodes[i].bytes_out;
            }
        }

        for &v in succ.row(id) {
            let v = v as NodeId;
            ws.ready[v] = ws.ready[v].max(done);
            ws.indeg[v] -= 1;
            if ws.indeg[v] == 0 {
                ws.heap.push(Reverse((OrderedF64(ws.ready[v]), seq, v as u32)));
                seq += 1;
            }
        }
    }
    debug_assert_eq!(scheduled, graph.live_count(), "graph has a cycle?");

    SimResult {
        makespan_ms: makespan,
        comp_busy_ms: comp_busy,
        comm_busy_ms: comm_busy,
        comp_idle_ms: comp_idle,
        comm_idle_ms: comm_idle,
        kernels,
        allreduces,
        peak_bytes,
    }
}

/// f64 wrapper with total order for the heap (times are never NaN).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OrderedF64(pub f64);

impl Eq for OrderedF64 {}

impl PartialOrd for OrderedF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrderedF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("NaN time")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::Role;

    /// Fixed-cost source: every compute op takes `comp` ms, every AllReduce
    /// `comm` ms.
    struct Fixed {
        comp: f64,
        comm: f64,
    }

    impl CostSource for Fixed {
        fn compute_time_ms(&self, _node: &Node) -> f64 {
            self.comp
        }
        fn comm_time_ms(&self, _bytes: f64) -> f64 {
            self.comm
        }
    }

    /// chain of `k` backward ops, each feeding an AllReduce + optimizer.
    fn bp_chain(k: usize) -> TrainingGraph {
        let mut b = GraphBuilder::new("chain", 4);
        let mut prev = b.constant("x", &[64]);
        for i in 0..k {
            let g = b.compute(OpKind::Mul, &format!("g{i}"), &[prev], &[64], Role::Backward);
            let p = b.param(&format!("w{i}"), &[64]);
            let ar = b.allreduce(&format!("ar{i}"), g, &[64]);
            b.optimizer_update(&format!("u{i}"), &[ar, p]);
            prev = g;
        }
        b.finish()
    }

    #[test]
    fn serial_chain_no_comm() {
        let g = bp_chain(5);
        let r = simulate(&g, &Fixed { comp: 1.0, comm: 0.0 }, SimOptions { ignore_comm: true, ..Default::default() });
        // 5 grads + 5 optimizer updates = 10 kernels of 1ms, serial device.
        assert_eq!(r.kernels, 10);
        assert_eq!(r.makespan_ms, 10.0);
        assert_eq!(r.comp_busy_ms, 10.0);
        assert_eq!(r.allreduces, 0);
        // Device never stalls: every kernel is ready by the time the
        // previous one finishes.
        assert_eq!(r.comp_idle_ms, 0.0);
    }

    #[test]
    fn workspace_reuse_identical_to_fresh() {
        let mut ws = SimWorkspace::new();
        let c = Fixed { comp: 0.7, comm: 1.3 };
        for k in [1usize, 6, 3] {
            let g = bp_chain(k);
            let fresh = simulate(&g, &c, SimOptions::default());
            let reused = simulate_in(&g, &c, SimOptions::default(), &mut NoRecord, &mut ws);
            assert_eq!(fresh, reused, "k={k}");
        }
    }

    #[test]
    fn comm_overlaps_compute() {
        let g = bp_chain(4);
        let r = simulate(&g, &Fixed { comp: 1.0, comm: 1.0 }, SimOptions::default());
        // 4 grads serial on device (t=1..4); AR_i starts at its grad's
        // completion, channel serializes; optimizer ops ride the device.
        assert_eq!(r.allreduces, 4);
        assert!(r.overlap_ratio() > 1.0, "overlap={}", r.overlap_ratio());
        // Makespan is far below full serialization.
        assert!(r.makespan_ms < r.comp_busy_ms + r.comm_busy_ms);
    }

    #[test]
    fn makespan_at_least_fo_bound() {
        let g = bp_chain(6);
        let c = Fixed { comp: 0.7, comm: 1.3 };
        let r = simulate(&g, &c, SimOptions::default());
        assert!(r.makespan_ms >= fo_bound(&g, &c) - 1e-9);
    }

    #[test]
    fn makespan_at_most_serial_sum() {
        let g = bp_chain(6);
        let c = Fixed { comp: 0.7, comm: 1.3 };
        let r = simulate(&g, &c, SimOptions::default());
        assert!(r.makespan_ms <= r.comp_busy_ms + r.comm_busy_ms + 1e-9);
    }

    #[test]
    fn channel_serializes_allreduces() {
        // One producer, two ARs on it: second waits for first.
        let mut b = GraphBuilder::new("two-ar", 2);
        let x = b.constant("x", &[64]);
        let gop = b.compute(OpKind::Mul, "g", &[x], &[64], Role::Backward);
        b.allreduce("ar1", gop, &[64]);
        b.allreduce("ar2", gop, &[64]);
        let g = b.finish();
        let r = simulate(&g, &Fixed { comp: 1.0, comm: 2.0 }, SimOptions::default());
        // grad done at 1; ar1 spans 1..3, ar2 3..5.
        assert_eq!(r.makespan_ms, 5.0);
        assert_eq!(r.comm_busy_ms, 4.0);
    }

    #[test]
    fn straggler_delays_comm() {
        let g = bp_chain(3);
        let base = simulate(&g, &Fixed { comp: 0.1, comm: 1.0 }, SimOptions::default());
        let slow = simulate(
            &g,
            &Fixed { comp: 0.1, comm: 1.0 },
            SimOptions { straggler_ms: 0.5, ignore_comm: false },
        );
        assert!(slow.makespan_ms > base.makespan_ms);
    }

    #[test]
    fn optimizer_waits_for_allreduce() {
        // comp=1, comm=10: the optimizer op for the first gradient cannot
        // start before its AR finishes at 1+10=11.
        let g = bp_chain(1);
        let r = simulate(&g, &Fixed { comp: 1.0, comm: 10.0 }, SimOptions::default());
        // grad 0..1, AR 1..11, optimizer 11..12.
        assert_eq!(r.makespan_ms, 12.0);
        // The device sat idle 1..11 waiting for the aggregated gradient;
        // the channel sat idle 0..1 waiting for the gradient.
        assert_eq!(r.comp_idle_ms, 10.0);
        assert_eq!(r.comm_idle_ms, 1.0);
    }

    #[test]
    fn fo_bound_is_max_of_totals() {
        let g = bp_chain(4);
        let c = Fixed { comp: 2.0, comm: 1.0 };
        // 8 compute ops * 2ms = 16; 4 ARs * 1ms = 4.
        assert_eq!(fo_bound(&g, &c), 16.0);
        let c2 = Fixed { comp: 0.1, comm: 5.0 };
        assert_eq!(fo_bound(&g, &c2), 20.0);
    }
}
