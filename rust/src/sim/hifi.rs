//! High-fidelity "real execution" — the stand-in for running the optimized
//! module on the paper's physical clusters (Table 2's ground truth).
//!
//! On top of the clean event engine this adds everything a real testbed has
//! that the cost model doesn't know about:
//!
//! * multiplicative lognormal noise on every kernel time (DVFS, cache
//!   effects) — from [`DeviceModel::measure_ms`];
//! * per-op host-side launch scheduling overhead (the framework's CPU
//!   time between kernels);
//! * AllReduce straggler synchronization: an AllReduce can only start when
//!   the *slowest* worker reaches it, modelled as the max of `W` half-normal
//!   skews per collective;
//! * noisy link bandwidth per collective.
//!
//! Running several iterations and averaging mirrors how per-iteration time
//! is measured in the paper's experiments.

use super::{simulate, CostSource, SimOptions, SimResult};
use crate::device::DeviceModel;
use crate::graph::{Node, TrainingGraph};
use crate::network::Cluster;
use crate::util::rng::Rng;
use std::cell::RefCell;

/// Hi-fi execution parameters.
#[derive(Debug, Clone)]
pub struct HifiOptions {
    /// Iterations to run and average.
    pub iterations: usize,
    /// Host-side per-kernel scheduling overhead (ms) — unknown to the
    /// cost model.
    pub sched_overhead_ms: f64,
    /// Scale of per-worker skew feeding the AllReduce straggler max (ms).
    pub skew_sigma_ms: f64,
    pub seed: u64,
}

impl Default for HifiOptions {
    fn default() -> Self {
        HifiOptions { iterations: 5, sched_overhead_ms: 0.012, skew_sigma_ms: 0.05, seed: 0xFEED }
    }
}

/// Noisy cost source for a single iteration.
struct NoisySource<'a> {
    device: &'a DeviceModel,
    cluster: &'a Cluster,
    sched_overhead_ms: f64,
    rng: RefCell<Rng>,
}

impl CostSource for NoisySource<'_> {
    fn compute_time_ms(&self, node: &Node) -> f64 {
        let true_ms = self.device.node_time_ms(node);
        let mut rng = self.rng.borrow_mut();
        self.device.measure_ms(true_ms, &mut rng) + self.sched_overhead_ms
    }

    fn comm_time_ms(&self, bytes: f64) -> f64 {
        let mut rng = self.rng.borrow_mut();
        self.cluster.measure_allreduce_ms(bytes, &mut rng)
    }
}

/// "Really execute" the graph: noisy per-iteration simulation, averaged.
/// This is what Table 2 compares the clean simulator against.
pub fn execute_real(
    graph: &TrainingGraph,
    device: &DeviceModel,
    cluster: &Cluster,
    opts: &HifiOptions,
) -> SimResult {
    let mut root = Rng::new(opts.seed);
    let mut acc = SimResult {
        makespan_ms: 0.0,
        comp_busy_ms: 0.0,
        comm_busy_ms: 0.0,
        comp_idle_ms: 0.0,
        comm_idle_ms: 0.0,
        kernels: 0,
        allreduces: 0,
        peak_bytes: 0.0,
    };
    for it in 0..opts.iterations.max(1) {
        let mut iter_rng = root.fork(it as u64);
        // Straggler: slowest of W workers' half-normal skews.
        let w = cluster.num_devices().max(1);
        let straggler = (0..w)
            .map(|_| iter_rng.gen_normal().abs() * opts.skew_sigma_ms)
            .fold(0.0f64, f64::max);
        let src = NoisySource {
            device,
            cluster,
            sched_overhead_ms: opts.sched_overhead_ms,
            rng: RefCell::new(iter_rng),
        };
        let r = simulate(
            graph,
            &src,
            SimOptions { straggler_ms: straggler, ignore_comm: cluster.num_devices() <= 1 },
        );
        acc.makespan_ms += r.makespan_ms;
        acc.comp_busy_ms += r.comp_busy_ms;
        acc.comm_busy_ms += r.comm_busy_ms;
        acc.comp_idle_ms += r.comp_idle_ms;
        acc.comm_idle_ms += r.comm_idle_ms;
        acc.kernels = r.kernels;
        acc.allreduces = r.allreduces;
        acc.peak_bytes = acc.peak_bytes.max(r.peak_bytes);
    }
    let k = opts.iterations.max(1) as f64;
    acc.makespan_ms /= k;
    acc.comp_busy_ms /= k;
    acc.comm_busy_ms /= k;
    acc.comp_idle_ms /= k;
    acc.comm_idle_ms /= k;
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::{OpKind, Role};

    fn small_graph() -> TrainingGraph {
        let mut b = GraphBuilder::new("hf", 12);
        let x = b.constant("x", &[1 << 20]);
        let mut prev = x;
        for i in 0..4 {
            let g = b.compute(OpKind::Mul, &format!("g{i}"), &[prev], &[1 << 20], Role::Backward);
            let p = b.param(&format!("w{i}"), &[1 << 20]);
            let ar = b.allreduce(&format!("ar{i}"), g, &[1 << 20]);
            b.optimizer_update(&format!("u{i}"), &[ar, p]);
            prev = g;
        }
        b.finish()
    }

    #[test]
    fn deterministic_given_seed() {
        let g = small_graph();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let o = HifiOptions::default();
        let a = execute_real(&g, &d, &c, &o);
        let b = execute_real(&g, &d, &c, &o);
        assert_eq!(a, b);
    }

    #[test]
    fn noisier_and_slower_than_clean_sim() {
        // Hi-fi adds overheads the clean model lacks, so "real" time should
        // exceed the noise-free simulation with exact costs.
        struct Exact<'a> {
            device: &'a DeviceModel,
            cluster: &'a Cluster,
        }
        impl CostSource for Exact<'_> {
            fn compute_time_ms(&self, node: &Node) -> f64 {
                self.device.node_time_ms(node)
            }
            fn comm_time_ms(&self, bytes: f64) -> f64 {
                self.cluster.allreduce_time_ms(bytes)
            }
        }
        let g = small_graph();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::cluster_a();
        let clean = simulate(&g, &Exact { device: &d, cluster: &c }, SimOptions::default());
        let real = execute_real(&g, &d, &c, &HifiOptions::default());
        assert!(real.makespan_ms > clean.makespan_ms, "real={} clean={}", real.makespan_ms, clean.makespan_ms);
        // ... but within a plausible error band (Table 2 reports 11-18%).
        let err = (real.makespan_ms - clean.makespan_ms) / real.makespan_ms;
        assert!(err < 0.5, "err={err}");
    }

    #[test]
    fn single_device_cluster_skips_comm() {
        let g = small_graph();
        let d = DeviceModel::gtx1080ti();
        let c = Cluster::single_device();
        let r = execute_real(&g, &d, &c, &HifiOptions::default());
        assert_eq!(r.comm_busy_ms, 0.0);
    }
}
