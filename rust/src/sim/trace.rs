//! Chrome-trace (chrome://tracing / Perfetto) export of a simulated
//! schedule — `disco trace --model transformer --out trace.json` renders
//! the device stream and the communication channel as two tracks, making
//! the overlap structure (and what a fusion strategy did to it) visible.

use super::{simulate_with, CostSource, Recorder, SimOptions, SimResult};
use crate::graph::{Node, TrainingGraph};
use crate::util::json::Json;

/// One scheduled interval.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: String,
    pub start_ms: f64,
    pub end_ms: f64,
    /// Track: false = device stream, true = comm channel.
    pub comm: bool,
}

/// Collecting recorder.
#[derive(Debug, Default)]
pub struct TraceRecorder {
    pub events: Vec<TraceEvent>,
}

impl Recorder for TraceRecorder {
    fn record(&mut self, node: &Node, start_ms: f64, end_ms: f64, comm: bool) {
        self.events.push(TraceEvent {
            name: node.name.clone(),
            start_ms,
            end_ms,
            comm,
        });
    }
}

/// Simulate and capture the schedule.
pub fn capture(
    graph: &TrainingGraph,
    costs: &dyn CostSource,
    opts: SimOptions,
) -> (SimResult, Vec<TraceEvent>) {
    let mut rec = TraceRecorder::default();
    let result = simulate_with(graph, costs, opts, &mut rec);
    (result, rec.events)
}

/// Render events as Chrome trace JSON (`chrome://tracing`, Perfetto).
/// Timestamps are microseconds per the trace-event format.
pub fn to_chrome_json(events: &[TraceEvent]) -> String {
    let mut arr = Vec::with_capacity(events.len());
    for e in events {
        arr.push(Json::obj(vec![
            ("name", Json::Str(e.name.clone())),
            ("cat", Json::Str(if e.comm { "comm" } else { "compute" }.into())),
            ("ph", Json::Str("X".into())),
            ("ts", Json::Num(e.start_ms * 1e3)),
            ("dur", Json::Num((e.end_ms - e.start_ms) * 1e3)),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(if e.comm { 2.0 } else { 1.0 })),
        ]));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(arr)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::{OpKind, Role};

    struct Unit;
    impl CostSource for Unit {
        fn compute_time_ms(&self, _n: &Node) -> f64 {
            1.0
        }
        fn comm_time_ms(&self, _b: f64) -> f64 {
            2.0
        }
    }

    fn graph() -> TrainingGraph {
        let mut b = GraphBuilder::new("t", 2);
        let x = b.constant("x", &[8]);
        let m = b.compute(OpKind::Mul, "m", &[x], &[8], Role::Backward);
        let p = b.param("w", &[8]);
        let ar = b.allreduce("ar", m, &[8]);
        b.optimizer_update("u", &[ar, p]);
        b.finish()
    }

    #[test]
    fn capture_produces_consistent_events() {
        let g = graph();
        let (res, events) = capture(&g, &Unit, SimOptions::default());
        // 2 compute (mul + optimizer) + 1 comm.
        assert_eq!(events.len(), 3);
        assert_eq!(events.iter().filter(|e| e.comm).count(), 1);
        // Events lie within the makespan and have positive duration.
        for e in &events {
            assert!(e.end_ms > e.start_ms);
            assert!(e.end_ms <= res.makespan_ms + 1e-9);
        }
        // No overlap within a track.
        for track in [false, true] {
            let mut t: Vec<_> = events.iter().filter(|e| e.comm == track).collect();
            t.sort_by(|a, b| a.start_ms.partial_cmp(&b.start_ms).unwrap());
            for w in t.windows(2) {
                assert!(w[1].start_ms >= w[0].end_ms - 1e-9);
            }
        }
    }

    #[test]
    fn chrome_json_is_valid() {
        let g = graph();
        let (_, events) = capture(&g, &Unit, SimOptions::default());
        let s = to_chrome_json(&events);
        let parsed = Json::parse(&s).unwrap();
        assert_eq!(parsed.get("traceEvents").as_arr().unwrap().len(), events.len());
    }

    #[test]
    fn memory_accounting_sane() {
        let g = graph();
        let r = crate::sim::simulate(&g, &Unit, SimOptions::default());
        // mul out (32B) + ar out (32B) + optimizer out (32B) never all live:
        // peak is bounded by the sum of transient outputs.
        assert!(r.peak_bytes > 0.0);
        assert!(r.peak_bytes <= 96.0);
    }
}
