//! Chrome-trace (chrome://tracing / Perfetto) export of a simulated
//! schedule — `disco trace --model transformer --out trace.json` renders
//! the device stream and the communication channel as two tracks, making
//! the overlap structure (and what a fusion strategy did to it) visible.

use super::{simulate_with, CostSource, Recorder, SimOptions, SimResult};
use crate::graph::{Node, TrainingGraph};
use crate::util::trace::{self as core, Event, TrackId};

/// Simulated-schedule pid in the shared track scheme (DESIGN.md §15):
/// search telemetry is pid 2, enactment pid 3 — merged views never
/// collide.
pub const SIM_PID: u32 = 1;

/// One scheduled interval.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: String,
    pub start_ms: f64,
    pub end_ms: f64,
    /// Track: false = device stream, true = comm channel.
    pub comm: bool,
    /// `Some((idx, count))` for one chunk of a chunked AllReduce
    /// (`idx` in `1..=count`); rendered on its own chunk-stream track
    /// below the channel so the whole-collective span stays visible.
    pub chunk: Option<(u32, u32)>,
}

/// Collecting recorder.
#[derive(Debug, Default)]
pub struct TraceRecorder {
    pub events: Vec<TraceEvent>,
}

impl Recorder for TraceRecorder {
    fn record(&mut self, node: &Node, start_ms: f64, end_ms: f64, comm: bool) {
        self.events.push(TraceEvent {
            name: node.name.clone(),
            start_ms,
            end_ms,
            comm,
            chunk: None,
        });
    }

    fn record_chunk(&mut self, node: &Node, idx: u32, count: u32, start_ms: f64, end_ms: f64) {
        self.events.push(TraceEvent {
            name: format!("{}[{idx}/{count}]", node.name),
            start_ms,
            end_ms,
            comm: true,
            chunk: Some((idx, count)),
        });
    }
}

/// Simulate and capture the schedule.
pub fn capture(
    graph: &TrainingGraph,
    costs: &dyn CostSource,
    opts: SimOptions,
) -> (SimResult, Vec<TraceEvent>) {
    let mut rec = TraceRecorder::default();
    let result = simulate_with(graph, costs, opts, &mut rec);
    (result, rec.events)
}

/// Lower captured sim events to the shared event shape: device stream
/// on tid 1, comm channel tid 2, chunk stream tid 3.
pub fn to_events(events: &[TraceEvent]) -> Vec<Event> {
    events
        .iter()
        .map(|e| {
            let (cat, tid) = if e.chunk.is_some() {
                ("comm-chunk", 3)
            } else if e.comm {
                ("comm", 2)
            } else {
                ("compute", 1)
            };
            Event::span(TrackId::new(SIM_PID, tid), e.name.clone(), e.start_ms, e.end_ms, cat)
        })
        .collect()
}

/// Track labels for the simulated-schedule lanes.
pub fn sim_tracks() -> Vec<(TrackId, String)> {
    vec![
        (TrackId::new(SIM_PID, 1), "device stream".to_string()),
        (TrackId::new(SIM_PID, 2), "comm channel".to_string()),
        (TrackId::new(SIM_PID, 3), "chunk stream".to_string()),
    ]
}

/// Render events as Chrome trace JSON (`chrome://tracing`, Perfetto)
/// via the shared emitter — same `ph:"X"`/µs shape as before, now with
/// `thread_name` metadata labeling the three lanes.
pub fn to_chrome_json(events: &[TraceEvent]) -> String {
    core::to_chrome_json(&to_events(events), &sim_tracks())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::{OpKind, Role};

    struct Unit;
    impl CostSource for Unit {
        fn compute_time_ms(&self, _n: &Node) -> f64 {
            1.0
        }
        fn comm_time_ms(&self, _b: f64) -> f64 {
            2.0
        }
    }

    fn graph() -> TrainingGraph {
        let mut b = GraphBuilder::new("t", 2);
        let x = b.constant("x", &[8]);
        let m = b.compute(OpKind::Mul, "m", &[x], &[8], Role::Backward);
        let p = b.param("w", &[8]);
        let ar = b.allreduce("ar", m, &[8]);
        b.optimizer_update("u", &[ar, p]);
        b.finish()
    }

    #[test]
    fn capture_produces_consistent_events() {
        let g = graph();
        let (res, events) = capture(&g, &Unit, SimOptions::default());
        // 2 compute (mul + optimizer) + 1 comm.
        assert_eq!(events.len(), 3);
        assert_eq!(events.iter().filter(|e| e.comm).count(), 1);
        // Events lie within the makespan and have positive duration.
        for e in &events {
            assert!(e.end_ms > e.start_ms);
            assert!(e.end_ms <= res.makespan_ms + 1e-9);
        }
        // No overlap within a track.
        for track in [false, true] {
            let mut t: Vec<_> = events.iter().filter(|e| e.comm == track).collect();
            t.sort_by(|a, b| a.start_ms.partial_cmp(&b.start_ms).unwrap());
            for w in t.windows(2) {
                assert!(w[1].start_ms >= w[0].end_ms - 1e-9);
            }
        }
    }

    #[test]
    fn chunked_capture_tiles_the_collective_span() {
        let mut g = graph();
        let ar = g.allreduces()[0];
        g.nodes[ar].chunk = Some(crate::graph::ChunkSpec::new(4));
        let (res, events) = capture(&g, &Unit, SimOptions::default());
        let whole: Vec<_> =
            events.iter().filter(|e| e.comm && e.chunk.is_none()).collect();
        let chunks: Vec<_> = events.iter().filter(|e| e.chunk.is_some()).collect();
        assert_eq!(whole.len(), 1);
        assert_eq!(chunks.len(), 4);
        // Chunks abut and exactly tile the collective's channel span
        // (Unit has no overhead, so the stream starts at the AR start).
        assert_eq!(chunks[0].start_ms, whole[0].start_ms);
        assert_eq!(chunks.last().unwrap().end_ms, whole[0].end_ms);
        for w in chunks.windows(2) {
            assert_eq!(w[1].start_ms, w[0].end_ms);
        }
        // Co-scheduling contract: the wait for chunk i never fires before
        // its start plus the per-chunk transfer (2ms / 4 chunks), and the
        // dependent optimizer never starts before its first chunk lands.
        for c in &chunks[..3] {
            assert_eq!(c.end_ms, c.start_ms + 0.5);
        }
        let opt = events.iter().find(|e| e.name == "u").unwrap();
        assert!(opt.start_ms >= chunks[0].end_ms);
        assert!(res.makespan_ms <= 5.0, "chunking must not lose vs whole-tensor 5.0");
    }

    #[test]
    fn chrome_json_is_valid() {
        use crate::util::json::Json;
        let g = graph();
        let (_, events) = capture(&g, &Unit, SimOptions::default());
        let s = to_chrome_json(&events);
        let parsed = Json::parse(&s).unwrap();
        let rows = parsed.get("traceEvents").as_arr().unwrap();
        // One "X" row per captured event plus thread_name metadata rows.
        let spans = rows.iter().filter(|r| r.get("ph").as_str() == Some("X")).count();
        assert_eq!(spans, events.len());
        assert_eq!(rows.len(), events.len() + sim_tracks().len());
        // File-order timestamps are monotone (shared emitter sorts).
        let ts: Vec<f64> = rows
            .iter()
            .filter(|r| r.get("ph").as_str() == Some("X"))
            .map(|r| r.get("ts").as_f64().unwrap())
            .collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn memory_accounting_sane() {
        let g = graph();
        let r = crate::sim::simulate(&g, &Unit, SimOptions::default());
        // mul out (32B) + ar out (32B) + optimizer out (32B) never all live:
        // peak is bounded by the sum of transient outputs.
        assert!(r.peak_bytes > 0.0);
        assert!(r.peak_bytes <= 96.0);
    }
}
