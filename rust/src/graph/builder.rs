//! Convenience builder for [`TrainingGraph`]s, used by the model zoo and
//! tests. Computes FLOP and byte accounting from shapes so the device
//! model gets consistent inputs.

use super::{DType, Node, NodeId, OpKind, Role, Shape, TrainingGraph};

/// Builder over an owned graph.
pub struct GraphBuilder {
    g: TrainingGraph,
    dtype: DType,
}

/// Cost factors for transcendental elementwise ops relative to one FLOP
/// per element (a GPU `exp` is several hardware ops).
fn elementwise_flop_factor(kind: OpKind) -> f64 {
    match kind {
        OpKind::Exp | OpKind::Log | OpKind::Tanh | OpKind::Sigmoid | OpKind::Gelu => 4.0,
        OpKind::Sqrt | OpKind::Rsqrt => 2.0,
        OpKind::Softmax => 5.0,
        OpKind::LayerNorm | OpKind::BatchNorm => 6.0,
        OpKind::CrossEntropy => 6.0,
        _ => 1.0,
    }
}

impl GraphBuilder {
    pub fn new(name: &str, num_workers: usize) -> GraphBuilder {
        GraphBuilder { g: TrainingGraph::new(name, num_workers), dtype: DType::F32 }
    }

    pub fn with_dtype(mut self, dt: DType) -> Self {
        self.dtype = dt;
        self
    }

    pub fn graph(&self) -> &TrainingGraph {
        &self.g
    }

    pub fn finish(self) -> TrainingGraph {
        debug_assert!(self.g.validate().is_ok());
        self.g
    }

    fn input_bytes(&self, inputs: &[NodeId]) -> f64 {
        inputs.iter().map(|&i| self.g.nodes[i].bytes_out).sum()
    }

    fn push(
        &mut self,
        kind: OpKind,
        name: &str,
        role: Role,
        inputs: Vec<NodeId>,
        dims: &[usize],
        flops: f64,
    ) -> NodeId {
        let shape = Shape::new(dims);
        let bytes_out = shape.bytes(self.dtype) as f64;
        let bytes_in = self.input_bytes(&inputs);
        self.g.push(Node {
            id: 0,
            name: name.to_string(),
            kind,
            role,
            orig_inputs: inputs.clone(),
            inputs,
            shape,
            dtype: self.dtype,
            flops,
            bytes_in,
            bytes_out,
            fused: None,
            ar_constituents: Vec::new(),
            chunk: None,
            shard: None,
            deleted: false,
        })
    }

    // ---- leaves ----------------------------------------------------------

    /// Model parameter (weight tensor).
    pub fn param(&mut self, name: &str, dims: &[usize]) -> NodeId {
        self.push(OpKind::Parameter, name, Role::Param, vec![], dims, 0.0)
    }

    /// Constant / input activation leaf.
    pub fn constant(&mut self, name: &str, dims: &[usize]) -> NodeId {
        self.push(OpKind::Constant, name, Role::Param, vec![], dims, 0.0)
    }

    // ---- generic compute ----------------------------------------------------

    /// Generic compute node; FLOPs estimated as `factor * out_elems` for
    /// elementwise-like ops, `in_elems` for data movement / reductions.
    pub fn compute(
        &mut self,
        kind: OpKind,
        name: &str,
        inputs: &[NodeId],
        out_dims: &[usize],
        role: Role,
    ) -> NodeId {
        let out_elems = Shape::new(out_dims).elems() as f64;
        let in_elems: f64 = inputs
            .iter()
            .map(|&i| self.g.nodes[i].shape.elems() as f64)
            .sum();
        let flops = match kind.pattern_class() {
            super::PatternClass::Injective => elementwise_flop_factor(kind) * out_elems,
            super::PatternClass::Reduction => elementwise_flop_factor(kind) * in_elems.max(out_elems),
            _ => in_elems.max(out_elems), // conservative default; use the
                                          // dedicated helpers for matmul/conv
        };
        self.push(kind, name, role, inputs.to_vec(), out_dims, flops)
    }

    /// Compute node with explicit FLOPs (for ops whose cost is not derivable
    /// from the output shape).
    pub fn compute_flops(
        &mut self,
        kind: OpKind,
        name: &str,
        inputs: &[NodeId],
        out_dims: &[usize],
        role: Role,
        flops: f64,
    ) -> NodeId {
        self.push(kind, name, role, inputs.to_vec(), out_dims, flops)
    }

    // ---- dense / conv helpers ---------------------------------------------------

    /// `[b?, m, k] x [k, n]` matmul: 2*m*k*n*batch FLOPs.
    pub fn matmul(
        &mut self,
        name: &str,
        inputs: &[NodeId],
        batch: usize,
        m: usize,
        k: usize,
        n: usize,
        role: Role,
    ) -> NodeId {
        let flops = 2.0 * batch as f64 * m as f64 * k as f64 * n as f64;
        let dims: Vec<usize> =
            if batch > 1 { vec![batch, m, n] } else { vec![m, n] };
        let kind = if batch > 1 { OpKind::BatchMatMul } else { OpKind::MatMul };
        self.push(kind, name, role, inputs.to_vec(), &dims, flops)
    }

    /// NCHW conv2d with square kernel `r`, stride `s`, "same"-ish output
    /// `h_out = h/s`, `w_out = w/s`: 2*N*K*C*R*R*h_out*w_out FLOPs.
    #[allow(clippy::too_many_arguments)]
    pub fn conv2d(
        &mut self,
        name: &str,
        inputs: &[NodeId],
        n: usize,
        c: usize,
        h: usize,
        w: usize,
        k: usize,
        r: usize,
        stride: usize,
        role: Role,
    ) -> NodeId {
        let (ho, wo) = (h / stride, w / stride);
        let flops = 2.0 * (n * k * c * r * r * ho * wo) as f64;
        self.push(OpKind::Conv2D, name, role, inputs.to_vec(), &[n, k, ho, wo], flops)
    }

    // ---- communication / optimizer ------------------------------------------------

    /// AllReduce of the gradient produced by `grad_op`. Registers itself as
    /// its own (singleton) constituent for tensor-fusion bookkeeping.
    pub fn allreduce(&mut self, name: &str, grad_op: NodeId, dims: &[usize]) -> NodeId {
        let id = self.push(OpKind::AllReduce, name, Role::Comm, vec![grad_op], dims, 0.0);
        self.g.nodes[id].ar_constituents = vec![id];
        id
    }

    /// Optimizer update consuming an aggregated gradient (+ the parameter).
    pub fn optimizer_update(&mut self, name: &str, inputs: &[NodeId]) -> NodeId {
        let dims: Vec<usize> = self.g.nodes[inputs[0]].shape.dims.clone();
        let elems = Shape::new(&dims).elems() as f64;
        // Adam: ~10 flops/element (m, v, bias correction, update).
        self.push(OpKind::ApplyOptimizer, name, Role::Optimizer, inputs.to_vec(), &dims, 10.0 * elems)
    }

    /// Convenience: gradient compute + AllReduce + optimizer chain for one
    /// parameter. Returns the AllReduce id.
    pub fn grad_sync(
        &mut self,
        base_name: &str,
        grad_inputs: &[NodeId],
        param: NodeId,
        grad_flops: f64,
    ) -> NodeId {
        let dims: Vec<usize> = self.g.nodes[param].shape.dims.clone();
        let g = self.compute_flops(
            OpKind::MatMul,
            &format!("{base_name}.grad"),
            grad_inputs,
            &dims,
            Role::Backward,
            grad_flops,
        );
        let ar = self.allreduce(&format!("{base_name}.allreduce"), g, &dims);
        self.optimizer_update(&format!("{base_name}.apply"), &[ar, param]);
        ar
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_flops() {
        let mut b = GraphBuilder::new("t", 2);
        let x = b.constant("x", &[32, 64]);
        let w = b.param("w", &[64, 128]);
        let y = b.matmul("y", &[x, w], 1, 32, 64, 128, Role::Forward);
        let n = &b.graph().nodes[y];
        assert_eq!(n.flops, 2.0 * 32.0 * 64.0 * 128.0);
        assert_eq!(n.shape.dims, vec![32, 128]);
        assert_eq!(n.bytes_out, 32.0 * 128.0 * 4.0);
        assert_eq!(n.bytes_in, (32.0 * 64.0 + 64.0 * 128.0) * 4.0);
    }

    #[test]
    fn conv_flops_and_shape() {
        let mut b = GraphBuilder::new("t", 2);
        let x = b.constant("x", &[8, 3, 224, 224]);
        let y = b.conv2d("c1", &[x], 8, 3, 224, 224, 64, 3, 1, Role::Forward);
        let n = &b.graph().nodes[y];
        assert_eq!(n.shape.dims, vec![8, 64, 224, 224]);
        assert_eq!(n.flops, 2.0 * (8 * 64 * 3 * 3 * 3 * 224 * 224) as f64);
    }

    #[test]
    fn elementwise_factors() {
        let mut b = GraphBuilder::new("t", 2);
        let x = b.constant("x", &[100]);
        let t = b.compute(OpKind::Tanh, "t", &[x], &[100], Role::Forward);
        let a = b.compute(OpKind::Add, "a", &[x, x], &[100], Role::Forward);
        assert_eq!(b.graph().nodes[t].flops, 400.0);
        assert_eq!(b.graph().nodes[a].flops, 100.0);
    }

    #[test]
    fn grad_sync_chain() {
        let mut b = GraphBuilder::new("t", 4);
        let p = b.param("w", &[64, 64]);
        let x = b.constant("x", &[64, 64]);
        let ar = b.grad_sync("w", &[x], p, 1000.0);
        let g = b.finish();
        assert_eq!(g.allreduces(), vec![ar]);
        assert_eq!(g.nodes[ar].ar_constituents, vec![ar]);
        // Optimizer consumes the allreduce.
        let succ = g.successors();
        assert_eq!(succ[ar].len(), 1);
        assert_eq!(g.nodes[succ[ar][0]].kind, OpKind::ApplyOptimizer);
    }
}
