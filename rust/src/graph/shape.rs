//! Tensor element types and shapes.

/// Element type of a tensor. The paper's workloads train in f32 (with f16
/// variants in some kernels); we carry the dtype so byte accounting —
/// which drives both the device roofline and the AllReduce model — is exact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F16,
    BF16,
    I32,
}

impl DType {
    /// Bytes per element.
    pub fn bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F16 | DType::BF16 => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::BF16 => "bf16",
            DType::I32 => "i32",
        }
    }

    pub fn from_name(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "f16" => Some(DType::F16),
            "bf16" => Some(DType::BF16),
            "i32" => Some(DType::I32),
            _ => None,
        }
    }
}

/// A tensor shape (row-major dims). Scalars have empty dims.
#[derive(Debug, Clone, PartialEq, Eq, Hash, Default)]
pub struct Shape {
    pub dims: Vec<usize>,
}

impl Shape {
    pub fn new(dims: &[usize]) -> Shape {
        Shape { dims: dims.to_vec() }
    }

    pub fn scalar() -> Shape {
        Shape { dims: vec![] }
    }

    /// Number of elements.
    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }

    /// Total bytes at the given dtype.
    pub fn bytes(&self, dt: DType) -> usize {
        self.elems() * dt.bytes()
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn to_string(&self) -> String {
        let inner: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        format!("[{}]", inner.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_bytes() {
        assert_eq!(DType::F32.bytes(), 4);
        assert_eq!(DType::F16.bytes(), 2);
        assert_eq!(DType::BF16.bytes(), 2);
    }

    #[test]
    fn dtype_name_roundtrip() {
        for dt in [DType::F32, DType::F16, DType::BF16, DType::I32] {
            assert_eq!(DType::from_name(dt.name()), Some(dt));
        }
        assert_eq!(DType::from_name("zzz"), None);
    }

    #[test]
    fn shape_elems_bytes() {
        let s = Shape::new(&[2, 3, 4]);
        assert_eq!(s.elems(), 24);
        assert_eq!(s.bytes(DType::F32), 96);
        assert_eq!(s.rank(), 3);
        assert_eq!(Shape::scalar().elems(), 1);
    }

    #[test]
    fn shape_display() {
        assert_eq!(Shape::new(&[8, 128]).to_string(), "[8,128]");
        assert_eq!(Shape::scalar().to_string(), "[]");
    }
}
