//! JSON (de)serialization of [`TrainingGraph`] — the wire format the
//! coordinator broadcasts in the enactment phase (paper §4.1: the Activator
//! fetches the optimized HLO module and broadcasts it to workers), and the
//! on-disk format for saved strategies.

use super::{DType, FusedGroup, Node, OpKind, OrigOp, Role, Shape, TrainingGraph};
use crate::util::json::Json;

fn shape_json(s: &Shape) -> Json {
    Json::arr_usize(&s.dims)
}

fn shape_from(j: &Json) -> Option<Shape> {
    let dims: Option<Vec<usize>> = j.as_arr()?.iter().map(|v| v.as_usize()).collect();
    Some(Shape { dims: dims? })
}

fn orig_op_json(o: &OrigOp) -> Json {
    Json::obj(vec![
        ("id", Json::Num(o.orig_id as f64)),
        ("kind", Json::Str(o.kind.name().to_string())),
        ("flops", Json::Num(o.flops)),
        ("bin", Json::Num(o.bytes_in)),
        ("bout", Json::Num(o.bytes_out)),
        ("t", Json::Num(o.time_ms)),
        ("dup", Json::Bool(o.duplicated)),
    ])
}

fn orig_op_from(j: &Json) -> Option<OrigOp> {
    Some(OrigOp {
        orig_id: j.get("id").as_usize()?,
        kind: OpKind::from_name(j.get("kind").as_str()?)?,
        flops: j.get("flops").as_f64()?,
        bytes_in: j.get("bin").as_f64()?,
        bytes_out: j.get("bout").as_f64()?,
        time_ms: j.get("t").as_f64()?,
        duplicated: j.get("dup").as_bool()?,
    })
}

fn node_json(n: &Node) -> Json {
    let mut fields = vec![
        ("id", Json::Num(n.id as f64)),
        ("name", Json::Str(n.name.clone())),
        ("kind", Json::Str(n.kind.name().to_string())),
        ("role", Json::Str(n.role.name().to_string())),
        ("inputs", Json::arr_usize(&n.inputs)),
        ("oinputs", Json::arr_usize(&n.orig_inputs)),
        ("shape", shape_json(&n.shape)),
        ("dtype", Json::Str(n.dtype.name().to_string())),
        ("flops", Json::Num(n.flops)),
        ("bin", Json::Num(n.bytes_in)),
        ("bout", Json::Num(n.bytes_out)),
        ("deleted", Json::Bool(n.deleted)),
    ];
    if let Some(g) = &n.fused {
        fields.push((
            "fused",
            Json::obj(vec![
                ("ops", Json::Arr(g.ops.iter().map(orig_op_json).collect())),
                (
                    "edges",
                    Json::Arr(
                        g.edges
                            .iter()
                            .map(|&(a, b)| Json::arr_usize(&[a, b]))
                            .collect(),
                    ),
                ),
            ]),
        ));
    }
    if !n.ar_constituents.is_empty() {
        fields.push(("ar", Json::arr_usize(&n.ar_constituents)));
    }
    // Emitted only when active: pre-chunk readers never see the field, and
    // pre-chunk payloads parse to the canonical unchunked form below.
    if n.chunk_count() >= 2 {
        fields.push(("chunk", Json::Num(n.chunk_count() as f64)));
    }
    // Same only-when-active rule for the gradient-sharding spec: the
    // canonical AllReduce kind serializes as no field at all.
    if n.is_sharded_collective() {
        fields.push(("shard", Json::Str(n.shard_kind().name().into())));
    }
    Json::obj(fields)
}

fn node_from(j: &Json) -> Option<Node> {
    let fused = match j.get("fused") {
        Json::Null => None,
        f => {
            let ops: Option<Vec<OrigOp>> =
                f.get("ops").as_arr()?.iter().map(orig_op_from).collect();
            let edges: Option<Vec<(usize, usize)>> = f
                .get("edges")
                .as_arr()?
                .iter()
                .map(|e| {
                    let a = e.as_arr()?;
                    Some((a.first()?.as_usize()?, a.get(1)?.as_usize()?))
                })
                .collect();
            Some(FusedGroup { ops: ops?, edges: edges? })
        }
    };
    let ar_constituents = match j.get("ar") {
        Json::Null => Vec::new(),
        a => a
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<usize>>>()?,
    };
    Some(Node {
        id: j.get("id").as_usize()?,
        name: j.get("name").as_str()?.to_string(),
        kind: OpKind::from_name(j.get("kind").as_str()?)?,
        role: Role::from_name(j.get("role").as_str()?)?,
        inputs: j
            .get("inputs")
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<usize>>>()?,
        orig_inputs: j
            .get("oinputs")
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<usize>>>()?,
        shape: shape_from(j.get("shape"))?,
        dtype: DType::from_name(j.get("dtype").as_str()?)?,
        flops: j.get("flops").as_f64()?,
        bytes_in: j.get("bin").as_f64()?,
        bytes_out: j.get("bout").as_f64()?,
        fused,
        ar_constituents,
        chunk: match j.get("chunk") {
            Json::Null => None,
            c => {
                let count = c.as_usize()? as u32;
                if count >= 2 {
                    Some(super::ChunkSpec::new(count))
                } else {
                    None
                }
            }
        },
        shard: match j.get("shard") {
            Json::Null => None,
            s => {
                let kind = super::CollectiveKind::from_name(s.as_str()?)?;
                // Canonicalize: a persisted AllReduce kind is no spec.
                if kind == super::CollectiveKind::ReduceScatterAllGather {
                    Some(super::ShardSpec::new(kind))
                } else {
                    None
                }
            }
        },
        deleted: j.get("deleted").as_bool()?,
    })
}

impl TrainingGraph {
    /// Serialize to a [`Json`] value (stable field order). The encoding
    /// is **lossless for every `Node` field** — in particular shapes,
    /// dtypes, flops/byte traffic, fused-group contents and duplicate
    /// operand edges (`inputs` like `[x, x]` keep their multiplicity),
    /// everything the strategy service's canonical fingerprint hashes —
    /// so `from_json_value(to_json_value(g)) == g` exactly
    /// (`prop_serial_roundtrip_lossless` in tests/properties.rs). Used
    /// directly by the `disco serve` wire protocol to embed graphs in
    /// request/response frames.
    pub fn to_json_value(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("num_workers", Json::Num(self.num_workers as f64)),
            ("nodes", Json::Arr(self.nodes.iter().map(node_json).collect())),
        ])
    }

    /// Serialize to a JSON string (stable field order).
    pub fn to_json(&self) -> String {
        self.to_json_value().to_string()
    }

    /// Parse a graph back from a [`TrainingGraph::to_json_value`] value.
    pub fn from_json_value(j: &Json) -> anyhow::Result<TrainingGraph> {
        let nodes: Option<Vec<Node>> =
            j.get("nodes").as_arr().ok_or_else(|| anyhow::anyhow!("missing nodes"))?
                .iter()
                .map(node_from)
                .collect();
        let g = TrainingGraph::from_parts(
            j.get("name")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("missing name"))?
                .to_string(),
            nodes.ok_or_else(|| anyhow::anyhow!("bad node"))?,
            j.get("num_workers")
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("missing num_workers"))?,
        );
        g.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(g)
    }

    /// Parse a graph back from [`TrainingGraph::to_json`] output.
    pub fn from_json(s: &str) -> anyhow::Result<TrainingGraph> {
        let j = Json::parse(s).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json_value(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::super::builder::GraphBuilder;
    use super::*;

    #[test]
    fn roundtrip_plain_graph() {
        let mut b = GraphBuilder::new("rt", 8);
        let p = b.param("w", &[64, 32]);
        let x = b.constant("x", &[16, 64]);
        let y = b.matmul("y", &[x, p], 1, 16, 64, 32, Role::Forward);
        let r = b.compute(OpKind::Relu, "r", &[y], &[16, 32], Role::Forward);
        b.grad_sync("w", &[r], p, 1234.0);
        let g = b.finish();
        let s = g.to_json();
        let g2 = TrainingGraph::from_json(&s).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn roundtrip_with_fused_group() {
        let mut b = GraphBuilder::new("rt2", 2);
        let x = b.constant("x", &[8]);
        let a = b.compute(OpKind::Add, "a", &[x], &[8], Role::Forward);
        let mut g = b.finish();
        // Hand-attach a fused group to exercise that path.
        g.nodes[a].kind = OpKind::Fused;
        g.nodes[a].fused = Some(FusedGroup {
            ops: vec![OrigOp {
                orig_id: a,
                kind: OpKind::Add,
                flops: 8.0,
                bytes_in: 32.0,
                bytes_out: 32.0,
                time_ms: 0.01,
                duplicated: false,
            }],
            edges: vec![],
        });
        let g2 = TrainingGraph::from_json(&g.to_json()).unwrap();
        assert_eq!(g, g2);
        assert_eq!(
            g2.nodes[a].fused.as_ref().unwrap().signature(),
            g.nodes[a].fused.as_ref().unwrap().signature()
        );
    }

    #[test]
    fn rejects_corrupt() {
        assert!(TrainingGraph::from_json("{").is_err());
        assert!(TrainingGraph::from_json("{\"name\":\"x\"}").is_err());
    }

    #[test]
    fn roundtrip_preserves_duplicate_operand_edges() {
        // x·x-style duplicate operand edges are semantically load-bearing
        // (PR 3's fusion fix) and the service fingerprint hashes operand
        // multiplicity — serialization must not dedup them.
        let mut b = GraphBuilder::new("rt3", 2);
        let x = b.constant("x", &[32]);
        let m = b.compute(OpKind::Mul, "sq", &[x, x], &[32], Role::Forward);
        let _ = b.compute(OpKind::Add, "a", &[m, m], &[32], Role::Forward);
        let g = b.finish();
        assert_eq!(g.nodes[m].inputs, vec![x, x]);
        let g2 = TrainingGraph::from_json(&g.to_json()).unwrap();
        assert_eq!(g, g2);
        assert_eq!(g2.nodes[m].inputs, vec![x, x]);
        assert_eq!(g2.nodes[m].orig_inputs, vec![x, x]);
    }

    #[test]
    fn roundtrip_preserves_chunk_spec() {
        use crate::fusion::set_chunks;
        let mut b = GraphBuilder::new("rt5", 4);
        let x = b.constant("x", &[1 << 14]);
        let gr = b.compute(OpKind::Mul, "g", &[x], &[1 << 14], Role::Backward);
        let ar = b.allreduce("ar", gr, &[1 << 14]);
        let mut g = b.finish();
        // Unchunked graphs must not emit the field at all (old readers).
        assert!(!g.to_json().contains("\"chunk\""));
        set_chunks(&mut g, ar, 8).unwrap();
        let g2 = TrainingGraph::from_json(&g.to_json()).unwrap();
        assert_eq!(g, g2);
        assert_eq!(g2.nodes[ar].chunk_count(), 8);
        assert_eq!(g.fingerprint(), g2.fingerprint());
    }

    #[test]
    fn roundtrip_preserves_shard_spec() {
        use crate::fusion::set_sharding;
        use crate::graph::CollectiveKind;
        let mut b = GraphBuilder::new("rt6", 4);
        let x = b.constant("x", &[1 << 14]);
        let gr = b.compute(OpKind::Mul, "g", &[x], &[1 << 14], Role::Backward);
        let p = b.param("w", &[1 << 14]);
        let ar = b.allreduce("ar", gr, &[1 << 14]);
        b.optimizer_update("u", &[ar, p]);
        let mut g = b.finish();
        // Unsharded graphs must not emit the field at all (old readers).
        assert!(!g.to_json().contains("\"shard\""));
        set_sharding(&mut g, ar, CollectiveKind::ReduceScatterAllGather).unwrap();
        let json = g.to_json();
        assert!(json.contains("\"shard\":\"rs_ag\""));
        let g2 = TrainingGraph::from_json(&json).unwrap();
        assert_eq!(g, g2);
        assert!(g2.nodes[ar].is_sharded_collective());
        assert_eq!(g.fingerprint(), g2.fingerprint());
    }

    #[test]
    fn roundtrip_after_fusion_preserves_tombstones_and_groups() {
        use crate::fusion::{fuse_ops, FusionKind};
        let mut b = GraphBuilder::new("rt4", 4);
        let x = b.constant("x", &[512]);
        let m1 = b.compute(OpKind::Mul, "m1", &[x], &[512], Role::Forward);
        let m2 = b.compute(OpKind::Tanh, "m2", &[m1], &[512], Role::Forward);
        let _ = b.compute(OpKind::Relu, "r", &[m2], &[512], Role::Forward);
        let mut g = b.finish();
        let f = fuse_ops(&mut g, m1, m2, FusionKind::NonDuplicate).unwrap();
        let g2 = TrainingGraph::from_json(&g.to_json()).unwrap();
        assert_eq!(g, g2);
        assert!(g2.nodes[m1].deleted && g2.nodes[m2].deleted);
        assert_eq!(
            g2.nodes[f].fused.as_ref().unwrap().signature(),
            g.nodes[f].fused.as_ref().unwrap().signature()
        );
    }
}
