//! Import real XLA HLO **text** modules into the DisCo IR.
//!
//! This closes the loop with actual compiler artifacts: the modules that
//! `python/compile/aot.py` exports (and any `.hlo.txt` dumped from XLA)
//! can be loaded as a [`TrainingGraph`] and pushed through the same
//! profiling / fusion / search pipeline as the synthetic model zoo —
//! `disco import-hlo artifacts/lm_grads.hlo.txt` optimizes the very
//! module the runtime executes.
//!
//! Scope: the ENTRY computation of the jax-emitted dialect (one
//! instruction per line, `name = type opcode(operands), attrs`). Nested
//! computations (reduce bodies, fusions) contribute no graph nodes; their
//! cost is folded into the calling instruction's FLOP estimate. FLOPs for
//! `dot`/`convolution` are estimated from operand/result shapes (the
//! contraction extent is inferred), elementwise ops count one FLOP per
//! element — adequate for structure-level optimization, and stated in
//! DESIGN.md §10.

use super::{DType, Node, NodeId, OpKind, Role, Shape, TrainingGraph};
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Parse `f32[8,64]{1,0}` → (dtype, shape). Tuple types take their first
/// element. `pred`/integer types map to I32-width accounting.
fn parse_type(s: &str) -> Option<(DType, Shape)> {
    let s = s.trim();
    if let Some(inner) = s.strip_prefix('(') {
        // Tuple: take the first element type — up to the first comma at
        // bracket/brace depth 0 (commas also appear inside dims/layouts).
        let mut depth = 0i32;
        let mut end = inner.len();
        for (i, c) in inner.char_indices() {
            match c {
                '[' | '{' => depth += 1,
                ']' | '}' => depth -= 1,
                ',' if depth == 0 => {
                    end = i;
                    break;
                }
                _ => {}
            }
        }
        return parse_type(inner[..end].trim_end_matches(')'));
    }
    let bracket = s.find('[')?;
    let dtype = match &s[..bracket] {
        "f32" => DType::F32,
        "f16" => DType::F16,
        "bf16" => DType::BF16,
        _ => DType::I32, // s32/u32/pred/s64…: byte accounting only
    };
    let rest = &s[bracket + 1..];
    let close = rest.find(']')?;
    let dims_str = &rest[..close];
    let dims: Vec<usize> = if dims_str.is_empty() {
        vec![]
    } else {
        dims_str.split(',').map(|d| d.trim().parse().ok()).collect::<Option<_>>()?
    };
    Some((dtype, Shape { dims }))
}

/// Map an HLO opcode to our [`OpKind`].
fn map_opcode(op: &str) -> OpKind {
    match op {
        "parameter" => OpKind::Parameter,
        "constant" | "iota" => OpKind::Constant,
        "dot" => OpKind::MatMul,
        "convolution" => OpKind::Conv2D,
        "add" => OpKind::Add,
        "subtract" => OpKind::Sub,
        "multiply" => OpKind::Mul,
        "divide" => OpKind::Div,
        "negate" => OpKind::Neg,
        "exponential" | "exponential-minus-one" => OpKind::Exp,
        "log" | "log-plus-one" => OpKind::Log,
        "sqrt" => OpKind::Sqrt,
        "rsqrt" => OpKind::Rsqrt,
        "tanh" => OpKind::Tanh,
        "logistic" => OpKind::Sigmoid,
        "maximum" => OpKind::Maximum,
        "minimum" => OpKind::Maximum,
        "select" => OpKind::Select,
        "compare" => OpKind::Compare,
        "convert" | "bitcast-convert" | "copy" => OpKind::Cast,
        "reduce" | "reduce-window" => OpKind::Reduce,
        "transpose" => OpKind::Transpose,
        "reshape" | "bitcast" => OpKind::Reshape,
        "broadcast" => OpKind::Broadcast,
        "concatenate" => OpKind::Concat,
        "slice" | "dynamic-slice" => OpKind::Slice,
        "gather" => OpKind::Gather,
        "scatter" | "dynamic-update-slice" => OpKind::Scatter,
        "sort" => OpKind::Sort,
        "all-reduce" => OpKind::AllReduce,
        "tuple" | "get-tuple-element" => OpKind::Reshape, // structural
        "power" => OpKind::Exp,
        "abs" | "sign" | "floor" | "ceil" | "round-nearest-afz" | "clamp" | "and" | "or"
        | "not" | "xor" => OpKind::Maximum,
        "rng" | "rng-bit-generator" => OpKind::Constant,
        "pad" | "reverse" => OpKind::Reshape,
        "custom-call" | "fusion" | "call" | "map" => OpKind::Fused,
        "while" => OpKind::While,
        "conditional" => OpKind::Conditional,
        _ => OpKind::Reduce, // conservative default for exotic ops
    }
}

/// Estimate FLOPs of one instruction from the shapes involved.
fn estimate_flops(kind: OpKind, out: &Shape, inputs: &[(DType, Shape)]) -> f64 {
    let out_elems = out.elems() as f64;
    match kind {
        OpKind::Parameter | OpKind::Constant => 0.0,
        OpKind::MatMul | OpKind::BatchMatMul => {
            // 2 * |out| * contraction extent. Infer the contraction as
            // |lhs| / leading-share: contraction ≈ lhs_elems * rhs_elems /
            // (out_elems * batch²) is fragile; use lhs_elems*rhs_elems/out
            // bounded to something sane.
            let lhs = inputs.first().map(|i| i.1.elems()).unwrap_or(1) as f64;
            let rhs = inputs.get(1).map(|i| i.1.elems()).unwrap_or(1) as f64;
            let k = ((lhs * rhs) / out_elems.max(1.0)).sqrt().max(1.0);
            2.0 * out_elems * k
        }
        OpKind::Conv2D => {
            let w = inputs.get(1).map(|i| i.1.elems()).unwrap_or(1) as f64;
            2.0 * out_elems * w / inputs.get(1).map(|i| i.1.dims.first().copied().unwrap_or(1)).unwrap_or(1) as f64
        }
        OpKind::Reduce => inputs.first().map(|i| i.1.elems()).unwrap_or(1) as f64,
        _ => out_elems,
    }
}

/// Import the ENTRY computation of an HLO-text module.
pub fn import_hlo_text(text: &str, num_workers: usize) -> Result<TrainingGraph> {
    // Locate the ENTRY block (jax dialect: `ENTRY main.163 {` … `}`).
    let entry_start = text
        .lines()
        .position(|l| l.trim_start().starts_with("ENTRY "))
        .ok_or_else(|| anyhow!("no ENTRY computation found"))?;
    let lines: Vec<&str> = text.lines().collect();

    let mut name = "hlo_import".to_string();
    if let Some(first) = lines.first() {
        if let Some(rest) = first.strip_prefix("HloModule ") {
            name = rest.split([',', ' ']).next().unwrap_or("hlo_import").to_string();
        }
    }

    let mut g = TrainingGraph::new(&name, num_workers);
    let mut by_name: HashMap<String, NodeId> = HashMap::new();
    let mut dtypes: HashMap<NodeId, (DType, Shape)> = HashMap::new();

    for raw in lines[entry_start + 1..].iter() {
        let line = raw.trim();
        if line.starts_with('}') {
            break;
        }
        let Some(eq) = line.find(" = ") else { continue };
        let lhs_name = line[..eq].trim_start_matches("ROOT ").trim().to_string();
        let rhs = line[eq + 3..].trim_start();
        // rhs = "<type> <opcode>(<operands>)<attrs>". Tuple types start
        // with '(' — consume the balanced group first so we don't mistake
        // it for the operand list.
        let (type_str, rest) = if rhs.starts_with('(') {
            let mut depth = 0usize;
            let mut end = 0usize;
            for (i, c) in rhs.char_indices() {
                match c {
                    '(' => depth += 1,
                    ')' => {
                        depth -= 1;
                        if depth == 0 {
                            end = i;
                            break;
                        }
                    }
                    _ => {}
                }
            }
            (&rhs[..=end], rhs[end + 1..].trim_start())
        } else {
            let sp = rhs
                .find(char::is_whitespace)
                .ok_or_else(|| anyhow!("bad instruction: {line}"))?;
            (&rhs[..sp], rhs[sp + 1..].trim_start())
        };
        let (dtype, shape) =
            parse_type(type_str).ok_or_else(|| anyhow!("bad type '{type_str}' in: {line}"))?;
        let paren = rest.find('(').ok_or_else(|| anyhow!("no operands: {line}"))?;
        let opcode = rest[..paren].trim();
        let close = rest[paren..]
            .find(')')
            .map(|i| paren + i)
            .ok_or_else(|| anyhow!("unclosed operands: {line}"))?;
        let operand_str = &rest[paren + 1..close];
        let mut inputs: Vec<NodeId> = Vec::new();
        let mut input_meta: Vec<(DType, Shape)> = Vec::new();
        for tok in operand_str.split(',') {
            let t = tok.trim().trim_start_matches('%');
            if t.is_empty() {
                continue;
            }
            // Operands may be "name" or "f32[...] name"; take the last token.
            let opname = t.rsplit(char::is_whitespace).next().unwrap_or(t);
            if let Some(&id) = by_name.get(opname) {
                if !inputs.contains(&id) {
                    inputs.push(id);
                    input_meta.push(dtypes[&id].clone());
                }
            }
        }

        let kind = map_opcode(opcode);
        let flops = estimate_flops(kind, &shape, &input_meta);
        let bytes_out = shape.bytes(dtype) as f64;
        let bytes_in: f64 =
            input_meta.iter().map(|(dt, sh)| sh.bytes(*dt) as f64).sum();
        let role = if kind == OpKind::AllReduce { Role::Comm } else { Role::Forward };
        let id = g.push(Node {
            id: 0,
            name: lhs_name.clone(),
            kind,
            role,
            inputs: inputs.clone(),
            orig_inputs: inputs,
            shape,
            dtype,
            flops,
            bytes_in,
            bytes_out,
            fused: None,
            ar_constituents: if kind == OpKind::AllReduce { vec![] } else { Vec::new() },
            deleted: false,
        });
        if kind == OpKind::AllReduce {
            g.nodes[id].ar_constituents = vec![id];
        }
        if kind == OpKind::Fused {
            // call/fusion/custom-call: an opaque sub-computation. Give it a
            // singleton group (itself) so every Fused node carries a group,
            // as the estimators require.
            let n = &g.nodes[id];
            let member = super::OrigOp {
                orig_id: id,
                kind: OpKind::Fused,
                flops: n.flops,
                bytes_in: n.bytes_in,
                bytes_out: n.bytes_out,
                time_ms: 0.0,
                duplicated: false,
            };
            g.nodes[id].fused = Some(super::FusedGroup { ops: vec![member], edges: vec![] });
        }
        by_name.insert(lhs_name, id);
        let meta = (g.nodes[id].dtype, g.nodes[id].shape.clone());
        dtypes.insert(id, meta);
    }

    if g.nodes.is_empty() {
        return Err(anyhow!("ENTRY computation had no instructions"));
    }
    g.validate().map_err(|e| anyhow!("imported graph invalid: {e}"))?;
    Ok(g)
}

/// Convenience: import from a file path.
pub fn import_hlo_file(path: &std::path::Path, num_workers: usize) -> Result<TrainingGraph> {
    let text = std::fs::read_to_string(path)?;
    import_hlo_text(&text, num_workers)
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = r#"HloModule tiny, entry_computation_layout={(f32[4]{0})->f32[4]{0}}

region_0.1 {
  a = f32[] parameter(0)
  b = f32[] parameter(1)
  ROOT r = f32[] add(a, b)
}

ENTRY main.9 {
  Arg_0.1 = f32[4]{0} parameter(0)
  constant.2 = f32[] constant(2)
  broadcast.3 = f32[4]{0} broadcast(constant.2), dimensions={}
  multiply.4 = f32[4]{0} multiply(Arg_0.1, broadcast.3)
  dot.5 = f32[4,4]{1,0} dot(multiply.4, multiply.4), lhs_contracting_dims={}, rhs_contracting_dims={}
  reduce.6 = f32[4]{0} reduce(dot.5, constant.2), dimensions={1}, to_apply=region_0.1
  ROOT tanh.7 = f32[4]{0} tanh(reduce.6)
}
"#;

    #[test]
    fn imports_tiny_module() {
        let g = import_hlo_text(TINY, 1).unwrap();
        assert_eq!(g.name, "tiny");
        assert!(g.validate().is_ok());
        assert_eq!(g.live_count(), 7);
        // Region bodies contributed nothing.
        assert!(g.live().all(|n| !n.name.starts_with("Arg_0.2")));
        // Wiring: multiply consumes the parameter and the broadcast.
        let mul = g.live().find(|n| n.kind == OpKind::Mul).unwrap();
        assert_eq!(mul.inputs.len(), 2);
        let dot = g.live().find(|n| n.kind == OpKind::MatMul).unwrap();
        assert!(dot.flops > 0.0);
        let tanh = g.live().find(|n| n.kind == OpKind::Tanh).unwrap();
        assert_eq!(g.nodes[tanh.inputs[0]].kind, OpKind::Reduce);
    }

    #[test]
    fn type_parser_cases() {
        assert_eq!(parse_type("f32[8,64]{1,0}").unwrap().1.dims, vec![8, 64]);
        assert_eq!(parse_type("f32[]").unwrap().1.dims, Vec::<usize>::new());
        assert_eq!(parse_type("s32[3]{0}").unwrap().0, DType::I32);
        assert_eq!(parse_type("bf16[2,2]{1,0}").unwrap().0, DType::BF16);
        // Tuple takes the first element.
        assert_eq!(parse_type("(f32[5]{0}, s32[2]{0})").unwrap().1.dims, vec![5]);
        assert!(parse_type("garbage").is_none());
    }

    #[test]
    fn rejects_entry_less_text() {
        assert!(import_hlo_text("HloModule x\n", 1).is_err());
    }
}
