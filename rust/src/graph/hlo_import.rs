//! Parse real XLA HLO **text** modules — both into the DisCo IR and into
//! the structured form the in-tree interpreter executes.
//!
//! This closes the loop with actual compiler artifacts twice over:
//!
//! * [`import_hlo_text`] loads a module as a [`TrainingGraph`] so the
//!   profiling / fusion / search pipeline can optimize it —
//!   `disco import-hlo artifacts/lm_grads.hlo.txt` optimizes the very
//!   module the runtime executes;
//! * [`parse_module`] keeps the *full* structured module — every
//!   computation, instruction, operand and attribute — which
//!   [`crate::runtime::interp`] evaluates for real (DESIGN.md §9).
//!
//! Scope: the jax-emitted dialect (one instruction per line,
//! `name = type opcode(operands), attrs`). Nested computations (reduce
//! bodies, fusion bodies) are parsed like any other computation; for graph
//! import they contribute no graph nodes, but their parsed bodies are
//! walked to fold an exact per-application FLOP count into the calling
//! instruction (previously a shape-only guess). FLOPs for
//! `dot`/`convolution` are estimated from operand/result shapes (the
//! contraction extent is inferred), elementwise ops count one FLOP per
//! element — adequate for structure-level optimization, and stated in
//! DESIGN.md §10.

use super::{DType, Node, NodeId, OpKind, Role, Shape, TrainingGraph};
use anyhow::{anyhow, Result};
use std::collections::HashMap;

// ---------------------------------------------------------------------------
// Structured module form (shared by graph import and the interpreter).
// ---------------------------------------------------------------------------

/// Primitive element type of one HLO array, as the interpreter needs it
/// (the byte-accounting [`DType`] folds pred/s32/u32 together; execution
/// must keep pred narrowing distinct from integer truncation). `f64`
/// maps to F32 storage; `s64`/`u32`/`u8` map to S32 storage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Prim {
    F32,
    F16,
    BF16,
    S32,
    Pred,
}

/// Shape of one HLO value: an array or a (possibly nested) tuple.
#[derive(Debug, Clone, PartialEq)]
pub enum HloShape {
    Array { dtype: DType, prim: Prim, shape: Shape },
    Tuple(Vec<HloShape>),
}

impl HloShape {
    /// Parse `f32[8,64]{1,0}`, `pred[]`, or `(f32[5]{0}, s32[2]{0})`.
    /// Layout annotations (`{1,0}`) are ignored. `pred`/integer types map
    /// to I32-width accounting.
    pub fn parse(s: &str) -> Option<HloShape> {
        let s = s.trim();
        if let Some(inner) = s.strip_prefix('(') {
            let inner = inner.strip_suffix(')').unwrap_or(inner);
            let mut elems = Vec::new();
            for part in split_top_level(inner) {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                elems.push(HloShape::parse(part)?);
            }
            return Some(HloShape::Tuple(elems));
        }
        let bracket = s.find('[')?;
        let (dtype, prim) = match &s[..bracket] {
            "f32" | "f64" => (DType::F32, Prim::F32),
            "f16" => (DType::F16, Prim::F16),
            "bf16" => (DType::BF16, Prim::BF16),
            "pred" => (DType::I32, Prim::Pred),
            _ => (DType::I32, Prim::S32), // s32/u32/s64/u8…
        };
        let rest = &s[bracket + 1..];
        let close = rest.find(']')?;
        let dims_str = &rest[..close];
        let dims: Vec<usize> = if dims_str.is_empty() {
            vec![]
        } else {
            dims_str.split(',').map(|d| d.trim().parse().ok()).collect::<Option<_>>()?
        };
        Some(HloShape::Array { dtype, prim, shape: Shape { dims } })
    }

    /// First array shape (tuples recurse into their first element) — the
    /// single-tensor view the graph importer uses for tuple-typed nodes.
    pub fn first_array(&self) -> Option<(DType, Shape)> {
        match self {
            HloShape::Array { dtype, shape, .. } => Some((*dtype, shape.clone())),
            HloShape::Tuple(elems) => elems.first()?.first_array(),
        }
    }

    /// First array's primitive type + shape — the interpreter's view.
    pub fn first_prim(&self) -> Option<(Prim, Shape)> {
        match self {
            HloShape::Array { prim, shape, .. } => Some((*prim, shape.clone())),
            HloShape::Tuple(elems) => elems.first()?.first_prim(),
        }
    }

    /// Element count of the array (first element for tuples).
    pub fn elems(&self) -> usize {
        self.first_array().map(|(_, s)| s.elems()).unwrap_or(0)
    }
}

/// One parsed HLO instruction.
#[derive(Debug, Clone)]
pub struct HloInstr {
    pub name: String,
    pub is_root: bool,
    pub shape: HloShape,
    pub opcode: String,
    /// Operand instruction names (type prefixes and `%` sigils stripped).
    pub operands: Vec<String>,
    /// Raw text between the operand parentheses — the literal payload for
    /// `constant`/`parameter`, empty for most ops.
    pub payload: String,
    /// `key=value` attributes after the operand list, in order.
    pub attrs: Vec<(String, String)>,
}

impl HloInstr {
    /// Look up an attribute by key.
    pub fn attr(&self, key: &str) -> Option<&str> {
        self.attrs.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Parse a `{1,0}`-style attribute into a dimension list. Missing or
    /// empty attributes yield an empty list.
    pub fn dims_attr(&self, key: &str) -> Vec<usize> {
        parse_dim_list(self.attr(key).unwrap_or(""))
    }
}

/// Parse `{0,2}` / `0,2` / `{}` into a dimension list.
pub fn parse_dim_list(s: &str) -> Vec<usize> {
    s.trim()
        .trim_start_matches('{')
        .trim_end_matches('}')
        .split(',')
        .filter_map(|t| t.trim().parse::<usize>().ok())
        .collect()
}

/// One computation (ENTRY or nested region/fusion body).
#[derive(Debug, Clone)]
pub struct HloComputation {
    pub name: String,
    pub is_entry: bool,
    pub instrs: Vec<HloInstr>,
}

impl HloComputation {
    /// Index of the root instruction (`ROOT`-marked, else the last one).
    pub fn root(&self) -> Option<&HloInstr> {
        self.instrs.iter().find(|i| i.is_root).or_else(|| self.instrs.last())
    }
}

/// A fully parsed HLO text module.
#[derive(Debug, Clone)]
pub struct HloModule {
    pub name: String,
    pub computations: Vec<HloComputation>,
}

impl HloModule {
    /// The ENTRY computation.
    pub fn entry(&self) -> Result<&HloComputation> {
        self.computations
            .iter()
            .find(|c| c.is_entry)
            .ok_or_else(|| anyhow!("no ENTRY computation found"))
    }

    /// Look up a nested computation by name (as cited by `to_apply=`/
    /// `calls=` attributes, which may carry a `%` sigil).
    pub fn computation(&self, name: &str) -> Option<&HloComputation> {
        let name = name.trim_start_matches('%');
        self.computations.iter().find(|c| c.name == name)
    }
}

/// Split at top-level commas: commas nested inside `()`, `[]`, or `{}`
/// don't split.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '(' | '[' | '{' => depth += 1,
            ')' | ']' | '}' => depth -= 1,
            ',' if depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if start < s.len() {
        out.push(&s[start..]);
    }
    out
}

/// Find the index of the `)` matching the `(` at `open` (byte offset).
fn matching_paren(s: &str, open: usize) -> Option<usize> {
    let mut depth = 0i32;
    for (i, c) in s[open..].char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(open + i);
                }
            }
            _ => {}
        }
    }
    None
}

/// Parse one instruction line; `None` for non-instruction lines.
fn parse_instr(line: &str) -> Result<Option<HloInstr>> {
    let line = line.trim();
    let Some(eq) = line.find(" = ") else { return Ok(None) };
    let mut lhs = line[..eq].trim();
    let is_root = lhs.starts_with("ROOT ");
    if is_root {
        lhs = lhs["ROOT ".len()..].trim();
    }
    let name = lhs.trim_start_matches('%').to_string();
    let rhs = line[eq + 3..].trim_start();

    // rhs = "<type> <opcode>(<operands>)[, attrs]". Tuple types start with
    // '(' — consume the balanced group first so we don't mistake it for
    // the operand list.
    let (type_str, rest) = if rhs.starts_with('(') {
        let end = matching_paren(rhs, 0).ok_or_else(|| anyhow!("unbalanced type: {line}"))?;
        (&rhs[..=end], rhs[end + 1..].trim_start())
    } else {
        let sp = rhs
            .find(char::is_whitespace)
            .ok_or_else(|| anyhow!("bad instruction: {line}"))?;
        (&rhs[..sp], rhs[sp + 1..].trim_start())
    };
    let shape =
        HloShape::parse(type_str).ok_or_else(|| anyhow!("bad type '{type_str}' in: {line}"))?;

    let paren = rest.find('(').ok_or_else(|| anyhow!("no operands: {line}"))?;
    let opcode = rest[..paren].trim().to_string();
    let close =
        matching_paren(rest, paren).ok_or_else(|| anyhow!("unclosed operands: {line}"))?;
    let payload = rest[paren + 1..close].to_string();

    // Constants / parameters keep their payload raw; everything else
    // resolves operand names ("name" or "f32[...] %name" → last token).
    let mut operands = Vec::new();
    if opcode != "constant" && opcode != "parameter" && opcode != "iota" {
        for tok in split_top_level(&payload) {
            let t = tok.trim();
            if t.is_empty() {
                continue;
            }
            let opname = t.rsplit(char::is_whitespace).next().unwrap_or(t);
            operands.push(opname.trim_start_matches('%').to_string());
        }
    }

    let mut attrs = Vec::new();
    let tail = rest[close + 1..].trim_start().trim_start_matches(',').trim_start();
    for part in split_top_level(tail) {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some(eq) = part.find('=') {
            attrs.push((part[..eq].trim().to_string(), part[eq + 1..].trim().to_string()));
        }
    }

    Ok(Some(HloInstr { name, is_root, shape, opcode, operands, payload, attrs }))
}

/// Parse a full HLO text module into structured form: every computation
/// (ENTRY and nested bodies), every instruction.
pub fn parse_module(text: &str) -> Result<HloModule> {
    let mut name = "hlo_module".to_string();
    let mut computations: Vec<HloComputation> = Vec::new();
    let mut cur: Option<HloComputation> = None;

    for raw in text.lines() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with("//") || line.starts_with('#') {
            continue;
        }
        if let Some(rest) = line.strip_prefix("HloModule ") {
            name = rest
                .split([',', ' '])
                .next()
                .unwrap_or("hlo_module")
                .trim_start_matches('%')
                .to_string();
            continue;
        }
        match &mut cur {
            None => {
                // Computation header: `name {`, `%name (args) -> type {`,
                // or `ENTRY name {`.
                if line.ends_with('{') && !line.contains(" = ") {
                    let mut head = line[..line.len() - 1].trim();
                    let is_entry = head.starts_with("ENTRY");
                    if is_entry {
                        head = head["ENTRY".len()..].trim_start();
                    }
                    let cname = head
                        .split(|c: char| c.is_whitespace() || c == '(')
                        .next()
                        .unwrap_or("")
                        .trim_start_matches('%')
                        .to_string();
                    cur = Some(HloComputation { name: cname, is_entry, instrs: Vec::new() });
                }
            }
            Some(comp) => {
                if line.starts_with('}') {
                    computations.push(cur.take().unwrap());
                    continue;
                }
                if let Some(instr) = parse_instr(line)? {
                    comp.instrs.push(instr);
                }
            }
        }
    }
    if let Some(comp) = cur {
        computations.push(comp); // tolerate a missing final brace
    }
    if computations.is_empty() {
        return Err(anyhow!("no computations found in HLO text"));
    }
    Ok(HloModule { name, computations })
}

// ---------------------------------------------------------------------------
// TrainingGraph import.
// ---------------------------------------------------------------------------

/// Parse `f32[8,64]{1,0}` → (dtype, shape). Tuple types take their first
/// element.
fn parse_type(s: &str) -> Option<(DType, Shape)> {
    HloShape::parse(s)?.first_array()
}

/// Map an HLO opcode to our [`OpKind`].
fn map_opcode(op: &str) -> OpKind {
    match op {
        "parameter" => OpKind::Parameter,
        "constant" | "iota" => OpKind::Constant,
        "dot" => OpKind::MatMul,
        "convolution" => OpKind::Conv2D,
        "add" => OpKind::Add,
        "subtract" => OpKind::Sub,
        "multiply" => OpKind::Mul,
        "divide" => OpKind::Div,
        "negate" => OpKind::Neg,
        "exponential" | "exponential-minus-one" => OpKind::Exp,
        "log" | "log-plus-one" => OpKind::Log,
        "sqrt" => OpKind::Sqrt,
        "rsqrt" => OpKind::Rsqrt,
        "tanh" => OpKind::Tanh,
        "logistic" => OpKind::Sigmoid,
        "maximum" => OpKind::Maximum,
        "minimum" => OpKind::Maximum,
        "select" => OpKind::Select,
        "compare" => OpKind::Compare,
        "convert" | "bitcast-convert" | "copy" => OpKind::Cast,
        "reduce" | "reduce-window" => OpKind::Reduce,
        "transpose" => OpKind::Transpose,
        "reshape" | "bitcast" => OpKind::Reshape,
        "broadcast" => OpKind::Broadcast,
        "concatenate" => OpKind::Concat,
        "slice" | "dynamic-slice" => OpKind::Slice,
        "gather" => OpKind::Gather,
        "scatter" | "dynamic-update-slice" => OpKind::Scatter,
        "sort" => OpKind::Sort,
        "all-reduce" => OpKind::AllReduce,
        "tuple" | "get-tuple-element" => OpKind::Reshape, // structural
        "power" => OpKind::Exp,
        "abs" | "sign" | "floor" | "ceil" | "round-nearest-afz" | "clamp" | "and" | "or"
        | "not" | "xor" => OpKind::Maximum,
        "rng" | "rng-bit-generator" => OpKind::Constant,
        "pad" | "reverse" => OpKind::Reshape,
        "custom-call" | "fusion" | "call" | "map" => OpKind::Fused,
        "while" => OpKind::While,
        "conditional" => OpKind::Conditional,
        _ => OpKind::Reduce, // conservative default for exotic ops
    }
}

/// Estimate FLOPs of one instruction from the shapes involved.
fn estimate_flops(kind: OpKind, out: &Shape, inputs: &[(DType, Shape)]) -> f64 {
    let out_elems = out.elems() as f64;
    match kind {
        OpKind::Parameter | OpKind::Constant => 0.0,
        OpKind::MatMul | OpKind::BatchMatMul => {
            // 2 * |out| * contraction extent, with the contraction inferred
            // as sqrt(lhs·rhs/|out|) — exact for plain [m,k]×[k,n] matmuls
            // and a sane bound elsewhere.
            let lhs = inputs.first().map(|i| i.1.elems()).unwrap_or(1) as f64;
            let rhs = inputs.get(1).map(|i| i.1.elems()).unwrap_or(1) as f64;
            let k = ((lhs * rhs) / out_elems.max(1.0)).sqrt().max(1.0);
            2.0 * out_elems * k
        }
        OpKind::Conv2D => {
            let w = inputs.get(1).map(|i| i.1.elems()).unwrap_or(1) as f64;
            2.0 * out_elems * w / inputs.get(1).map(|i| i.1.dims.first().copied().unwrap_or(1)).unwrap_or(1) as f64
        }
        OpKind::Reduce => inputs.first().map(|i| i.1.elems()).unwrap_or(1) as f64,
        _ => out_elems,
    }
}

/// Total FLOPs of a parsed nested computation, one application: sum the
/// per-instruction estimates over its declared shapes. Reduce bodies are
/// scalar computations, so this is typically 1–3 FLOPs; fusion bodies
/// carry their real internal shapes.
fn computation_flops(comp: &HloComputation) -> f64 {
    comp.instrs
        .iter()
        .map(|i| {
            let kind = map_opcode(&i.opcode);
            let out = i.shape.first_array().map(|(_, s)| s).unwrap_or_default();
            // Operand shapes aren't resolved here; the estimate only needs
            // them for dot/conv/reduce, which use the output-shape bound.
            estimate_flops(kind, &out, &[])
        })
        .sum()
}

/// FLOPs for an instruction, folding in the cost of any nested computation
/// it applies (`to_apply=` for reduce/map, `calls=` for fusion/call).
fn instr_flops(
    module: &HloModule,
    instr: &HloInstr,
    kind: OpKind,
    out: &Shape,
    inputs: &[(DType, Shape)],
) -> f64 {
    let base = estimate_flops(kind, out, inputs);
    let body = instr
        .attr("to_apply")
        .or_else(|| instr.attr("calls"))
        .and_then(|name| module.computation(name));
    match (kind, body) {
        // One body application per reduced input element.
        (OpKind::Reduce, Some(b)) => {
            let apps = inputs.first().map(|i| i.1.elems()).unwrap_or(1) as f64;
            apps * computation_flops(b).max(1.0)
        }
        // Opaque fused/called bodies execute once; their internal shapes
        // are the honest cost.
        (OpKind::Fused, Some(b)) => computation_flops(b).max(base),
        _ => base,
    }
}

/// Import the ENTRY computation of an HLO-text module as a
/// [`TrainingGraph`].
pub fn import_hlo_text(text: &str, num_workers: usize) -> Result<TrainingGraph> {
    let module = parse_module(text)?;
    let entry = module.entry()?;

    let mut g = TrainingGraph::new(&module.name, num_workers);
    let mut by_name: HashMap<String, NodeId> = HashMap::new();
    let mut dtypes: HashMap<NodeId, (DType, Shape)> = HashMap::new();

    for instr in &entry.instrs {
        let (dtype, shape) = instr
            .shape
            .first_array()
            .ok_or_else(|| anyhow!("empty tuple type on {}", instr.name))?;
        let mut inputs: Vec<NodeId> = Vec::new();
        let mut input_meta: Vec<(DType, Shape)> = Vec::new();
        for opname in &instr.operands {
            if let Some(&id) = by_name.get(opname.as_str()) {
                if !inputs.contains(&id) {
                    inputs.push(id);
                    input_meta.push(dtypes[&id].clone());
                }
            }
        }

        let kind = map_opcode(&instr.opcode);
        let flops = instr_flops(&module, instr, kind, &shape, &input_meta);
        let bytes_out = shape.bytes(dtype) as f64;
        let bytes_in: f64 =
            input_meta.iter().map(|(dt, sh)| sh.bytes(*dt) as f64).sum();
        let role = if kind == OpKind::AllReduce { Role::Comm } else { Role::Forward };
        let id = g.push(Node {
            id: 0,
            name: instr.name.clone(),
            kind,
            role,
            inputs: inputs.clone(),
            orig_inputs: inputs,
            shape,
            dtype,
            flops,
            bytes_in,
            bytes_out,
            fused: None,
            ar_constituents: if kind == OpKind::AllReduce { vec![] } else { Vec::new() },
            chunk: None,
            shard: None,
            deleted: false,
        });
        if kind == OpKind::AllReduce {
            g.nodes[id].ar_constituents = vec![id];
        }
        if kind == OpKind::Fused {
            // call/fusion/custom-call: an opaque sub-computation. Give it a
            // singleton group (itself) so every Fused node carries a group,
            // as the estimators require.
            let n = &g.nodes[id];
            let member = super::OrigOp {
                orig_id: id,
                kind: OpKind::Fused,
                flops: n.flops,
                bytes_in: n.bytes_in,
                bytes_out: n.bytes_out,
                time_ms: 0.0,
                duplicated: false,
            };
            g.nodes[id].fused = Some(super::FusedGroup { ops: vec![member], edges: vec![] });
        }
        by_name.insert(instr.name.clone(), id);
        let meta = (g.nodes[id].dtype, g.nodes[id].shape.clone());
        dtypes.insert(id, meta);
    }

    if g.nodes.is_empty() {
        return Err(anyhow!("ENTRY computation had no instructions"));
    }
    g.validate().map_err(|e| anyhow!("imported graph invalid: {e}"))?;
    Ok(g)
}

/// Convenience: import from a file path.
pub fn import_hlo_file(path: &std::path::Path, num_workers: usize) -> Result<TrainingGraph> {
    let text = std::fs::read_to_string(path)?;
    import_hlo_text(&text, num_workers)
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = r#"HloModule tiny, entry_computation_layout={(f32[4]{0})->f32[4]{0}}

region_0.1 {
  a = f32[] parameter(0)
  b = f32[] parameter(1)
  ROOT r = f32[] add(a, b)
}

ENTRY main.9 {
  Arg_0.1 = f32[4]{0} parameter(0)
  constant.2 = f32[] constant(2)
  broadcast.3 = f32[4]{0} broadcast(constant.2), dimensions={}
  multiply.4 = f32[4]{0} multiply(Arg_0.1, broadcast.3)
  dot.5 = f32[4,4]{1,0} dot(multiply.4, multiply.4), lhs_contracting_dims={}, rhs_contracting_dims={}
  reduce.6 = f32[4]{0} reduce(dot.5, constant.2), dimensions={1}, to_apply=region_0.1
  ROOT tanh.7 = f32[4]{0} tanh(reduce.6)
}
"#;

    #[test]
    fn imports_tiny_module() {
        let g = import_hlo_text(TINY, 1).unwrap();
        assert_eq!(g.name, "tiny");
        assert!(g.validate().is_ok());
        assert_eq!(g.live_count(), 7);
        // Region bodies contributed nothing.
        assert!(g.live().all(|n| !n.name.starts_with("Arg_0.2")));
        // Wiring: multiply consumes the parameter and the broadcast.
        let mul = g.live().find(|n| n.kind == OpKind::Mul).unwrap();
        assert_eq!(mul.inputs.len(), 2);
        let dot = g.live().find(|n| n.kind == OpKind::MatMul).unwrap();
        assert!(dot.flops > 0.0);
        let tanh = g.live().find(|n| n.kind == OpKind::Tanh).unwrap();
        assert_eq!(g.nodes[tanh.inputs[0]].kind, OpKind::Reduce);
    }

    #[test]
    fn structured_parse_sees_nested_bodies() {
        let m = parse_module(TINY).unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.computations.len(), 2);
        let region = m.computation("region_0.1").unwrap();
        assert_eq!(region.instrs.len(), 3);
        assert_eq!(region.root().unwrap().opcode, "add");
        assert!(!region.is_entry);
        let entry = m.entry().unwrap();
        assert_eq!(entry.instrs.len(), 7);
        assert_eq!(entry.root().unwrap().opcode, "tanh");
        // The reduce cites the region and carries its attrs.
        let red = entry.instrs.iter().find(|i| i.opcode == "reduce").unwrap();
        assert_eq!(red.attr("to_apply"), Some("region_0.1"));
        assert_eq!(red.dims_attr("dimensions"), vec![1]);
        assert_eq!(red.operands, vec!["dot.5", "constant.2"]);
    }

    #[test]
    fn reduce_flops_fold_in_the_parsed_body() {
        let g = import_hlo_text(TINY, 1).unwrap();
        let red = g.live().find(|n| n.kind == OpKind::Reduce).unwrap();
        // 16 input elements, 1-FLOP scalar add body.
        assert!((red.flops - 16.0).abs() < 1e-9, "flops={}", red.flops);
    }

    #[test]
    fn type_parser_cases() {
        assert_eq!(parse_type("f32[8,64]{1,0}").unwrap().1.dims, vec![8, 64]);
        assert_eq!(parse_type("f32[]").unwrap().1.dims, Vec::<usize>::new());
        assert_eq!(parse_type("s32[3]{0}").unwrap().0, DType::I32);
        assert_eq!(parse_type("bf16[2,2]{1,0}").unwrap().0, DType::BF16);
        // The interpreter-facing primitive type keeps pred distinct from
        // the I32 byte-accounting bucket.
        assert_eq!(HloShape::parse("pred[4]").unwrap().first_prim().unwrap().0, Prim::Pred);
        assert_eq!(HloShape::parse("s32[4]").unwrap().first_prim().unwrap().0, Prim::S32);
        assert_eq!(HloShape::parse("f16[4]").unwrap().first_prim().unwrap().0, Prim::F16);
        assert_eq!(HloShape::parse("f64[4]").unwrap().first_prim().unwrap().0, Prim::F32);
        // Tuple takes the first element.
        assert_eq!(parse_type("(f32[5]{0}, s32[2]{0})").unwrap().1.dims, vec![5]);
        assert!(parse_type("garbage").is_none());
        // Full tuple shape retained in structured form.
        match HloShape::parse("(f32[5]{0}, s32[2]{0})").unwrap() {
            HloShape::Tuple(elems) => assert_eq!(elems.len(), 2),
            other => panic!("expected tuple, got {other:?}"),
        }
    }

    #[test]
    fn instr_parser_attrs_and_payloads() {
        let i = parse_instr("  c = f32[2,2]{1,0} constant({ { 1, 2 }, { 3, 4 } })")
            .unwrap()
            .unwrap();
        assert_eq!(i.opcode, "constant");
        assert!(i.operands.is_empty());
        assert_eq!(i.payload, "{ { 1, 2 }, { 3, 4 } }");

        let i = parse_instr("ROOT s = f32[2]{0} slice(x), slice={[1:3]}").unwrap().unwrap();
        assert!(i.is_root);
        assert_eq!(i.attr("slice"), Some("{[1:3]}"));

        let i = parse_instr("d = f32[4,4] dot(a, b), lhs_contracting_dims={1}, rhs_contracting_dims={0}")
            .unwrap()
            .unwrap();
        assert_eq!(i.dims_attr("lhs_contracting_dims"), vec![1]);
        assert_eq!(i.dims_attr("rhs_contracting_dims"), vec![0]);
        assert_eq!(i.operands, vec!["a", "b"]);
    }

    #[test]
    fn rejects_entry_less_text() {
        assert!(import_hlo_text("HloModule x\n", 1).is_err());
    }
}
