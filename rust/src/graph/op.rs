//! Operator vocabulary of the IR, and the classifications the fusion
//! passes need (XLA-style fusibility, TVM-style pattern classes).

/// Kinds of instruction in our HLO-like IR. This mirrors the op set of the
/// paper's benchmark models (CNNs + NLP models): dense/conv compute,
/// elementwise math, normalization, data movement, communication, and the
/// control-flow ops whose fusion is invalid (Alg. 1 validity check).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpKind {
    // -- leaves --------------------------------------------------------
    Parameter,
    Constant,
    // -- heavy compute --------------------------------------------------
    Conv2D,
    MatMul,
    BatchMatMul,
    // -- elementwise ----------------------------------------------------
    Add,
    Sub,
    Mul,
    Div,
    Neg,
    Exp,
    Log,
    Sqrt,
    Rsqrt,
    Tanh,
    Sigmoid,
    Relu,
    Gelu,
    Maximum,
    Select,
    Compare,
    Cast,
    // -- reductions / normalization --------------------------------------
    Reduce,
    Softmax,
    LayerNorm,
    BatchNorm,
    Pool,
    // -- data movement ----------------------------------------------------
    Transpose,
    Reshape,
    Broadcast,
    Concat,
    Slice,
    Gather,
    Scatter,
    Embedding,
    Sort,
    // -- training-specific -------------------------------------------------
    Dropout,
    CrossEntropy,
    ApplyOptimizer,
    // -- communication -------------------------------------------------------
    AllReduce,
    // -- structured -----------------------------------------------------------
    /// A fused computation op produced by an op-fusion transform.
    Fused,
    // -- control flow (never fusible, paper §4.5 validity) ----------------------
    While,
    Conditional,
}

/// TVM-style pattern classes (paper §7.1): injective ops fuse freely,
/// reductions fuse with input injectives, complex-out-fusible ops accept
/// elementwise epilogues, opaque ops never fuse.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PatternClass {
    Injective,
    Reduction,
    ComplexOutFusible,
    Opaque,
}

impl OpKind {
    /// All op kinds, for feature one-hot encoding (GNN input) and tests.
    pub const ALL: [OpKind; 40] = [
        OpKind::Parameter,
        OpKind::Constant,
        OpKind::Conv2D,
        OpKind::MatMul,
        OpKind::BatchMatMul,
        OpKind::Add,
        OpKind::Sub,
        OpKind::Mul,
        OpKind::Div,
        OpKind::Neg,
        OpKind::Exp,
        OpKind::Log,
        OpKind::Sqrt,
        OpKind::Rsqrt,
        OpKind::Tanh,
        OpKind::Sigmoid,
        OpKind::Relu,
        OpKind::Gelu,
        OpKind::Maximum,
        OpKind::Select,
        OpKind::Compare,
        OpKind::Cast,
        OpKind::Reduce,
        OpKind::Softmax,
        OpKind::LayerNorm,
        OpKind::BatchNorm,
        OpKind::Pool,
        OpKind::Transpose,
        OpKind::Reshape,
        OpKind::Broadcast,
        OpKind::Concat,
        OpKind::Slice,
        OpKind::Gather,
        OpKind::Scatter,
        OpKind::Embedding,
        OpKind::Sort,
        OpKind::Dropout,
        OpKind::CrossEntropy,
        OpKind::ApplyOptimizer,
        OpKind::AllReduce,
    ];

    /// Index into the one-hot feature encoding used by the GNN estimator.
    /// Fused/control-flow ops never appear inside a fused subgraph.
    pub fn feature_index(self) -> usize {
        OpKind::ALL
            .iter()
            .position(|&k| k == self)
            .unwrap_or(OpKind::ALL.len())
    }

    pub fn name(self) -> &'static str {
        match self {
            OpKind::Parameter => "parameter",
            OpKind::Constant => "constant",
            OpKind::Conv2D => "conv2d",
            OpKind::MatMul => "matmul",
            OpKind::BatchMatMul => "batch_matmul",
            OpKind::Add => "add",
            OpKind::Sub => "sub",
            OpKind::Mul => "mul",
            OpKind::Div => "div",
            OpKind::Neg => "neg",
            OpKind::Exp => "exp",
            OpKind::Log => "log",
            OpKind::Sqrt => "sqrt",
            OpKind::Rsqrt => "rsqrt",
            OpKind::Tanh => "tanh",
            OpKind::Sigmoid => "sigmoid",
            OpKind::Relu => "relu",
            OpKind::Gelu => "gelu",
            OpKind::Maximum => "maximum",
            OpKind::Select => "select",
            OpKind::Compare => "compare",
            OpKind::Cast => "cast",
            OpKind::Reduce => "reduce",
            OpKind::Softmax => "softmax",
            OpKind::LayerNorm => "layer_norm",
            OpKind::BatchNorm => "batch_norm",
            OpKind::Pool => "pool",
            OpKind::Transpose => "transpose",
            OpKind::Reshape => "reshape",
            OpKind::Broadcast => "broadcast",
            OpKind::Concat => "concat",
            OpKind::Slice => "slice",
            OpKind::Gather => "gather",
            OpKind::Scatter => "scatter",
            OpKind::Embedding => "embedding",
            OpKind::Sort => "sort",
            OpKind::Dropout => "dropout",
            OpKind::CrossEntropy => "cross_entropy",
            OpKind::ApplyOptimizer => "apply_optimizer",
            OpKind::AllReduce => "all_reduce",
            OpKind::Fused => "fused",
            OpKind::While => "while",
            OpKind::Conditional => "conditional",
        }
    }

    pub fn from_name(s: &str) -> Option<OpKind> {
        OpKind::ALL
            .iter()
            .copied()
            .chain([OpKind::Fused, OpKind::While, OpKind::Conditional])
            .find(|k| k.name() == s)
    }

    /// Is this a computation op that op-fusion may touch? (Paper validity:
    /// parameters, constants, control flow, communication and optimizer
    /// updates are excluded.)
    pub fn is_fusible_compute(self) -> bool {
        !matches!(
            self,
            OpKind::Parameter
                | OpKind::Constant
                | OpKind::AllReduce
                | OpKind::ApplyOptimizer
                | OpKind::While
                | OpKind::Conditional
        )
    }

    /// Elementwise (one output element per input element, same shape)?
    pub fn is_elementwise(self) -> bool {
        matches!(
            self,
            OpKind::Add
                | OpKind::Sub
                | OpKind::Mul
                | OpKind::Div
                | OpKind::Neg
                | OpKind::Exp
                | OpKind::Log
                | OpKind::Sqrt
                | OpKind::Rsqrt
                | OpKind::Tanh
                | OpKind::Sigmoid
                | OpKind::Relu
                | OpKind::Gelu
                | OpKind::Maximum
                | OpKind::Select
                | OpKind::Compare
                | OpKind::Cast
                | OpKind::Dropout
        )
    }

    /// TVM pattern class (used by the TVM-rule baseline).
    pub fn pattern_class(self) -> PatternClass {
        if self.is_elementwise()
            || matches!(self, OpKind::Transpose | OpKind::Reshape | OpKind::Broadcast | OpKind::Slice | OpKind::Concat)
        {
            PatternClass::Injective
        } else if matches!(self, OpKind::Reduce | OpKind::Softmax | OpKind::LayerNorm | OpKind::BatchNorm | OpKind::Pool | OpKind::CrossEntropy) {
            PatternClass::Reduction
        } else if matches!(self, OpKind::Conv2D | OpKind::MatMul | OpKind::BatchMatMul | OpKind::Embedding) {
            PatternClass::ComplexOutFusible
        } else {
            PatternClass::Opaque
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_roundtrip_all() {
        for k in OpKind::ALL {
            assert_eq!(OpKind::from_name(k.name()), Some(k), "{k:?}");
        }
        assert_eq!(OpKind::from_name("fused"), Some(OpKind::Fused));
        assert_eq!(OpKind::from_name("while"), Some(OpKind::While));
    }

    #[test]
    fn feature_indices_unique_and_dense() {
        let mut seen = vec![false; OpKind::ALL.len()];
        for k in OpKind::ALL {
            let i = k.feature_index();
            assert!(i < OpKind::ALL.len());
            assert!(!seen[i], "duplicate index for {k:?}");
            seen[i] = true;
        }
    }

    #[test]
    fn validity_exclusions() {
        assert!(!OpKind::Parameter.is_fusible_compute());
        assert!(!OpKind::While.is_fusible_compute());
        assert!(!OpKind::AllReduce.is_fusible_compute());
        assert!(!OpKind::ApplyOptimizer.is_fusible_compute());
        assert!(OpKind::MatMul.is_fusible_compute());
        assert!(OpKind::Relu.is_fusible_compute());
    }

    #[test]
    fn pattern_classes() {
        assert_eq!(OpKind::Add.pattern_class(), PatternClass::Injective);
        assert_eq!(OpKind::Reshape.pattern_class(), PatternClass::Injective);
        assert_eq!(OpKind::Reduce.pattern_class(), PatternClass::Reduction);
        assert_eq!(OpKind::Conv2D.pattern_class(), PatternClass::ComplexOutFusible);
        assert_eq!(OpKind::Gather.pattern_class(), PatternClass::Opaque);
        assert_eq!(OpKind::AllReduce.pattern_class(), PatternClass::Opaque);
    }
}
